#!/usr/bin/env python
"""Offline calibration controller for per-class level budgets.

Serving records, per precision class, a histogram of the MSDF exit
levels its tokens actually committed at (the
``exit_level_hist_by_class`` block of ``ContinuousBatcher.stats()`` /
``ServingGateway.stats()`` — core/policy.py precision classes).  This
tool closes the loop: it fits the smallest ``budget(L)`` clamp whose
observed-exit coverage meets a target, per class and — when given a
``{"layers": {name: stats, ...}}`` dump — per layer.  A fitted budget
replaces the margin machinery of a ``bounded`` class with a static
truncation that reproduces ``coverage`` of its commits at serve time;
the residual ``1 - coverage`` of tokens are the ones a ``budget(L)``
deployment would decide from a too-short prefix.

Numpy-only on purpose: the controller runs offline against stats dumps,
never inside a trace.

CLI::

    python tools/calibrate_levels.py stats.json --coverage 0.99 -o budgets.json

``stats.json`` is a single engine ``stats()`` dict or a
``{"layers": {...}}`` map of them; the output maps class labels (or
``layer -> label``) to fitted level budgets.

The ``frontier_row`` schema is what ``benchmarks/run.py``'s
``precision_policy_bench`` suite emits into ``BENCH_progressive.json``:
one accuracy-vs-levels-vs-latency record per policy operating point.
"""

from __future__ import annotations

import argparse
import json

import numpy as np

__all__ = ["fit_budget", "fit_class_budgets", "fit_layer_budgets",
           "frontier_row", "main"]


def fit_budget(hist, coverage: float = 0.99) -> int:
    """Smallest level count ``L`` such that at least ``coverage`` of the
    observed exits commit within the first ``L`` levels.

    ``hist[l]`` counts tokens committed at 0-based level ``l`` — i.e.
    after ``l + 1`` streamed levels — so the fitted budget is
    ``argmin_L { cumsum(hist)[L-1] / total >= coverage }``.  An
    all-zero histogram is an error: with no observed exits there is no
    evidence to fit, and silently returning the full depth would ship a
    degenerate "calibrated" budget that no serving data supports.
    """
    if not 0.0 < coverage <= 1.0:
        raise ValueError(f"coverage must be in (0, 1], got {coverage}")
    h = np.asarray(hist, np.float64)
    if h.ndim != 1 or h.size == 0:
        raise ValueError(f"hist must be a non-empty 1-D histogram, "
                         f"got shape {h.shape}")
    total = h.sum()
    if total <= 0:
        raise ValueError(
            "empty exit histogram: no observed exits to calibrate from "
            "(run the engine with a progressive class and re-export "
            "stats() before fitting — a budget fitted from zero evidence "
            "would be degenerate)")
    cum = np.cumsum(h) / total
    # tolerance absorbs the float division: a bin holding exactly the
    # coverage mass satisfies it
    return int(np.searchsorted(cum, coverage - 1e-12) + 1)


def fit_class_budgets(hist_by_class: dict, coverage: float = 0.99) -> dict:
    """Per-class fitted budgets from a ``stats()``
    ``exit_level_hist_by_class`` map (string class labels -> level
    histogram lists).

    Classes whose histogram holds no observed exits are SKIPPED (engines
    seed zero histograms for classes that never committed a token); a
    map with no evidence at all fits to an empty dict — the CLI turns
    that into a hard error.
    """
    return {label: fit_budget(h, coverage)
            for label, h in sorted(hist_by_class.items())
            if np.asarray(h, np.float64).sum() > 0}


def fit_layer_budgets(stats_by_layer: dict, coverage: float = 0.99) -> dict:
    """Per-layer x per-class budgets from ``{layer: stats()-dict}``.
    Layers without per-class histograms fit to an empty map."""
    return {layer: fit_class_budgets(
        st.get("exit_level_hist_by_class", {}), coverage)
        for layer, st in sorted(stats_by_layer.items())}


def frontier_row(label: str, levels: int, n_levels: int, agreement: float,
                 mean_exit_level: float, us: float | None = None,
                 full_us: float | None = None) -> dict:
    """One accuracy-vs-levels-vs-latency frontier record (the
    ``precision_policy_frontier`` rows of ``BENCH_progressive.json``).

    ``agreement`` is the fraction of tokens matching the exact-class
    run; ``levels`` the operating point's level budget (clamp, or the
    worst committed level + 1 for margin classes); ``us``/``full_us``
    attach measured wall-clock when available.
    """
    row = {
        "class": str(label),
        "levels": int(levels),
        "n_levels": int(n_levels),
        "agreement_vs_exact": float(agreement),
        "mean_exit_level": float(mean_exit_level),
        "levels_saved_frac": float(1.0 - (mean_exit_level + 1.0) / n_levels),
    }
    if us is not None:
        row["us_per_call"] = float(us)
        if full_us:
            row["wallclock_saved_frac"] = float(1.0 - us / full_us)
    return row


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="fit per-class level budgets from observed "
                    "exit-level histograms")
    ap.add_argument("stats_json",
                    help="engine stats() dump, or {'layers': {...}} map")
    ap.add_argument("--coverage", type=float, default=0.99,
                    help="fraction of observed exits the fitted budget "
                         "must cover (default 0.99)")
    ap.add_argument("-o", "--out", default=None,
                    help="output JSON path (default: stdout)")
    args = ap.parse_args(argv)
    with open(args.stats_json) as f:
        stats = json.load(f)
    if "layers" in stats:
        budgets = fit_layer_budgets(stats["layers"], args.coverage)
        any_fit = any(budgets.values())
    else:
        budgets = fit_class_budgets(
            stats.get("exit_level_hist_by_class", {}), args.coverage)
        any_fit = bool(budgets)
    if not any_fit:
        raise SystemExit(
            f"{args.stats_json}: every exit histogram is empty or "
            f"all-zero — nothing to calibrate (serve progressive traffic "
            f"and re-export stats() first)")
    payload = {"coverage": args.coverage, "budgets": budgets}
    text = json.dumps(payload, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    else:
        print(text)


if __name__ == "__main__":
    main()
