#!/usr/bin/env python
"""l2r-lint: run the static exactness/overflow/compiled/sharding audits.

Four passes over the registered claimed-exact entry points
(repro/analysis/registry.py):

1. **exactness** — trace every registered walk (head + attention, all
   schedules, the backends available on this host) and taint-audit the
   jaxpr: integer ops only between plane extraction and the level
   accumulator, int32 ``dot_general`` accumulation, guarded-f32 fast
   path only where the guard holds.  ``--hlo`` additionally compiles
   each entry and re-checks the optimized module (slower; the CI gate
   runs it).
2. **overflow** — certify the worst-case int32 accumulator magnitude of
   every entry's digit config and of every config in the arch registry
   (``configs/registry.py``).
3. **compiled** — build the smoke serving stack (gateway + batcher),
   serve a tiny workload, and audit the artifacts: AOT bucket coverage,
   actually-donated decode state, retrace budgets.  ``--skip-compiled``
   skips this (it executes real compiles).
4. **sharding** (``--sharding``) — lower every entry carrying a
   ShardingContract under its declared mesh and verify the partitioned
   module: exactly the declared per-level reductions, zero GSPMD
   resharding, no float cross-shard sums on plane-derived values,
   conformant input shardings — plus the per-entry sync-cost
   certificate (collective count, bytes-on-wire, sync-every-k table)
   in the JSON report.  Needs >= 2 devices; CI runs the whole lint
   under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

A registered entry that must be SKIPPED (e.g. the sharded walks on a
single-device host) is a FAILURE, not a silent pass — ``--allow-skips``
downgrades that for local runs on small hosts.

Exit status 1 on any violation; ``--json`` writes the full report.

CI::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python tools/l2r_lint.py --hlo --sharding \\
        --json lint-report.json
"""

from __future__ import annotations

import argparse
import json
import sys


def _skip_row(e, allow_skips: bool) -> dict:
    """A skipped registered entry: loud failure unless --allow-skips —
    'skipped' must never read as 'passed' in CI."""
    row = {"entry": e.name, "tags": list(e.tags)}
    if allow_skips:
        row.update(status="skip", reason=e.skip)
    else:
        row.update(status="violation", ok=False, violations=[{
            "entry": e.name, "primitive": "registry",
            "reason": f"registered entry SKIPPED ({e.skip}) — run under "
                      "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                      "or pass --allow-skips",
            "detail": ""}])
    return row


def _pass_exactness(entries, with_hlo: bool, allow_skips: bool) -> list[dict]:
    import jax

    from repro.analysis import exactness

    rows = []
    for e in entries:
        if e.contract is None:
            continue  # sharding-only entry: audited by --sharding
        row = {"entry": e.name, "tags": list(e.tags)}
        if e.skip:
            rows.append(_skip_row(e, allow_skips))
            continue
        fn, args = e.build()
        rep = exactness.audit_exactness(fn, args, e.contract, entry=e.name)
        row.update(status="ok" if rep.ok else "violation", **rep.to_json())
        if with_hlo and rep.ok:
            text = jax.jit(fn).lower(*args).compile().as_text()
            hlo_v = exactness.audit_hlo_text(text, e.contract, entry=e.name)
            if hlo_v:
                row["status"] = "violation"
                row["violations"] = (row.get("violations", [])
                                     + [v.to_json() for v in hlo_v])
                row["ok"] = False
        rows.append(row)
    return rows


def _pass_overflow(entries) -> list[dict]:
    from repro.analysis import overflow

    rows = []
    for e in entries:
        c = e.contract
        if c is None:
            continue  # sharding-only entry: no digit config to certify
        cert = overflow.certify(c.n_bits, c.log2_radix, c.k, levels=c.levels)
        rows.append({"entry": e.name, "status": "ok" if cert.sound
                     else "violation", **cert.to_json()})
    for row in overflow.audit_registry():
        rows.append({"entry": f"configs/{row['arch']}/{row['site']}",
                     "status": "ok" if row["sound"] else "violation", **row})
    return rows


def _pass_compiled() -> list[dict]:
    import dataclasses

    import jax
    import numpy as np

    from repro.analysis import compiled as C
    from repro.configs import get_smoke
    from repro.core.quant import QuantConfig
    from repro.models.common import materialize
    from repro.models.transformer import lm_build
    from repro.serve import ContinuousBatcher, Request, ServingGateway
    from repro.serve.engine import prepare_params

    cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
    params = prepare_params(cfg, materialize(lm_build(cfg),
                                             jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)

    def requests(n=3, max_new=3):
        return [Request(uid=i, prompt=rng.integers(
                    0, cfg.vocab, (int(L),)).astype(np.int32),
                    max_new_tokens=max_new)
                for i, L in enumerate(rng.integers(3, 20, n))]

    gw = ServingGateway(cfg, params, n_slots=2, max_len=32)
    gw.warmup()
    gw.run(requests())
    gw_rep = C.audit_gateway(gw)

    b = ContinuousBatcher(cfg, params, n_slots=2, max_len=32)
    for r in requests(2):
        b.submit(r)
    b.step()  # prefill + first decode; the audited step donates its state
    b_rep = C.audit_batcher(b)
    for rep in (gw_rep, b_rep):
        rep["status"] = "ok" if rep["ok"] else "violation"
    return [gw_rep, b_rep]


def _pass_sharding(entries, allow_skips: bool) -> list[dict]:
    from repro.analysis import sharding

    return sharding.audit_sharded_registry(entries, allow_skips=allow_skips)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="static L2R invariant linter")
    ap.add_argument("--json", default=None, help="write JSON report here")
    ap.add_argument("--hlo", action="store_true",
                    help="also compile each entry and audit the optimized "
                         "HLO module (slower)")
    ap.add_argument("--sharding", action="store_true",
                    help="audit every entry carrying a ShardingContract: "
                         "collective schedule, reduction taint, layout "
                         "conformance + sync-cost certificates (needs >= 2 "
                         "devices; CI uses the virtual-8-device XLA flag)")
    ap.add_argument("--allow-skips", action="store_true",
                    help="report skipped registry entries as SKIP instead "
                         "of FAIL (local runs on small hosts)")
    ap.add_argument("--skip-compiled", action="store_true",
                    help="skip the serving-artifact pass (pass 3)")
    ap.add_argument("--tags", default=None,
                    help="comma-separated entry tag filter (e.g. gemm,head)")
    args = ap.parse_args(argv)

    from repro.analysis import registry

    tags = tuple(args.tags.split(",")) if args.tags else None
    entries = registry.iter_entries(tags)

    report = {
        "exactness": _pass_exactness(entries, with_hlo=args.hlo,
                                     allow_skips=args.allow_skips),
        "overflow": _pass_overflow(entries),
        "compiled": [] if args.skip_compiled else _pass_compiled(),
    }
    if args.sharding:
        report["sharding"] = _pass_sharding(entries, args.allow_skips)

    n_bad = 0
    for pass_name, rows in report.items():
        for row in rows:
            mark = {"ok": "PASS", "skip": "SKIP"}.get(row["status"], "FAIL")
            if mark == "FAIL":
                n_bad += 1
            print(f"[{pass_name:9s}] {mark} {row['entry']}")
            for v in row.get("violations", []):
                reason = v["reason"] if isinstance(v, dict) else v
                print(f"            - {reason}")
    report["n_violations"] = n_bad
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
    print(f"l2r-lint: {n_bad} violation(s) across "
          f"{sum(len(r) for r in report.values() if isinstance(r, list))} "
          f"checks")
    return 1 if n_bad else 0


if __name__ == "__main__":
    sys.exit(main())
