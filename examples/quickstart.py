"""Quickstart: the L2R composite inner-product unit in five acts.

    PYTHONPATH=src python examples/quickstart.py

1. cycle-accurate CIPU simulation (the paper's Fig. 1 datapath),
2. MSDF digit-plane GEMM == exact integer matmul,
3. progressive precision (online early output) with hard error bounds,
4. the Pallas TPU kernel (validated in interpret mode on CPU),
5. the accelerator model reproducing the paper's Tables I/II.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import (hw_model, l2r_matmul_int, network_cycles,
                        peak_gops, simulate_cipu)
from repro.core.progressive import progressive_matmul
from repro.kernels.l2r_gemm import l2r_gemm, int_gemm_ref

rng = np.random.default_rng(0)

print("=" * 70)
print("1) Cycle-accurate composite IPU (k=72 products, n=8 bits)")
a = rng.integers(0, 256, (1, 72))
b = rng.integers(0, 256, (1, 72))
trace = simulate_cipu(jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32), 8)
print(f"   exact SOP     : {int((a * b).sum())}")
print(f"   CIPU result   : {int(trace.final[0])}  (64 cycles, carry-free)")
sb = np.asarray(trace.stable_bits[0])
print(f"   stable MSBs over cycles 1,8,16,32,64: "
      f"{[int(sb[i-1]) for i in (1, 8, 16, 32, 64)]}  <- online output")

print("=" * 70)
print("2) MSDF digit-plane GEMM (radix-4) == integer matmul, bit-exact")
A = rng.integers(-128, 128, (64, 128), dtype=np.int8)
B = rng.integers(-128, 128, (128, 32), dtype=np.int8)
exact = np.asarray(A, np.int64) @ np.asarray(B, np.int64)
out = np.asarray(l2r_matmul_int(jnp.asarray(A), jnp.asarray(B)), np.int64)
print(f"   max |err| = {np.abs(out - exact).max()} (must be 0)")

print("=" * 70)
print("3) Progressive precision: error vs MSDF levels (bound always holds)")
res = progressive_matmul(jnp.asarray(A), jnp.asarray(B))
for lv in range(res.partial.shape[0]):
    err = np.abs(np.asarray(res.partial[lv], np.int64) - exact).max()
    print(f"   level {lv+1}/7: max err {err:>8d}   bound {int(res.tail_bound[lv]):>9d}")

print("=" * 70)
print("4) Pallas TPU kernel (interpret mode on CPU), bit-exact vs oracle")
Ap = rng.integers(-128, 128, (128, 256), dtype=np.int8)
Bp = rng.integers(-128, 128, (256, 128), dtype=np.int8)
# force the Pallas path: the dispatcher's CPU default is the (much
# faster) jnp level-stacked schedule
kout = l2r_gemm(jnp.asarray(Ap), jnp.asarray(Bp), backend="pallas-interpret")
kref = int_gemm_ref(jnp.asarray(Ap), jnp.asarray(Bp))
print(f"   kernel == oracle: {bool(np.array_equal(np.asarray(kout), np.asarray(kref)))}")

print("=" * 70)
print("5) Accelerator model vs the paper")
print(f"   peak GOPS   : L2R {peak_gops():.2f} (paper 48.97) | "
      f"baseline {peak_gops(l2r=False):.2f} (paper 14.40)")
print(f"   VGG-16 speedup: {network_cycles(l2r=False)/network_cycles():.2f}x "
      f"(paper 3.40x)")
t2 = hw_model.table2()
print(f"   TOPS/W      : {t2['l2r_cipu']['tops_w']:.2f} (paper 1.20) | "
      f"GOPS/mm^2 {t2['l2r_cipu']['gops_mm2']:.1f} (paper 200.45)")
