"""Per-request precision classes: one mixed batch, three SLAs.

    PYTHONPATH=src python examples/precision_policies.py

PR 8's policy layer (core/policy.py) turns the streaming walks'
batch-global early-exit decision into a PER-ROW one: each request
carries a `PrecisionClass` —

  * ``exact``        — run the full digit stream (reference quality);
  * ``budget(L)``    — clamp at level L (latency SLA; tokens identical
                       to a `levels=L` truncated run);
  * ``bounded(eps)`` — early-exit once the argmax margin beats the
                       scaled tail bound by eps (``bounded(0)`` IS the
                       legacy early-exit walk, bit for bit);

packed into a `LevelPolicy` pytree and folded inside ONE fused while
loop.  This demo shows:

  1. the raw head walk serving a mixed batch, each row committing at
     its own class's level — bit-identical to serving that row alone;
  2. a mixed-class batch through the `ContinuousBatcher` (precision on
     `Request`), with per-class exit-level histograms in `stats()`;
  3. the offline calibration loop: fit a `budget(L)` from the bounded
     class's observed exit histogram (tools/calibrate_levels.py).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import importlib.util

import jax
import numpy as np

from repro.core.policy import LevelPolicy, PrecisionClass
from repro.core.progressive import streaming_argmax
from repro.core.quant import QuantConfig

rng = np.random.default_rng(0)
qc = QuantConfig()
n_levels = 2 * qc.planes - 1

# ----------------------------------- 1. mixed classes in one head walk
print("== one fused walk, three precision classes ==")
from repro.models.protohead import prototype_head

xq, xs, w_q, _ = prototype_head(rng, 256, 32, 9, cfg=qc)
classes = [PrecisionClass.exact(), PrecisionClass.budget(3),
           PrecisionClass.bounded()] * 3
pol = LevelPolicy.from_classes(classes)
_, tok, lv = streaming_argmax(xq, w_q.q, xs, w_q.scale, qc.n_bits,
                              qc.log2_radix, early_exit=True, policy=pol)
_, tok_full, _ = streaming_argmax(xq, w_q.q, xs, w_q.scale, qc.n_bits,
                                  qc.log2_radix)
for i, c in enumerate(classes[:3]):
    rows = [j for j in range(len(classes)) if classes[j] is c or
            classes[j].label() == c.label()]
    lvs = np.asarray(lv)[rows]
    agree = np.mean(np.asarray(tok)[rows] == np.asarray(tok_full)[rows])
    print(f"  {c.label():<12} exit levels {lvs.tolist()}  "
          f"agreement vs exact {agree:.2f}")
print(f"  (full depth = level {n_levels - 1}; budget(3) caps at 2; "
      f"bounded rows stop at their own margin)")

# ------------------------------- 2. mixed classes through the batcher
print("\n== mixed-class batch through ContinuousBatcher ==")
from repro.configs import get_smoke
from repro.models.common import materialize
from repro.models.transformer import lm_build
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import prepare_params

cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
desc = lm_build(cfg)
params = prepare_params(cfg, materialize(desc, jax.random.PRNGKey(0)), desc)
prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
           for n in (5, 7, 6)]
eng = ContinuousBatcher(cfg, params, n_slots=3, max_len=48,
                        progressive=True, early_exit=True)
for i, (p, c) in enumerate(zip(prompts, [PrecisionClass.exact(),
                                         PrecisionClass.budget(3),
                                         PrecisionClass.bounded()])):
    eng.submit(Request(uid=i, prompt=p, max_new_tokens=8, precision=c))
eng.run(max_steps=200)
st = eng.stats()
print(f"  served {st['tokens']} tokens over {st['n_levels']} levels, "
      f"mean exit level {st['mean_exit_level']:.2f}")
for label, hist in st["exit_level_hist_by_class"].items():
    h = np.asarray(hist, np.float64)
    mean = (h * np.arange(h.size)).sum() / max(h.sum(), 1)
    print(f"  {label:<12} hist {np.asarray(hist).tolist()}  "
          f"mean exit {mean:.2f}")

# --------------------------------- 3. close the loop: fit a budget
print("\n== calibration: bounded histogram -> fitted budget(L) ==")
_spec = importlib.util.spec_from_file_location(
    "calibrate_levels", os.path.join(os.path.dirname(__file__), "..",
                                     "tools", "calibrate_levels.py"))
cal = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(cal)
fits = cal.fit_class_budgets(st["exit_level_hist_by_class"], coverage=0.99)
print(f"  fitted budgets @99% coverage: {fits}")
bounded_fit = fits.get("bounded(0)", n_levels)
print(f"  -> redeploy the bounded class as "
      f"PrecisionClass.budget({bounded_fit}): a static clamp that "
      f"reproduces 99% of its observed commits")
print("  (benchmarks/run.py precision_policy_bench measures the full "
      "accuracy-vs-levels-vs-latency frontier into "
      "BENCH_progressive.json)")
