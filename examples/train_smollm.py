"""End-to-end training driver: train a ~135M-param smolLM on the
structured synthetic stream for a few hundred steps.

    PYTHONPATH=src python examples/train_smollm.py [--full] [--steps 300]

Default uses a width-reduced config so the loop runs quickly on CPU; the
--full flag trains the real 135M-parameter assigned configuration (slow
on CPU, the intended artifact for a v5e pod).  Exercises the real stack:
sharded data pipeline, remat train step, ZeRO-friendly AdamW, async
checkpointing + auto-resume, fault supervisor.
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="the real 135M config (use a TPU pod; slow on CPU)")
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="smollm_ckpt_")
    argv = ["--arch", "smollm-135m", "--steps", str(args.steps),
            "--global-batch", "8", "--seq-len", "128",
            "--ckpt-dir", ckpt, "--ckpt-every", "100",
            "--lr", "3e-3", "--log-every", "20"]
    if not args.full:
        argv.append("--smoke")
    losses = train_main(argv)
    assert losses[-1] < losses[0], "training must reduce the loss"
    print(f"checkpoints in {ckpt}")
