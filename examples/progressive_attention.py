"""Margin-bounded progressive decode attention, end to end.

    PYTHONPATH=src python examples/progressive_attention.py

PR 7 takes the MSDF property into attention: QK^T runs digit-serial
over the incrementally plane-stacked KV cache, and the per-row score
walk can STOP as soon as every row's running max and softmax normalizer
are decided within a scaled tail bound (`attn_early_exit` /
`attn_exit_tol` on ModelConfig).  This demo shows:

  1. how the exit level responds to score sharpness and tolerance —
     peaked score rows decide after a few significance levels, flat
     rows need the full walk;
  2. per-layer exit-level histograms from a real (smoke-sized) LM,
     collected with `attn_exit_tap()` during an eagerly-executed decode
     step (`jax.disable_jit` — the tap records only concrete values);
  3. greedy decode token parity: early exit changes how many levels the
     walk runs, never the committed tokens.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import QuantConfig
from repro.models.attention import (attn_exit_tap, decode_attention,
                                    init_kv_cache, update_kv_cache)

rng = np.random.default_rng(0)
qc = QuantConfig()
n_levels = 2 * qc.planes - 1

# ------------------------------------------ 1. sharpness vs exit level
print("== exit level vs score sharpness (eager decode_attention) ==")
b, length, kvh, g, dh = 4, 64, 2, 2, 64
cache = init_kv_cache(b, length, kvh, dh, jnp.float32, quant=qc)
ks = jnp.asarray(rng.standard_normal((b, length, kvh, dh)), jnp.float32)
vs = jnp.asarray(rng.standard_normal((b, length, kvh, dh)), jnp.float32)
pos = jnp.asarray(np.tile(np.arange(length), (b, 1)), jnp.int32)
cache = update_kv_cache(cache, ks, vs, pos, quant=qc)
qpos = jnp.full((b,), length - 1, jnp.int32)

for sharp, name in [(0.2, "flat scores "), (1.0, "typical     "),
                    (4.0, "peaked      ")]:
    q = jnp.asarray(rng.standard_normal((b, 1, kvh * g, dh)) * sharp,
                    jnp.float32)
    for tol in (1e-4, 1e-2):
        with attn_exit_tap() as rec:
            out = decode_attention(q, cache.k, cache.v, cache.positions,
                                   qpos, l2r=qc, k_planes=cache.k_planes,
                                   k_scale=cache.k_scale, early_exit=True,
                                   exit_tol=tol)
        full = decode_attention(q, cache.k, cache.v, cache.positions, qpos,
                                l2r=qc, k_planes=cache.k_planes,
                                k_scale=cache.k_scale)
        lv = rec[0]["exit_levels"].ravel()
        err = float(jnp.max(jnp.abs(out - full)))
        print(f"  {name} tol={tol:.0e}: walk ran "
              f"{rec[0]['levels_run']}/{n_levels} levels | per-row exit "
              f"histogram {np.bincount(lv, minlength=n_levels).tolist()} | "
              f"max |out - full| {err:.2e}")

# --------------------------- 2. per-layer histograms from a real model
print("\n== per-layer exit levels, smoke LM decode step ==")
from repro.configs import get_smoke
from repro.models.common import materialize
from repro.models.transformer import init_lm_state, lm_build, lm_forward

cfg = dataclasses.replace(get_smoke("smollm-135m"), attn_l2r=qc,
                          attn_early_exit=True, attn_exit_tol=1e-3)
params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)

state = init_lm_state(cfg, 2, max_len=16, dtype=jnp.float32)
_, state, _ = lm_forward(cfg, params, tokens=prompt, mode="prefill",
                         state=state)
tok = prompt[:, -1:]
with jax.disable_jit(), attn_exit_tap() as rec:
    _, state, _ = lm_forward(cfg, params, tokens=tok, mode="decode",
                             state=state)
print(f"  {len(rec)} attention calls recorded (one per attention layer)")
for i, r in enumerate(rec):
    lv = r["exit_levels"].ravel()
    print(f"  layer {i}: walk ran {r['levels_run']}/{n_levels} levels | "
          f"exit histogram {np.bincount(lv, minlength=n_levels).tolist()}")

# ------------------------------------------------ 3. token parity
print("\n== greedy token parity: early exit never changes tokens ==")
from repro.serve.engine import greedy_generate

cfg_q = dataclasses.replace(cfg, attn_early_exit=False)
out_q = np.asarray(greedy_generate(cfg_q, params, prompt, steps=6))
out_e = np.asarray(greedy_generate(cfg, params, prompt, steps=6))
print(f"  full-depth quantized tokens: {out_q.tolist()}")
print(f"  early-exit tokens:           {out_e.tolist()}")
print(f"  bit-identical: {np.array_equal(out_q, out_e)}")
