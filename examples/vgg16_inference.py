"""VGG-16 inference through the L2R pipeline — the paper's evaluation.

    PYTHONPATH=src python examples/vgg16_inference.py

Compares float32 conv, exact W8A8 L2R digit-plane conv, and the
progressive-precision modes, then prints the per-layer Cycle_P walk of
the modeled accelerator (the execution-cycles evaluation of the paper).
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cycle_model import (AcceleratorConfig, VGG16_CONV_LAYERS,
                                    layer_cycles)
from repro.core.quant import QuantConfig
from repro.models.cnn import vgg16_apply, vgg16_build, vgg16_quantize_weights
from repro.models.common import materialize

params = materialize(vgg16_build(n_classes=10), jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
img = jnp.asarray(rng.standard_normal((4, 64, 64, 3)).astype(np.float32))

# the L2R weight cache: quantize every conv/fc weight ONCE at load time;
# the forward passes below then carry no weight quantization at all
cfg = QuantConfig()
wq = vgg16_quantize_weights(params, cfg)

print("forward float32 ...")
t0 = time.time()
lf = np.asarray(vgg16_apply(params, img))
print(f"  {time.time()-t0:.1f}s  logits[0,:4] = {np.round(lf[0, :4], 3)}")

print("forward L2R W8A8 (exact MSDF stream, fused conv, cached weights) ...")
t0 = time.time()
lq = np.asarray(vgg16_apply(params, img, l2r=cfg, weights_q=wq))
rel = np.abs(lq - lf).max() / np.abs(lf).max()
print(f"  {time.time()-t0:.1f}s  rel err vs float: {rel:.4f}")
agree = (lq.argmax(-1) == lf.argmax(-1)).mean()
print(f"  top-1 agreement: {agree*100:.0f}%")

for lv in (5, 3):
    lp = np.asarray(vgg16_apply(params, img, l2r=cfg, levels=lv, weights_q=wq))
    rel = np.abs(lp - lq).max() / (np.abs(lq).max() + 1e-9)
    agree = (lp.argmax(-1) == lq.argmax(-1)).mean()
    print(f"progressive levels={lv}/7: rel err {rel:.3f}, "
          f"top-1 agreement {agree*100:.0f}% (early MSDF exit)")

print("\nmodeled accelerator cycles (Cycle_P, 8x8 PEs @ 400 MHz):")
cfg = AcceleratorConfig()
tot_l = tot_b = 0
for layer in VGG16_CONV_LAYERS:
    cl, cb = layer_cycles(layer, cfg, True), layer_cycles(layer, cfg, False)
    tot_l += cl
    tot_b += cb
    print(f"  {layer.name:9s} L2R {cl/1e6:8.1f}M  baseline {cb/1e6:8.1f}M  "
          f"({cb/cl:.2f}x)")
print(f"  {'total':9s} L2R {tot_l/1e6:8.1f}M  baseline {tot_b/1e6:8.1f}M  "
      f"({tot_b/tot_l:.2f}x — paper: 3.40x)")
