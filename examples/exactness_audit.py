"""Exactness auditing: prove the L2R walks are exact, not just test them.

    PYTHONPATH=src python examples/exactness_audit.py

Five acts using the l2r-lint API (``repro.analysis``, CLI in
``tools/l2r_lint.py`` — the CI gate runs the same passes over every
registered entry point plus the compiled serving artifacts):

1. audit a registered claimed-exact walk (jaxpr taint pass),
2. catch a seeded violation (an unguarded f32 dot on the exact path),
3. certify int32 non-overflow for a digit config — and find the exact
   contraction length where the certificate flips to unsound,
4. sweep every arch in the config registry,
5. sharding audit: sweep the shard_mapped entries (on multi-device
   hosts the full schedule + sync-cost certificate; everywhere, catch
   a synthetic GSPMD float-reassociation — the PR 5 bug class — from
   partitioned HLO text alone).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import (ExactnessContract, audit_exactness,
                            audit_registry, certify)
from repro.analysis.registry import iter_entries

print("=" * 70)
print("1) Audit a registered claimed-exact entry point")
entry = next(e for e in iter_entries() if e.name == "gemm/stacked/jnp")
fn, args = entry.build()
rep = audit_exactness(fn, args, entry.contract, entry=entry.name)
print(f"   {entry.name}: ok={rep.ok}  eqns={rep.eqns_checked} "
      f"tainted={rep.tainted_eqns} int_dots={rep.int_dots} "
      f"f32_fastpath_dots={rep.f32_fastpath_dots}")
assert rep.ok

print("=" * 70)
print("2) Seeded violation: f32 dot without precision=HIGHEST")


def buggy_walk(aq, bq):
    # the bug class the pass exists for: XLA's default precision may
    # use bf16 passes on TPU — bit-exactness silently gone
    out = jax.lax.dot_general(aq.astype(jnp.float32),
                              bq.astype(jnp.float32),
                              (((1,), (0,)), ((), ())))
    return out.astype(jnp.int32)


rng = np.random.default_rng(0)
aq = rng.integers(-128, 128, (4, 24)).astype(np.int8)
bq = rng.integers(-128, 128, (24, 16)).astype(np.int8)
rep = audit_exactness(buggy_walk, (aq, bq), ExactnessContract(k=24))
assert not rep.ok
for v in rep.violations:
    print(f"   CAUGHT {v.primitive}: {v.reason}")

print("=" * 70)
print("3) Overflow certification (n_bits=8, radix-4)")
cert = certify(n_bits=8, log2_radix=2, k=512)
print(f"   k=512: bound={cert.bound} (exact={cert.exact}) "
      f"sound={cert.sound} headroom={cert.headroom_bits:.1f} bits")
k_max = cert.limit // cert.per_element
for k in (k_max, k_max + 1):
    c = certify(8, 2, k)
    print(f"   k={k}: bound={c.bound} sound={c.sound}")
assert certify(8, 2, k_max).sound and not certify(8, 2, k_max + 1).sound
x, y, t = certify(8, 2, 1).witness
print(f"   witness: x={x}, y={y} achieve the per-element bound "
      f"after {t} level(s)")

print("=" * 70)
print("4) Registry sweep: every arch, head + attention sites")
rows = audit_registry()
for r in rows[:4]:
    print(f"   {r['arch']:>18} {r['site']:<10} k={r['k']:<5} "
          f"bound={r['bound']:<12} sound={r['sound']}")
print(f"   ... {len(rows)} sites total, "
      f"{sum(r['sound'] for r in rows)} sound")
assert all(r["sound"] for r in rows)

print("=" * 70)
print("5) Sharding audit: the shard_mapped entries")
from repro.analysis import audit_partitioned_hlo, audit_sharded_registry
from repro.analysis.sharding import ShardingContract

# on a 1-device host the sharded entries skip (allow_skips keeps this
# example runnable anywhere; the CI lint job runs without it under
# XLA_FLAGS=--xla_force_host_platform_device_count=8 so a skip FAILS)
for row in audit_sharded_registry(allow_skips=True):
    line = f"   {row['entry']}: {row['status']}"
    if row["status"] == "ok":
        cert = row["cost"]
        k8 = cert["sync_every_k"][-1]
        line += (f"  collectives/walk={cert['collectives_per_walk']}"
                 f"  wire={cert['wire_bytes_per_walk']:.0f}B"
                 f"  sync-every-8 saves {k8['savings_frac']:.0%}")
    print(line)

# the PR 5 bug class needs no devices to demonstrate: a partitioned
# module whose float contraction GSPMD split across shards — partial
# sums joined by a float `add` all-reduce, bit-parity silently gone
bad_hlo = """\
HloModule jit_step, num_partitions=8

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  ROOT %ar = f32[8,16]{1,0} all-reduce(f32[8,16]{1,0} %p0), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%sum, metadata={op_name="jit(step)/dot_general"}
}
"""
violations, _ = audit_partitioned_hlo(
    bad_hlo, ShardingContract(mesh_axes=(("data", 2), ("model", 4))))
assert violations
for v in violations:
    print(f"   CAUGHT {v.primitive}: {v.reason}")

print("=" * 70)
print("all audits behaved as expected; CLI equivalent:")
print("    PYTHONPATH=src python tools/l2r_lint.py --hlo --sharding")
