"""The serving gateway: bucketed AOT prefill, donated decode, async emit.

    PYTHONPATH=src python examples/serve_gateway.py

A mixed-length request trace is served twice — through the plain
`ContinuousBatcher` (one prefill trace per unique prompt length, full
KV-cache copy per decode step, a host sync per slot per step) and
through `ServingGateway` (one AOT-compiled prefill executable per
power-of-2 length bucket, packed multi-prompt prefill, donated decode
state, tokens drained by an async emit thread).  Output streams are
bit-identical; the gateway additionally reports throughput and p50/p99
TTFT / per-token latency, and a second pass replays a Poisson arrival
trace in real time.
"""

import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.quant import QuantConfig
from repro.models.common import materialize
from repro.models.transformer import lm_build
from repro.serve import ContinuousBatcher, Request, ServingGateway
from repro.serve.engine import prepare_params

cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
params = prepare_params(cfg, materialize(lm_build(cfg), jax.random.PRNGKey(0)))

rng = np.random.default_rng(0)
lengths = [3, 5, 8, 11, 17, 23, 9, 14]  # spans the 8/16/32 buckets
prompts = [rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
           for L in lengths]


def make_requests():
    return [Request(uid=i, prompt=p, max_new_tokens=8)
            for i, p in enumerate(prompts)]


print("--- plain ContinuousBatcher (reference) ---")
ref = make_requests()
eng = ContinuousBatcher(cfg, params, n_slots=4, max_len=32,
                        progressive=True, early_exit=True)
for r in ref:
    eng.submit(r)
t0 = time.perf_counter()
eng.run(max_steps=1000)
print(f"batcher: {eng.steps} decode steps, "
      f"{time.perf_counter() - t0:.2f}s wall")

print("--- ServingGateway (offline drain) ---")
served = make_requests()
gw = ServingGateway(cfg, params, n_slots=4, max_len=32, prefill_group=4,
                    progressive=True, early_exit=True)
gw.run(served)
gw.close()
st = gw.stats()
for a, b in zip(ref, served):
    assert a.output == b.output, (a.uid, a.output, b.output)
    assert a.exit_levels == b.exit_levels
print(f"gateway: {st['tokens']} tokens in {st['steps']} decode dispatches "
      f"+ {st['prefills']} packed prefills (buckets {st['buckets']})")
print(f"  {st['tokens_per_s']:.1f} tok/s | ttft p50/p99 "
      f"{st['ttft_p50_s'] * 1e3:.1f}/{st['ttft_p99_s'] * 1e3:.1f} ms | "
      f"tpot p50/p99 {st['tpot_p50_s'] * 1e3:.1f}/"
      f"{st['tpot_p99_s'] * 1e3:.1f} ms")
print(f"  mean exit level {st['mean_exit_level']:.2f}/{st['n_levels'] - 1} "
      f"(saved {st['mean_levels_saved']:.2f} levels/token)")
print("  output streams bit-identical to the plain batcher")

print("--- ServingGateway (real-time Poisson arrivals) ---")
online = make_requests()
gw2 = ServingGateway(cfg, params, n_slots=4, max_len=32, prefill_group=4,
                     progressive=True, early_exit=True)
t0 = time.perf_counter() + 0.01
arrival = t0
for r in online:
    arrival += float(rng.exponential(0.03))
    r.t_arrival = arrival
    gw2.submit(r)
gw2.run(realtime=True)
gw2.close()
st2 = gw2.stats()
for a, b in zip(ref, online):
    assert a.output == b.output
print(f"online: {st2['tokens_per_s']:.1f} tok/s | ttft p50 "
      f"{st2['ttft_p50_s'] * 1e3:.1f} ms (includes queueing) | "
      f"tokens still bit-identical")
