"""Batched serving with the L2R W8A8 weight format.

    PYTHONPATH=src python examples/serve_decode.py

Runs the same prompts through (a) bf16/f32 weights, (b) int8-stored
weights (the L2R serving format — exactly the integer arithmetic the
composite IPU streams MSDF), and (c) the digit-plane progressive mode,
comparing outputs and timing.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.launch.serve import main as serve_main

print("--- float weights ---")
a = serve_main(["--arch", "smollm-135m", "--smoke", "--batch", "2",
                "--prompt-len", "12", "--steps", "8"])
print("--- int8 (L2R W8A8) weights ---")
b = serve_main(["--arch", "smollm-135m", "--smoke", "--batch", "2",
                "--prompt-len", "12", "--steps", "8", "--wq"])
print("--- progressive MSDF (5/7 levels) ---")
c = serve_main(["--arch", "smollm-135m", "--smoke", "--batch", "2",
                "--prompt-len", "12", "--steps", "8", "--l2r-levels", "5"])

agree_q = (a == b).mean()
agree_p = (a == c).mean()
print(f"\ntoken agreement: int8 vs float {agree_q*100:.0f}% | "
      f"progressive vs float {agree_p*100:.0f}%")
print("(random untrained weights -> near-uniform logits, so argmax is "
      "maximally quantization-sensitive; on trained checkpoints W8A8 "
      "agreement is the ~99% regime — see tests/test_vgg16.py for the "
      "bounded-error checks on realistic activations)")
