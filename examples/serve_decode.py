"""Batched serving with the L2R W8A8 weight format.

    PYTHONPATH=src python examples/serve_decode.py

Runs the same prompts through (a) bf16/f32 weights, (b) int8-stored
weights (the L2R serving format — exactly the integer arithmetic the
composite IPU streams MSDF), and (c) the digit-plane progressive mode,
comparing outputs and timing.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.launch.serve import main as serve_main

print("--- float weights ---")
a = serve_main(["--arch", "smollm-135m", "--smoke", "--batch", "2",
                "--prompt-len", "12", "--steps", "8"])
print("--- int8 (L2R W8A8) weights ---")
b = serve_main(["--arch", "smollm-135m", "--smoke", "--batch", "2",
                "--prompt-len", "12", "--steps", "8", "--wq"])
print("--- progressive MSDF (5/7 levels) ---")
c = serve_main(["--arch", "smollm-135m", "--smoke", "--batch", "2",
                "--prompt-len", "12", "--steps", "8", "--l2r-levels", "5"])

agree_q = (a == b).mean()
agree_p = (a == c).mean()
print(f"\ntoken agreement: int8 vs float {agree_q*100:.0f}% | "
      f"progressive vs float {agree_p*100:.0f}%")
print("(random untrained weights -> near-uniform logits, so argmax is "
      "maximally quantization-sensitive; on trained checkpoints W8A8 "
      "agreement is the ~99% regime — see tests/test_vgg16.py for the "
      "bounded-error checks on realistic activations)")

# --- sharded serving: the same progressive engine on a device mesh ---
# Installing a mesh routes the whole stack onto the sharded paths: the
# LM-head plane stack is vocab-sharded over "model" at load
# (prepare_params), slot state is placed per engine.state_specs, and the
# head streams as the shard_mapped consensus walk whose early exit stops
# at the fleet-wide slowest row — tokens and exit levels bit-identical
# to the single-device engine.  A multi-device CPU needs the virtual-
# device flag BEFORE jax initializes, so the demo runs in a subprocess.
import subprocess

from repro.launch.mesh import virtual_device_env

SHARDED_DEMO = """
import dataclasses, sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_smoke
from repro.core.quant import QuantConfig
from repro.launch.mesh import install_local_mesh
from repro.models.common import materialize
from repro.models.transformer import lm_build
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import prepare_params
from repro.sharding import ctx

cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
raw = materialize(lm_build(cfg), jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab, (6,)).astype(np.int32)
           for _ in range(3)]

def run(mesh_shape):
    ctx.set_mesh(None)
    if mesh_shape:
        install_local_mesh(*mesh_shape)  # (data, model)
    eng = ContinuousBatcher(cfg, prepare_params(cfg, raw), n_slots=2,
                            max_len=24, progressive=True, early_exit=True)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    eng.run(max_steps=50)
    return eng

single = run(None)
sharded = run((2, 4))  # data=2 x model=4 over 8 virtual devices
s1, s2 = single.stats(), sharded.stats()
assert s1 == s2, (s1, s2)
print(f"sharded(2x4) == single-device: tokens={s2['tokens']} "
      f"mean_exit={s2['mean_exit_level']:.2f}/{s2['n_levels'] - 1} "
      f"stats identical")
"""
print("--- sharded progressive serving (2x4 virtual-device mesh) ---")
out = subprocess.run(
    [sys.executable, "-c", SHARDED_DEMO], text=True, capture_output=True,
    cwd=os.path.join(os.path.dirname(__file__), ".."),
    env=virtual_device_env(8))
print(out.stdout.strip())
if out.returncode != 0:
    print(out.stderr[-2000:])
    sys.exit("sharded serving demo failed")
