"""Progressive-precision classification: the online early-exit win.

    PYTHONPATH=src python examples/progressive_precision.py

The hardware's MSDF property means the most significant digits of every
logit arrive first; a classifier can commit to its argmax as soon as the
top-1 margin exceeds the hard bound on the unseen digit tail.  This
example measures how many MSDF levels random classifier heads actually
need — the average is well below the full stream, which is the
throughput/latency advantage of the online unit (paper §I).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core.progressive import earliest_decision_level, progressive_matmul

rng = np.random.default_rng(0)

for (rows, k, classes) in [(512, 64, 16), (512, 256, 100), (256, 1024, 1000)]:
    a = rng.integers(-128, 128, (rows, k), dtype=np.int8)
    b = rng.integers(-128, 128, (k, classes), dtype=np.int8)
    res = progressive_matmul(jnp.asarray(a), jnp.asarray(b))
    lv = np.asarray(earliest_decision_level(res))
    full = res.partial.shape[0]
    exact_arg = (a.astype(np.int64) @ b.astype(np.int64)).argmax(-1)
    early = lv < full - 1
    sound = all(
        np.asarray(res.partial[lv[i], i]).argmax() == exact_arg[i]
        for i in np.where(early)[0][:200]
    )
    hist = np.bincount(lv, minlength=full)
    print(f"K={k:5d} classes={classes:4d}: mean exit level "
          f"{lv.mean()+1:.2f}/{full} | {early.mean()*100:4.0f}% exit early | "
          f"early decisions sound: {sound}")
    print(f"   exit-level histogram: {hist.tolist()}")
print("\n(each early exit saves the remaining plane-pair MXU passes — the "
      "tensor analogue of reading MSDs after the online delay)")
