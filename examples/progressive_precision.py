"""Progressive precision end to end: the streaming early-exit subsystem.

    PYTHONPATH=src python examples/progressive_precision.py

The hardware's MSDF property means the most significant digits of every
output arrive first; any consumer whose decision depends on an argmax can
commit as soon as the top-1 margin exceeds the hard bound on the unseen
digit tail.  This demo walks the consumers the streaming emitter
(core/progressive.py, schedule="streaming" in kernels/l2r_gemm) feeds:

  1. a classifier head reading the raw logit stream,
  2. the fused conv emitting per-level feature-map prefixes with a
     shrinking error envelope (l2r_conv2d_progressive),
  3. greedy LM decoding that commits each token at its earliest sound
     level (serve progressive decode) — tokens bit-identical to the full
     evaluation, levels saved for free,
  4. the early-exit WHILE scan: the same level walk as a lax.while_loop
     that STOPS once every row has decided, so the saved levels are
     measured wall-clock inside one fused computation, not accounting.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.progressive import earliest_decision_level, progressive_matmul
from repro.core.quant import QuantConfig

rng = np.random.default_rng(0)

# ---------------------------------------------------- 1. logit stream
print("== classifier head on the raw MSDF stream ==")
for (rows, k, classes) in [(512, 64, 16), (256, 256, 100)]:
    a = rng.integers(-128, 128, (rows, k), dtype=np.int8)
    b = rng.integers(-128, 128, (k, classes), dtype=np.int8)
    res = progressive_matmul(jnp.asarray(a), jnp.asarray(b))
    lv = np.asarray(earliest_decision_level(res))
    full = res.partial.shape[0]
    early = lv < full - 1
    print(f"K={k:4d} classes={classes:4d}: mean exit level "
          f"{lv.mean() + 1:.2f}/{full} | {early.mean() * 100:4.0f}% exit "
          f"early | histogram {np.bincount(lv, minlength=full).tolist()}")

# ------------------------------------------------- 2. conv early output
print("\n== fused conv: per-level prefix stream + error envelope ==")
from repro.kernels.l2r_gemm import l2r_conv2d, l2r_conv2d_progressive

cfg = QuantConfig()
x = jnp.asarray(rng.standard_normal((1, 16, 16, 8)).astype(np.float32))
w = jnp.asarray((rng.standard_normal((3, 3, 8, 16)) * 0.2).astype(np.float32))
res, scale = l2r_conv2d_progressive(x, w, cfg)
exact = np.asarray(res.partial[-1], np.int64)
for t in range(res.partial.shape[0]):
    err = np.abs(np.asarray(res.partial[t], np.int64) - exact).max()
    print(f"  level {t + 1}/{res.partial.shape[0]}: max |tail| = {err:>8d}"
          f"  (hard bound {float(res.tail_bound[t]):>12.0f})")
print("  each level is bit-identical to l2r_conv2d(levels=t+1); a"
      " downstream online consumer may start on the MS digits immediately")

# -------------------------------------------- 3. progressive decode
print("\n== progressive greedy decode (streamed LM head) ==")
from repro.configs import get_smoke
from repro.models.common import materialize
from repro.models.transformer import lm_build
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import greedy_generate

lm_cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
params = materialize(lm_build(lm_cfg), jax.random.PRNGKey(0))
prompts = [rng.integers(0, lm_cfg.vocab, (6,)).astype(np.int32)
           for _ in range(3)]

eng = ContinuousBatcher(lm_cfg, params, n_slots=2, max_len=32,
                        progressive=True)
reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
        for i, p in enumerate(prompts)]
for r in reqs:
    eng.submit(r)
eng.run(max_steps=100)
stats = eng.stats()
print(f"  decoded {stats['tokens']} tokens | mean exit level "
      f"{stats['mean_exit_level']:.2f}/{stats['n_levels'] - 1} | "
      f"mean levels saved {stats['mean_levels_saved']:.2f}")
print(f"  exit-level histogram: {stats['exit_level_hist']}")

ref = np.asarray(greedy_generate(lm_cfg, params,
                                 jnp.asarray(prompts[0][None]), steps=5,
                                 max_len=32))[0].tolist()
print(f"  request 0 tokens {reqs[0].output} == full-precision greedy "
      f"{ref}: {reqs[0].output == ref}")
print(f"  prefill exit levels (streamed LAST-prompt-token head): "
      f"{[r.prefill_exit_level for r in reqs]}")
print("  (the early exits change how many levels were computed, never "
      "the tokens)")

# ------------------------------------- 4. wall-clock early exit
print("\n== early-exit scan: saved levels as saved wall-clock ==")
import time

from repro.core.progressive import streaming_argmax
from repro.models.protohead import prototype_head

# a decisive-margin classifier head (prototype columns), serving-sized
qc = QuantConfig()
xq, xs, w_q, _ = prototype_head(rng, k=2048, classes=64, rows=256, cfg=qc)

f_scan = jax.jit(lambda a, s: streaming_argmax(a, w_q.q, s, w_q.scale)[1:])
f_while = jax.jit(lambda a, s: streaming_argmax(a, w_q.q, s, w_q.scale,
                                                early_exit=True)[1:])


def bench(f, n=20):
    jax.block_until_ready(f(xq, xs))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(xq, xs))
    return (time.perf_counter() - t0) / n * 1e6


tok_s, lv_s = f_scan(xq, xs)
tok_w, lv_w = f_while(xq, xs)
assert (np.asarray(tok_s) == np.asarray(tok_w)).all()
assert (np.asarray(lv_s) == np.asarray(lv_w)).all()
us_scan, us_while = bench(f_scan), bench(f_while)
n_lv = 2 * qc.planes - 1
print(f"  batch exit level {int(np.asarray(lv_w).max())}/{n_lv - 1} "
      f"(mean {float(np.asarray(lv_w).mean()):.2f})")
print(f"  fixed scan {us_scan:8.1f} us | early-exit while "
      f"{us_while:8.1f} us | saved {100 * (1 - us_while / us_scan):.0f}%")
print("  (tokens and exit levels bit-identical — only the control flow "
      "changed)")
