"""Logical-axis -> mesh-axis rules (GSPMD sharding for params/opt/data).

Production meshes (launch/mesh.py):
  single pod : (data=16, model=16)            = 256 chips (v5e pod)
  multi pod  : (pod=2, data=16, model=16)     = 512 chips

Parameter logical axes used by the model zoo:
  vocab   — embedding/logit vocab dim      -> "model"
  embed   — the d_model residual dim       -> replicated (activations are
            batch/sequence-sharded instead; Megatron-style TP)
  qkv     — flattened heads*head_dim       -> "model"  (all assigned archs
            divide by 16 even when head counts do not)
  ffn     — MLP hidden / conv channels     -> "model"
  experts — MoE expert stack               -> "model"  (64/16, 128/16)
  layers  — scanned-stack leading axis     -> replicated (candidate for a
            future pipeline axis)

Optimizer state (AdamW m/v) additionally shards its largest replicated,
divisible dim over the vacant "data" axis (ZeRO-1) — without this a 27B
model's optimizer does not fit 16 GB/chip.
"""

from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import Param

__all__ = [
    "PARAM_RULES",
    "dp_axes",
    "batch_spec",
    "param_specs",
    "zero1_specs",
    "named",
    "logical_rules",
]

PARAM_RULES: dict[str, Any] = {
    "vocab": "model",
    "embed": None,
    "qkv": "model",
    "ffn": "model",
    "experts": "model",
    "layers": None,
}


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def logical_rules(mesh: Mesh) -> dict[str, Any]:
    return dict(PARAM_RULES)


def batch_spec(mesh: Mesh, batch_size: int) -> P:
    """Tokens/labels (B, S): batch over DP axes when divisible."""
    axes = dp_axes(mesh)
    size = math.prod(mesh.shape[a] for a in axes)
    if batch_size % size == 0:
        return P(axes, None)
    if batch_size % mesh.shape["data"] == 0:
        return P("data", None)
    return P(None, None)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def safe_spec(shape: tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop (replicate) any sharded dim the mesh does not divide — e.g.
    vocab 50280 (mamba2) / 51865 (whisper) are not 16-divisible — and
    dedupe mesh axes (MoE expert stacks map both 'experts' and 'ffn' to
    "model"; the leading dim — experts — wins)."""
    fixed = []
    used: set = set()
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is not None and dim % _axis_size(mesh, ax) != 0:
            ax = None
        if ax is not None:
            key = tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)
            if used & set(key):
                ax = None
            else:
                used |= set(key)
        fixed.append(ax)
    return P(*fixed)


def param_specs(desc_tree, mesh: Mesh):
    rules = logical_rules(mesh)

    def f(p: Param):
        spec = P(*(rules.get(a, None) if a is not None else None for a in p.axes))
        return safe_spec(p.shape, spec, mesh)

    return jax.tree.map(f, desc_tree, is_leaf=lambda x: isinstance(x, Param))


def zero1_specs(desc_tree, mesh: Mesh):
    """Optimizer-state specs: param spec + 'data' on the largest
    still-replicated dim that divides (ZeRO-1 optimizer sharding)."""
    rules = logical_rules(mesh)
    dsize = mesh.shape["data"]

    def f(p: Param):
        base = safe_spec(
            p.shape,
            P(*(rules.get(a, None) if a is not None else None for a in p.axes)),
            mesh,
        )
        spec = list(base)
        # pick the largest unsharded, divisible dim
        best, best_dim = None, 0
        for i, (dim, s) in enumerate(zip(p.shape, spec)):
            if s is None and dim % dsize == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best is not None:
            spec[best] = "data"
        return P(*spec)

    return jax.tree.map(f, desc_tree, is_leaf=lambda x: isinstance(x, Param))


def named(mesh: Mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
