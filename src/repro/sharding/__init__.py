from .axes import (PARAM_RULES, dp_axes, batch_spec, param_specs, zero1_specs,
                   named, logical_rules, safe_spec)
from . import ctx
