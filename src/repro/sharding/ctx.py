"""Optional module-level mesh context for intra-model sharding hints.

GSPMD propagates most shardings from the parameter/in-out specs, but a
few interior tensors (MoE dispatch buffers, router state) propagate badly
— the baseline deepseek-moe cell is 12x collective-bound because of it.
When a mesh is installed here, `hint(x, *spec)` pins those tensors;
without one it is an identity, so single-device tests and the baseline
dry-run sweeps are unaffected.

The installed mesh is also what routes the L2R serving stack onto its
sharded paths: `core/progressive.py:streaming_argmax` switches to the
``shard_map``ped consensus level walk, `quantize_weights(..., shard=)`
pins the cached weight plane stacks, and `ContinuousBatcher` places its
slot state with `serve.engine.state_specs`.  A mesh leaked from one test
silently changes all of that in later tests, so the test suite restores
``set_mesh(None)`` after every test (tests/conftest.py autouse fixture).
"""

from __future__ import annotations

import contextlib
import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Mesh | None = None
_HINTS_ENABLED: bool = True

__all__ = ["set_mesh", "get_mesh", "hint", "hint_dp", "hint_uneven",
           "hints_disabled", "mesh_axis_size", "safe_axes", "constrain"]


def set_mesh(mesh: Mesh | None):
    global _MESH
    _MESH = mesh


def get_mesh() -> Mesh | None:
    return _MESH


@contextlib.contextmanager
def hints_disabled():
    """Trace-scoped off-switch for the interior hints: inside this
    context `hint` / `hint_dp` / `hint_uneven` are identities even with
    a mesh installed, while `get_mesh()` (and everything routed off it —
    the sharded consensus head walk, the weight-cache sharding) still
    sees the mesh.

    Why it exists: the interior hints were built for the GSPMD
    training/MoE paths, where activations are genuinely distributed.  A
    REPLICATED backbone (the progressive serving default) gains nothing
    from them — worse, pinning interior tensors of a replicated
    computation onto model axes makes GSPMD repartition float
    contractions (observed: the attention o-projection over the
    hint-sharded flattened-heads axis), which reassociates sums and
    breaks bit-parity with the unmeshed trace.  The serving step
    factories trace the backbone under this context when the state is
    replicated (engine.make_prefill_step / make_decode_step
    ``backbone_hints=False``)."""
    global _HINTS_ENABLED
    prev = _HINTS_ENABLED
    _HINTS_ENABLED = False
    try:
        yield
    finally:
        _HINTS_ENABLED = prev


def mesh_axis_size(mesh: Mesh, axis) -> int:
    """Total size of a mesh axis entry (name, tuple of names, or None)."""
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def _check_spec_rank(x: jax.Array, spec: tuple, fn: str) -> None:
    """A spec longer than the operand rank used to be silently
    zip-truncated (the trailing entries were dropped with no error — the
    same bug class as the `pad_to` rank fix): raise with the shapes."""
    if len(spec) > x.ndim:
        raise ValueError(
            f"{fn}: spec {spec!r} has {len(spec)} entries but x has rank "
            f"{x.ndim} (shape {x.shape}); a spec must not name more dims "
            f"than the operand has — extra entries used to be silently "
            f"dropped")


def safe_axes(mesh: Mesh, shape: tuple[int, ...], spec: tuple) -> tuple:
    """Per-dim mesh axes of ``spec`` with unknown axis names dropped and
    non-divisible dims replicated — the pure (explicit-mesh) core of
    :func:`hint`, shared by the weight-cache sharding in core/quant.py
    (which must not read the module global: its jit cache keys on the
    mesh argument instead)."""
    fixed = []
    for dim, ax in zip(shape, spec + (None,) * (len(shape) - len(spec))):
        if ax is not None and isinstance(ax, (tuple, list)):
            ax = tuple(a for a in ax if a in mesh.axis_names) or None
        if ax is not None and not isinstance(ax, (tuple, list)) \
                and ax not in mesh.axis_names:
            ax = None
        fixed.append(ax if ax is None or dim % mesh_axis_size(mesh, ax) == 0
                     else None)
    return tuple(fixed)


def constrain(x: jax.Array, mesh: Mesh | None, *spec) -> jax.Array:
    """with_sharding_constraint against an EXPLICIT mesh (identity when
    ``mesh`` is None), with the divisibility/unknown-axis guards of
    :func:`hint`.  Callers whose jit caches must key on the mesh (the
    load-time weight caches) use this instead of the module context."""
    if mesh is None:
        return x
    _check_spec_rank(x, spec, "constrain")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*safe_axes(mesh, x.shape, spec))))


def hint(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint(x, P(*spec)) when a mesh is installed
    (and hints are not scoped off — see :func:`hints_disabled`);
    non-divisible dims are silently replicated.  A spec longer than the
    operand rank raises (it used to be silently zip-truncated)."""
    if _MESH is None or not _HINTS_ENABLED:
        return x
    return constrain(x, _MESH, *spec)


def hint_dp(x: jax.Array) -> jax.Array:
    """Shard dim 0 over the data-parallel axes."""
    if _MESH is None or not _HINTS_ENABLED:
        return x
    dp = tuple(a for a in ("pod", "data") if a in _MESH.axis_names)
    return hint(x, dp)


def hint_uneven(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint WITHOUT the divisibility guard: GSPMD
    pads uneven tiles (e.g. 10 KV heads over a 16-way axis).  Used to
    head-shard attention where head counts do not divide the mesh.  The
    rank check still applies — an overlong spec is a bug, not padding."""
    if _MESH is None or not _HINTS_ENABLED:
        return x
    _check_spec_rank(x, spec, "hint_uneven")
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, P(*spec)))
