"""Optional module-level mesh context for intra-model sharding hints.

GSPMD propagates most shardings from the parameter/in-out specs, but a
few interior tensors (MoE dispatch buffers, router state) propagate badly
— the baseline deepseek-moe cell is 12x collective-bound because of it.
When a mesh is installed here, `hint(x, *spec)` pins those tensors;
without one it is an identity, so single-device tests and the baseline
dry-run sweeps are unaffected.
"""

from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Mesh | None = None

__all__ = ["set_mesh", "get_mesh", "hint", "hint_dp"]


def set_mesh(mesh: Mesh | None):
    global _MESH
    _MESH = mesh


def get_mesh() -> Mesh | None:
    return _MESH


def _axis_size(axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return math.prod(_MESH.shape[a] for a in axis)
    return _MESH.shape[axis]


def hint(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint(x, P(*spec)) when a mesh is installed;
    non-divisible dims are silently replicated."""
    if _MESH is None:
        return x
    fixed = []
    for dim, ax in zip(x.shape, spec + (None,) * (len(x.shape) - len(spec))):
        if ax is not None and isinstance(ax, (tuple, list)):
            ax = tuple(a for a in ax if a in _MESH.axis_names) or None
        if ax is not None and not isinstance(ax, (tuple, list)) \
                and ax not in _MESH.axis_names:
            ax = None
        fixed.append(ax if ax is None or dim % _axis_size(ax) == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, P(*fixed)))


def hint_dp(x: jax.Array) -> jax.Array:
    """Shard dim 0 over the data-parallel axes."""
    if _MESH is None:
        return x
    dp = tuple(a for a in ("pod", "data") if a in _MESH.axis_names)
    return hint(x, dp)


def hint_uneven(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint WITHOUT the divisibility guard: GSPMD
    pads uneven tiles (e.g. 10 KV heads over a 16-way axis).  Used to
    head-shard attention where head counts do not divide the mesh."""
    if _MESH is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, P(*spec)))
