"""granite-8b [dense] — llama-arch, code.

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152
[arXiv:2405.04324; hf].  Pure full attention -> long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    head_dim=128,
    d_ff=14336,
    vocab=49_152,
    ffn_kind="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    ffn_kind="swiglu",
    compute_dtype="float32",
)
