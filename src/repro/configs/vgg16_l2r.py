"""VGG-16 + L2R-CIPU — the paper's own evaluation configuration.

Not an LM: selected via --arch vgg16-l2r in examples/benchmarks.  Bundles
the quantization config (n=8 bits, radix-4 digit planes — the TPU mapping
of the paper's bit-serial schedule) and the accelerator cycle/hw model
configuration used to reproduce Tables I/II.
"""

import dataclasses

from repro.core.cycle_model import AcceleratorConfig
from repro.core.quant import QuantConfig


@dataclasses.dataclass(frozen=True)
class VGG16L2RConfig:
    n_classes: int = 1000
    quant: QuantConfig = QuantConfig(n_bits=8, log2_radix=2)
    accel: AcceleratorConfig = AcceleratorConfig()
    levels: int | None = None  # None = exact; fewer = progressive precision


CONFIG = VGG16L2RConfig()
SMOKE = VGG16L2RConfig(n_classes=10)
