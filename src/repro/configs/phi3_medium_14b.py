"""phi3-medium-14b [dense] — RoPE SwiGLU GQA.

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352
[arXiv:2404.14219; unverified].  Pure full attention -> long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv=10,
    head_dim=128,
    d_ff=17920,
    vocab=100_352,
    ffn_kind="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="phi3-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=8,
    n_kv=2,
    head_dim=8,
    d_ff=160,
    vocab=512,
    ffn_kind="swiglu",
    tie_embeddings=False,
    compute_dtype="float32",
)
