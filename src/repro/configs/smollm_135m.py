"""smollm-135m [dense] — llama-arch small.

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M; hf].  Also the end-to-end training example
(examples/train_smollm.py).  Pure full attention -> long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv=3,
    head_dim=64,
    d_ff=1536,
    vocab=49_152,
    ffn_kind="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="smollm-smoke",
    family="dense",
    n_layers=6,
    d_model=96,
    n_heads=3,
    n_kv=1,
    head_dim=32,
    d_ff=256,
    vocab=512,
    ffn_kind="swiglu",
    compute_dtype="float32",
)
