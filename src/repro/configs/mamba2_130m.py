"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

24L d_model=768 d_ff=0 vocab=50280, ssm_state=128 [arXiv:2405.21060;
unverified].  Each layer is a Mamba-2 block (no separate MLP):
d_inner = 2*768 = 1536, head_dim 64 -> 24 SSD heads, conv width 4.
O(1) recurrent state -> runs long_500k.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=24,  # SSD heads (d_inner / ssm_head_dim); attention-free
    n_kv=24,
    d_ff=0,
    vocab=50_280,
    layer_pattern=("ssd",),
    ffn_pattern=("none",),
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    rope_mode="none",
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=8,
    n_kv=8,
    d_ff=0,
    vocab=512,
    layer_pattern=("ssd",),
    ffn_pattern=("none",),
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=16,
    rope_mode="none",
    compute_dtype="float32",
)
