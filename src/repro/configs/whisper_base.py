"""whisper-base [audio] — encoder-decoder, conv frontend (stub).

6L d_model=512 8H (kv=8 / MHA) d_ff=2048 vocab=51865 [arXiv:2212.04356;
unverified].  Per the assignment the mel+conv frontend is a STUB:
input_specs supplies precomputed frame embeddings (B, 1500, 512).
LayerNorm, GELU, learned positions, attention biases (whisper idioms).
Decoder is pure full attention -> long_500k skipped (and the enc-dec
task caps source length at 1500 frames).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,            # decoder layers
    encoder_layers=6,
    encoder_seq=1500,
    d_model=512,
    n_heads=8,
    n_kv=8,
    head_dim=64,
    d_ff=2048,
    vocab=51_865,
    ffn_kind="gelu",
    use_layer_norm=True,
    qkv_bias=True,
    rope_mode="none",
    norm_eps=1e-5,
    embeds_input=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="encdec",
    n_layers=2,
    encoder_layers=2,
    encoder_seq=24,
    d_model=64,
    n_heads=4,
    n_kv=4,
    head_dim=16,
    d_ff=128,
    vocab=512,
    ffn_kind="gelu",
    use_layer_norm=True,
    qkv_bias=True,
    rope_mode="none",
    embeds_input=True,
    compute_dtype="float32",
)
