"""Architecture registry: --arch <id> -> config, shapes, input specs.

The 10 assigned architectures x 4 shapes = 40 cells.  `long_500k`
requires sub-quadratic attention: it runs for the SSM/hybrid/mostly-local
archs and is a documented skip for the pure-full-attention ones
(DESIGN.md §4; EXPERIMENTS.md §Dry-run lists each skip).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

from . import (deepseek_moe_16b, gemma3_27b, granite_8b,
               llama4_maverick_400b_a17b, mamba2_130m, phi3_medium_14b,
               qwen2_vl_7b, recurrentgemma_2b, smollm_135m, whisper_base)

__all__ = ["ARCHS", "SHAPES", "get_config", "get_smoke", "input_specs",
           "cell_supported", "all_cells"]

_MODULES = {
    "recurrentgemma-2b": recurrentgemma_2b,
    "phi3-medium-14b": phi3_medium_14b,
    "smollm-135m": smollm_135m,
    "gemma3-27b": gemma3_27b,
    "granite-8b": granite_8b,
    "mamba2-130m": mamba2_130m,
    "llama4-maverick-400b-a17b": llama4_maverick_400b_a17b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "whisper-base": whisper_base,
    "qwen2-vl-7b": qwen2_vl_7b,
}

ARCHS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention over the 500k context:
_LONG_OK = {"recurrentgemma-2b", "mamba2-130m", "gemma3-27b"}
LONG_SKIP_REASON = (
    "pure full-attention decode over a 524288-token KV cache; assignment "
    "directs skip for non-SSM/hybrid/local archs (DESIGN.md §4)"
)


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _MODULES[arch].SMOKE


def cell_supported(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch not in _LONG_OK:
        return False, LONG_SKIP_REASON
    return True, ""


def all_cells():
    for a in ARCHS:
        for s in SHAPES:
            yield a, s, *cell_supported(a, s)


def input_specs(arch: str, shape: str, cfg: ModelConfig | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: the full token (or stub-embedding) batch;
    decode: the current token; the cache/state enters separately via
    serve.engine.abstract_state.
    """
    cfg = cfg or get_config(arch)
    sp = SHAPES[shape]
    b, s = sp.global_batch, sp.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    sd = jax.ShapeDtypeStruct

    if sp.kind == "decode":
        out = {"tokens": sd((b, 1), i32)}
        if cfg.rope_mode == "mrope":
            out["rope_positions"] = sd((3, b, 1), i32)
        return out

    if cfg.family == "encdec":
        return {
            "frames": sd((b, cfg.encoder_seq, cfg.d_model), bf16),
            "tokens": sd((b, s), i32),
            **({"labels": sd((b, s), i32)} if sp.kind == "train" else {}),
        }
    if cfg.embeds_input:  # vlm stub: precomputed patch/text embeddings
        out = {"embeds": sd((b, s, cfg.d_model), bf16)}
        if cfg.rope_mode == "mrope":
            out["rope_positions"] = sd((3, b, s), i32)
        if sp.kind == "train":
            out["labels"] = sd((b, s), i32)
        return out
    out = {"tokens": sd((b, s), i32)}
    if sp.kind == "train":
        out["labels"] = sd((b, s), i32)
    return out
