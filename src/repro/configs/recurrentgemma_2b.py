"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 pattern.

26L d_model=2560 10H (GQA kv=1 / MQA) d_ff=7680 vocab=256000
[arXiv:2402.19427; hf].  Pattern: (rec, rec, local-attn) repeated; local
window 2048; GeGLU MLP; head_dim 256; gemma-style embed scaling.
Bounded state (LRU + 2048-window KV) -> runs the long_500k cell.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv=1,
    head_dim=256,
    d_ff=7680,
    vocab=256_000,
    layer_pattern=("rec", "rec", "local"),
    window=2048,
    lru_width=2560,
    conv1d_width=4,
    ffn_kind="geglu",
    scale_embeddings=True,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv=1,
    head_dim=16,
    d_ff=128,
    vocab=512,
    layer_pattern=("rec", "rec", "local"),
    window=16,
    lru_width=64,
    ffn_kind="geglu",
    scale_embeddings=True,
    compute_dtype="float32",
)
