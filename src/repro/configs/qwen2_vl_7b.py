"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution (frontend stub).

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064
[arXiv:2409.12191; hf].  Per the assignment the vision tower is a STUB:
input_specs supplies precomputed patch/text embeddings plus the 3-stream
M-RoPE position ids (temporal/height/width).  Pure full attention ->
long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv=4,
    head_dim=128,
    d_ff=18944,
    vocab=152_064,
    ffn_kind="swiglu",
    rope_mode="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    embeds_input=True,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen2vl-smoke",
    family="vlm",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    ffn_kind="swiglu",
    rope_mode="mrope",
    mrope_sections=(4, 2, 2),
    qkv_bias=True,
    embeds_input=True,
    tie_embeddings=False,
    compute_dtype="float32",
)
