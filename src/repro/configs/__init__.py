from .registry import (ARCHS, SHAPES, all_cells, cell_supported, get_config,
                       get_smoke, input_specs)

__all__ = ["ARCHS", "SHAPES", "all_cells", "cell_supported", "get_config",
           "get_smoke", "input_specs"]
