"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained.

28L d_model=2048 16H (GQA kv=16 / MHA) d_ff=1408 vocab=102400, MoE 64e
top-6 [arXiv:2401.06066; hf].  Layer 0 is a dense MLP (hidden 10944, the
published config); layers 1..27 are MoE with 2 shared experts.
Pure full attention -> long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    head_dim=128,
    d_ff=1408,          # assigned: per-expert hidden
    vocab=102_400,
    ffn_kind="swiglu",
    ffn_pattern=("moe",),
    first_k_dense=1,
    dense_d_ff=10944,
    n_experts=64,
    experts_per_token=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    rope_theta=10_000.0,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="deepseek-smoke",
    family="moe",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv=4,
    head_dim=16,
    d_ff=48,
    vocab=512,
    ffn_kind="swiglu",
    ffn_pattern=("moe",),
    first_k_dense=1,
    dense_d_ff=192,
    n_experts=8,
    experts_per_token=2,
    n_shared_experts=2,
    moe_d_ff=48,
    tie_embeddings=False,
    compute_dtype="float32",
)
