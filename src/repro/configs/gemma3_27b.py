"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144
[hf:google/gemma-3-1b-pt; unverified].  head_dim = d_model//n_heads = 168
(assignment convention).  5 sliding-window layers (1024) per global
layer; only ~1/6 of layers hold full-length KV -> runs long_500k.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv=16,
    head_dim=168,
    d_ff=21504,
    vocab=262_144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    ffn_kind="geglu",
    scale_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    n_layers=8,
    d_model=96,
    n_heads=4,
    n_kv=2,
    head_dim=24,
    d_ff=192,
    vocab=512,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=16,
    ffn_kind="geglu",
    scale_embeddings=True,
    compute_dtype="float32",
)
