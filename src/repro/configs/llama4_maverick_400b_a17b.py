"""llama4-maverick-400b-a17b [moe] — MoE top-1 + shared expert, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].  Llama-4 interleaves
MoE every other layer (dense layers use d_ff 16384); each MoE layer has
128 routed experts (top-1, d_ff 8192) + 1 shared expert.  Totals ~400B
params / ~17B active.  Pure full attention -> long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    head_dim=128,
    d_ff=8192,          # assigned: per-expert hidden
    vocab=202_048,
    ffn_kind="swiglu",
    ffn_pattern=("mlp", "moe"),  # interleave_moe_layer_step = 2
    dense_d_ff=16384,
    n_experts=128,
    experts_per_token=1,
    n_shared_experts=1,
    moe_d_ff=8192,
    rope_theta=500_000.0,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="llama4-smoke",
    family="moe",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=96,
    vocab=512,
    ffn_kind="swiglu",
    ffn_pattern=("mlp", "moe"),
    dense_d_ff=192,
    n_experts=8,
    experts_per_token=1,
    n_shared_experts=1,
    moe_d_ff=96,
    tie_embeddings=False,
    compute_dtype="float32",
)
