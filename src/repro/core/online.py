"""Most-significant-digit-first (MSDF / left-to-right) schedules.

The composite unit of the paper streams one partial-product term
PP_{i,j} = sum_k A_{k,i} * B_{k,j} per cycle, most significant first.  At
digit-plane granularity the stream is over plane pairs (i, j); the
significance of a pair is s = i + j (weight radix**s).  The *online*
property is that after consuming the pairs with the highest significance
levels, the remaining (unseen) tail has a strictly bounded magnitude, so
most-significant output digits can be emitted early.
"""

from __future__ import annotations

from typing import List, Tuple

__all__ = [
    "msdf_pairs",
    "msdf_levels",
    "msdf_level_slices",
    "tail_bound",
    "online_delay",
]


def msdf_levels(planes: int) -> List[int]:
    """Significance levels s = i + j in MSDF (descending) order."""
    return list(range(2 * planes - 2, -1, -1))


def msdf_pairs(planes: int, levels: int | None = None) -> List[Tuple[int, int]]:
    """Plane-pair schedule in MSDF order.

    Pairs (i, j) are emitted grouped by descending significance s = i + j;
    within a level, descending i (arbitrary but fixed — matches the
    paper's row-major walk of the partial product array transposed to
    MSDF order).  ``levels`` truncates to the first `levels` significance
    levels (the progressive-precision prefix).
    """
    out: List[Tuple[int, int]] = []
    lv = msdf_levels(planes)
    if levels is not None:
        lv = lv[:levels]
    for s in lv:
        for i in range(min(s, planes - 1), -1, -1):
            j = s - i
            if j < 0 or j >= planes:
                continue
            out.append((i, j))
    return out


def msdf_level_slices(
    planes: int, levels: int | None = None
) -> List[Tuple[int, int, int]]:
    """Level-stacked schedule: ``[(s, i_lo, i_hi)]`` in MSDF order.

    Significance level ``s`` fuses every plane pair ``(i, s - i)`` for
    ``i in [i_lo, i_hi]`` into ONE contraction: because the pair index
    range at a fixed level is *contiguous* in ``i`` (and hence in
    ``j = s - i``), the level's operands are contiguous slices of the
    K-stacked plane tensors (quant.py:stack_planes_lhs/rhs) and the D²
    pair matmuls of :func:`msdf_pairs` collapse to 2D-1 level matmuls.
    ``levels`` truncates identically to :func:`msdf_pairs` — the same
    pair set is processed, so truncated results are bit-identical.
    """
    out: List[Tuple[int, int, int]] = []
    lv = msdf_levels(planes)
    if levels is not None:
        lv = lv[:levels]
    for s in lv:
        out.append((s, max(0, s - planes + 1), min(s, planes - 1)))
    return out


def tail_bound(
    planes: int,
    levels_done: int,
    log2_radix: int,
    k: int,
    signed: bool = True,
) -> int:
    """Upper bound on |sum of unprocessed plane-pair products|.

    After the first ``levels_done`` significance levels, the unseen tail is
      sum_{s < s_min} n_pairs(s) * dmax_i * dmax_j * k * radix**s
    with dmax = radix - 1 for unsigned planes (the signed top plane has
    magnitude <= radix/2 <= radix-1, so this is a valid upper bound).
    ``k`` is the contraction (inner-product) length.
    """
    r = 1 << log2_radix
    dmax = r - 1
    s_min = 2 * planes - 1 - levels_done  # smallest processed level
    bound = 0
    for s in range(0, s_min):
        n_pairs = sum(
            1
            for i in range(planes)
            if 0 <= s - i < planes
        )
        bound += n_pairs * dmax * dmax * k * (r ** s)
    return bound


def online_delay(n_bits: int, log2_radix: int) -> int:
    """Steps before the first output digit is guaranteed stable.

    Digit-level analogue of the paper's delta_Mult: the first MS output
    digit of the product is stable once the unseen tail is smaller than
    the weight of that digit.  For the plane-pair stream this is the
    number of levels L such that tail_bound < radix**(2*planes - 1 - L)
    ... resolved numerically for k = 1.
    """
    planes = n_bits // log2_radix
    r = 1 << log2_radix
    for lv in range(1, 2 * planes):
        top_weight = r ** (2 * planes - 1 - lv)
        if tail_bound(planes, lv, log2_radix, k=1) < top_weight:
            return lv
    return 2 * planes - 1
