"""Cycle-exact performance model of the L2R-CIPU accelerator (paper §II-B).

Implements the paper's cycle formula

  Cycle_P = (n^2 + delta_Mult) * (k*k + ceil(N/T_n))
            * ceil(R*C / (T_r*T_c)) * ceil(M/T_m)

for the proposed design, and the corresponding count for the conventional
right-to-left bit-serial baseline (computation pattern of Loom [3]): both
operands bit-serial -> n_a * n_w cycles per multiplication, and — the
bottleneck the paper attacks — **no digit-level overlap** between the
multiplier, the reduction tree and the accumulator, which serializes the
4 pipeline stages into delta_IP(baseline) = 4 * n^2 = (2n)^2 cycles per
SOP wave (this reproduces the paper's printed 14.40 GOPS baseline peak
exactly; see DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List

__all__ = [
    "ConvLayer",
    "AcceleratorConfig",
    "VGG16_CONV_LAYERS",
    "sop_latency_l2r",
    "sop_latency_baseline",
    "layer_cycles",
    "network_cycles",
    "peak_gops",
    "effective_gops",
    "inference_seconds",
]


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    name: str
    R: int  # output rows
    C: int  # output cols
    N: int  # input channels
    M: int  # output channels
    k: int = 3  # kernel size

    @property
    def macs(self) -> int:
        return self.R * self.C * self.M * self.N * self.k * self.k

    @property
    def ops(self) -> int:
        return 2 * self.macs


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """Paper's configuration: 8x8 PE array, T_n=8 channels, T_m=1."""

    n_bits: int = 8
    delta_mult: int = 11  # online delay of mult + reduction pipe (calibrated, DESIGN.md §7)
    T_n: int = 8
    T_r: int = 8
    T_c: int = 8
    T_m: int = 1
    k: int = 3
    freq_hz: float = 400e6

    @property
    def macs_per_pe(self) -> int:
        return self.k * self.k * self.T_n  # 72

    @property
    def pes(self) -> int:
        return self.T_r * self.T_c  # 64


# VGG-16 convolutional body (224x224 ImageNet input), layer = post-conv map.
VGG16_CONV_LAYERS: List[ConvLayer] = [
    ConvLayer("conv1_1", 224, 224, 3, 64),
    ConvLayer("conv1_2", 224, 224, 64, 64),
    ConvLayer("conv2_1", 112, 112, 64, 128),
    ConvLayer("conv2_2", 112, 112, 128, 128),
    ConvLayer("conv3_1", 56, 56, 128, 256),
    ConvLayer("conv3_2", 56, 56, 256, 256),
    ConvLayer("conv3_3", 56, 56, 256, 256),
    ConvLayer("conv4_1", 28, 28, 256, 512),
    ConvLayer("conv4_2", 28, 28, 512, 512),
    ConvLayer("conv4_3", 28, 28, 512, 512),
    ConvLayer("conv5_1", 14, 14, 512, 512),
    ConvLayer("conv5_2", 14, 14, 512, 512),
    ConvLayer("conv5_3", 14, 14, 512, 512),
]


def sop_latency_l2r(cfg: AcceleratorConfig) -> int:
    """delta_IP of the composite unit: n^2 partial-product cycles plus the
    online delay of the multiplier/compressor pipeline."""
    return cfg.n_bits**2 + cfg.delta_mult


def sop_latency_baseline(cfg: AcceleratorConfig) -> int:
    """Loom-pattern [3] right-to-left bit-serial SOP latency: n_a*n_w
    bit-pair cycles with the four datapath stages (multiply, tree,
    accumulate, writeback) fully serialized — no online overlap."""
    return 4 * cfg.n_bits**2


def layer_cycles(layer: ConvLayer, cfg: AcceleratorConfig, l2r: bool = True) -> int:
    """Paper's Cycle_P for one conv layer."""
    delta_ip = sop_latency_l2r(cfg) if l2r else sop_latency_baseline(cfg)
    reduction_and_channels = cfg.k * cfg.k + math.ceil(layer.N / cfg.T_n)
    spatial_tiles = math.ceil((layer.R * layer.C) / (cfg.T_r * cfg.T_c))
    output_tiles = math.ceil(layer.M / cfg.T_m)
    return delta_ip * reduction_and_channels * spatial_tiles * output_tiles


def network_cycles(
    layers: List[ConvLayer] | None = None,
    cfg: AcceleratorConfig = AcceleratorConfig(),
    l2r: bool = True,
) -> int:
    layers = VGG16_CONV_LAYERS if layers is None else layers
    return sum(layer_cycles(l, cfg, l2r) for l in layers)


def peak_gops(cfg: AcceleratorConfig = AcceleratorConfig(), l2r: bool = True) -> float:
    """Peak throughput: all PEs streaming SOPs back-to-back.

    GOPS = PEs * (2 * MACs per SOP) / delta_IP * f.
    L2R (delta_mult=11): 49.15 GOPS (paper prints 48.97, Δ0.4%);
    baseline: 14.40 GOPS (exact match to Table II).
    """
    delta_ip = sop_latency_l2r(cfg) if l2r else sop_latency_baseline(cfg)
    ops_per_wave = cfg.pes * 2 * cfg.macs_per_pe
    return ops_per_wave * cfg.freq_hz / delta_ip / 1e9


def inference_seconds(
    layers: List[ConvLayer] | None = None,
    cfg: AcceleratorConfig = AcceleratorConfig(),
    l2r: bool = True,
    n_tiles: int = 1,
) -> float:
    """Wall time for one inference on ``n_tiles`` parallel network tiles."""
    return network_cycles(layers, cfg, l2r) / n_tiles / cfg.freq_hz


def effective_gops(
    layers: List[ConvLayer] | None = None,
    cfg: AcceleratorConfig = AcceleratorConfig(),
    l2r: bool = True,
) -> float:
    layers = VGG16_CONV_LAYERS if layers is None else layers
    ops = sum(l.ops for l in layers)
    return ops / inference_seconds(layers, cfg, l2r) / 1e9
