"""Digit-serial (L2R) attention score walks over plane-stacked operands.

Attention's QK^T is a batch of inner products — exactly the contraction
the paper's composite unit streams most-significant-digit first.  This
module maps the GEMM schedules of core/l2r_gemm.py onto the attention
score layout: queries are the LHS (ascending plane stack on the head
dim), cached keys the RHS (descending stack on the head dim, the
``PlaneOperands.prepare_rhs(axis=-1)`` layout the incrementally stacked
KV cache of models/attention.py maintains), and every significance level
is one GQA einsum ``"bqkgd,bskd->bkgqs"`` over a contiguous slice pair.

Three entry points, one arithmetic:

* :func:`attn_scores_stacked` — 2D-1 fused level passes (the oracle and
  the default schedule), bit-identical at every ``levels`` truncation to
  the plane-pair decomposition.
* :func:`attn_scores_streaming_scan` — per-level prefix emitter with the
  same fold API as core/progressive.py: every prefix bit-identical to
  the truncated stacked schedule (same fixed-window trick — both stacks
  zero-padded by D-1 blocks, out-of-range pairs hit zeros).
* :func:`attn_scores_streaming_while` — the early-exit ``lax.while_loop``
  form: stops once the consumer's decision fold says every score row is
  decided (models/attention.py uses it for margin-bounded progressive
  decode attention).

Quantization is per *vector*: each query row and each cached key slot
carries its own scale (:func:`quantize_per_vector` — the one formula of
core/quant.py:_symmetric_quant), so scales commute with the score
contraction and incremental cache updates are chunking-independent.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .l2r_gemm import _f32_dot_exact
from .online import msdf_level_slices
from .progressive import _level_walk, _while_emitter
from .quant import (PlaneOperands, QuantConfig, _symmetric_quant,
                    plane_count, stack_planes_lhs, stack_planes_rhs)

__all__ = [
    "quantize_per_vector",
    "attn_scores_stacked",
    "attn_scores_streaming_scan",
    "attn_scores_streaming_while",
]


def quantize_per_vector(x: jax.Array, cfg: QuantConfig):
    """Symmetric quantization with one scale per trailing vector.

    x (..., K) -> (q (..., K) int, scale (..., 1) f32).  Used for both
    sides of the score walk: per-query-row scales (LHS) and per-key-slot
    scales (RHS) both broadcast against the (..., Q, S) score matrix, so
    the int accumulator dequantizes exactly regardless of how the S axis
    is chunked or incrementally appended.  ``quantize``'s axis argument
    keeps only ONE axis for the scale; attention needs every leading
    axis kept, hence the direct :func:`_symmetric_quant` call (same
    formula — bit-identical scales).
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    return _symmetric_quant(xf, amax, cfg)


# --------------------------------------------------------------- operands
def _check_attn_operand(op, want_side: str, n_bits: int, log2_radix: int,
                        other) -> None:
    if not op.matches(n_bits, log2_radix, side=want_side, contract_axis=None):
        other_desc = other.describe() if isinstance(other, PlaneOperands) \
            else f"array(shape={tuple(other.shape)}, dtype={other.dtype})"
        raise ValueError(
            f"{op.describe()} cannot feed the {want_side} slot of an "
            f"attention score walk with n_bits={n_bits}, "
            f"log2_radix={log2_radix} (other operand: {other_desc}); "
            f"re-prepare the stack for this config")


def _attn_core_stacks(qq, kq, n_bits: int, log2_radix: int):
    """D-plane raw-digit core stacks for the stacked schedule.

    qq: (B, Q, Kv, G, dh) int or a prepared LHS :class:`PlaneOperands`;
    kq: (B, S, Kv, dh) int or a prepared RHS stack on axis -1 (the
    incrementally stacked KV cache).  Returns (q_stack, k_stack, dh).
    """
    if isinstance(qq, PlaneOperands):
        _check_attn_operand(qq, "lhs", n_bits, log2_radix, kq)
        q_stack, dh = qq.core_stack(shifted=False), qq.k
    else:
        dh = qq.shape[-1]
        q_stack = stack_planes_lhs(qq, n_bits, log2_radix, shifted=False)
    if isinstance(kq, PlaneOperands):
        _check_attn_operand(kq, "rhs", n_bits, log2_radix, qq)
        k_stack = kq.core_stack(shifted=False)
    else:
        k_stack = stack_planes_rhs(kq, n_bits, log2_radix, axis=-1,
                                   shifted=False)
    return q_stack, k_stack, dh


def _attn_window_stacks(qq, kq, n_bits: int, log2_radix: int):
    """Zero-padded (2D-1)-block stacks for the fixed-width streaming
    window (the attention analogue of progressive.py:_streaming_operands;
    a window-padded cache stack is consumed with NO padding copy)."""
    d = plane_count(n_bits, log2_radix)
    if isinstance(qq, PlaneOperands):
        _check_attn_operand(qq, "lhs", n_bits, log2_radix, kq)
        q_pad, dh = qq.window_stack(), qq.k
    else:
        dh = qq.shape[-1]
        q_stack = stack_planes_lhs(qq, n_bits, log2_radix, shifted=False)
        q_pad = jnp.pad(q_stack,
                        [(0, 0)] * (q_stack.ndim - 1) + [(0, (d - 1) * dh)])
    if isinstance(kq, PlaneOperands):
        _check_attn_operand(kq, "rhs", n_bits, log2_radix, qq)
        k_pad = kq.window_stack()
    else:
        k_rev = stack_planes_rhs(kq, n_bits, log2_radix, axis=-1,
                                 shifted=False)
        k_pad = jnp.pad(k_rev,
                        [(0, 0)] * (k_rev.ndim - 1) + [(0, (d - 1) * dh)])
    return q_pad, k_pad, dh


def _score_shape(qq, kq) -> tuple[int, ...]:
    qs = qq.stack.shape if isinstance(qq, PlaneOperands) else qq.shape
    ks = kq.stack.shape if isinstance(kq, PlaneOperands) else kq.shape
    b, q, kv, g = qs[:4]
    return (b, kv, g, q, ks[1])


def _level_einsum(a_l, b_l, use_f32: bool):
    t = jnp.einsum(
        "bqkgd,bskd->bkgqs", a_l, b_l,
        preferred_element_type=jnp.float32 if use_f32 else jnp.int32,
        # HIGHEST pins true-f32 accumulation (exact under the digit-
        # magnitude guard); DEFAULT could route through TF32/bf16
        precision=jax.lax.Precision.HIGHEST if use_f32 else None,
    )
    return t.astype(jnp.int32)


# --------------------------------------------------------- stacked schedule
def attn_scores_stacked(
    qq,
    kq,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
) -> jax.Array:
    """Level-stacked digit-serial QK^T: int32 scores (B, Kv, G, Q, S).

    qq: (B, Q, Kv, G, dh) signed ints (or a prepared LHS stack); kq:
    (B, S, Kv, dh) signed ints (or the cache's RHS stack on axis -1).
    With ``levels=None`` this equals the int32 einsum of the raw
    operands exactly; fewer levels give the MSDF progressive prefix,
    pair-set-identical to the pair decomposition (the GEMM schedules'
    truncation contract, core/online.py:msdf_level_slices).
    """
    d = plane_count(n_bits, log2_radix)
    q_stack, k_stack, dh = _attn_core_stacks(qq, kq, n_bits, log2_radix)
    slices = msdf_level_slices(d, levels)
    acc = jnp.zeros(_score_shape(qq, kq), jnp.int32)
    if not slices:  # levels=0: empty MSDF prefix
        return acc
    use_f32 = _f32_dot_exact(
        dh, max(hi - lo + 1 for _, lo, hi in slices), log2_radix)
    if use_f32:
        q_stack = q_stack.astype(jnp.float32)
        k_stack = k_stack.astype(jnp.float32)
    for (s, i_lo, i_hi) in slices:
        a_l = q_stack[..., i_lo * dh:(i_hi + 1) * dh]
        r0 = (d - 1 - s + i_lo) * dh
        b_l = k_stack[..., r0:r0 + (i_hi - i_lo + 1) * dh]
        acc = acc + (_level_einsum(a_l, b_l, use_f32) << (log2_radix * s))
    return acc


# ------------------------------------------------------- streaming emitters
def _attn_stream_setup(qq, kq, n_bits: int, log2_radix: int):
    """Per-level ``term(ao, bo)`` of the fixed-width attention window —
    the same closure contract as progressive.py:_stream_setup, so the
    scan and while control flows share identical arithmetic."""
    d = plane_count(n_bits, log2_radix)
    q_pad, k_pad, dh = _attn_window_stacks(qq, kq, n_bits, log2_radix)
    use_f32 = _f32_dot_exact(dh, d, log2_radix)
    if use_f32:
        q_pad = q_pad.astype(jnp.float32)
        k_pad = k_pad.astype(jnp.float32)
    w = d * dh

    def term(ao, bo):
        a_l = jax.lax.dynamic_slice_in_dim(q_pad, ao * dh, w,
                                           axis=q_pad.ndim - 1)
        b_l = jax.lax.dynamic_slice_in_dim(k_pad, bo * dh, w,
                                           axis=k_pad.ndim - 1)
        return _level_einsum(a_l, b_l, use_f32)

    return term


def attn_scores_streaming_scan(
    qq,
    kq,
    fold=None,
    init=None,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    emit: bool = False,
):
    """Scan the per-level MSDF score prefix stream.

    ``fold(carry, partial, level_index) -> carry`` consumes each int32
    score prefix (B, Kv, G, Q, S) as it is emitted; every prefix is
    bit-identical to :func:`attn_scores_stacked` truncated at that
    depth.  Returns ``(final_partial, final_fold_carry, stack_or_None)``
    (``emit=True`` also stacks the per-level prefixes — tests only).
    """
    d = plane_count(n_bits, log2_radix)
    a_off, b_off, svals = _level_walk(d, levels)
    n_steps = int(svals.shape[0])
    acc0 = jnp.zeros(_score_shape(qq, kq), jnp.int32)
    if n_steps == 0:
        empty = jnp.zeros((0, *acc0.shape), jnp.int32) if emit else None
        return acc0, init, empty

    term = _attn_stream_setup(qq, kq, n_bits, log2_radix)

    def step(carry, xs):
        acc, fold_c = carry
        ao, bo, s, idx = xs
        acc = acc + (term(ao, bo) << (log2_radix * s))
        if fold is not None:
            fold_c = fold(fold_c, acc, idx)
        return (acc, fold_c), (acc if emit else None)

    xs = (jnp.asarray(a_off), jnp.asarray(b_off), jnp.asarray(svals),
          jnp.arange(n_steps, dtype=jnp.int32))
    (acc, fold_c), ys = jax.lax.scan(step, (acc0, init), xs)
    return acc, fold_c, ys


def attn_scores_streaming_while(
    qq,
    kq,
    fold=None,
    init=None,
    done_fn=None,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
):
    """Early-exit streaming score walk: the SAME level walk as
    :func:`attn_scores_streaming_scan`, run as a ``lax.while_loop``
    that stops as soon as ``done_fn(fold_carry)`` is True (typically
    "every score row's max and normalizer are decided" — the
    margin-bounded progressive attention fold of models/attention.py).
    Identical per-level arithmetic -> the prefix after ``levels_run``
    iterations is bit-identical to the scan's, and so is the exit level.

    Returns ``(partial, fold_carry, levels_run)``.
    """
    d = plane_count(n_bits, log2_radix)
    a_off, b_off, svals = _level_walk(d, levels)
    n_steps = int(svals.shape[0])
    acc0 = jnp.zeros(_score_shape(qq, kq), jnp.int32)
    if n_steps == 0:
        return acc0, init, jnp.int32(0)

    term = _attn_stream_setup(qq, kq, n_bits, log2_radix)
    t, acc, fold_c = _while_emitter(term, a_off, b_off, svals, log2_radix,
                                    acc0, fold, init, done_fn)
    return acc, fold_c, t
