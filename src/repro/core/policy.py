"""Per-request precision classes: ONE decision fold for every streaming
walk.

Every early-exit consumer in the stack used to carry its own private
decision closure — the local head argmax (`streaming_argmax`), the
shard_mapped consensus walk (`_streaming_argmax_sharded`), and the
margin-bounded decode attention (`models/attention.py`) each re-derived
"has this row seen enough significance levels?" with slightly different
carries and done predicates.  This module is the single home of that
logic, and it generalizes the batch-global knobs (`levels`,
`early_exit`) into **per-row precision classes**:

  * ``exact``      — the row never early-commits; the walk runs full
                     depth for it and the committed value is the
                     full-precision fallback (bit-identical to the
                     legacy no-early-exit path).
  * ``budget(L)``  — the row force-commits at level L (index L-1): its
                     committed value is the argmax of the dequantized
                     prefix after L levels, bit-identical to a legacy
                     run truncated at ``levels=L`` (the tail bounds are
                     truncation-independent, so margin decisions before
                     the clamp are identical too).
  * ``bounded(tol)`` — margin early-exit: the row commits once the
                     top-1 lower confidence bound beats every other
                     entry's upper bound minus ``tol``.  ``tol=0`` is
                     the legacy early-exit walk bit for bit; ``tol>0``
                     trades up to ~``tol`` of score margin for earlier
                     exits.  In the attention walk ``tol`` is the
                     normalizer tolerance (the legacy ``exit_tol``).

A :class:`LevelPolicy` is a tiny pytree of per-row ``(mode, clamp,
tol)`` arrays; one mixed batch can therefore serve heterogeneous SLAs
inside ONE fused while loop — each row commits by its own rule, and the
loop stops at the slowest row's level (rows are decision-independent,
so a row's committed token/level never depends on its batch-mates).

:class:`PrecisionClass` is the host-side description (`Request`
carries one); ``LevelPolicy.from_classes`` turns a list of them into
device rows, and ``label()`` is the stable string key of the per-class
exit histograms in ``stats()``.

The fold builders:

  * :func:`head_walk_machinery` — the head-argmax fold shared by the
    local AND the shard_mapped consensus walk; the cross-shard
    reductions (pmax/pmin over ``model``, the early-exit consensus
    psum over the data axes) degrade to identities when no axis name
    is given, which is exactly the single-device walk.
  * :func:`attn_walk_machinery` — the decode-attention fold (max
    decided AND normalizer pinned); budget rows snapshot their int32
    score prefix at the clamp so their softmax sees exactly the
    ``levels=L`` scores even when batch-mates stream deeper.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MODE_EXACT",
    "MODE_BUDGET",
    "MODE_BOUNDED",
    "NO_CLAMP",
    "COLL_TAG_MAX",
    "COLL_TAG_MIN",
    "COLL_TAG_CONSENSUS",
    "PrecisionClass",
    "LevelPolicy",
    "decision_state",
    "policy_commit",
    "head_walk_machinery",
    "attn_walk_machinery",
]

# Named-collective tags: every cross-shard reduction the consensus walk
# declares is traced under one of these ``jax.named_scope``s, so the
# scope name lands in the jaxpr's ``source_info.name_stack`` AND the
# compiled HLO's ``metadata op_name``.  The sharding auditor
# (analysis/sharding.py) matches schedule to source through them — an
# all-reduce WITHOUT an l2r_coll tag in the partitioned module was
# inserted by GSPMD, not declared by the walk.
COLL_TAG_MAX = "l2r_coll_max"
COLL_TAG_MIN = "l2r_coll_min"
COLL_TAG_CONSENSUS = "l2r_coll_consensus"

MODE_EXACT = 0
MODE_BUDGET = 1
MODE_BOUNDED = 2
# BUDGET clamp sentinel for non-budget rows: larger than any level index
# the walk can reach, so `idx >= clamp - 1` never fires.  The policy
# deliberately does NOT know the stream depth — the same rows drive
# walks of any n_levels.
NO_CLAMP = 2**31 - 1


@dataclasses.dataclass(frozen=True)
class PrecisionClass:
    """Host-side precision class of one request (see module docstring).

    ``kind`` is "exact" | "budget" | "bounded"; ``levels`` is the budget
    clamp (levels of the walk the row pays for), ``tol`` the bounded
    margin slack in the scaled score domain.  Hashable and frozen: used
    as a stats key (via :meth:`label`) and safe as a jit static.
    """

    kind: str
    levels: int | None = None
    tol: float = 0.0

    def __post_init__(self):
        if self.kind not in ("exact", "budget", "bounded"):
            raise ValueError(f"unknown precision class kind: {self.kind!r}")
        if self.kind == "budget" and (self.levels is None or self.levels < 1):
            raise ValueError("budget class needs levels >= 1 "
                             f"(got {self.levels})")

    @classmethod
    def exact(cls) -> "PrecisionClass":
        return cls("exact")

    @classmethod
    def budget(cls, levels: int) -> "PrecisionClass":
        return cls("budget", levels=int(levels))

    @classmethod
    def bounded(cls, tol: float = 0.0) -> "PrecisionClass":
        return cls("bounded", tol=float(tol))

    def label(self) -> str:
        """Stable string key of the per-class exit histograms."""
        if self.kind == "exact":
            return "exact"
        if self.kind == "budget":
            return f"budget({self.levels})"
        return f"bounded({self.tol:g})"

    def row(self) -> tuple[int, int, float]:
        """(mode, clamp, tol) device-row values of this class."""
        if self.kind == "exact":
            return MODE_EXACT, NO_CLAMP, 0.0
        if self.kind == "budget":
            return MODE_BUDGET, int(self.levels), 0.0
        return MODE_BOUNDED, NO_CLAMP, float(self.tol)


class LevelPolicy(NamedTuple):
    """Per-row precision policy of one streaming walk (a pytree).

    mode:  (rows,) int32 — MODE_EXACT / MODE_BUDGET / MODE_BOUNDED.
    clamp: (rows,) int32 — budget rows force-commit at level index
           ``clamp - 1`` (i.e. after ``clamp`` levels); NO_CLAMP on
           other rows.
    tol:   (rows,) float32 — bounded rows' margin slack (head walk) /
           normalizer tolerance (attention walk); 0 elsewhere.

    Registered as a pytree (NamedTuple), so it rides through jit,
    shard_map in_specs, and ``.at[row].set`` slot splicing unchanged.
    """

    mode: jax.Array
    clamp: jax.Array
    tol: jax.Array

    # -------------------------------------------------- constructors
    @classmethod
    def from_classes(cls, classes) -> "LevelPolicy":
        rows = [c.row() for c in classes]
        mode = np.asarray([r[0] for r in rows], np.int32)
        clamp = np.asarray([r[1] for r in rows], np.int32)
        tol = np.asarray([r[2] for r in rows], np.float32)
        return cls(jnp.asarray(mode), jnp.asarray(clamp), jnp.asarray(tol))

    @classmethod
    def exact(cls, rows: int) -> "LevelPolicy":
        return cls.from_classes([PrecisionClass.exact()] * rows)

    @classmethod
    def budget(cls, levels: int, rows: int) -> "LevelPolicy":
        return cls.from_classes([PrecisionClass.budget(levels)] * rows)

    @classmethod
    def bounded(cls, rows: int, tol: float = 0.0) -> "LevelPolicy":
        return cls.from_classes([PrecisionClass.bounded(tol)] * rows)

    # -------------------------------------------------------- editing
    @property
    def rows(self) -> int:
        return int(self.mode.shape[0])

    def set_row(self, i: int, pc: PrecisionClass) -> "LevelPolicy":
        """Functional slot update (the batcher's admission/retirement
        splice): row ``i`` becomes class ``pc``."""
        m, c, t = pc.row()
        return LevelPolicy(self.mode.at[i].set(m),
                           self.clamp.at[i].set(c),
                           self.tol.at[i].set(t))

    def reshape(self, shape) -> "LevelPolicy":
        """Broadcast helper for non-(rows,) walks (decode attention
        reshapes to (B, 1, 1) against its (B, Kv, G) decision rows)."""
        return LevelPolicy(self.mode.reshape(shape),
                           self.clamp.reshape(shape),
                           self.tol.reshape(shape))


# ------------------------------------------------------ decision machinery
def decision_state(values: jax.Array, bvec: jax.Array):
    """Is the argmax of `values` invariant to any ±bvec perturbation?

    values: (..., N) scores; bvec: per-entry bound, broadcastable to
    values.  Decided iff the top-1 lower confidence bound strictly beats
    every other entry's upper bound.  Returns (decided (...,), argmax).
    """
    top = jnp.argmax(values, axis=-1)
    lb = values - bvec
    ub = values + bvec
    lb_top = jnp.take_along_axis(lb, top[..., None], axis=-1)[..., 0]
    ub_others = jnp.where(
        jax.nn.one_hot(top, values.shape[-1], dtype=bool), -jnp.inf, ub)
    return lb_top > jnp.max(ub_others, axis=-1), top.astype(jnp.int32)


def policy_commit(policy: LevelPolicy | None, decided, idx, done):
    """The one mode/clamp gate of every policy walk.

    ``decided`` is this level's margin decision per row, ``done`` the
    rows already committed.  Returns ``(newly, forced)``:

      * ``newly``  — rows committing BY MARGIN this level (exact rows
        are never eligible; with no policy every row is, which is the
        legacy batch-global walk);
      * ``forced`` — budget rows hitting their clamp this level without
        a margin decision (the caller commits them from the dequantized
        prefix — the truncated walk's fallback).

    The two are disjoint and both imply ``~done``.  Shapes follow
    ``decided`` (policy leaves must be broadcastable to it).
    """
    if policy is None:
        newly = decided & ~done
        return newly, jnp.zeros_like(newly)
    eligible = policy.mode != MODE_EXACT
    newly = decided & eligible & ~done
    forced = (policy.mode == MODE_BUDGET) & (idx >= policy.clamp - 1) \
        & ~done & ~newly
    return newly, forced


# --------------------------------------------------------- head argmax walk
def head_walk_machinery(bounds_f32, xsf, wsr, bias, out_dtype, *,
                        safety: float, n_levels: int, m_global: int,
                        n_total: int, policy: LevelPolicy | None = None,
                        early_exit: bool = False, model_ax: str | None = None,
                        dp: tuple = ()):
    """The head-argmax decision fold — local and sharded are ONE fold.

    Returns ``(fold, init, done_fn, finalize)`` for the streaming
    emitters (`streaming_matmul_scan` / `streaming_matmul_while`):
    ``fold`` carries ``(tok, lv, done, all_done)``, ``done_fn`` reads
    the consensus scalar, ``finalize(acc, carry)`` dequantizes exactly
    like ``l2r_matmul_f`` and falls undecided rows back to the full
    argmax, returning ``(logits, tok, lv)``.

    ``xsf``/``wsr``/``bias`` are the LOCAL (per-shard) scale/bias
    arrays; ``model_ax``/``dp`` name the mesh axes of the consensus
    walk.  With no axis names every cross-shard reduction is the
    identity and the early-exit consensus is a local ``sum(done) ==
    m_global`` — exactly the single-device walk (``jnp.all(done)``).
    The per-level decision is the masked own/others form of
    :func:`decision_state` (one finite entry per side), reduced with
    pmax/pmin when sharded — bit-identical either way.

    Per-row policy semantics (see module docstring): bounded rows
    widen the margin test by their ``tol``; budget rows force-commit at
    their clamp from the ``out_dtype`` round-trip of the prefix (the
    SAME dequantization the truncated walk's fallback argmax sees, so
    ``budget(L)`` == ``levels=L`` bit for bit); exact rows never set
    ``done`` — the loop runs full depth for them and ``finalize``
    commits the full-precision fallback.
    """
    m_l = xsf.shape[0]
    n_l = wsr.shape[-1]
    # |fl(v) - v| <= ~3 ulp(|v|) across the cast + two scale products and
    # the bias add; 8 ulp of the row max is a comfortable envelope
    eps = 8.0 * jnp.finfo(jnp.float32).eps
    off = (jax.lax.axis_index(model_ax) * n_l if model_ax
           else jnp.int32(0))
    col = off + jnp.arange(n_l, dtype=jnp.int32)

    def vmax_all(v):  # exact: max commutes/associates exactly
        if not model_ax:
            return v
        with jax.named_scope(COLL_TAG_MAX):
            return jax.lax.pmax(v, model_ax)

    def vmin_all(v):
        if not model_ax:
            return v
        with jax.named_scope(COLL_TAG_MIN):
            return jax.lax.pmin(v, model_ax)

    def gmax_first(vals):
        """(global max, FIRST global index achieving it) — exactly
        ``jnp.argmax``'s value and tie-break on the unsharded row."""
        vmax_l = jnp.max(vals, axis=-1)
        amax_l = jnp.argmax(vals, axis=-1).astype(jnp.int32) + off
        vmax = vmax_all(vmax_l)
        cand = jnp.where(vmax_l == vmax, amax_l, jnp.int32(n_total))
        return vmax, vmin_all(cand)

    def dequant_roundtrip(partial):
        """The l2r_matmul_f dequantization: f32 product, output cast,
        back to f32 for the argmax — the bit pattern every fallback
        (and every budget clamp commit) must reproduce."""
        logits = (partial.astype(jnp.float32) * xsf * wsr).astype(out_dtype)
        full = logits.astype(jnp.float32)
        if bias is not None:
            logits = logits + bias.astype(logits.dtype)
            full = full + bias.astype(jnp.float32)
        return logits, full

    def fold(carry, partial, idx):
        tok, lv, done, _ = carry
        values = partial.astype(jnp.float32) * xsf * wsr
        if bias is not None:
            values = values + bias.astype(jnp.float32)
        vmax_abs = vmax_all(jnp.max(jnp.abs(values), axis=-1,
                                    keepdims=True))
        bvec = bounds_f32[idx] * xsf * wsr * (1.0 + safety) + eps * vmax_abs
        _, gtop = gmax_first(values)
        own = col[None, :] == gtop[:, None]
        # decision_state on the (possibly sharded) row: lb of the owned
        # winner, ub of everything else — the same single masked entry
        lb_top = vmax_all(jnp.max(
            jnp.where(own, values - bvec, -jnp.inf), axis=-1))
        ub_others = vmax_all(jnp.max(
            jnp.where(own, -jnp.inf, values + bvec), axis=-1))
        if policy is None:
            decided = lb_top > ub_others
        else:
            # bounded rows trade up to `tol` of margin for earlier exits
            # (tol=0 rows reproduce the strict test bit for bit)
            decided = lb_top > ub_others - policy.tol
        newly, forced = policy_commit(policy, decided, idx, done)
        tok = jnp.where(newly, gtop, tok)
        if policy is not None:
            # budget clamp: commit the row from the out_dtype round-trip
            # of THIS prefix — the value a levels=clamp run's fallback
            # argmax would commit
            _, full = dequant_roundtrip(partial)
            _, ftok = gmax_first(full)
            tok = jnp.where(forced, ftok, tok)
        commit = newly | forced
        lv = jnp.where(commit, idx, lv)
        done = done | commit
        # the consensus scalar is only read by the while loop's done_fn;
        # the fixed scan must not pay a per-level psum for a flag nobody
        # reads (loop-carried values are not DCE'd)
        if early_exit:
            n_done = jnp.sum(done.astype(jnp.int32))
            if dp:
                with jax.named_scope(COLL_TAG_CONSENSUS):
                    n_done = jax.lax.psum(n_done, dp)
            all_done = n_done == m_global
        else:
            all_done = jnp.bool_(False)
        return tok, lv, done, all_done

    init = (jnp.zeros((m_l,), jnp.int32),
            jnp.full((m_l,), max(n_levels - 1, 0), jnp.int32),
            jnp.zeros((m_l,), bool),
            jnp.bool_(False))

    def done_fn(carry):
        return carry[3]

    def finalize(acc, carry):
        # dequantize exactly like l2r_matmul_f: f32 product, then output
        # cast.  Whenever an undecided row exists the loop exhausted its
        # stream (undecided rows hold `all_done` False), so `acc` IS the
        # full (or levels-truncated) result — the fallback argmax is
        # identical on both control flows.
        tok, lv, done, _ = carry
        logits, full = dequant_roundtrip(acc)
        _, fallback = gmax_first(full)
        tok = jnp.where(done, tok, fallback)
        return logits, tok, lv

    return fold, init, done_fn, finalize


# ------------------------------------------------------ decode attention walk
def attn_walk_machinery(bounds_f32, dequant, valid_b, scale_row, *,
                        rows_shape: tuple, n_levels: int,
                        safety: float = 1e-5, exit_tol: float = 1e-4,
                        policy: LevelPolicy | None = None,
                        score_shape: tuple | None = None):
    """The decode-attention decision fold (models/attention.py).

    ``dequant(partial)`` maps the int32 score prefix (B, Kv, G, 1, S)
    to scaled scores; ``valid_b`` is the (B, 1, 1, 1, S) slot-validity
    mask; ``scale_row`` the (broadcastable) per-entry scale product
    ``q_scale * k_scale * softmax_scale`` on the (B, Kv, G, S) row
    layout; ``rows_shape`` = (B, Kv, G), the decision rows.

    A row is decided when BOTH its running max is invariant to the tail
    (:func:`decision_state`) and its normalizer is pinned (every
    unmasked score known to within the tolerance — the per-row ``tol``
    for bounded policy rows, ``exit_tol`` otherwise).  Returns ``(fold,
    init, done_fn)``; without a policy the carry is the legacy
    ``(done, lv)``, with one it is ``(done, lv, forced, s_commit)``
    where budget rows SNAPSHOT their int32 prefix at the clamp —
    ``jnp.where(forced[..., None, None], s_commit, acc)`` then feeds
    softmax the exact ``levels=clamp`` scores even when batch-mates
    stream deeper.  Bounded rows keep the legacy batch-coupled
    semantics (softmax over the prefix at the GLOBAL stop level): their
    guarantee is the decision, not the score bits, so serving them
    alone can stop the loop earlier and move non-argmax softmax weights
    within the tolerance.
    """
    neg = jnp.float32(-1e30)
    eps = 8.0 * jnp.finfo(jnp.float32).eps
    valid_row = valid_b[:, :, :, 0, :]  # (B, 1, 1, S)
    pol = policy.reshape((-1, 1, 1)) if policy is not None else None
    tol = pol.tol if pol is not None else exit_tol

    def decide(partial, idx, done):
        values = jnp.where(valid_b, dequant(partial), neg)[:, :, :, 0, :]
        vmax = jnp.max(jnp.abs(jnp.where(valid_row, values, 0.0)),
                       axis=-1, keepdims=True)
        # per-entry bound on the unseen tail, in the scaled score domain;
        # masked slots are EXACT (-1e30 by fiat) -> bound 0
        bvec = bounds_f32[idx] * scale_row * (1.0 + safety) + eps * vmax
        bvec = jnp.where(valid_row, bvec, 0.0)
        max_decided, _ = decision_state(values, bvec)
        norm_decided = jnp.max(bvec, axis=-1) <= tol
        return policy_commit(pol, max_decided & norm_decided, idx, done)

    if policy is None:
        def fold(carry, partial, idx):
            done, lv = carry
            newly, _ = decide(partial, idx, done)
            lv = jnp.where(newly, idx, lv)
            return done | newly, lv

        init = (jnp.zeros(rows_shape, bool),
                jnp.full(rows_shape, max(n_levels - 1, 0), jnp.int32))
    else:
        def fold(carry, partial, idx):
            done, lv, forced_any, s_commit = carry
            newly, forced = decide(partial, idx, done)
            commit = newly | forced
            lv = jnp.where(commit, idx, lv)
            s_commit = jnp.where(forced[..., None, None], partial, s_commit)
            return done | commit, lv, forced_any | forced, s_commit

        assert score_shape is not None, \
            "policy attention walk: pass the (B, Kv, G, 1, S) score shape"
        init = (jnp.zeros(rows_shape, bool),
                jnp.full(rows_shape, max(n_levels - 1, 0), jnp.int32),
                jnp.zeros(rows_shape, bool),
                jnp.zeros(score_shape, jnp.int32))

    def done_fn(carry):
        return jnp.all(carry[0])

    return fold, init, done_fn
