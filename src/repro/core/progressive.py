"""Streaming progressive-precision subsystem (online early output).

The hardware's defining property is that most-significant output digits
are available after the online delay, long before the computation
finishes.  This module is the tensor-level realization of that property
**on the level-stacked schedule** (core/l2r_gemm.py): a single
``lax.scan`` walks the significance levels s = 2D-2 .. 0 most significant
first, carrying only the running ``(…, M, N)`` accumulator, and after
every level the prefix sum is *bit-identical* to the stacked schedule
truncated at that depth (`l2r_matmul_int_stacked(..., levels=t+1)`).

Mechanics: both operands keep the pre-stacked digit-plane layout the
dispatcher uses (quant.py:stack_planes_lhs/rhs) and are zero-padded by
D-1 extra plane blocks.  Every level then reads a *fixed-width* window of
D plane blocks — LHS at block ``i_lo(s)``, RHS at block ``d-1-s+i_lo`` —
and the pairs outside the level's true range land on zero blocks on
exactly one side, contributing nothing.  A fixed window makes the level
loop a scan (one fused contraction per step), which is what lets
consumers *fold* over the stream (`streaming_matmul_scan`) without ever
materializing the ``(L, …, M, N)`` snapshot stack: early-exit consumers
(VGG classify heads, progressive decode) carry only their decision state.

Two control flows share that per-level step: the fixed-length ``lax.scan``
(`streaming_matmul_scan` — the oracle, always runs every level) and the
``lax.while_loop`` early-exit emitter (`streaming_matmul_while`), which
carries the consumer's fold/decision state and STOPS once every row in
the tile has decided — turning saved levels into saved wall-clock inside
one fused computation instead of merely skipped follow-up passes.

Decision machinery: `level_bounds` gives per-level hard bounds on the
unseen tail (core/online.py:tail_bound) in three forms — a conservatively
up-rounded float32 (for scaled-domain decisions), an int32 bound with an
explicit exactness guard (`decidable`; levels whose true bound exceeds
the int32 clip are simply never decidable — conservative, never wrong),
and the raw Python ints.  `earliest_decision_level` compares margins and
bounds in a single dtype (int32) under that guard.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .l2r_gemm import _f32_dot_exact
from .online import msdf_levels, tail_bound
from .quant import (PlaneOperands, plane_count, stack_planes_lhs,
                    stack_planes_rhs)

__all__ = [
    "ProgressiveResult",
    "LevelBounds",
    "level_bounds",
    "progressive_matmul",
    "streaming_matmul_scan",
    "streaming_matmul_while",
    "l2r_matmul_int_streaming",
    "streaming_argmax",
    "decision_state",
    "earliest_decision_level",
]

# int32 decision clip: bounds above this cannot be compared exactly in
# int32 (2*bound must not overflow), so those levels are marked
# undecidable instead of comparing in a lossy dtype.
_BOUND_CLIP = (2**31 - 1) // 2


class ProgressiveResult(NamedTuple):
    """Stacked per-level prefix results of the MSDF stream.

    partial:    (L, ..., M, N) int32 prefix sums, level l includes the
                top (l+1) significance levels — bit-identical to the
                stacked schedule truncated at levels=l+1.
    tail_bound: (L,) float32 — hard bound on |exact - partial[l]|,
                conservatively rounded toward +inf.
    bound_i32:  (L,) int32 — the same bound where it fits the int32
                decision range (clipped otherwise).
    decidable:  (L,) bool — True iff bound_i32 is the exact bound, i.e.
                int32 margin comparisons at this level are sound.
    """

    partial: jax.Array
    tail_bound: jax.Array
    bound_i32: jax.Array
    decidable: jax.Array


class LevelBounds(NamedTuple):
    """Per-level tail bounds in the three dtypes consumers need."""

    f32: jax.Array        # (L,) float32, rounded toward +inf
    i32: jax.Array        # (L,) int32, clipped at the decision range
    decidable: jax.Array  # (L,) bool, True iff i32 is exact
    exact: tuple          # Python ints (host-side reporting)


def _f32_up(b: int) -> np.float32:
    """Smallest float32 >= the exact integer bound (inf if out of range)."""
    v = np.float32(b)
    if np.isinf(v):
        return v
    # float32 -> exact int comparison in unbounded Python ints
    if int(v) < b:
        v = np.nextafter(v, np.float32(np.inf))
    return v


def level_bounds(d: int, log2_radix: int, k: int,
                 levels: int | None = None) -> LevelBounds:
    """Hard tail bounds after each of the first `levels` MSDF levels."""
    n_levels = len(msdf_levels(d)[:levels])
    exact = tuple(tail_bound(d, t + 1, log2_radix, k)
                  for t in range(n_levels))
    f32 = np.asarray([_f32_up(b) for b in exact], np.float32)
    fits = np.asarray([b <= _BOUND_CLIP for b in exact], bool)
    i32 = np.asarray([b if f else _BOUND_CLIP for b, f in zip(exact, fits)],
                     np.int32)
    return LevelBounds(jnp.asarray(f32), jnp.asarray(i32),
                       jnp.asarray(fits), exact)


# ------------------------------------------------------- streaming emitter
def _contract_k(x) -> int:
    """Contraction length of a raw operand or a pre-stacked PlaneOperands."""
    return x.k if isinstance(x, PlaneOperands) else x.shape[-1]


def _lhs_lead(aq) -> tuple[int, ...]:
    """Leading (…, M) output shape contributed by the LHS operand."""
    return aq.stack.shape[:-1] if isinstance(aq, PlaneOperands) \
        else aq.shape[:-1]


def _rhs_n(bq) -> int:
    return bq.stack.shape[-1] if isinstance(bq, PlaneOperands) \
        else bq.shape[-1]


def _streaming_operands(aq, bq, n_bits, log2_radix):
    """Zero-padded raw-digit plane stacks for the fixed-width level scan.

    Either operand may already be a :class:`~repro.core.quant.PlaneOperands`
    (e.g. the load-time weight-stack cache): its window stack is consumed
    directly — bit-identical to inline extraction, which produces the
    very same stack — so per-step streaming does no plane extraction at
    all for pre-stacked sides.  A stack built for a different digit
    config would walk the level schedule wrong, so mismatches raise
    rather than silently mis-slice.
    """
    d = plane_count(n_bits, log2_radix)
    for op, want in ((aq, "lhs"), (bq, "rhs")):
        if isinstance(op, PlaneOperands) \
                and not op.matches(n_bits, log2_radix, side=want):
            raise ValueError(
                f"PlaneOperands(side={op.side!r}, n_bits={op.n_bits}, "
                f"log2_radix={op.log2_radix}) cannot feed the {want} slot "
                f"of a streaming walk with n_bits={n_bits}, "
                f"log2_radix={log2_radix}; re-prepare the stack for this "
                f"config")
    if isinstance(aq, PlaneOperands):
        a_pad = aq.window_stack()
    else:
        k = aq.shape[-1]
        a_stack = stack_planes_lhs(aq, n_bits, log2_radix, shifted=False)
        a_pad = jnp.pad(a_stack,
                        [(0, 0)] * (a_stack.ndim - 1) + [(0, (d - 1) * k)])
    if isinstance(bq, PlaneOperands):
        b_pad = bq.window_stack()
    else:
        k = bq.shape[0]
        b_rev = stack_planes_rhs(bq, n_bits, log2_radix, shifted=False)
        b_pad = jnp.pad(b_rev,
                        [(0, (d - 1) * k)] + [(0, 0)] * (b_rev.ndim - 1))
    return a_pad, b_pad


def _level_walk(d: int, levels: int | None):
    """Per-step (a_off, b_off, s) block offsets of the fixed-width window.

    Level s reads LHS blocks [i_lo, i_lo+D) and RHS (reversed) blocks
    [d-1-s+i_lo, d-1-s+i_lo+D); the window positions past the level's
    true pair range hit zero padding on exactly one side.
    """
    svals = msdf_levels(d)[:levels]
    a_off = np.asarray([max(0, s - d + 1) for s in svals], np.int32)
    b_off = np.asarray([d - 1 - s + a for s, a in zip(svals, a_off)],
                       np.int32)
    return a_off, b_off, np.asarray(svals, np.int32)


def _stream_setup(aq, bq, n_bits, log2_radix):
    """Shared operand prep of the scan and while emitters: zero-padded
    plane stacks, the f32 fast-path decision, and the per-level term
    function.  BOTH control flows call the identical ``term(ao, bo)`` —
    same slices, same dot, same dtypes — which is what makes the
    while-loop path bit-identical to the scan oracle."""
    d = plane_count(n_bits, log2_radix)
    k = _contract_k(aq)
    a_pad, b_pad = _streaming_operands(aq, bq, n_bits, log2_radix)
    # the fixed window spans up to D real pairs -> the f32 exactness guard
    # must hold for a depth-D*K contraction of raw digits
    use_f32 = _f32_dot_exact(k, d, log2_radix)
    if use_f32:
        a_pad = a_pad.astype(jnp.float32)
        b_pad = b_pad.astype(jnp.float32)
    w = d * k

    def term(ao, bo):
        a_l = jax.lax.dynamic_slice_in_dim(a_pad, ao * k, w,
                                           axis=a_pad.ndim - 1)
        b_l = jax.lax.dynamic_slice_in_dim(b_pad, bo * k, w, axis=0)
        t = jax.lax.dot_general(
            a_l, b_l,
            ((((a_l.ndim - 1),), ((0,))), ((), ())),
            preferred_element_type=jnp.float32 if use_f32 else jnp.int32,
            precision=jax.lax.Precision.HIGHEST if use_f32 else None,
        )
        return t.astype(jnp.int32)

    return term


def streaming_matmul_scan(
    aq: jax.Array,
    bq: jax.Array,
    fold: Callable | None = None,
    init=None,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    emit: bool = False,
):
    """Scan the per-level MSDF prefix stream; never stacks levels itself.

    ``fold(carry, partial, level_index) -> carry`` consumes each prefix
    as it is emitted (the software analogue of a downstream online unit
    reading digits before the producer finishes); the scan carries only
    the ``(…, M, N)`` accumulator plus the fold's own state.  With
    ``emit=True`` the per-level prefixes are also returned stacked
    (``(L, …, M, N)`` — only for consumers that genuinely need the full
    snapshot history, e.g. `progressive_matmul`).

    Returns ``(final_partial, final_fold_carry, stack_or_None)``.  Each
    prefix is bit-identical to ``l2r_matmul_int_stacked(..., levels=t+1)``.

    This fixed-length scan is the ORACLE of the streaming subsystem: it
    always executes every requested level.  :func:`streaming_matmul_while`
    runs the same walk as a ``lax.while_loop`` that stops once the fold's
    decision state says no more digits are needed.

    Either operand may be a pre-stacked
    :class:`~repro.core.quant.PlaneOperands` (raw-digit layout) — the
    stream is bit-identical to inline extraction.
    """
    d = plane_count(n_bits, log2_radix)
    a_off, b_off, svals = _level_walk(d, levels)
    n_steps = int(svals.shape[0])
    acc0 = jnp.zeros((*_lhs_lead(aq), _rhs_n(bq)), jnp.int32)
    if n_steps == 0:  # levels=0: empty MSDF prefix
        empty = jnp.zeros((0, *acc0.shape), jnp.int32) if emit else None
        return acc0, init, empty

    term = _stream_setup(aq, bq, n_bits, log2_radix)

    def step(carry, xs):
        acc, fold_c = carry
        ao, bo, s, idx = xs
        acc = acc + (term(ao, bo) << (log2_radix * s))
        if fold is not None:
            fold_c = fold(fold_c, acc, idx)
        return (acc, fold_c), (acc if emit else None)

    xs = (jnp.asarray(a_off), jnp.asarray(b_off), jnp.asarray(svals),
          jnp.arange(n_steps, dtype=jnp.int32))
    (acc, fold_c), ys = jax.lax.scan(step, (acc0, init), xs)
    return acc, fold_c, ys


def _while_emitter(term, a_off, b_off, svals, log2_radix, acc0,
                   fold, init, done_fn):
    """Shared ``lax.while_loop`` harness of the early-exit emitters (GEMM
    and fused conv): one significance level per iteration — ``term(ao,
    bo)`` shifted to its level and accumulated, the fold applied, the
    done predicate polled in the loop condition.  Returns ``(levels_run,
    acc, fold_carry)``."""
    n_steps = int(svals.shape[0])
    a_off = jnp.asarray(a_off)
    b_off = jnp.asarray(b_off)
    svals = jnp.asarray(svals)

    def cond(state):
        t, _, fold_c = state
        running = t < n_steps
        if done_fn is not None:
            running = running & ~done_fn(fold_c)
        return running

    def body(state):
        t, acc, fold_c = state
        acc = acc + (term(a_off[t], b_off[t]) << (log2_radix * svals[t]))
        if fold is not None:
            fold_c = fold(fold_c, acc, t)
        return t + 1, acc, fold_c

    return jax.lax.while_loop(cond, body, (jnp.int32(0), acc0, init))


def streaming_matmul_while(
    aq: jax.Array,
    bq: jax.Array,
    fold: Callable | None = None,
    init=None,
    done_fn: Callable | None = None,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
):
    """Early-exit streaming emitter: the SAME level walk as
    :func:`streaming_matmul_scan`, run as a ``lax.while_loop`` that stops
    as soon as ``done_fn(fold_carry)`` (a scalar bool — typically "every
    row in the tile has decided") becomes True, so saved levels are saved
    wall-clock *inside* the fused computation, not just skipped follow-up
    passes.

    The loop body is the identical per-level arithmetic of the scan (same
    slices, same dot, same order), so after ``levels_run`` iterations the
    accumulator is bit-identical to the scan's prefix at that depth — and
    since ``done_fn`` only reads the fold state the scan would have
    produced, the exit level itself is bit-identical too.  With
    ``done_fn=None`` the loop runs every level (control-flow-only change;
    final result bit-identical to the scan and the stacked schedule).

    Returns ``(partial, fold_carry, levels_run)``: ``partial`` is the
    prefix after ``levels_run`` levels (== the full result iff the stream
    was exhausted), ``levels_run`` the number of levels actually executed.
    """
    d = plane_count(n_bits, log2_radix)
    a_off, b_off, svals = _level_walk(d, levels)
    n_steps = int(svals.shape[0])
    acc0 = jnp.zeros((*_lhs_lead(aq), _rhs_n(bq)), jnp.int32)
    if n_steps == 0:  # levels=0: empty MSDF prefix
        return acc0, init, jnp.int32(0)

    term = _stream_setup(aq, bq, n_bits, log2_radix)
    t, acc, fold_c = _while_emitter(term, a_off, b_off, svals, log2_radix,
                                    acc0, fold, init, done_fn)
    return acc, fold_c, t


@partial(jax.jit,
         static_argnames=("n_bits", "log2_radix", "levels", "early_exit"))
def l2r_matmul_int_streaming(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    early_exit: bool = False,
) -> jax.Array:
    """Final (or `levels`-truncated) result via the streaming schedule.

    Bit-identical to `l2r_matmul_int_stacked`; carries only the running
    accumulator — the dispatcher's ``schedule="streaming"`` jnp entry.
    ``early_exit=True`` runs the while-loop emitter instead of the fixed
    scan: with no consumer decision state it still executes every level
    (control-flow-only — the mode consumers with a fold terminate early).
    """
    if early_exit:
        acc, _, _ = streaming_matmul_while(aq, bq, None, None, None,
                                           n_bits, log2_radix, levels)
        return acc
    acc, _, _ = streaming_matmul_scan(aq, bq, None, None, n_bits,
                                      log2_radix, levels)
    return acc


@partial(jax.jit, static_argnames=("n_bits", "log2_radix", "levels"))
def progressive_matmul(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
) -> ProgressiveResult:
    """Full per-level snapshot stack of the MSDF stream.

    Built on the same streaming scan the serving consumers fold over;
    the ``(L, …, M, N)`` stack exists only because this API returns it
    (tests/benchmarks) — early-exit consumers use
    :func:`streaming_matmul_scan` / :func:`streaming_argmax` instead.
    """
    bounds = level_bounds(plane_count(n_bits, log2_radix), log2_radix,
                          _contract_k(aq), levels)
    _, _, stack = streaming_matmul_scan(aq, bq, None, None, n_bits,
                                        log2_radix, levels, emit=True)
    return ProgressiveResult(partial=stack, tail_bound=bounds.f32,
                             bound_i32=bounds.i32, decidable=bounds.decidable)


# ------------------------------------------------------ decision machinery
def decision_state(values: jax.Array, bvec: jax.Array):
    """Is the argmax of `values` invariant to any ±bvec perturbation?

    values: (..., N) scores; bvec: per-entry bound, broadcastable to
    values.  Decided iff the top-1 lower confidence bound strictly beats
    every other entry's upper bound.  Returns (decided (...,), argmax).
    """
    top = jnp.argmax(values, axis=-1)
    lb = values - bvec
    ub = values + bvec
    lb_top = jnp.take_along_axis(lb, top[..., None], axis=-1)[..., 0]
    ub_others = jnp.where(
        jax.nn.one_hot(top, values.shape[-1], dtype=bool), -jnp.inf, ub)
    return lb_top > jnp.max(ub_others, axis=-1), top.astype(jnp.int32)


def streaming_argmax(
    xq: jax.Array,
    wq: jax.Array,
    xs: jax.Array,
    ws: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    bias: jax.Array | None = None,
    out_dtype=jnp.float32,
    safety: float = 1e-5,
    early_exit: bool = False,
):
    """Stream a quantized classifier/LM-head matmul, committing the argmax
    of the *dequantized* scores at the earliest sound level.

    xq (M, K) int row activations with per-row scales xs (M, 1); wq (K, N)
    int weights with per-out-channel scales ws (1, N) — either side may
    instead be a pre-stacked :class:`~repro.core.quant.PlaneOperands`
    (the ``QuantizedWeights.planes`` load-time cache for wq), which skips
    per-call plane extraction with a bit-identical stream.  ``levels``
    truncates the stream exactly like every other `levels` in the stack
    (the final prefix then equals the truncated one-shot matmul).

    The decision runs in the scaled domain — per-entry bound
    ``tail * xs * ws`` (per-channel weight scales mean a scalar int
    margin test would be unsound) — widened by two float32 slack terms:
    a relative ``safety`` on the bound itself, and a per-row absolute
    term of a few ulps of the LARGEST score magnitude, because the
    rounding error of ``int32 partial -> f32 * scales`` scales with the
    score, not with the (possibly much smaller) tail bound.  Rows never
    decided early fall back to the final argmax, so the committed index
    ALWAYS equals the full-precision (or `levels`-truncated) argmax.

    ``early_exit=True`` runs the while-loop emitter: the level loop STOPS
    once every row has decided, so the committed tokens and exit levels
    (bit-identical to the scan path) come with actual wall-clock savings
    inside the fused computation.  The returned ``logits`` are then the
    dequantized prefix at the exit level — every committed row's argmax
    equals the full argmax (that is the decision guarantee), but the logit
    VALUES carry the undigested tail; consumers that need full-depth logit
    values keep ``early_exit=False``.

    Returns ``(logits (M, N) out_dtype, tok (M,) int32, exit_level (M,)
    int32)`` where exit_level counts levels actually needed (L-1 = full
    stream).  With ``early_exit=False`` the ``logits`` reproduce
    kernels/l2r_gemm ``l2r_matmul_f`` dequantization bit-for-bit (same op
    order), so downstream argmaxes agree with the non-streaming path.
    """
    d = plane_count(n_bits, log2_radix)
    bounds = level_bounds(d, log2_radix, _contract_k(xq), levels)
    n_levels = int(bounds.f32.shape[0])
    wsr = ws.reshape(1, -1).astype(jnp.float32)
    xsf = xs.astype(jnp.float32)
    m = _lhs_lead(xq)[-1]
    # |fl(v) - v| <= ~3 ulp(|v|) across the cast + two scale products and
    # the bias add; 8 ulp of the row max is a comfortable envelope
    eps = 8.0 * jnp.finfo(jnp.float32).eps

    def fold(carry, partial, idx):
        tok, lv, done = carry
        values = partial.astype(jnp.float32) * xsf * wsr
        if bias is not None:
            values = values + bias.astype(jnp.float32)
        vmax = jnp.max(jnp.abs(values), axis=-1, keepdims=True)
        bvec = bounds.f32[idx] * xsf * wsr * (1.0 + safety) + eps * vmax
        decided, am = decision_state(values, bvec)
        newly = decided & ~done
        tok = jnp.where(newly, am, tok)
        lv = jnp.where(newly, idx, lv)
        return tok, lv, done | decided

    init = (jnp.zeros((m,), jnp.int32),
            jnp.full((m,), max(n_levels - 1, 0), jnp.int32),
            jnp.zeros((m,), bool))
    if early_exit:
        acc, (tok, lv, done), _ = streaming_matmul_while(
            xq, wq, fold, init, lambda c: jnp.all(c[2]),
            n_bits, log2_radix, levels)
    else:
        acc, (tok, lv, done), _ = streaming_matmul_scan(
            xq, wq, fold, init, n_bits, log2_radix, levels)
    # dequantize exactly like l2r_matmul_f: f32 product, then output cast.
    # Early exit only stops the loop short when EVERY row decided, so
    # whenever the fallback below is reachable (some row undecided) the
    # stream was exhausted and `acc` IS the full (or levels-truncated)
    # result — the fallback argmax is identical on both control flows.
    logits = (acc.astype(jnp.float32) * xsf * wsr).astype(out_dtype)
    full = logits.astype(jnp.float32)
    if bias is not None:
        logits = logits + bias.astype(logits.dtype)
        full = full + bias.astype(jnp.float32)
    tok = jnp.where(done, tok, jnp.argmax(full, axis=-1).astype(jnp.int32))
    return logits, tok, lv


def earliest_decision_level(result: ProgressiveResult) -> jax.Array:
    """Earliest MSDF level at which greedy argmax over the last axis is
    already decided (top-1 margin exceeds twice the tail bound).

    The margin and the bound are compared in ONE dtype (int32); levels
    whose exact bound does not fit the int32 decision range carry
    ``decidable=False`` and are skipped (conservative — a lossy float
    comparison could declare an unsound early exit).  Returns (...,)
    int32 per row; value L-1 means "needed the full stream".
    """
    partial = result.partial  # (L, ..., N)
    extra = (1,) * (partial.ndim - 2)
    b32 = result.bound_i32.reshape((-1,) + extra)       # (L, 1, ..., 1)
    ok = result.decidable.reshape((-1,) + extra)
    top2 = jax.lax.top_k(partial, 2)[0]  # (L, ..., 2)
    margin = top2[..., 0] - top2[..., 1]  # int32, exact
    decided = ok & (margin > 2 * b32)  # 2*b32 <= 2^31-2: no overflow
    lv = jnp.argmax(decided, axis=0)  # first True (0 if none True!)
    any_decided = jnp.any(decided, axis=0)
    return jnp.where(any_decided, lv, partial.shape[0] - 1).astype(jnp.int32)
