"""Streaming progressive-precision subsystem (online early output).

The hardware's defining property is that most-significant output digits
are available after the online delay, long before the computation
finishes.  This module is the tensor-level realization of that property
**on the level-stacked schedule** (core/l2r_gemm.py): a single
``lax.scan`` walks the significance levels s = 2D-2 .. 0 most significant
first, carrying only the running ``(…, M, N)`` accumulator, and after
every level the prefix sum is *bit-identical* to the stacked schedule
truncated at that depth (`l2r_matmul_int_stacked(..., levels=t+1)`).

Mechanics: both operands keep the pre-stacked digit-plane layout the
dispatcher uses (quant.py:stack_planes_lhs/rhs) and are zero-padded by
D-1 extra plane blocks.  Every level then reads a *fixed-width* window of
D plane blocks — LHS at block ``i_lo(s)``, RHS at block ``d-1-s+i_lo`` —
and the pairs outside the level's true range land on zero blocks on
exactly one side, contributing nothing.  A fixed window makes the level
loop a scan (one fused contraction per step), which is what lets
consumers *fold* over the stream (`streaming_matmul_scan`) without ever
materializing the ``(L, …, M, N)`` snapshot stack: early-exit consumers
(VGG classify heads, progressive decode) carry only their decision state.

Two control flows share that per-level step: the fixed-length ``lax.scan``
(`streaming_matmul_scan` — the oracle, always runs every level) and the
``lax.while_loop`` early-exit emitter (`streaming_matmul_while`), which
carries the consumer's fold/decision state and STOPS once every row in
the tile has decided — turning saved levels into saved wall-clock inside
one fused computation instead of merely skipped follow-up passes.

Decision machinery: `level_bounds` gives per-level hard bounds on the
unseen tail (core/online.py:tail_bound) in three forms — a conservatively
up-rounded float32 (for scaled-domain decisions), an int32 bound with an
explicit exactness guard (`decidable`; levels whose true bound exceeds
the int32 clip are simply never decidable — conservative, never wrong),
and the raw Python ints.  `earliest_decision_level` compares margins and
bounds in a single dtype (int32) under that guard.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .l2r_gemm import _f32_dot_exact
from .online import msdf_levels, tail_bound
# decision_state moved to core/policy.py (the one decision fold of every
# streaming walk); re-exported here for existing importers
from .policy import LevelPolicy, decision_state, head_walk_machinery
from .quant import (PlaneOperands, plane_count, stack_planes_lhs,
                    stack_planes_rhs)

__all__ = [
    "ProgressiveResult",
    "LevelBounds",
    "level_bounds",
    "progressive_matmul",
    "streaming_matmul_scan",
    "streaming_matmul_while",
    "l2r_matmul_int_streaming",
    "streaming_argmax",
    "sharded_walk_axes",
    "decision_state",
    "earliest_decision_level",
    "CONSENSUS_WALK_SCOPE",
]

#: named scope wrapping the shard_mapped consensus walk body — every
#: collective the walk declares carries this prefix in its HLO
#: ``metadata op_name`` (see analysis/sharding.py)
CONSENSUS_WALK_SCOPE = "l2r_consensus_walk"

# int32 decision clip: bounds above this cannot be compared exactly in
# int32 (2*bound must not overflow), so those levels are marked
# undecidable instead of comparing in a lossy dtype.
_BOUND_CLIP = (2**31 - 1) // 2


class ProgressiveResult(NamedTuple):
    """Stacked per-level prefix results of the MSDF stream.

    partial:    (L, ..., M, N) int32 prefix sums, level l includes the
                top (l+1) significance levels — bit-identical to the
                stacked schedule truncated at levels=l+1.
    tail_bound: (L,) float32 — hard bound on |exact - partial[l]|,
                conservatively rounded toward +inf.
    bound_i32:  (L,) int32 — the same bound where it fits the int32
                decision range (clipped otherwise).
    decidable:  (L,) bool — True iff bound_i32 is the exact bound, i.e.
                int32 margin comparisons at this level are sound.
    """

    partial: jax.Array
    tail_bound: jax.Array
    bound_i32: jax.Array
    decidable: jax.Array


class LevelBounds(NamedTuple):
    """Per-level tail bounds in the three dtypes consumers need."""

    f32: jax.Array        # (L,) float32, rounded toward +inf
    i32: jax.Array        # (L,) int32, clipped at the decision range
    decidable: jax.Array  # (L,) bool, True iff i32 is exact
    exact: tuple          # Python ints (host-side reporting)


def _f32_up(b: int) -> np.float32:
    """Smallest float32 >= the exact integer bound (inf if out of range)."""
    v = np.float32(b)
    if np.isinf(v):
        return v
    # float32 -> exact int comparison in unbounded Python ints
    if int(v) < b:
        v = np.nextafter(v, np.float32(np.inf))
    return v


def level_bounds(d: int, log2_radix: int, k: int,
                 levels: int | None = None) -> LevelBounds:
    """Hard tail bounds after each of the first `levels` MSDF levels."""
    n_levels = len(msdf_levels(d)[:levels])
    exact = tuple(tail_bound(d, t + 1, log2_radix, k)
                  for t in range(n_levels))
    f32 = np.asarray([_f32_up(b) for b in exact], np.float32)
    fits = np.asarray([b <= _BOUND_CLIP for b in exact], bool)
    i32 = np.asarray([b if f else _BOUND_CLIP for b, f in zip(exact, fits)],
                     np.int32)
    return LevelBounds(jnp.asarray(f32), jnp.asarray(i32),
                       jnp.asarray(fits), exact)


# ------------------------------------------------------- streaming emitter
def _contract_k(x) -> int:
    """Contraction length of a raw operand or a pre-stacked PlaneOperands."""
    return x.k if isinstance(x, PlaneOperands) else x.shape[-1]


def _lhs_lead(aq) -> tuple[int, ...]:
    """Leading (…, M) output shape contributed by the LHS operand."""
    return aq.stack.shape[:-1] if isinstance(aq, PlaneOperands) \
        else aq.shape[:-1]


def _rhs_n(bq) -> int:
    return bq.stack.shape[-1] if isinstance(bq, PlaneOperands) \
        else bq.shape[-1]


def _streaming_operands(aq, bq, n_bits, log2_radix):
    """Zero-padded raw-digit plane stacks for the fixed-width level scan.

    Either operand may already be a :class:`~repro.core.quant.PlaneOperands`
    (e.g. the load-time weight-stack cache): its window stack is consumed
    directly — bit-identical to inline extraction, which produces the
    very same stack — so per-step streaming does no plane extraction at
    all for pre-stacked sides.  A stack built for a different digit
    config would walk the level schedule wrong, so mismatches raise
    rather than silently mis-slice.
    """
    d = plane_count(n_bits, log2_radix)
    for op, want, other in ((aq, "lhs", bq), (bq, "rhs", aq)):
        if isinstance(op, PlaneOperands) \
                and not op.matches(n_bits, log2_radix, side=want):
            other_desc = other.describe() if isinstance(other, PlaneOperands) \
                else f"array(shape={tuple(other.shape)}, dtype={other.dtype})"
            raise ValueError(
                f"{op.describe()} cannot feed the {want} slot "
                f"of a streaming walk with n_bits={n_bits}, "
                f"log2_radix={log2_radix} (other operand: {other_desc}); "
                f"re-prepare the stack for this config")
    if isinstance(aq, PlaneOperands):
        a_pad = aq.window_stack()
    else:
        k = aq.shape[-1]
        a_stack = stack_planes_lhs(aq, n_bits, log2_radix, shifted=False)
        a_pad = jnp.pad(a_stack,
                        [(0, 0)] * (a_stack.ndim - 1) + [(0, (d - 1) * k)])
    if isinstance(bq, PlaneOperands):
        b_pad = bq.window_stack()
    else:
        k = bq.shape[0]
        b_rev = stack_planes_rhs(bq, n_bits, log2_radix, shifted=False)
        b_pad = jnp.pad(b_rev,
                        [(0, (d - 1) * k)] + [(0, 0)] * (b_rev.ndim - 1))
    return a_pad, b_pad


def _level_walk(d: int, levels: int | None):
    """Per-step (a_off, b_off, s) block offsets of the fixed-width window.

    Level s reads LHS blocks [i_lo, i_lo+D) and RHS (reversed) blocks
    [d-1-s+i_lo, d-1-s+i_lo+D); the window positions past the level's
    true pair range hit zero padding on exactly one side.
    """
    svals = msdf_levels(d)[:levels]
    a_off = np.asarray([max(0, s - d + 1) for s in svals], np.int32)
    b_off = np.asarray([d - 1 - s + a for s, a in zip(svals, a_off)],
                       np.int32)
    return a_off, b_off, np.asarray(svals, np.int32)


def _stream_setup(aq, bq, n_bits, log2_radix):
    """Shared operand prep of the scan and while emitters: zero-padded
    plane stacks, the f32 fast-path decision, and the per-level term
    function.  BOTH control flows call the identical ``term(ao, bo)`` —
    same slices, same dot, same dtypes — which is what makes the
    while-loop path bit-identical to the scan oracle."""
    d = plane_count(n_bits, log2_radix)
    k = _contract_k(aq)
    a_pad, b_pad = _streaming_operands(aq, bq, n_bits, log2_radix)
    # the fixed window spans up to D real pairs -> the f32 exactness guard
    # must hold for a depth-D*K contraction of raw digits
    use_f32 = _f32_dot_exact(k, d, log2_radix)
    if use_f32:
        a_pad = a_pad.astype(jnp.float32)
        b_pad = b_pad.astype(jnp.float32)
    w = d * k

    def term(ao, bo):
        a_l = jax.lax.dynamic_slice_in_dim(a_pad, ao * k, w,
                                           axis=a_pad.ndim - 1)
        b_l = jax.lax.dynamic_slice_in_dim(b_pad, bo * k, w, axis=0)
        t = jax.lax.dot_general(
            a_l, b_l,
            ((((a_l.ndim - 1),), ((0,))), ((), ())),
            preferred_element_type=jnp.float32 if use_f32 else jnp.int32,
            precision=jax.lax.Precision.HIGHEST if use_f32 else None,
        )
        return t.astype(jnp.int32)

    return term


def streaming_matmul_scan(
    aq: jax.Array,
    bq: jax.Array,
    fold: Callable | None = None,
    init=None,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    emit: bool = False,
):
    """Scan the per-level MSDF prefix stream; never stacks levels itself.

    ``fold(carry, partial, level_index) -> carry`` consumes each prefix
    as it is emitted (the software analogue of a downstream online unit
    reading digits before the producer finishes); the scan carries only
    the ``(…, M, N)`` accumulator plus the fold's own state.  With
    ``emit=True`` the per-level prefixes are also returned stacked
    (``(L, …, M, N)`` — only for consumers that genuinely need the full
    snapshot history, e.g. `progressive_matmul`).

    Returns ``(final_partial, final_fold_carry, stack_or_None)``.  Each
    prefix is bit-identical to ``l2r_matmul_int_stacked(..., levels=t+1)``.

    This fixed-length scan is the ORACLE of the streaming subsystem: it
    always executes every requested level.  :func:`streaming_matmul_while`
    runs the same walk as a ``lax.while_loop`` that stops once the fold's
    decision state says no more digits are needed.

    Either operand may be a pre-stacked
    :class:`~repro.core.quant.PlaneOperands` (raw-digit layout) — the
    stream is bit-identical to inline extraction.
    """
    d = plane_count(n_bits, log2_radix)
    a_off, b_off, svals = _level_walk(d, levels)
    n_steps = int(svals.shape[0])
    acc0 = jnp.zeros((*_lhs_lead(aq), _rhs_n(bq)), jnp.int32)
    if n_steps == 0:  # levels=0: empty MSDF prefix
        empty = jnp.zeros((0, *acc0.shape), jnp.int32) if emit else None
        return acc0, init, empty

    term = _stream_setup(aq, bq, n_bits, log2_radix)

    def step(carry, xs):
        acc, fold_c = carry
        ao, bo, s, idx = xs
        acc = acc + (term(ao, bo) << (log2_radix * s))
        if fold is not None:
            fold_c = fold(fold_c, acc, idx)
        return (acc, fold_c), (acc if emit else None)

    xs = (jnp.asarray(a_off), jnp.asarray(b_off), jnp.asarray(svals),
          jnp.arange(n_steps, dtype=jnp.int32))
    (acc, fold_c), ys = jax.lax.scan(step, (acc0, init), xs)
    return acc, fold_c, ys


def _while_emitter(term, a_off, b_off, svals, log2_radix, acc0,
                   fold, init, done_fn):
    """Shared ``lax.while_loop`` harness of the early-exit emitters (GEMM
    and fused conv): one significance level per iteration — ``term(ao,
    bo)`` shifted to its level and accumulated, the fold applied, the
    done predicate polled in the loop condition.  Returns ``(levels_run,
    acc, fold_carry)``."""
    n_steps = int(svals.shape[0])
    a_off = jnp.asarray(a_off)
    b_off = jnp.asarray(b_off)
    svals = jnp.asarray(svals)

    def cond(state):
        t, _, fold_c = state
        running = t < n_steps
        if done_fn is not None:
            running = running & ~done_fn(fold_c)
        return running

    def body(state):
        t, acc, fold_c = state
        acc = acc + (term(a_off[t], b_off[t]) << (log2_radix * svals[t]))
        if fold is not None:
            fold_c = fold(fold_c, acc, t)
        return t + 1, acc, fold_c

    return jax.lax.while_loop(cond, body, (jnp.int32(0), acc0, init))


def streaming_matmul_while(
    aq: jax.Array,
    bq: jax.Array,
    fold: Callable | None = None,
    init=None,
    done_fn: Callable | None = None,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
):
    """Early-exit streaming emitter: the SAME level walk as
    :func:`streaming_matmul_scan`, run as a ``lax.while_loop`` that stops
    as soon as ``done_fn(fold_carry)`` (a scalar bool — typically "every
    row in the tile has decided") becomes True, so saved levels are saved
    wall-clock *inside* the fused computation, not just skipped follow-up
    passes.

    The loop body is the identical per-level arithmetic of the scan (same
    slices, same dot, same order), so after ``levels_run`` iterations the
    accumulator is bit-identical to the scan's prefix at that depth — and
    since ``done_fn`` only reads the fold state the scan would have
    produced, the exit level itself is bit-identical too.  With
    ``done_fn=None`` the loop runs every level (control-flow-only change;
    final result bit-identical to the scan and the stacked schedule).

    Returns ``(partial, fold_carry, levels_run)``: ``partial`` is the
    prefix after ``levels_run`` levels (== the full result iff the stream
    was exhausted), ``levels_run`` the number of levels actually executed.
    """
    d = plane_count(n_bits, log2_radix)
    a_off, b_off, svals = _level_walk(d, levels)
    n_steps = int(svals.shape[0])
    acc0 = jnp.zeros((*_lhs_lead(aq), _rhs_n(bq)), jnp.int32)
    if n_steps == 0:  # levels=0: empty MSDF prefix
        return acc0, init, jnp.int32(0)

    term = _stream_setup(aq, bq, n_bits, log2_radix)
    t, acc, fold_c = _while_emitter(term, a_off, b_off, svals, log2_radix,
                                    acc0, fold, init, done_fn)
    return acc, fold_c, t


@partial(jax.jit,
         static_argnames=("n_bits", "log2_radix", "levels", "early_exit"))
def l2r_matmul_int_streaming(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    early_exit: bool = False,
) -> jax.Array:
    """Final (or `levels`-truncated) result via the streaming schedule.

    Bit-identical to `l2r_matmul_int_stacked`; carries only the running
    accumulator — the dispatcher's ``schedule="streaming"`` jnp entry.
    ``early_exit=True`` runs the while-loop emitter instead of the fixed
    scan: with no consumer decision state it still executes every level
    (control-flow-only — the mode consumers with a fold terminate early).
    """
    if early_exit:
        acc, _, _ = streaming_matmul_while(aq, bq, None, None, None,
                                           n_bits, log2_radix, levels)
        return acc
    acc, _, _ = streaming_matmul_scan(aq, bq, None, None, n_bits,
                                      log2_radix, levels)
    return acc


@partial(jax.jit, static_argnames=("n_bits", "log2_radix", "levels"))
def progressive_matmul(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
) -> ProgressiveResult:
    """Full per-level snapshot stack of the MSDF stream.

    Built on the same streaming scan the serving consumers fold over;
    the ``(L, …, M, N)`` stack exists only because this API returns it
    (tests/benchmarks) — early-exit consumers use
    :func:`streaming_matmul_scan` / :func:`streaming_argmax` instead.
    """
    bounds = level_bounds(plane_count(n_bits, log2_radix), log2_radix,
                          _contract_k(aq), levels)
    _, _, stack = streaming_matmul_scan(aq, bq, None, None, n_bits,
                                        log2_radix, levels, emit=True)
    return ProgressiveResult(partial=stack, tail_bound=bounds.f32,
                             bound_i32=bounds.i32, decidable=bounds.decidable)


# ------------------------------------------------------ decision machinery
def streaming_argmax(
    xq: jax.Array,
    wq: jax.Array,
    xs: jax.Array,
    ws: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    bias: jax.Array | None = None,
    out_dtype=jnp.float32,
    safety: float = 1e-5,
    early_exit: bool = False,
    mesh=None,
    policy: LevelPolicy | None = None,
):
    """Stream a quantized classifier/LM-head matmul, committing the argmax
    of the *dequantized* scores at the earliest sound level.

    xq (M, K) int row activations with per-row scales xs (M, 1); wq (K, N)
    int weights with per-out-channel scales ws (1, N) — either side may
    instead be a pre-stacked :class:`~repro.core.quant.PlaneOperands`
    (the ``QuantizedWeights.planes`` load-time cache for wq), which skips
    per-call plane extraction with a bit-identical stream.  ``levels``
    truncates the stream exactly like every other `levels` in the stack
    (the final prefix then equals the truncated one-shot matmul).

    The decision runs in the scaled domain — per-entry bound
    ``tail * xs * ws`` (per-channel weight scales mean a scalar int
    margin test would be unsound) — widened by two float32 slack terms:
    a relative ``safety`` on the bound itself, and a per-row absolute
    term of a few ulps of the LARGEST score magnitude, because the
    rounding error of ``int32 partial -> f32 * scales`` scales with the
    score, not with the (possibly much smaller) tail bound.  Rows never
    decided early fall back to the final argmax, so the committed index
    ALWAYS equals the full-precision (or `levels`-truncated) argmax.

    ``early_exit=True`` runs the while-loop emitter: the level loop STOPS
    once every row has decided, so the committed tokens and exit levels
    (bit-identical to the scan path) come with actual wall-clock savings
    inside the fused computation.  The returned ``logits`` are then the
    dequantized prefix at the exit level — every committed row's argmax
    equals the full argmax (that is the decision guarantee), but the logit
    VALUES carry the undigested tail; consumers that need full-depth logit
    values keep ``early_exit=False``.

    Returns ``(logits (M, N) out_dtype, tok (M,) int32, exit_level (M,)
    int32)`` where exit_level counts levels actually needed (L-1 = full
    stream).  With ``early_exit=False`` the ``logits`` reproduce
    kernels/l2r_gemm ``l2r_matmul_f`` dequantization bit-for-bit (same op
    order), so downstream argmaxes agree with the non-streaming path.

    **Sharded walk.**  When a mesh is installed (``sharding.ctx``, or the
    explicit ``mesh=`` override) whose ``model`` axis divides N and/or
    whose data axes divide M, the walk runs as the ``shard_map``ped
    consensus emitter (:func:`_streaming_argmax_sharded`): the RHS plane
    stack is vocab-sharded, the LHS stack batch-sharded, every level's
    decision is reached from per-shard (max, first-index, runner-up)
    triples reduced across ``model``, and the early-exit ``done_fn``
    reaches global consensus via a ``psum`` of per-row decided flags —
    the loop stops at the fleet-wide slowest row.  Prefixes, committed
    decisions, and exit levels are bit-identical to this single-device
    path (the sharded accumulator is integer-exact per vocab shard, the
    decision floats are elementwise, and every cross-shard reduction is
    an exact max/min/sum of the same values).

    **Per-row policy.**  ``policy`` (core/policy.py:LevelPolicy, one row
    per M) replaces the batch-global decision with per-row precision
    classes: ``bounded(0)`` rows reproduce this walk bit for bit,
    ``budget(L)`` rows force-commit at level L with the token a
    ``levels=L`` run would commit, ``exact`` rows never early-commit
    (full-depth fallback).  Rows are decision-independent, so a mixed
    batch commits each row exactly as a single-class batch would;
    ``early_exit`` still picks the while-loop emitter, which stops at
    the slowest row (an exact row keeps the loop running full depth).
    """
    axes = sharded_walk_axes(_lhs_lead(xq), _rhs_n(wq), mesh)
    if axes is not None:
        return _streaming_argmax_sharded(
            xq, wq, xs, ws, n_bits, log2_radix, levels, bias, out_dtype,
            safety, early_exit, policy, *axes)
    d = plane_count(n_bits, log2_radix)
    bounds = level_bounds(d, log2_radix, _contract_k(xq), levels)
    n_levels = int(bounds.f32.shape[0])
    wsr = ws.reshape(1, -1).astype(jnp.float32)
    xsf = xs.astype(jnp.float32)
    m = _lhs_lead(xq)[-1]
    if policy is not None:
        assert policy.mode.shape == (m,), \
            f"policy rows {policy.mode.shape} != batch rows ({m},)"
    fold, init, done_fn, finalize = head_walk_machinery(
        bounds.f32, xsf, wsr, bias, out_dtype, safety=safety,
        n_levels=n_levels, m_global=m, n_total=_rhs_n(wq),
        policy=policy, early_exit=early_exit)
    if early_exit:
        acc, carry, _ = streaming_matmul_while(
            xq, wq, fold, init, done_fn, n_bits, log2_radix, levels)
    else:
        acc, carry, _ = streaming_matmul_scan(
            xq, wq, fold, init, n_bits, log2_radix, levels)
    return finalize(acc, carry)


# ------------------------------------------------- sharded streaming walk
def sharded_walk_axes(lead: tuple[int, ...], n: int, mesh=None):
    """Mesh routing of the streaming walk: ``(mesh, dp_axes, model_axis)``
    when the sharded consensus emitter applies, ``None`` otherwise.

    ``mesh`` defaults to the installed context mesh (sharding/ctx.py).
    The walk shards the batch (M) over the data-parallel axes and the
    vocab (N) over ``model``; an axis that does not divide its dim is
    dropped (that side replicates — still correct, the other side still
    shards), and when neither axis is usable (or the mesh is trivial)
    the caller takes the plain single-device path.  Only 2-D tiles
    stream sharded (the serving consumers all reshape to (M, K)).
    """
    from repro.sharding import ctx

    mesh = mesh if mesh is not None else ctx.get_mesh()
    if mesh is None or len(lead) != 1:
        return None
    m = lead[0]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = ctx.mesh_axis_size(mesh, dp) if dp else 1
    if dp_size <= 1 or m % dp_size:
        dp = ()
    model = "model" if "model" in mesh.axis_names else None
    if model is not None and (mesh.shape["model"] <= 1
                              or n % mesh.shape["model"]):
        model = None
    if not dp and model is None:
        return None
    return mesh, dp, model


def _streaming_argmax_sharded(xq, wq, xs, ws, n_bits, log2_radix, levels,
                              bias, out_dtype, safety, early_exit, policy,
                              mesh, dp, model_ax):
    """The ``shard_map``ped consensus level walk behind
    :func:`streaming_argmax` (see its docstring for routing).

    Layout: the LHS activation stack is batch-sharded over the ``dp``
    axes, the RHS weight stack (raw or the ``QuantizedWeights.planes``
    cache) vocab-sharded over ``model``; K — the contraction — is never
    sharded, so each device's accumulator tile is the integer-exact
    column/row slice of the single-device accumulator at every level
    (the f32 fast path is guarded exact, the int32 path is exact
    arithmetic — neither depends on reduction order).

    Per-level global decision, from per-shard triples reduced over
    ``model`` (every reduction an exact max/min of identical floats, so
    decided/argmax/exit-level are bit-identical to the oracle):

      * global top = ``pmax`` of local maxima; first-occurrence index =
        ``pmin`` over shards of (local first-achiever index, or N);
      * the top's lower confidence bound comes from the one shard that
        owns the winning column (``pmax`` of the owner's value, -inf
        elsewhere); the runner-up upper bound is the ``pmax`` of each
        shard's max-excluding-the-winner;
      * decided rows then update tok/lv exactly as the local fold does.

    Early-exit consensus: the fold ``psum``s the per-row decided flags
    over the data axes (rows are replicated across ``model``; the
    decision scalars already agree there) and the while loop's
    ``done_fn`` reads that scalar — every device stops at the SAME
    level, the fleet-wide slowest row's, which is exactly where the
    single-device while loop stops for the full batch.

    The decision fold itself is core/policy.py:head_walk_machinery —
    the SAME fold as the local walk, with the cross-shard reductions
    (pmax/pmin over ``model``, the consensus psum over ``dp``) switched
    on by the axis names.  Per-row policies shard their rows over the
    data axes like every other per-row carry.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    d = plane_count(n_bits, log2_radix)
    bounds = level_bounds(d, log2_radix, _contract_k(xq), levels)
    n_levels = int(bounds.f32.shape[0])
    m = _lhs_lead(xq)[-1]
    n_total = _rhs_n(wq)
    wsr = ws.reshape(1, -1).astype(jnp.float32)
    xsf = xs.astype(jnp.float32)
    has_bias = bias is not None
    b_arr = bias.reshape(-1) if has_bias else jnp.zeros((n_total,), jnp.float32)
    dp_spec = dp if dp else None
    if policy is not None:
        assert policy.mode.shape == (m,), \
            f"policy rows {policy.mode.shape} != batch rows ({m},)"

    def walk(bf32, xq_s, wq_s, xsf_s, wsr_s, bias_s, *maybe_policy):
        # the walk-level named scope prefixes every op_name inside the
        # trace (incl. head_walk_machinery's l2r_coll_* reduction tags),
        # so the sharding auditor can attribute each collective of the
        # partitioned module to this declared consensus schedule
        with jax.named_scope(CONSENSUS_WALK_SCOPE):
            policy_s = maybe_policy[0] if maybe_policy else None
            fold, init, done_fn, finalize = head_walk_machinery(
                bf32, xsf_s, wsr_s, bias_s if has_bias else None, out_dtype,
                safety=safety, n_levels=n_levels, m_global=m, n_total=n_total,
                policy=policy_s, early_exit=early_exit,
                model_ax=model_ax, dp=dp)
            if early_exit:
                acc, carry, _ = streaming_matmul_while(
                    xq_s, wq_s, fold, init, done_fn,
                    n_bits, log2_radix, levels)
            else:
                acc, carry, _ = streaming_matmul_scan(
                    xq_s, wq_s, fold, init, n_bits, log2_radix, levels)
            # dequantize + fallback exactly as the single-device path:
            # the out_dtype round-trip must match bit for bit
            return finalize(acc, carry)

    args = [bounds.f32, xq, wq, xsf, wsr, b_arr]
    in_specs = [P(None), P(dp_spec, None), P(None, model_ax),
                P(dp_spec, None), P(None, model_ax), P(model_ax)]
    if policy is not None:
        args.append(policy)
        in_specs.append(LevelPolicy(P(dp_spec), P(dp_spec), P(dp_spec)))
    fn = shard_map(
        walk, mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(dp_spec, model_ax), P(dp_spec), P(dp_spec)),
        check_rep=False)
    return fn(*args)


def earliest_decision_level(result: ProgressiveResult) -> jax.Array:
    """Earliest MSDF level at which greedy argmax over the last axis is
    already decided (top-1 margin exceeds twice the tail bound).

    The margin and the bound are compared in ONE dtype (int32); levels
    whose exact bound does not fit the int32 decision range carry
    ``decidable=False`` and are skipped (conservative — a lossy float
    comparison could declare an unsound early exit).  Returns (...,)
    int32 per row; value L-1 means "needed the full stream".
    """
    partial = result.partial  # (L, ..., N)
    extra = (1,) * (partial.ndim - 2)
    b32 = result.bound_i32.reshape((-1,) + extra)       # (L, 1, ..., 1)
    ok = result.decidable.reshape((-1,) + extra)
    top2 = jax.lax.top_k(partial, 2)[0]  # (L, ..., 2)
    margin = top2[..., 0] - top2[..., 1]  # int32, exact
    decided = ok & (margin > 2 * b32)  # 2*b32 <= 2^31-2: no overflow
    lv = jnp.argmax(decided, axis=0)  # first True (0 if none True!)
    any_decided = jnp.any(decided, axis=0)
    return jnp.where(any_decided, lv, partial.shape[0] - 1).astype(jnp.int32)
