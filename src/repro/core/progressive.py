"""Progressive-precision (online early-output) machinery.

The hardware's defining property is that most-significant output digits
are available after the online delay, long before the computation
finishes.  The serving-level analogue implemented here: accumulate the
MSDF plane-pair stream level by level, tracking the hard tail bound from
core/online.py; a consumer (e.g. greedy decoding) may stop as soon as its
decision is invariant to any completion of the tail — exactly how a
downstream online unit starts consuming digits before its producer
finishes.
"""

from __future__ import annotations

from functools import partial
from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp

from .online import msdf_pairs, tail_bound
from .quant import QuantConfig, digit_planes, quantize

__all__ = ["ProgressiveResult", "progressive_matmul", "earliest_decision_level"]


class ProgressiveResult(NamedTuple):
    """Stacked per-level prefix results of the MSDF stream.

    partial:    (L, ..., M, N) int32 prefix sums, level l includes the
                top (l+1) significance levels.
    tail_bound: (L,) int64 — hard bound on |exact - partial[l]|.
    """

    partial: jax.Array
    tail_bound: jax.Array


@partial(jax.jit, static_argnames=("n_bits", "log2_radix"))
def progressive_matmul(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
) -> ProgressiveResult:
    """Run the full MSDF stream, snapshotting after every significance level."""
    d = n_bits // log2_radix
    k = aq.shape[-1]
    ap = digit_planes(aq, n_bits, log2_radix)
    bp = digit_planes(bq, n_bits, log2_radix)
    n_levels = 2 * d - 1

    acc = jnp.zeros((*aq.shape[:-1], bq.shape[-1]), jnp.int32)
    snaps = []
    bounds = []
    for lv in range(1, n_levels + 1):
        s = 2 * d - 1 - lv  # significance of this level
        for i in range(min(s, d - 1), -1, -1):
            j = s - i
            if j < 0 or j >= d:
                continue
            term = jax.lax.dot_general(
                ap[i], bp[j],
                ((((ap[i].ndim - 1),), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            acc = acc + (term << (log2_radix * s))
        snaps.append(acc)
        bounds.append(tail_bound(d, lv, log2_radix, k))
    # float32 bound (exactly representable range is ample here and avoids
    # depending on x64 mode); consumers compare against int32 margins.
    return ProgressiveResult(
        partial=jnp.stack(snaps),
        tail_bound=jnp.asarray(bounds, jnp.float32),
    )


def earliest_decision_level(result: ProgressiveResult) -> jax.Array:
    """Earliest MSDF level at which greedy argmax over the last axis is
    already decided (top-1 margin exceeds twice the tail bound).

    Returns (...,) int32 per row; value L-1 means "needed the full stream".
    """
    partial = result.partial  # (L, ..., N)
    bound = result.tail_bound.reshape((-1,) + (1,) * (partial.ndim - 1))
    top2 = jax.lax.top_k(partial, 2)[0]  # (L, ..., 2)
    margin = top2[..., 0] - top2[..., 1]
    decided = margin > 2 * bound[..., 0]  # (L, ...)
    lv = jnp.argmax(decided, axis=0)  # first True (0 if none True!)
    any_decided = jnp.any(decided, axis=0)
    return jnp.where(any_decided, lv, partial.shape[0] - 1).astype(jnp.int32)
