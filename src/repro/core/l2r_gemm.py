"""L2R digit-plane GEMM — the TPU-native mapping of the composite IPU.

The paper's unit computes p = sum_k A_k B_k by streaming partial-product
terms PP_{i,j} = sum_k A_{k,i} B_{k,j} most-significant-first.  At tensor
granularity the same decomposition over radix-2^b digits gives

    A @ B = sum_{i,j} (A_i @ B_j) * 2^{b (i+j)}

where A_i, B_j are small-integer digit planes: **each term is itself a
matmul**, i.e. an MXU-shaped operation, and the k-way counter circuit of
the paper becomes the K-contraction of the plane matmul.  Processing the
(i, j) pairs in decreasing significance s = i + j preserves the online
property: truncating the stream after `levels` significance levels yields
a result with a hard error bound (core/online.py:tail_bound).

Two schedules live here: the pair loop (``l2r_matmul_int``, one small
matmul per (i, j) pair — the reference/oracle) and the **level-stacked**
schedule (``l2r_matmul_int_stacked``: planes extracted once, each
significance level s = i + j fused into ONE matmul over a concatenated K
axis — 2D-1 large passes instead of D² small ones, bit-identical
including truncation).  The production entry point is the backend
dispatcher in repro/kernels/l2r_gemm/ops.py, which routes to the stacked
schedule here (jnp backend) or to the Pallas VMEM-tiled kernels; both
are validated against the pair loop.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .online import msdf_level_slices, msdf_pairs
from .quant import (QuantConfig, QuantizedWeights, digit_planes, quantize,
                    stack_planes_lhs, stack_planes_rhs)

__all__ = ["l2r_matmul_int", "l2r_matmul_int_stacked", "stacked_gemm_planes",
           "l2r_matmul", "l2r_dense"]


@partial(jax.jit, static_argnames=("n_bits", "log2_radix", "levels"))
def l2r_matmul_int(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
) -> jax.Array:
    """Exact (or MSDF-truncated) integer matmul via digit planes.

    Args:
      aq: (..., M, K) signed ints (int8/int16).
      bq: (K, N) signed ints.
      levels: number of MSDF significance levels to process
        (None or 2*D-1 -> exact; fewer -> progressive-precision prefix).

    Returns int32 (..., M, N); with levels=None this equals
    aq.astype(int32) @ bq.astype(int32) exactly.
    """
    d = n_bits // log2_radix
    ap = digit_planes(aq, n_bits, log2_radix)  # (D, ..., M, K) int8
    bp = digit_planes(bq, n_bits, log2_radix)  # (D, K, N) int8
    acc = jnp.zeros((*aq.shape[:-1], bq.shape[-1]), jnp.int32)
    for (i, j) in msdf_pairs(d, levels):
        term = jax.lax.dot_general(
            ap[i].astype(jnp.int8),
            bp[j].astype(jnp.int8),
            ((((ap[i].ndim - 1),), ((0,))), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc + (term << (log2_radix * (i + j)))
    return acc


@partial(jax.jit, static_argnames=("n_bits", "log2_radix", "levels"))
def l2r_matmul_int_stacked(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
) -> jax.Array:
    """Level-stacked MSDF integer matmul: bit-identical to
    :func:`l2r_matmul_int`, 2D-1 matmuls instead of D².

    Digit planes are extracted ONCE and pre-shifted to their significance
    (``A'_i = A_i << b*i``, ``B'_j = B_j << b*j``), then stacked along the
    contraction axis.  Every significance level ``s = i + j`` becomes a
    single matmul over a concatenated K axis:

        level s:  A'[i_lo..i_hi]  @  stack(B'_{s-i_lo} .. B'_{s-i_hi})

    Because both sides carry their shift, ``A'_i @ B'_j = (A_i @ B_j) <<
    b(i+j)`` exactly (int32 accumulate), so no per-term shift/add remains
    and the per-level contraction is one MXU-shaped pass of depth
    ``n_pairs(s) * K``.  ``levels`` truncation processes the identical
    pair set as the pair loop -> bit-identical progressive prefixes.
    """
    a_stack = stack_planes_lhs(aq, n_bits, log2_radix, shifted=False)
    b_rev = stack_planes_rhs(bq, n_bits, log2_radix, shifted=False)
    return stacked_gemm_planes(a_stack, b_rev, aq.shape[-1],
                               n_bits, log2_radix, levels, shifted=False)


def _f32_dot_exact(k: int, max_pairs: int, log2_radix: int) -> bool:
    """Can a level contraction of raw digits run exactly in float32?

    Every term of a level sum is a product of digits with magnitude
    <= radix-1, so any prefix of the accumulation is bounded by
    ``n_pairs(s) * K * (radix-1)^2``.  When that stays below 2^24 every
    intermediate is an exactly-representable f32 integer and the BLAS
    sgemm result is bit-exact — on CPU hosts this path is ~3x faster
    than XLA's int32 GEMM loop.
    """
    dmax = (1 << log2_radix) - 1
    return max_pairs * k * dmax * dmax < (1 << 24)


def stacked_gemm_planes(
    a_stack: jax.Array,
    b_rev: jax.Array,
    k: int,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    shifted: bool = True,
) -> jax.Array:
    """Level-stacked contraction over pre-stacked digit planes.

    a_stack: (..., M, D*K) ascending planes; b_rev: (D*K, N) descending
    (see quant.py:stack_planes_lhs/rhs); ``k`` is the un-stacked
    contraction length.  Exposed separately so callers that reuse a plane
    stack across many GEMMs (the fused conv's tap loop) extract planes
    once instead of once per call.

    ``shifted=True`` consumes pre-shifted bit-field planes (the MXU
    operand format): one int dot per level, no shifts at all.
    ``shifted=False`` consumes raw digits and shifts once per level; the
    small digit magnitudes let the contraction run through the f32 BLAS
    fast path when :func:`_f32_dot_exact` holds (guarded — falls back to
    int dots otherwise).  Both are bit-identical to the pair loop.
    """
    d = n_bits // log2_radix
    slices = msdf_level_slices(d, levels)
    acc = jnp.zeros((*a_stack.shape[:-1], b_rev.shape[-1]), jnp.int32)
    if not slices:  # levels=0: empty MSDF prefix, same as the pair loop
        return acc
    use_f32 = not shifted and _f32_dot_exact(
        k, max(hi - lo + 1 for _, lo, hi in slices), log2_radix)
    if use_f32:
        a_stack = a_stack.astype(jnp.float32)
        b_rev = b_rev.astype(jnp.float32)
    for (s, i_lo, i_hi) in slices:
        a_l = a_stack[..., i_lo * k:(i_hi + 1) * k]
        r0 = (d - 1 - s + i_lo) * k
        b_l = b_rev[r0:r0 + (i_hi - i_lo + 1) * k]
        term = jax.lax.dot_general(
            a_l, b_l,
            ((((a_l.ndim - 1),), ((0,))), ((), ())),
            preferred_element_type=jnp.float32 if use_f32 else jnp.int32,
            # HIGHEST pins true-f32 accumulation: DEFAULT would route
            # through TF32/bf16 passes on GPU/TPU and break bit-exactness
            precision=jax.lax.Precision.HIGHEST if use_f32 else None,
        )
        term = term.astype(jnp.int32)
        if not shifted:
            term = term << (log2_radix * s)
        acc = acc + term
    return acc


def l2r_matmul(
    x: jax.Array,
    w: jax.Array | None,
    cfg: QuantConfig = QuantConfig(),
    levels: int | None = None,
    w_q: tuple[jax.Array, jax.Array] | QuantizedWeights | None = None,
) -> jax.Array:
    """Float-in/float-out matmul computed through the L2R pipeline.

    x is quantized per-tensor on the fly; w may be pre-quantized
    (w_q = (wq, w_scale), e.g. per-channel at load time).  The result is
    dequantized to x.dtype.  With levels=None this is standard W8A8
    inference arithmetic, but computed via the MSDF plane stream.
    """
    # per-row (per-token) activation scales commute with the K-contraction
    xq, x_scale = quantize(x, cfg, axis=x.ndim - 2 if cfg.per_channel else None)
    if w_q is None:
        wq, w_scale = quantize(w, cfg, axis=-1)  # per-out-channel: (1, N)
    elif isinstance(w_q, QuantizedWeights):
        wq, w_scale = w_q.q, w_q.scale
    else:
        wq, w_scale = w_q
    out = l2r_matmul_int(xq, wq, cfg.n_bits, cfg.log2_radix, levels)
    return (out.astype(jnp.float32) * x_scale * w_scale).astype(x.dtype)


def l2r_dense(
    x: jax.Array,
    w: jax.Array | None,
    cfg: QuantConfig | None,
    levels: int | None = None,
    w_q: tuple[jax.Array, jax.Array] | QuantizedWeights | None = None,
) -> jax.Array:
    """Drop-in dense: bf16 einsum when cfg is None, L2R path otherwise.

    Used by the model stack (models/common.py:dense) so the paper's
    technique is a first-class switch on every architecture.  ``w_q``
    carries pre-quantized weights (core/quant.py:QuantizedWeights, built
    once at load) so the hot path skips per-forward weight quantization.
    """
    if cfg is None:
        return jax.lax.dot_general(
            x, w.astype(x.dtype),
            (((x.ndim - 1,), (0,)), ((), ())),
        )
    lead = x.shape[:-1]
    n = (w_q.q if isinstance(w_q, QuantizedWeights) else w_q[0]
         if w_q is not None else w).shape[-1]
    out = l2r_matmul(x.reshape(-1, x.shape[-1]), w, cfg, levels, w_q=w_q)
    return out.reshape(*lead, n)
