"""L2R digit-plane GEMM — the TPU-native mapping of the composite IPU.

The paper's unit computes p = sum_k A_k B_k by streaming partial-product
terms PP_{i,j} = sum_k A_{k,i} B_{k,j} most-significant-first.  At tensor
granularity the same decomposition over radix-2^b digits gives

    A @ B = sum_{i,j} (A_i @ B_j) * 2^{b (i+j)}

where A_i, B_j are small-integer digit planes: **each term is itself a
matmul**, i.e. an MXU-shaped operation, and the k-way counter circuit of
the paper becomes the K-contraction of the plane matmul.  Processing the
(i, j) pairs in decreasing significance s = i + j preserves the online
property: truncating the stream after `levels` significance levels yields
a result with a hard error bound (core/online.py:tail_bound).

This file is the pure-jnp reference/production implementation; the Pallas
VMEM-tiled kernel lives in repro/kernels/l2r_gemm/ and is validated
against this module.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .online import msdf_pairs
from .quant import QuantConfig, digit_planes, quantize

__all__ = ["l2r_matmul_int", "l2r_matmul", "l2r_dense"]


@partial(jax.jit, static_argnames=("n_bits", "log2_radix", "levels"))
def l2r_matmul_int(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
) -> jax.Array:
    """Exact (or MSDF-truncated) integer matmul via digit planes.

    Args:
      aq: (..., M, K) signed ints (int8/int16).
      bq: (K, N) signed ints.
      levels: number of MSDF significance levels to process
        (None or 2*D-1 -> exact; fewer -> progressive-precision prefix).

    Returns int32 (..., M, N); with levels=None this equals
    aq.astype(int32) @ bq.astype(int32) exactly.
    """
    d = n_bits // log2_radix
    ap = digit_planes(aq, n_bits, log2_radix)  # (D, ..., M, K) int8
    bp = digit_planes(bq, n_bits, log2_radix)  # (D, K, N) int8
    acc = jnp.zeros((*aq.shape[:-1], bq.shape[-1]), jnp.int32)
    for (i, j) in msdf_pairs(d, levels):
        term = jax.lax.dot_general(
            ap[i].astype(jnp.int8),
            bp[j].astype(jnp.int8),
            ((((ap[i].ndim - 1),), ((0,))), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc + (term << (log2_radix * (i + j)))
    return acc


def l2r_matmul(
    x: jax.Array,
    w: jax.Array,
    cfg: QuantConfig = QuantConfig(),
    levels: int | None = None,
    w_q: tuple[jax.Array, jax.Array] | None = None,
) -> jax.Array:
    """Float-in/float-out matmul computed through the L2R pipeline.

    x is quantized per-tensor on the fly; w may be pre-quantized
    (w_q = (wq, w_scale), e.g. per-channel at load time).  The result is
    dequantized to x.dtype.  With levels=None this is standard W8A8
    inference arithmetic, but computed via the MSDF plane stream.
    """
    # per-row (per-token) activation scales commute with the K-contraction
    xq, x_scale = quantize(x, cfg, axis=x.ndim - 2 if cfg.per_channel else None)
    if w_q is None:
        wq, w_scale = quantize(w, cfg, axis=-1)  # per-out-channel: (1, N)
    else:
        wq, w_scale = w_q
    out = l2r_matmul_int(xq, wq, cfg.n_bits, cfg.log2_radix, levels)
    return (out.astype(jnp.float32) * x_scale * w_scale).astype(x.dtype)


def l2r_dense(
    x: jax.Array,
    w: jax.Array,
    cfg: QuantConfig | None,
    levels: int | None = None,
) -> jax.Array:
    """Drop-in dense: bf16 einsum when cfg is None, L2R path otherwise.

    Used by the model stack (models/common.py:dense) so the paper's
    technique is a first-class switch on every architecture.
    """
    if cfg is None:
        return jax.lax.dot_general(
            x, w.astype(x.dtype),
            (((x.ndim - 1,), (0,)), ((), ())),
        )
    lead = x.shape[:-1]
    out = l2r_matmul(x.reshape(-1, x.shape[-1]), w, cfg, levels)
    return out.reshape(*lead, w.shape[-1])
