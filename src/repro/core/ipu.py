"""Cycle-accurate functional model of the L2R Composite Inner Product Unit.

This module reproduces — bit-true at the register level — the datapath of
Fig. 1 of the paper:

  * k parallel AND-plane partial products, summed by a **counter circuit**
    into one partial-product term PP_{i,j} = sum_k A_{k,i} * B_{k,j};
  * a **PPR register pair** in carry-save form, left-shifted each cycle;
  * a **residual register pair** in carry-save form, folded in (via the
    mux on its path) only every n-th cycle, at which point the PPR is
    reset through its zero-mux;
  * a **6:2 compressor** built from a chain of 3:2 carry-save adders —
    no carry propagation occurs anywhere in the per-cycle loop (the
    defining property of the LR/online datapath, and the source of the
    paper's 0.34 ns vs 3.23 ns critical-path advantage).

Cycle c processes bit pair (i, j) with i = c // n + 1 (activation bit,
MSB first), j = c % n + 1 (weight bit, MSB first); total n^2 cycles per
SOP, matching delta_IP = n^2 + delta_Mult (the extra delta_Mult cycles
are the compressor/counter pipeline latency, modeled in cycle_model.py).

The simulator is exact: after n^2 cycles  res_s + res_c == sum_k A_k*B_k
for unsigned n-bit operands (the hardware unit processes magnitudes; sign
handling lives in the surrounding PE, see core/l2r_gemm.py for the
signed digit-plane scheme used by the TPU mapping).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CIPUTrace", "simulate_cipu", "simulate_cipu_python", "stable_msb_count"]


def _csa(a, b, c):
    """3:2 carry-save adder (bitwise; value-preserving: a+b+c == s+cy)."""
    s = a ^ b ^ c
    cy = ((a & b) | (a & c) | (b & c)) << 1
    return s, cy


def _compress_6_2(x0, x1, x2, x3, x4, x5):
    """6:2 compressor as a CSA tree; value-preserving, no carry propagate."""
    s0, c0 = _csa(x0, x1, x2)
    s1, c1 = _csa(x3, x4, x5)
    s2, c2 = _csa(s0, c0, s1)
    s3, c3 = _csa(s2, c1, c2)
    return s3, c3


class CIPUTrace(NamedTuple):
    """Per-SOP simulation result.

    final:       exact inner product (== sum_k A_k * B_k).
    stable_bits: (n_cycles,) number of finalized (online-emittable) MSBs
                 after each cycle — demonstrates the online delay.
    """

    final: jax.Array
    stable_bits: jax.Array


@partial(jax.jit, static_argnames=("n_bits",))
def simulate_cipu(a: jax.Array, b: jax.Array, n_bits: int = 8) -> CIPUTrace:
    """Simulate the CIPU for a batch of SOP windows.

    Args:
      a: (..., k) unsigned activations, values in [0, 2**n_bits).
      b: (..., k) unsigned weights, same range.
      n_bits: operand precision n.

    Returns CIPUTrace with final == sum over k of a*b (exact) and the
    per-cycle count of stable output MSBs.
    """
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    n = n_bits
    k = a.shape[-1]
    out_bits = 2 * n + int(np.ceil(np.log2(max(k, 2))))  # SOP width
    if out_bits > 31:
        raise ValueError(
            f"SOP width {out_bits} exceeds int32 simulation range "
            f"(n_bits={n_bits}, k={k}); the hardware unit is n<=16, k<=72."
        )

    # Bit i (1-indexed, MSB first): (x >> (n - i)) & 1.
    cycles = np.arange(n * n)
    i_idx = cycles // n + 1
    j_idx = cycles % n + 1

    # Max possible contribution of all cycles strictly after cycle c
    # (weight of (i,j) in the final integer SOP is 2^(2n-i-j), count <= k).
    w = (2.0 ** (2 * n - i_idx - j_idx)) * k
    tail_after = (np.cumsum(w[::-1])[::-1] - w).astype(np.int64)
    tail_after = jnp.asarray(tail_after, jnp.int32)  # fits: k*(2^n-1)^2*n^2 small here
    i_arr = jnp.asarray(i_idx, jnp.int32)
    j_arr = jnp.asarray(j_idx, jnp.int32)

    batch_shape = a.shape[:-1]
    zeros = jnp.zeros(batch_shape, jnp.int32)

    def cycle(state, inputs):
        ppr_s, ppr_c, res_s, res_c = state
        i, j, tail = inputs
        # counter circuit: sum of k single-bit partial products
        a_bits = (a >> (n - i)) & 1
        b_bits = (b >> (n - j)) & 1
        cnt = jnp.sum(a_bits & b_bits, axis=-1)

        wrap = j == n  # last weight bit of this activation row
        # muxes: residual only enters the compressor on wrap cycles;
        # on wrap the PPR zero-mux resets the row accumulator.
        res_in_s = jnp.where(wrap, res_s << 1, 0)
        res_in_c = jnp.where(wrap, res_c << 1, 0)
        s, c = _compress_6_2(ppr_s << 1, ppr_c << 1, cnt, res_in_s, res_in_c, zeros)

        # register enables: wrap -> residual loads, PPR clears.
        new_ppr_s = jnp.where(wrap, 0, s)
        new_ppr_c = jnp.where(wrap, 0, c)
        new_res_s = jnp.where(wrap, s, res_s)
        new_res_c = jnp.where(wrap, c, res_c)

        # --- online-output bookkeeping (not part of the datapath) ---
        # value if every future counter output were zero:
        ppr_v = new_ppr_s + new_ppr_c
        res_v = new_res_s + new_res_c
        done_row_shift = jnp.where(wrap, n - i, n - i + 1)
        ppr_shift = jnp.where(wrap, 0, (n - j) + (n - i))
        v_hat = (res_v << done_row_shift) + jnp.where(
            wrap, 0, ppr_v << ppr_shift
        )
        stable = stable_msb_count(v_hat, v_hat + tail, out_bits)
        return (new_ppr_s, new_ppr_c, new_res_s, new_res_c), stable

    init = (zeros, zeros, zeros, zeros)
    (ppr_s, ppr_c, res_s, res_c), stable_bits = jax.lax.scan(
        cycle, init, (i_arr, j_arr, tail_after)
    )
    final = res_s + res_c
    return CIPUTrace(final=final, stable_bits=jnp.moveaxis(stable_bits, 0, -1))


def stable_msb_count(lo: jax.Array, hi: jax.Array, width: int) -> jax.Array:
    """Number of leading bits shared by all values in [lo, hi]."""
    diff = lo ^ hi
    # position of highest set bit of diff (0 if equal)
    nz = diff > 0
    top = jnp.where(nz, jnp.floor(jnp.log2(jnp.maximum(diff, 1))), -1)
    return (width - 1 - top).astype(jnp.int32).clip(0, width)


def simulate_cipu_python(a, b, n_bits: int = 8) -> int:
    """Plain-Python golden model (single SOP) for unit tests."""
    n = n_bits
    k = len(a)
    ppr_s = ppr_c = res_s = res_c = 0
    for c in range(n * n):
        i, j = c // n + 1, c % n + 1
        cnt = sum(((a[kk] >> (n - i)) & 1) & ((b[kk] >> (n - j)) & 1) for kk in range(k))
        wrap = j == n
        x3 = (res_s << 1) if wrap else 0
        x4 = (res_c << 1) if wrap else 0
        inputs = [ppr_s << 1, ppr_c << 1, cnt, x3, x4, 0]

        def csa(x, y, z):
            return x ^ y ^ z, ((x & y) | (x & z) | (y & z)) << 1

        s0, c0 = csa(inputs[0], inputs[1], inputs[2])
        s1, c1 = csa(inputs[3], inputs[4], inputs[5])
        s2, c2 = csa(s0, c0, s1)
        s3, c3 = csa(s2, c1, c2)
        if wrap:
            res_s, res_c, ppr_s, ppr_c = s3, c3, 0, 0
        else:
            ppr_s, ppr_c = s3, c3
    return res_s + res_c
