"""Quantization and digit-plane decomposition for L2R arithmetic.

The paper's composite inner product unit consumes n-bit fixed-point
operands digit-serially, most-significant-digit first.  On TPU we realize
the same decomposition as *digit planes*: an n-bit integer tensor is split
into D = n / log2(radix) planes of small digits such that

    x = sum_i plane[i] * radix**i            (exact, two's complement)

Low planes hold unsigned digits in [0, radix); the **top plane is signed**
(arithmetic shift) so the reconstruction is exact for negative values —
this is the tensor-level analogue of the sign handling in a Baugh-Wooley
style serial multiplier.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "QuantConfig",
    "QuantizedWeights",
    "quantize",
    "quantize_weights",
    "dequantize",
    "digit_planes",
    "from_digit_planes",
    "shifted_planes",
    "stack_planes_lhs",
    "stack_planes_rhs",
    "plane_count",
    "max_digit",
]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Configuration of the L2R digit-plane arithmetic.

    Attributes:
      n_bits:      operand precision (the paper evaluates n = 8).
      log2_radix:  bits per digit; 1 -> bit-serial (paper's datapath),
                   2 -> radix-4 (default TPU mapping), 4 -> radix-16.
      per_channel: quantize scales per output channel (axis -1) instead of
                   per tensor.
    """

    n_bits: int = 8
    log2_radix: int = 2
    per_channel: bool = True

    def __post_init__(self):
        if self.n_bits % self.log2_radix:
            raise ValueError(
                f"n_bits={self.n_bits} must be divisible by "
                f"log2_radix={self.log2_radix}"
            )

    @property
    def planes(self) -> int:
        return self.n_bits // self.log2_radix

    @property
    def radix(self) -> int:
        return 1 << self.log2_radix

    @property
    def qmax(self) -> int:
        return (1 << (self.n_bits - 1)) - 1

    @property
    def qmin(self) -> int:
        return -(1 << (self.n_bits - 1))


def plane_count(n_bits: int, log2_radix: int) -> int:
    return n_bits // log2_radix


def max_digit(log2_radix: int) -> int:
    return (1 << log2_radix) - 1


def _int_dtype(n_bits: int):
    return jnp.int8 if n_bits <= 8 else jnp.int16


def _symmetric_quant(xf: jax.Array, amax: jax.Array, cfg: QuantConfig):
    """Shared scale/round/clip core: the ONE place the quantization
    formula lives, so load-time weight caches (quantize_weights) stay
    bit-identical to on-the-fly quantization (quantize) by construction."""
    scale = jnp.maximum(amax, 1e-30) / cfg.qmax
    q = jnp.clip(jnp.round(xf / scale), cfg.qmin, cfg.qmax)
    return q.astype(_int_dtype(cfg.n_bits)), scale


@partial(jax.jit, static_argnames=("cfg", "axis"))
def quantize(x: jax.Array, cfg: QuantConfig = QuantConfig(), axis: int | None = None):
    """Symmetric quantization to n-bit signed integers.

    Returns (q, scale) with x ~= q * scale.  ``axis`` selects the
    reduction axes kept for the scale; ``None`` uses cfg.per_channel
    (scale per trailing axis) or per-tensor.
    """
    xf = x.astype(jnp.float32)
    if axis is None and cfg.per_channel and x.ndim >= 2:
        amax = jnp.max(jnp.abs(xf), axis=tuple(range(x.ndim - 1)), keepdims=True)
    elif axis is not None:
        reduce_axes = tuple(a for a in range(x.ndim) if a != axis % x.ndim)
        amax = jnp.max(jnp.abs(xf), axis=reduce_axes, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(xf))
    return _symmetric_quant(xf, amax, cfg)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


@partial(jax.jit, static_argnames=("n_bits", "log2_radix"))
def digit_planes(x: jax.Array, n_bits: int = 8, log2_radix: int = 2) -> jax.Array:
    """Decompose signed integers into digit planes, **least significant
    plane first** (plane index == significance i).

    Output shape: (D, *x.shape), small-int dtype (int8).  For all planes
    i < D-1 the digits are unsigned in [0, radix); the top plane is the
    arithmetic right shift (signed) so that

        sum_i out[i] << (log2_radix * i) == x        (exact)
    """
    d = plane_count(n_bits, log2_radix)
    r_mask = (1 << log2_radix) - 1
    xi = x.astype(jnp.int32)
    planes = [
        (xi >> (log2_radix * i)) & r_mask for i in range(d - 1)
    ]
    planes.append(xi >> (log2_radix * (d - 1)))  # arithmetic shift: signed top
    return jnp.stack(planes).astype(jnp.int8)


@partial(jax.jit, static_argnames=("n_bits", "log2_radix"))
def shifted_planes(x: jax.Array, n_bits: int = 8, log2_radix: int = 2) -> jax.Array:
    """Digit planes pre-shifted to their significance: ``out[i] = plane_i << b*i``.

    Each shifted plane is a bit-field of ``x`` (the top one sign-extended),
    so it fits in the same signed n-bit dtype as the input and

        sum_i out[i] == x                                (exact)

    This is the operand format of the level-stacked schedule: with both
    sides pre-shifted, ``A'_i @ B'_j == (A_i @ B_j) << b(i+j)`` and the
    per-term shift disappears from the inner loop entirely.
    """
    d = plane_count(n_bits, log2_radix)
    xi = x.astype(jnp.int32)
    r_mask = (1 << log2_radix) - 1
    planes = [xi & (r_mask << (log2_radix * i)) for i in range(d - 1)]
    # signed top bit-field: clear the low bits, keep the sign extension
    planes.append(xi - (xi & ((1 << (log2_radix * (d - 1))) - 1)))
    return jnp.stack(planes).astype(_int_dtype(n_bits))


@partial(jax.jit, static_argnames=("n_bits", "log2_radix", "shifted"))
def stack_planes_lhs(xq: jax.Array, n_bits: int = 8, log2_radix: int = 2,
                     shifted: bool = True) -> jax.Array:
    """LHS plane stack: (..., M, K) -> (..., M, D*K), plane i at columns
    ``[i*K, (i+1)*K)`` (ascending significance).

    ``shifted=True`` stacks pre-shifted bit-fields (the Pallas/MXU operand
    format: products land at their final weight).  ``shifted=False``
    stacks raw digits in [0, radix) — the small-magnitude format whose
    per-level sums fit the f32 exact-integer range, enabling the BLAS
    fast path of core/l2r_gemm.py:stacked_gemm_planes.
    """
    sp = (shifted_planes if shifted else digit_planes)(xq, n_bits, log2_radix)
    return jnp.concatenate(list(sp), axis=-1)


@partial(jax.jit, static_argnames=("n_bits", "log2_radix", "axis", "shifted"))
def stack_planes_rhs(wq: jax.Array, n_bits: int = 8, log2_radix: int = 2,
                     axis: int = 0, shifted: bool = True) -> jax.Array:
    """RHS plane stack: (K, N) -> (D*K, N), plane j at rows
    ``[(D-1-j)*K, (D-j)*K)`` (descending significance).

    The reversal makes every significance level a *contiguous* row slice
    paired against a contiguous column slice of the LHS stack: level s
    pairs LHS block i (ascending) with RHS block ``D-1-(s-i)`` (also
    ascending in i) — see online.py:msdf_level_slices.  ``axis`` selects
    the contraction axis to stack along (conv weights (kh, kw, cin, cout)
    stack their cin axis, axis=-2); ``shifted`` as in
    :func:`stack_planes_lhs`.
    """
    sp = (shifted_planes if shifted else digit_planes)(wq, n_bits, log2_radix)
    return jnp.concatenate(list(sp)[::-1], axis=axis if axis >= 0
                           else axis % wq.ndim)


@partial(jax.tree_util.register_dataclass, data_fields=("q", "scale"),
         meta_fields=())
@dataclasses.dataclass
class QuantizedWeights:
    """Pre-quantized matmul/conv weights: built ONCE at model load.

    ``q`` keeps the weight's natural shape ((K, N) dense, (kh, kw, cin,
    cout) conv); ``scale`` broadcasts against the output channels.
    Passing this through the model stack removes per-forward weight
    re-quantization (abs-max reduce + divide + round per call) from the
    traced hot path — weights quantize exactly once per load.
    """

    q: jax.Array
    scale: jax.Array

    @property
    def shape(self) -> tuple[int, ...]:
        return self.q.shape

    @property
    def ndim(self) -> int:
        return self.q.ndim


@partial(jax.jit, static_argnames=("cfg", "channel_axes"))
def quantize_weights(
    w: jax.Array,
    cfg: QuantConfig = QuantConfig(),
    channel_axes: tuple[int, ...] = (-1,),
) -> QuantizedWeights:
    """Symmetric per-channel weight quantization -> :class:`QuantizedWeights`.

    ``channel_axes`` are the axes that KEEP independent scales (default:
    the trailing output-channel axis; stacked-layer weights pass (0, -1)).
    Jitted and sharing :func:`_symmetric_quant` with :func:`quantize` so
    the cached scales are bit-identical to on-the-fly quantization (XLA
    folds the /qmax divide identically under jit).
    """
    wf = w.astype(jnp.float32)
    keep = {a % w.ndim for a in channel_axes}
    reduce_axes = tuple(a for a in range(w.ndim) if a not in keep)
    amax = jnp.max(jnp.abs(wf), axis=reduce_axes, keepdims=True)
    return QuantizedWeights(*_symmetric_quant(wf, amax, cfg))


@partial(jax.jit, static_argnames=("log2_radix",))
def from_digit_planes(planes: jax.Array, log2_radix: int = 2) -> jax.Array:
    """Exact inverse of :func:`digit_planes` (returns int32)."""
    d = planes.shape[0]
    acc = jnp.zeros(planes.shape[1:], jnp.int32)
    for i in range(d):
        acc = acc + (planes[i].astype(jnp.int32) << (log2_radix * i))
    return acc
