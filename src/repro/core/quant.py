"""Quantization and digit-plane decomposition for L2R arithmetic.

The paper's composite inner product unit consumes n-bit fixed-point
operands digit-serially, most-significant-digit first.  On TPU we realize
the same decomposition as *digit planes*: an n-bit integer tensor is split
into D = n / log2(radix) planes of small digits such that

    x = sum_i plane[i] * radix**i            (exact, two's complement)

Low planes hold unsigned digits in [0, radix); the **top plane is signed**
(arithmetic shift) so the reconstruction is exact for negative values —
this is the tensor-level analogue of the sign handling in a Baugh-Wooley
style serial multiplier.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "QuantConfig",
    "QuantizedWeights",
    "PlaneOperands",
    "quantize",
    "quantize_weights",
    "dequantize",
    "digit_planes",
    "from_digit_planes",
    "shifted_planes",
    "stack_planes_lhs",
    "stack_planes_rhs",
    "plane_count",
    "max_digit",
]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Configuration of the L2R digit-plane arithmetic.

    Attributes:
      n_bits:      operand precision (the paper evaluates n = 8).
      log2_radix:  bits per digit; 1 -> bit-serial (paper's datapath),
                   2 -> radix-4 (default TPU mapping), 4 -> radix-16.
      per_channel: quantize scales per output channel (axis -1) instead of
                   per tensor.
    """

    n_bits: int = 8
    log2_radix: int = 2
    per_channel: bool = True

    def __post_init__(self):
        if self.n_bits % self.log2_radix:
            raise ValueError(
                f"n_bits={self.n_bits} must be divisible by "
                f"log2_radix={self.log2_radix}"
            )

    @property
    def planes(self) -> int:
        return self.n_bits // self.log2_radix

    @property
    def radix(self) -> int:
        return 1 << self.log2_radix

    @property
    def qmax(self) -> int:
        return (1 << (self.n_bits - 1)) - 1

    @property
    def qmin(self) -> int:
        return -(1 << (self.n_bits - 1))


def plane_count(n_bits: int, log2_radix: int) -> int:
    return n_bits // log2_radix


def max_digit(log2_radix: int) -> int:
    return (1 << log2_radix) - 1


def _int_dtype(n_bits: int):
    return jnp.int8 if n_bits <= 8 else jnp.int16


def _symmetric_quant(xf: jax.Array, amax: jax.Array, cfg: QuantConfig):
    """Shared scale/round/clip core: the ONE place the quantization
    formula lives, so load-time weight caches (quantize_weights) stay
    bit-identical to on-the-fly quantization (quantize) by construction."""
    scale = jnp.maximum(amax, 1e-30) / cfg.qmax
    q = jnp.clip(jnp.round(xf / scale), cfg.qmin, cfg.qmax)
    return q.astype(_int_dtype(cfg.n_bits)), scale


@partial(jax.jit, static_argnames=("cfg", "axis"))
def quantize(x: jax.Array, cfg: QuantConfig = QuantConfig(), axis: int | None = None):
    """Symmetric quantization to n-bit signed integers.

    Returns (q, scale) with x ~= q * scale.  ``axis`` selects the
    reduction axes kept for the scale; ``None`` uses cfg.per_channel
    (scale per trailing axis) or per-tensor.
    """
    xf = x.astype(jnp.float32)
    if axis is None and cfg.per_channel and x.ndim >= 2:
        amax = jnp.max(jnp.abs(xf), axis=tuple(range(x.ndim - 1)), keepdims=True)
    elif axis is not None:
        reduce_axes = tuple(a for a in range(x.ndim) if a != axis % x.ndim)
        amax = jnp.max(jnp.abs(xf), axis=reduce_axes, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(xf))
    return _symmetric_quant(xf, amax, cfg)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


@partial(jax.jit, static_argnames=("n_bits", "log2_radix"))
def digit_planes(x: jax.Array, n_bits: int = 8, log2_radix: int = 2) -> jax.Array:
    """Decompose signed integers into digit planes, **least significant
    plane first** (plane index == significance i).

    Output shape: (D, *x.shape), small-int dtype (int8).  For all planes
    i < D-1 the digits are unsigned in [0, radix); the top plane is the
    arithmetic right shift (signed) so that

        sum_i out[i] << (log2_radix * i) == x        (exact)
    """
    d = plane_count(n_bits, log2_radix)
    r_mask = (1 << log2_radix) - 1
    xi = x.astype(jnp.int32)
    planes = [
        (xi >> (log2_radix * i)) & r_mask for i in range(d - 1)
    ]
    planes.append(xi >> (log2_radix * (d - 1)))  # arithmetic shift: signed top
    return jnp.stack(planes).astype(jnp.int8)


@partial(jax.jit, static_argnames=("n_bits", "log2_radix"))
def shifted_planes(x: jax.Array, n_bits: int = 8, log2_radix: int = 2) -> jax.Array:
    """Digit planes pre-shifted to their significance: ``out[i] = plane_i << b*i``.

    Each shifted plane is a bit-field of ``x`` (the top one sign-extended),
    so it fits in the same signed n-bit dtype as the input and

        sum_i out[i] == x                                (exact)

    This is the operand format of the level-stacked schedule: with both
    sides pre-shifted, ``A'_i @ B'_j == (A_i @ B_j) << b(i+j)`` and the
    per-term shift disappears from the inner loop entirely.
    """
    d = plane_count(n_bits, log2_radix)
    xi = x.astype(jnp.int32)
    r_mask = (1 << log2_radix) - 1
    planes = [xi & (r_mask << (log2_radix * i)) for i in range(d - 1)]
    # signed top bit-field: clear the low bits, keep the sign extension
    planes.append(xi - (xi & ((1 << (log2_radix * (d - 1))) - 1)))
    return jnp.stack(planes).astype(_int_dtype(n_bits))


@partial(jax.jit, static_argnames=("n_bits", "log2_radix", "shifted"))
def stack_planes_lhs(xq: jax.Array, n_bits: int = 8, log2_radix: int = 2,
                     shifted: bool = True) -> jax.Array:
    """LHS plane stack: (..., M, K) -> (..., M, D*K), plane i at columns
    ``[i*K, (i+1)*K)`` (ascending significance).

    ``shifted=True`` stacks pre-shifted bit-fields (the Pallas/MXU operand
    format: products land at their final weight).  ``shifted=False``
    stacks raw digits in [0, radix) — the small-magnitude format whose
    per-level sums fit the f32 exact-integer range, enabling the BLAS
    fast path of core/l2r_gemm.py:stacked_gemm_planes.
    """
    sp = (shifted_planes if shifted else digit_planes)(xq, n_bits, log2_radix)
    return jnp.concatenate(list(sp), axis=-1)


@partial(jax.jit, static_argnames=("n_bits", "log2_radix", "axis", "shifted"))
def stack_planes_rhs(wq: jax.Array, n_bits: int = 8, log2_radix: int = 2,
                     axis: int = 0, shifted: bool = True) -> jax.Array:
    """RHS plane stack: (K, N) -> (D*K, N), plane j at rows
    ``[(D-1-j)*K, (D-j)*K)`` (descending significance).

    The reversal makes every significance level a *contiguous* row slice
    paired against a contiguous column slice of the LHS stack: level s
    pairs LHS block i (ascending) with RHS block ``D-1-(s-i)`` (also
    ascending in i) — see online.py:msdf_level_slices.  ``axis`` selects
    the contraction axis to stack along (conv weights (kh, kw, cin, cout)
    stack their cin axis, axis=-2); ``shifted`` as in
    :func:`stack_planes_lhs`.
    """
    sp = (shifted_planes if shifted else digit_planes)(wq, n_bits, log2_radix)
    return jnp.concatenate(list(sp)[::-1], axis=axis if axis >= 0
                           else axis % wq.ndim)


@partial(jax.tree_util.register_dataclass, data_fields=("stack",),
         meta_fields=("side", "n_bits", "log2_radix", "k", "axis", "shifted",
                      "pad_planes"))
@dataclasses.dataclass(frozen=True)
class PlaneOperands:
    """A digit-plane stack as a first-class operand.

    The L2R schedules never consume raw int tensors — every one of them
    walks a *plane stack* (ascending LHS / descending RHS, see
    :func:`stack_planes_lhs` / :func:`stack_planes_rhs`).  This record
    makes that stack an explicit, reusable operand so callers that feed
    the same tensor into many GEMM calls (the fused conv's kh*kw taps,
    the decode loop's per-step weight matmuls) extract planes once and
    pass the stack everywhere.

    Fields (``stack`` is the only array; the rest are static pytree meta,
    so jit traces key on the layout):

      side:       "lhs" (ascending planes on the last axis) or "rhs"
                  (descending planes on the contraction axis).
      k:          the un-stacked contraction length (stack axis length is
                  ``(d + pad_planes) * k``).
      axis:       the stacking axis, counted FROM THE END (negative) so
                  the meta survives leading-axis slicing (e.g. scanning a
                  stacked-layer weight cache strips the layer axis).
      shifted:    True -> pre-shifted bit-field planes (the Pallas/MXU
                  operand format); False -> raw digits in [0, radix)
                  (small magnitudes: the jnp f32-BLAS fast-path format).
      pad_planes: trailing zero plane blocks appended after the D real
                  planes (the streaming emitters read fixed-width windows
                  of a (2D-1)-block stack; caches built with
                  ``window_pad=True`` carry the zeros so per-step
                  streaming needs no padding copy).

    The two layouts convert exactly in both directions (a shifted plane
    is its raw digit ``<< b*i``, a bit-field of the operand, so both fit
    the operand dtype); every consumer therefore accepts either and
    converts with :meth:`core_stack` / :meth:`window_stack`.
    """

    stack: jax.Array
    side: str
    n_bits: int
    log2_radix: int
    k: int
    axis: int
    shifted: bool
    pad_planes: int

    @property
    def d(self) -> int:
        return plane_count(self.n_bits, self.log2_radix)

    @classmethod
    def prepare_lhs(cls, aq: jax.Array, n_bits: int = 8, log2_radix: int = 2,
                    shifted: bool = False,
                    window_pad: bool = False) -> "PlaneOperands":
        """Stack LHS planes once: (..., M, K) -> (..., M, D*K) operand."""
        st = stack_planes_lhs(aq, n_bits, log2_radix, shifted=shifted)
        d = plane_count(n_bits, log2_radix)
        k = aq.shape[-1]
        pad = d - 1 if window_pad else 0
        if pad:
            st = jnp.pad(st, [(0, 0)] * (st.ndim - 1) + [(0, pad * k)])
        return cls(st, "lhs", n_bits, log2_radix, k, -1, shifted, pad)

    @classmethod
    def prepare_rhs(cls, wq: jax.Array, n_bits: int = 8, log2_radix: int = 2,
                    axis: int = 0, shifted: bool = False,
                    window_pad: bool = False) -> "PlaneOperands":
        """Stack RHS planes once: contraction ``axis`` grows to D*K
        (descending significance — every level a contiguous slice)."""
        ax = axis if axis < 0 else axis - wq.ndim
        st = stack_planes_rhs(wq, n_bits, log2_radix, axis=ax, shifted=shifted)
        d = plane_count(n_bits, log2_radix)
        k = wq.shape[ax]
        pad = d - 1 if window_pad else 0
        if pad:
            pads = [(0, 0)] * st.ndim
            pads[ax % st.ndim] = (0, pad * k)
            st = jnp.pad(st, pads)
        return cls(st, "rhs", n_bits, log2_radix, k, ax, shifted, pad)

    def describe(self) -> str:
        """One-line layout summary for mismatch errors: the digit config
        AND the stack shape, so a failed :meth:`matches` can say exactly
        which side is wrong (see the dispatcher / streaming raise sites)."""
        return (f"PlaneOperands(side={self.side!r}, n_bits={self.n_bits}, "
                f"log2_radix={self.log2_radix}, k={self.k}, "
                f"axis={self.axis}, shifted={self.shifted}, "
                f"pad_planes={self.pad_planes}, "
                f"stack.shape={tuple(self.stack.shape)})")

    def matches(self, n_bits: int, log2_radix: int, ndim: int | None = None,
                side: str | None = None,
                contract_axis: int | None = None) -> bool:
        """Is this stack usable for a call with the given digit config
        (and optionally rank / side / contraction-axis position)?  The
        ONE compatibility predicate every consumer guards on — a stack
        built for another radix walks the level schedule wrong, so
        mismatches must fall back to the raw weight or raise."""
        if (self.n_bits, self.log2_radix) != (n_bits, log2_radix):
            return False
        if ndim is not None and self.stack.ndim != ndim:
            return False
        if side is not None and self.side != side:
            return False
        if contract_axis is not None \
                and self.axis % self.stack.ndim != contract_axis:
            return False
        return True

    def with_layout(self, shifted: bool) -> "PlaneOperands":
        """Exact raw-digit <-> pre-shifted conversion (chunk-wise shifts;
        bit-fields stay in the operand dtype, zero pad blocks unaffected)."""
        if shifted == self.shifted:
            return self
        ax = self.axis % self.stack.ndim
        n_chunks = self.d + self.pad_planes
        shp = self.stack.shape
        r = self.stack.reshape(*shp[:ax], n_chunks, self.k, *shp[ax + 1:])
        if self.side == "lhs":
            amt = [self.log2_radix * i if i < self.d else 0
                   for i in range(n_chunks)]
        else:
            amt = [self.log2_radix * (self.d - 1 - i) if i < self.d else 0
                   for i in range(n_chunks)]
        # raw low digits are non-negative and the top chunk is a sign-
        # extended bit-field, so arithmetic shifts are exact both ways.
        # Layout dtypes differ: raw digits live in int8 (digit_planes),
        # shifted bit-fields in the operand dtype (shifted_planes) — cast
        # BEFORE the left shift so high-significance chunks don't wrap.
        if shifted:
            r = r.astype(_int_dtype(self.n_bits))
        sh = jnp.asarray(amt, r.dtype).reshape(
            (1,) * ax + (n_chunks,) + (1,) * (r.ndim - ax - 1))
        out = jnp.left_shift(r, sh) if shifted \
            else jnp.right_shift(r, sh).astype(jnp.int8)
        return dataclasses.replace(self, stack=out.reshape(shp),
                                   shifted=shifted)

    def core_stack(self, shifted: bool) -> jax.Array:
        """The D-plane stack (window padding sliced off) in the requested
        layout — the stacked-schedule operand."""
        po = self.with_layout(shifted)
        if self.pad_planes == 0:
            return po.stack
        ax = self.axis % self.stack.ndim
        return jax.lax.slice_in_dim(po.stack, 0, self.d * self.k, axis=ax)

    def window_stack(self) -> jax.Array:
        """Raw-digit stack zero-padded to the fixed (2D-1)-block streaming
        window — the streaming-emitter operand (core/progressive.py)."""
        st = self.with_layout(False).stack
        need = (self.d - 1) - self.pad_planes
        if need > 0:
            ax = self.axis % st.ndim
            pads = [(0, 0)] * st.ndim
            pads[ax] = (0, need * self.k)
            st = jnp.pad(st, pads)
        return st


@partial(jax.tree_util.register_dataclass, data_fields=("q", "scale", "planes"),
         meta_fields=())
@dataclasses.dataclass
class QuantizedWeights:
    """Pre-quantized matmul/conv weights: built ONCE at model load.

    ``q`` keeps the weight's natural shape ((K, N) dense, (kh, kw, cin,
    cout) conv); ``scale`` broadcasts against the output channels.
    Passing this through the model stack removes per-forward weight
    re-quantization (abs-max reduce + divide + round per call) from the
    traced hot path — weights quantize exactly once per load.

    ``planes`` optionally caches the reversed RHS digit-plane stack
    (:class:`PlaneOperands`, built by ``quantize_weights(...,
    prestack=True)``): consumers then skip per-call plane extraction too
    — the stack is extracted exactly once per process.  Costs D x (or
    2D-1 x with ``window_pad``) the int8 weight bytes; ``None`` keeps
    the extract-per-call behavior.
    """

    q: jax.Array
    scale: jax.Array
    planes: PlaneOperands | None = None

    @property
    def shape(self) -> tuple[int, ...]:
        return self.q.shape

    @property
    def ndim(self) -> int:
        return self.q.ndim


@partial(jax.jit, static_argnames=("cfg", "channel_axes", "prestack",
                                   "plane_axis", "window_pad",
                                   "plane_shifted", "shard", "mesh"))
def quantize_weights(
    w: jax.Array,
    cfg: QuantConfig = QuantConfig(),
    channel_axes: tuple[int, ...] = (-1,),
    prestack: bool = False,
    plane_axis: int | None = None,
    window_pad: bool = False,
    plane_shifted: bool = False,
    shard: tuple | None = None,
    mesh=None,
) -> QuantizedWeights:
    """Symmetric per-channel weight quantization -> :class:`QuantizedWeights`.

    ``channel_axes`` are the axes that KEEP independent scales (default:
    the trailing output-channel axis; stacked-layer weights pass (0, -1)).
    Jitted and sharing :func:`_symmetric_quant` with :func:`quantize` so
    the cached scales are bit-identical to on-the-fly quantization (XLA
    folds the /qmax divide identically under jit).

    ``prestack=True`` additionally caches the reversed RHS plane stack
    (:class:`PlaneOperands`) along ``plane_axis`` (the contraction axis:
    default 0, conv weights pass -2, stacked-layer weights 1);
    ``window_pad`` appends the streaming window's zero plane blocks so
    per-step streaming consumers skip the padding copy too.
    ``plane_shifted`` picks the cached layout: False (default) stores
    raw digits — consumed as-is by the jnp f32-fast-path and streaming
    schedules, converted per call (exact chunk shifts) on Pallas; True
    stores the pre-shifted Pallas/MXU layout, moving that conversion to
    load time — the right choice when the deployment backend is
    ``pallas-tpu`` (jnp consumers then convert instead, equally exact).

    ``shard`` + ``mesh`` pin the cache's sharding at build time: a
    PartitionSpec-style tuple over the RAW weight's dims (e.g. ``(None,
    "model")`` for an LM head (K, V) — the vocab shard of the sharded
    serving path), applied to ``q``, ``scale``, and the plane stack.
    Stacking happens along the contraction axis, so the raw-weight spec
    carries over to the stack unchanged (the stacked axis keeps its
    entry; non-divisible dims replicate via the hint guard).  Both are
    STATIC jit args — the trace cache keys on the mesh, so building the
    same weight under a different (or no) mesh never reuses a stale
    sharded trace.  Sharding never changes values: every consumer is
    bit-identical to the replicated cache.
    """
    from repro.sharding.ctx import constrain

    wf = w.astype(jnp.float32)
    keep = {a % w.ndim for a in channel_axes}
    reduce_axes = tuple(a for a in range(w.ndim) if a not in keep)
    amax = jnp.max(jnp.abs(wf), axis=reduce_axes, keepdims=True)
    q, scale = _symmetric_quant(wf, amax, cfg)
    if shard is not None and mesh is not None:
        q = constrain(q, mesh, *shard)
        scale = constrain(scale, mesh, *shard)
    planes = None
    if prestack:
        # trace-time int32 soundness certificate for the cached stack's
        # contraction (analysis/overflow.py; deferred import — analysis
        # imports this module).  window_pad adds zero planes only and
        # never changes the bound.
        from repro.analysis.overflow import check_or_raise as _certify
        _certify(cfg.n_bits, cfg.log2_radix,
                 int(w.shape[0 if plane_axis is None else plane_axis]),
                 where="quantize_weights")
        planes = PlaneOperands.prepare_rhs(
            q, cfg.n_bits, cfg.log2_radix,
            axis=0 if plane_axis is None else plane_axis,
            shifted=plane_shifted, window_pad=window_pad)
        if shard is not None and mesh is not None:
            planes = dataclasses.replace(
                planes, stack=constrain(planes.stack, mesh, *shard))
    return QuantizedWeights(q, scale, planes)


@partial(jax.jit, static_argnames=("log2_radix",))
def from_digit_planes(planes: jax.Array, log2_radix: int = 2) -> jax.Array:
    """Exact inverse of :func:`digit_planes` (returns int32)."""
    d = planes.shape[0]
    acc = jnp.zeros(planes.shape[1:], jnp.int32)
    for i in range(d):
        acc = acc + (planes[i].astype(jnp.int32) << (log2_radix * i))
    return acc
