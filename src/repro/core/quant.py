"""Quantization and digit-plane decomposition for L2R arithmetic.

The paper's composite inner product unit consumes n-bit fixed-point
operands digit-serially, most-significant-digit first.  On TPU we realize
the same decomposition as *digit planes*: an n-bit integer tensor is split
into D = n / log2(radix) planes of small digits such that

    x = sum_i plane[i] * radix**i            (exact, two's complement)

Low planes hold unsigned digits in [0, radix); the **top plane is signed**
(arithmetic shift) so the reconstruction is exact for negative values —
this is the tensor-level analogue of the sign handling in a Baugh-Wooley
style serial multiplier.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "QuantConfig",
    "quantize",
    "dequantize",
    "digit_planes",
    "from_digit_planes",
    "plane_count",
    "max_digit",
]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Configuration of the L2R digit-plane arithmetic.

    Attributes:
      n_bits:      operand precision (the paper evaluates n = 8).
      log2_radix:  bits per digit; 1 -> bit-serial (paper's datapath),
                   2 -> radix-4 (default TPU mapping), 4 -> radix-16.
      per_channel: quantize scales per output channel (axis -1) instead of
                   per tensor.
    """

    n_bits: int = 8
    log2_radix: int = 2
    per_channel: bool = True

    def __post_init__(self):
        if self.n_bits % self.log2_radix:
            raise ValueError(
                f"n_bits={self.n_bits} must be divisible by "
                f"log2_radix={self.log2_radix}"
            )

    @property
    def planes(self) -> int:
        return self.n_bits // self.log2_radix

    @property
    def radix(self) -> int:
        return 1 << self.log2_radix

    @property
    def qmax(self) -> int:
        return (1 << (self.n_bits - 1)) - 1

    @property
    def qmin(self) -> int:
        return -(1 << (self.n_bits - 1))


def plane_count(n_bits: int, log2_radix: int) -> int:
    return n_bits // log2_radix


def max_digit(log2_radix: int) -> int:
    return (1 << log2_radix) - 1


def _int_dtype(n_bits: int):
    return jnp.int8 if n_bits <= 8 else jnp.int16


@partial(jax.jit, static_argnames=("cfg", "axis"))
def quantize(x: jax.Array, cfg: QuantConfig = QuantConfig(), axis: int | None = None):
    """Symmetric quantization to n-bit signed integers.

    Returns (q, scale) with x ~= q * scale.  ``axis`` selects the
    reduction axes kept for the scale; ``None`` uses cfg.per_channel
    (scale per trailing axis) or per-tensor.
    """
    xf = x.astype(jnp.float32)
    if axis is None and cfg.per_channel and x.ndim >= 2:
        amax = jnp.max(jnp.abs(xf), axis=tuple(range(x.ndim - 1)), keepdims=True)
    elif axis is not None:
        reduce_axes = tuple(a for a in range(x.ndim) if a != axis % x.ndim)
        amax = jnp.max(jnp.abs(xf), axis=reduce_axes, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-30) / cfg.qmax
    q = jnp.clip(jnp.round(xf / scale), cfg.qmin, cfg.qmax)
    return q.astype(_int_dtype(cfg.n_bits)), scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


@partial(jax.jit, static_argnames=("n_bits", "log2_radix"))
def digit_planes(x: jax.Array, n_bits: int = 8, log2_radix: int = 2) -> jax.Array:
    """Decompose signed integers into digit planes, **least significant
    plane first** (plane index == significance i).

    Output shape: (D, *x.shape), small-int dtype (int8).  For all planes
    i < D-1 the digits are unsigned in [0, radix); the top plane is the
    arithmetic right shift (signed) so that

        sum_i out[i] << (log2_radix * i) == x        (exact)
    """
    d = plane_count(n_bits, log2_radix)
    r_mask = (1 << log2_radix) - 1
    xi = x.astype(jnp.int32)
    planes = [
        (xi >> (log2_radix * i)) & r_mask for i in range(d - 1)
    ]
    planes.append(xi >> (log2_radix * (d - 1)))  # arithmetic shift: signed top
    return jnp.stack(planes).astype(jnp.int8)


@partial(jax.jit, static_argnames=("log2_radix",))
def from_digit_planes(planes: jax.Array, log2_radix: int = 2) -> jax.Array:
    """Exact inverse of :func:`digit_planes` (returns int32)."""
    d = planes.shape[0]
    acc = jnp.zeros(planes.shape[1:], jnp.int32)
    for i in range(d):
        acc = acc + (planes[i].astype(jnp.int32) << (log2_radix * i))
    return acc
