"""Core L2R (left-to-right / MSDF online arithmetic) library.

The paper's contribution, reproduced at three levels:
  * bit/register-true: ipu.py (cycle-accurate composite IPU),
  * tensor/TPU-native: quant.py + online.py + l2r_gemm.py + progressive.py
    (digit-plane GEMM with MSDF ordering and early output),
  * accelerator model: cycle_model.py + hw_model.py (Tables I/II).
"""

from .quant import (QuantConfig, QuantizedWeights, quantize, quantize_weights,
                    dequantize, digit_planes, from_digit_planes,
                    shifted_planes, stack_planes_lhs, stack_planes_rhs)
from .online import (msdf_pairs, msdf_levels, msdf_level_slices, tail_bound,
                     online_delay)
from .ipu import simulate_cipu, simulate_cipu_python, CIPUTrace
from .l2r_gemm import l2r_matmul_int, l2r_matmul_int_stacked, l2r_matmul, l2r_dense
from .progressive import progressive_matmul, earliest_decision_level, ProgressiveResult
from .cycle_model import (
    AcceleratorConfig,
    ConvLayer,
    VGG16_CONV_LAYERS,
    layer_cycles,
    network_cycles,
    peak_gops,
    effective_gops,
    inference_seconds,
)
from . import hw_model

__all__ = [
    "QuantConfig", "QuantizedWeights", "quantize", "quantize_weights",
    "dequantize", "digit_planes", "from_digit_planes",
    "shifted_planes", "stack_planes_lhs", "stack_planes_rhs",
    "msdf_pairs", "msdf_levels", "msdf_level_slices", "tail_bound", "online_delay",
    "simulate_cipu", "simulate_cipu_python", "CIPUTrace",
    "l2r_matmul_int", "l2r_matmul_int_stacked", "l2r_matmul", "l2r_dense",
    "progressive_matmul", "earliest_decision_level", "ProgressiveResult",
    "AcceleratorConfig", "ConvLayer", "VGG16_CONV_LAYERS",
    "layer_cycles", "network_cycles", "peak_gops", "effective_gops",
    "inference_seconds", "hw_model",
]
