"""Analytical area/power/latency model reproducing Tables I and II.

Tables I/II of the paper are Synopsys DC synthesis results on NanGate
45 nm at 400 MHz — not re-synthesizable in this environment.  We
reproduce them with a component-level model plus a small, explicit set of
calibrated constants:

  structural (parameter-free):
    * gate inventory of one CIPU PE: AND plane, k:2 counter tree, 6:2
      compressor row, carry-save PPR/residual register *pairs*, gating
      muxes (Fig. 1 of the paper);
    * gate inventory of the baseline bit-serial PE (Loom pattern [3]):
      AND plane, counter tree, carry-propagate accumulator, full
      partial-product-array storage (R2L cannot retire digits early — the
      storage L2R saves), pipeline stage latches;
    * critical paths: L2R = AND + 3 CSA stages + mux (constant in n);
      baseline = AND + unpipelined counter tree + 2n+log2(k)-bit CPA.

  calibrated (each documented, fitted once against Table I):
    * O      — buffer/interconnect/control area shared by both designs;
    * S      — baseline synthesis-slack storage bits (cells the coarse
               inventory misses: clock gating, deskew, scan);
    * P_buf  — SRAM + clock-tree power shared by both designs;
    * alpha_base, alpha_l2r — lumped switching-activity coefficients
      (they absorb glitching, clock power and wire load, so they exceed 1
      and are not comparable across the two inventories; the physically
      meaningful outcome is per-PE power: 354 µW (L2R) vs 588 µW
      (baseline), the carry-save activity advantage of LR datapaths [2]).

With those, Table I is matched exactly (by construction) and every
derived Table II column (peak GOPS, TOPS/W, GOPS/mm²) is a *prediction*
checked against the paper in tests/test_cycle_model.py.
"""

from __future__ import annotations

import dataclasses
import math

from .cycle_model import AcceleratorConfig, peak_gops

__all__ = [
    "NanGate45",
    "PEInventory",
    "cipu_pe_inventory",
    "baseline_pe_inventory",
    "calibration",
    "accelerator_area_um2",
    "accelerator_power_mw",
    "critical_path_ns",
    "table1",
    "table2",
    "PAPER_TABLE1",
    "PAPER_TABLE2",
]


@dataclasses.dataclass(frozen=True)
class NanGate45:
    """NanGate 45 nm open cell library unit costs (typical corner).

    Areas in µm²; energies in fJ per (lumped) active cycle; delays in ns.
    """

    area_fa: float = 4.256
    area_dff: float = 4.522
    area_and2: float = 0.798
    area_xor2: float = 1.596
    area_mux2: float = 1.862
    energy_fa: float = 2.2
    energy_dff: float = 1.6
    energy_and2: float = 0.35
    energy_xor2: float = 0.9
    energy_mux2: float = 0.55
    delay_and2: float = 0.032
    delay_mux2: float = 0.045
    delay_fa_sum: float = 0.085  # one CSA stage
    delay_cpa_per_bit: float = 0.095  # ripple carry per bit


@dataclasses.dataclass(frozen=True)
class PEInventory:
    fa: int = 0
    dff: float = 0
    and2: int = 0
    xor2: int = 0
    mux2: int = 0

    def area(self, lib: NanGate45) -> float:
        return (
            self.fa * lib.area_fa
            + self.dff * lib.area_dff
            + self.and2 * lib.area_and2
            + self.xor2 * lib.area_xor2
            + self.mux2 * lib.area_mux2
        )

    def energy_fj(self, lib: NanGate45) -> float:
        """Energy per cycle at unit activity."""
        return (
            self.fa * lib.energy_fa
            + self.dff * lib.energy_dff
            + self.and2 * lib.energy_and2
            + self.xor2 * lib.energy_xor2
            + self.mux2 * lib.energy_mux2
        )


def cipu_pe_inventory(cfg: AcceleratorConfig = AcceleratorConfig()) -> PEInventory:
    """One composite IPU (paper Fig. 1): k·k·T_n = 72 bit products/cycle."""
    n = cfg.n_bits
    k = cfg.macs_per_pe  # 72
    w = 2 * n  # PPR / residual width (paper: 2x operand width)
    return PEInventory(
        fa=(k - 2) + 4 * w,  # counter tree (k:2 CSA) + 6:2 compressor row
        dff=4 * w,  # PPR pair + residual pair (carry-save)
        and2=k,  # AND plane
        mux2=2 * w,  # residual gating + PPR zero mux
    )


def _baseline_structural(cfg: AcceleratorConfig) -> PEInventory:
    n = cfg.n_bits
    k = cfg.macs_per_pe
    w = 2 * n + math.ceil(math.log2(k))  # CPA/accumulator width
    return PEInventory(
        fa=(k - 2) + w + 2 * w,  # counter tree + CPA + stage adders
        dff=5 * w + 2 * n * n,  # acc, stage latches, output + full PP array
        and2=k,
        mux2=w // 2,
    )


# ---------------- calibration ----------------

_PAPER_AREA = {"baseline": 324_379.52, "l2r_cipu": 244_394.24}
_PAPER_POWER = {"baseline": 55.61, "l2r_cipu": 40.67}
_BUFFER_POWER_MW = 18.0  # SRAM + clock tree, shared by both designs


def calibration(cfg: AcceleratorConfig = AcceleratorConfig(), lib: NanGate45 = NanGate45()):
    """Solve the calibrated constants (see module docstring).

    Returns dict with overhead area O, baseline slack bits S, activity
    coefficients, and the L2R/baseline activity ratio.
    """
    a_l2r = cipu_pe_inventory(cfg).area(lib)
    o = _PAPER_AREA["l2r_cipu"] - cfg.pes * a_l2r
    a_base_target = (_PAPER_AREA["baseline"] - o) / cfg.pes
    a_base_struct = _baseline_structural(cfg).area(lib)
    slack_bits = (a_base_target - a_base_struct) / lib.area_dff

    e_l2r = cipu_pe_inventory(cfg).energy_fj(lib)
    base_inv = baseline_pe_inventory(cfg, lib)
    e_base = base_inv.energy_fj(lib)
    mw = lambda e_fj, alpha: alpha * e_fj * cfg.freq_hz * cfg.pes / 1e12
    alpha_base = (_PAPER_POWER["baseline"] - _BUFFER_POWER_MW) / mw(e_base, 1.0)
    alpha_l2r = (_PAPER_POWER["l2r_cipu"] - _BUFFER_POWER_MW) / mw(e_l2r, 1.0)
    return dict(
        overhead_area_um2=o,
        baseline_slack_bits=slack_bits,
        alpha_base=alpha_base,
        alpha_l2r=alpha_l2r,
        activity_ratio=alpha_l2r / alpha_base,
    )


def baseline_pe_inventory(
    cfg: AcceleratorConfig = AcceleratorConfig(), lib: NanGate45 = NanGate45()
) -> PEInventory:
    """Structural baseline PE + calibrated slack storage."""
    s = _baseline_structural(cfg)
    a_l2r = cipu_pe_inventory(cfg).area(lib)
    o = _PAPER_AREA["l2r_cipu"] - cfg.pes * a_l2r
    a_base_target = (_PAPER_AREA["baseline"] - o) / cfg.pes
    slack_bits = max(0.0, (a_base_target - s.area(lib)) / lib.area_dff)
    return dataclasses.replace(s, dff=s.dff + slack_bits)


def accelerator_area_um2(
    l2r: bool = True,
    cfg: AcceleratorConfig = AcceleratorConfig(),
    lib: NanGate45 = NanGate45(),
) -> float:
    cal = calibration(cfg, lib)
    inv = cipu_pe_inventory(cfg) if l2r else baseline_pe_inventory(cfg, lib)
    return inv.area(lib) * cfg.pes + cal["overhead_area_um2"]


def accelerator_power_mw(
    l2r: bool = True,
    cfg: AcceleratorConfig = AcceleratorConfig(),
    lib: NanGate45 = NanGate45(),
) -> float:
    cal = calibration(cfg, lib)
    if l2r:
        inv, alpha = cipu_pe_inventory(cfg), cal["alpha_l2r"]
    else:
        inv, alpha = baseline_pe_inventory(cfg, lib), cal["alpha_base"]
    return alpha * inv.energy_fj(lib) * cfg.freq_hz * cfg.pes / 1e12 + _BUFFER_POWER_MW


def critical_path_ns(
    l2r: bool = True,
    cfg: AcceleratorConfig = AcceleratorConfig(),
    lib: NanGate45 = NanGate45(),
) -> float:
    """Structural (un-calibrated) critical path — the model's prediction
    of Table I latency.

    L2R: AND plane + ~3 CSA stages visible in one cycle (the counter tree
    is pipelined across the delta_Mult online-delay cycles) + gating mux.
    Baseline: AND + full counter tree (no digit-level pipelining in the
    R2L pattern) + (2n + log2 k)-bit carry chain + output mux.
    """
    if l2r:
        return lib.delay_and2 + 3 * lib.delay_fa_sum + lib.delay_mux2
    k = cfg.macs_per_pe
    tree_depth = math.ceil(math.log(k / 2, 1.5))  # k:2 CSA reduction depth
    w = 2 * cfg.n_bits + math.ceil(math.log2(k))
    return (
        lib.delay_and2
        + tree_depth * lib.delay_fa_sum
        + w * lib.delay_cpa_per_bit
        + lib.delay_mux2
    )


# ----- paper-printed values (for tests / reports) -----
PAPER_TABLE1 = {
    "baseline": {"latency_ns": 3.23, "area_um2": 324_379.52, "power_mw": 55.61},
    "l2r_cipu": {"latency_ns": 0.34, "area_um2": 244_394.24, "power_mw": 40.67},
}

PAPER_TABLE2 = {
    "cheng2024": dict(tech_nm=40, freq_mhz=500, bits=8, gops=7.87, time_ms=None,
                      power_mw=91.84, tops_w=0.08, gops_mm2=19.19, network="LENET-5"),
    "eyeriss": dict(tech_nm=65, freq_mhz=200, bits=16, gops=46.04, time_ms=4309,
                    power_mw=236.0, tops_w=0.19, gops_mm2=3.75, network="VGG-16"),
    "baseline": dict(tech_nm=45, freq_mhz=400, bits=8, gops=14.40, time_ms=2.24,
                     power_mw=55.61, tops_w=0.25, gops_mm2=44.40, network="VGG-16"),
    "l2r_cipu": dict(tech_nm=45, freq_mhz=400, bits=8, gops=48.97, time_ms=0.86,
                     power_mw=40.67, tops_w=1.20, gops_mm2=200.45, network="VGG-16"),
}


def table1(cfg: AcceleratorConfig = AcceleratorConfig(), lib: NanGate45 = NanGate45()):
    """Model's reproduction of Table I (area/power calibrated; latency predicted)."""
    out = {}
    for name, l2r in (("baseline", False), ("l2r_cipu", True)):
        out[name] = {
            "latency_ns": critical_path_ns(l2r, cfg, lib),
            "area_um2": accelerator_area_um2(l2r, cfg, lib),
            "power_mw": accelerator_power_mw(l2r, cfg, lib),
        }
    return out


def table2(cfg: AcceleratorConfig = AcceleratorConfig(), lib: NanGate45 = NanGate45()):
    """Model's reproduction of the derivable Table II rows.

    GOPS comes from the cycle model (prediction), TOPS/W and GOPS/mm²
    derive from GOPS / calibrated power & area.  External rows [4][5] are
    carried as published constants (PAPER_TABLE2).
    """
    out = {}
    for name, l2r in (("baseline", False), ("l2r_cipu", True)):
        gops = peak_gops(cfg, l2r)
        power = accelerator_power_mw(l2r, cfg, lib)
        area_mm2 = accelerator_area_um2(l2r, cfg, lib) / 1e6
        out[name] = dict(
            gops=gops,
            power_mw=power,
            tops_w=gops / power,
            gops_mm2=gops / area_mm2,
        )
    return out
