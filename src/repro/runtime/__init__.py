from .fault import *
