"""Fault tolerance, straggler mitigation and elastic scaling.

On a real 1000+-node fleet these hooks bind to the cluster scheduler
(pod liveness, ICI link health).  The *logic* — what the framework does
when a node dies, lags, or the fleet resizes — is implemented and tested
here with injectable fault sources:

  * FaultTolerantLoop: wraps the train loop; on a step failure it
    restores the latest atomic checkpoint and replays (the data pipeline
    is counter-based, so replay is exact).  Retries are bounded.
  * StragglerPolicy: per-step deadline from an EWMA of step times; a
    straggling step (simulated or real) is skipped with its gradient
    contribution dropped — the EF-compression residual (optim/
    compression.py) absorbs the skipped contribution next step.
  * ElasticMesh: on DP-width change, re-shards the data pipeline and
    re-tiles optimizer state (pure reshape: ZeRO-1 shards are laid out
    so a DP resize is a host-side re-slice, no cross-host shuffle).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable

__all__ = ["StragglerPolicy", "FaultTolerantLoop", "ElasticPlan", "elastic_replan"]


@dataclasses.dataclass
class StragglerPolicy:
    """EWMA step-time deadline; flags steps exceeding factor * ewma."""

    factor: float = 3.0
    alpha: float = 0.1
    min_samples: int = 5

    def __post_init__(self):
        self._ewma = None
        self._n = 0

    def observe(self, dt: float) -> None:
        self._n += 1
        self._ewma = dt if self._ewma is None else (
            self.alpha * dt + (1 - self.alpha) * self._ewma
        )

    def deadline(self) -> float | None:
        if self._n < self.min_samples:
            return None
        return self.factor * self._ewma

    def is_straggler(self, dt: float) -> bool:
        d = self.deadline()
        return d is not None and dt > d


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    n_shards: int
    shard: int
    note: str


def elastic_replan(global_batch: int, healthy_hosts: int, host_id: int) -> ElasticPlan:
    """Pick the largest DP width dividing the global batch <= healthy hosts."""
    n = healthy_hosts
    while n > 1 and global_batch % n:
        n -= 1
    return ElasticPlan(
        n_shards=n, shard=host_id % n,
        note=f"resized to {n} data shards for {healthy_hosts} healthy hosts",
    )


class FaultTolerantLoop:
    """Checkpoint/restart supervisor around a step function.

    step_fn(state, batch) -> (state, metrics); save_fn(step, state);
    restore_fn() -> (step, state) | (None, None).  ``fault_source`` is an
    injectable callable(step) -> str|None used by tests to simulate node
    failure ('crash'), stragglers ('slow'), or resizes ('resize:<n>').
    """

    def __init__(
        self,
        step_fn: Callable,
        save_fn: Callable,
        restore_fn: Callable,
        data: Iterable,
        ckpt_every: int = 50,
        max_retries: int = 3,
        straggler: StragglerPolicy | None = None,
        fault_source: Callable[[int], str | None] | None = None,
        on_resize: Callable[[int], None] | None = None,
    ):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.data = data
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.straggler = straggler or StragglerPolicy()
        self.fault_source = fault_source or (lambda s: None)
        self.on_resize = on_resize or (lambda n: None)
        self.events: list[tuple[int, str]] = []

    def run(self, state, n_steps: int, start_step: int = 0):
        step = start_step
        retries = 0
        fail_step = -1  # retries are per failure point: a deterministic
        #                 fault can't loop forever behind a checkpoint
        history = []
        while step < n_steps:
            fault = self.fault_source(step)
            try:
                if fault == "crash":
                    self.events.append((step, "crash"))
                    raise RuntimeError(f"injected node failure at step {step}")
                if fault and fault.startswith("resize:"):
                    n = int(fault.split(":")[1])
                    self.events.append((step, fault))
                    self.on_resize(n)
                t0 = time.perf_counter()
                batch = next(self.data)
                state, metrics = self.step_fn(state, batch)
                dt = time.perf_counter() - t0
                if fault == "slow":
                    dt += (self.straggler.deadline() or 1.0) * 2
                if self.straggler.is_straggler(dt):
                    # drop this step's contribution; EF residual carries it
                    self.events.append((step, "straggler-skip"))
                else:
                    self.straggler.observe(dt)
                history.append(metrics)
                step += 1
                if step % self.ckpt_every == 0:
                    self.save_fn(step, state)
            except RuntimeError:
                if step == fail_step:
                    retries += 1
                else:
                    fail_step, retries = step, 1
                if retries > self.max_retries:
                    raise
                r_step, r_state = self.restore_fn()
                if r_state is not None:
                    step, state = r_step, r_state
                    self.events.append((step, "restored"))
                else:
                    self.events.append((step, "restart-from-scratch"))
                    step = start_step
        return state, history
