"""Pallas TPU kernels (all validated in interpret mode on this CPU host):

  l2r_gemm        — MSDF digit-plane int8 GEMM (the composite IPU on the
                    MXU; the paper's primary compute hot-spot);
  flash_attention — roofline-driven beyond-paper kernel (score blocks in
                    VMEM; §Perf hillclimb A);
  msdf_ipu        — register-level PE-array simulation of the CIPU
                    (design-space sweeps + hardware regression oracle).
"""
