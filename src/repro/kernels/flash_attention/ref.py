"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


@partial(jax.jit, static_argnames=("causal", "window", "scale"))
def attention_ref(q, k, v, causal: bool = True, window: int | None = None,
                  scale: float | None = None):
    """Naive full-matrix attention.  q: (B,Sq,H,dh); k,v: (B,Skv,Kv,dh)."""
    b, sq, h, dh = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None]
    kv_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p, vr.astype(jnp.float32)).astype(v.dtype)
