"""Pallas TPU kernel: flash attention (online-softmax, VMEM-resident).

Motivated directly by the baseline roofline (EXPERIMENTS.md §Roofline):
every long-sequence cell is dominated by HBM traffic of materialized
attention score blocks (e.g. phi3 prefill_32k: 44.8 s memory term vs
7.7 s compute).  Keeping the (bq, bkv) score tile in VMEM with online
max/denominator carries — the same carry-free-accumulate discipline as
the paper's PPR/residual registers, one level up the hierarchy — removes
that traffic entirely: HBM touches only Q, K, V, O.

Grid: (batch*q_heads, n_q_blocks, n_kv_blocks), KV innermost so the
(acc, m, l) scratch carries across KV iterations.  GQA is handled in the
index map (kv head = q head // group); causal/window blocks outside the
band are predicated off with pl.when (no MXU work on TPU).

VMEM at (bq, bkv, dh) = (512, 512, 128): q/k/v tiles 128+128+128 KiB,
f32 score tile 1 MiB, acc 256 KiB — ~1.7 MiB << 16 MiB.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, bq, bkv, n_kv, causal, window, scale, kv_len):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    kv_start = kj * bkv
    # static-shape mask positions
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kv_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)

    # band test: does this (q, kv) block intersect the visible region?
    live = kv_start < kv_len
    if causal:
        live &= kv_start <= q_start + bq - 1
    if window is not None:
        live &= kv_start + bkv > q_start - window + 1

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, dh)
        k = k_ref[0].astype(jnp.float32)  # (bkv, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = kv_pos < kv_len
        if causal:
            mask &= kv_pos <= q_pos
        if window is not None:
            mask &= kv_pos > q_pos - window
        s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bkv", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, Sq, H, dh)
    k: jax.Array,  # (B, Skv, Kv, dh)
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = 512,
    bkv: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, sq, h, dh = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    bq = min(bq, sq)
    bkv = min(bkv, skv)

    # pad sequence dims to block multiples (masked out in-kernel)
    pq = (-sq) % bq
    pkv = (-skv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))

    # (B, S, H, dh) -> (B*H, S, dh) program-major layout
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq + pq, dh)
    kt = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv + pkv, dh)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv + pkv, dh)

    n_q = (sq + pq) // bq
    n_kv = (skv + pkv) // bkv

    kernel = functools.partial(
        _kernel, bq=bq, bkv=bkv, n_kv=n_kv, causal=causal, window=window,
        scale=scale, kv_len=skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, qi, kj: (bh, qi, 0)),
            # GQA: kv head = q head // g
            pl.BlockSpec((1, bkv, dh),
                         lambda bh, qi, kj, g=g, kvh=kvh:
                         ((bh // g // kvh) * kvh + (bh // g) % kvh, kj, 0)),
            pl.BlockSpec((1, bkv, dh),
                         lambda bh, qi, kj, g=g, kvh=kvh:
                         ((bh // g // kvh) * kvh + (bh // g) % kvh, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq + pq, dh), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.reshape(b, h, sq + pq, dh).transpose(0, 2, 1, 3)
    return out[:, :sq]
