"""Pallas TPU kernel: flash attention (online-softmax, VMEM-resident).

Motivated directly by the baseline roofline (EXPERIMENTS.md §Roofline):
every long-sequence cell is dominated by HBM traffic of materialized
attention score blocks (e.g. phi3 prefill_32k: 44.8 s memory term vs
7.7 s compute).  Keeping the (bq, bkv) score tile in VMEM with online
max/denominator carries — the same carry-free-accumulate discipline as
the paper's PPR/residual registers, one level up the hierarchy — removes
that traffic entirely: HBM touches only Q, K, V, O.

Grid: (batch*q_heads, n_q_blocks, n_kv_blocks), KV innermost so the
(acc, m, l) scratch carries across KV iterations.  GQA is handled in the
index map (kv head = q head // group); causal/window blocks outside the
band are predicated off with pl.when (no MXU work on TPU).

VMEM at (bq, bkv, dh) = (512, 512, 128): q/k/v tiles 128+128+128 KiB,
f32 score tile 1 MiB, acc 256 KiB — ~1.7 MiB << 16 MiB.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.online import msdf_level_slices
from repro.core.quant import (QuantConfig, plane_count, stack_planes_lhs,
                              stack_planes_rhs)

__all__ = ["flash_attention_pallas", "flash_attention_l2r_pallas"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, bq, bkv, n_kv, causal, window, scale, kv_len):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    kv_start = kj * bkv
    # static-shape mask positions
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kv_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)

    # band test: does this (q, kv) block intersect the visible region?
    live = kv_start < kv_len
    if causal:
        live &= kv_start <= q_start + bq - 1
    if window is not None:
        live &= kv_start + bkv > q_start - window + 1

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, dh)
        k = k_ref[0].astype(jnp.float32)  # (bkv, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = kv_pos < kv_len
        if causal:
            mask &= kv_pos <= q_pos
        if window is not None:
            mask &= kv_pos > q_pos - window
        s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bkv", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, Sq, H, dh)
    k: jax.Array,  # (B, Skv, Kv, dh)
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = 512,
    bkv: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, sq, h, dh = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    bq = min(bq, sq)
    bkv = min(bkv, skv)

    # pad sequence dims to block multiples (masked out in-kernel)
    pq = (-sq) % bq
    pkv = (-skv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))

    # (B, S, H, dh) -> (B*H, S, dh) program-major layout
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq + pq, dh)
    kt = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv + pkv, dh)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv + pkv, dh)

    n_q = (sq + pq) // bq
    n_kv = (skv + pkv) // bkv

    kernel = functools.partial(
        _kernel, bq=bq, bkv=bkv, n_kv=n_kv, causal=causal, window=window,
        scale=scale, kv_len=skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, qi, kj: (bh, qi, 0)),
            # GQA: kv head = q head // g
            pl.BlockSpec((1, bkv, dh),
                         lambda bh, qi, kj, g=g, kvh=kvh:
                         ((bh // g // kvh) * kvh + (bh // g) % kvh, kj, 0)),
            pl.BlockSpec((1, bkv, dh),
                         lambda bh, qi, kj, g=g, kvh=kvh:
                         ((bh // g // kvh) * kvh + (bh // g) % kvh, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq + pq, dh), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.reshape(b, h, sq + pq, dh).transpose(0, 2, 1, 3)
    return out[:, :sq]


# -------------------------------------------------- flash-fused L2R scores
def _l2r_kernel(q_ref, k_ref, v_ref, qs_ref, ks_ref, o_ref,
                acc_ref, m_ref, l_ref,
                *, bq, bkv, n_kv, causal, window, scale, kv_len,
                slices, dh):
    """Flash attention with the MSDF level walk fused into the score tile.

    Identical online-softmax structure to :func:`_kernel`; the one change
    is the score dot: instead of a float QK^T pass, the (bq, bkv) tile is
    accumulated by a STATIC walk over significance levels — each level
    one int MXU pass over a contiguous plane-slice pair of the
    pre-shifted stacks (the level-stacked schedule of
    kernels/l2r_gemm, nested inside the KV-block walk).  ``slices`` is
    the host-enumerated ``msdf_level_slices`` prefix, so a truncated
    ``levels`` processes exactly the MSDF pair set of the truncated
    stacked schedule while the softmax/PV stream stays float — the
    progressive score prefix rides inside the flash fusion instead of
    materializing (L, Q, S) snapshots in HBM.
    """
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    kv_start = kj * bkv
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kv_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)

    live = kv_start < kv_len
    if causal:
        live &= kv_start <= q_start + bq - 1
    if window is not None:
        live &= kv_start + bkv > q_start - window + 1

    @pl.when(live)
    def _compute():
        qst = q_ref[0]  # (bq, D*dh) ascending pre-shifted planes
        kst = k_ref[0]  # (bkv, D*dh) descending pre-shifted planes
        d = qst.shape[-1] // dh  # plane count implicit in the stack width
        s_int = jnp.zeros((bq, bkv), jnp.int32)
        for (lvl, i_lo, i_hi) in slices:
            a_l = qst[:, i_lo * dh:(i_hi + 1) * dh]
            r0 = (d - 1 - lvl + i_lo) * dh
            b_l = kst[:, r0:r0 + (i_hi - i_lo + 1) * dh]
            # pre-shifted planes are bit-fields of the int operand: every
            # product already carries its final significance — one int
            # pass per level, no shifts (same body as the stacked GEMM)
            s_int += jax.lax.dot_general(
                a_l, b_l, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32)
        # per-query-row x per-key-slot dequantization, then the usual
        # softmax scale — scales commute with the head-dim contraction
        s = (s_int.astype(jnp.float32) * qs_ref[0]
             * ks_ref[0].reshape(1, bkv) * scale)
        mask = kv_pos < kv_len
        if causal:
            mask &= kv_pos <= q_pos
        if window is not None:
            mask &= kv_pos > q_pos - window
        s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "causal", "window",
                     "scale", "bq", "bkv", "interpret"),
)
def flash_attention_l2r_pallas(
    q: jax.Array,  # (B, Sq, H, dh)
    k: jax.Array,  # (B, Skv, Kv, dh)
    v: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = 256,
    bkv: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Flash attention whose QK^T is the digit-serial level walk.

    The streaming-level-walk fusion: q and k are quantized per vector
    (one scale per query row / key slot — the scales that commute with
    the head-dim contraction AND with KV blocking, core/l2r_attention.py),
    their pre-shifted plane stacks stream through the online-softmax
    KV-block walk, and each (bq, bkv) score tile is built by the static
    MSDF level schedule in VMEM.  ``levels`` truncates that schedule —
    the fused analogue of ``l2r_attn_scores(..., levels=...)``: the score
    matrix the softmax sees is the dequantized truncated prefix, with no
    per-level HBM snapshots.  Softmax statistics, PV, and the output stay
    float; v is untouched.

    VMEM at (bq, bkv, dh, D) = (256, 256, 128, 4): q/k plane tiles
    128 + 128 KiB int8, v 64 KiB, f32 score tile 256 KiB, acc 128 KiB —
    well under budget.  This CPU container validates with
    ``interpret=True``; parity vs the jnp quantized path is numerical
    (online softmax reassociates), vs ``attention_ref`` it adds the
    quantization error of W8A8 scores.
    """
    b, sq, h, dh = q.shape
    _, skv, kvh, _ = k.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    d = plane_count(n_bits, log2_radix)
    cfg = QuantConfig(n_bits=n_bits, log2_radix=log2_radix)

    from repro.core.l2r_attention import quantize_per_vector
    qq, qs = quantize_per_vector(q, cfg)   # scales (B, Sq, H, 1)
    kq, ks = quantize_per_vector(k, cfg)   # scales (B, Skv, Kv, 1)
    q_stack = stack_planes_lhs(qq, n_bits, log2_radix)            # ascending
    k_stack = stack_planes_rhs(kq, n_bits, log2_radix, axis=-1)   # descending

    pq = (-sq) % bq
    pkv = (-skv) % bkv
    if pq:
        q_stack = jnp.pad(q_stack, ((0, 0), (0, pq), (0, 0), (0, 0)))
        qs = jnp.pad(qs, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k_stack = jnp.pad(k_stack, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        ks = jnp.pad(ks, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))

    qt = q_stack.transpose(0, 2, 1, 3).reshape(b * h, sq + pq, d * dh)
    kt = k_stack.transpose(0, 2, 1, 3).reshape(b * kvh, skv + pkv, d * dh)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv + pkv, dh)
    qst = qs.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(
        b * h, sq + pq, 1)
    kst = ks.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(
        b * kvh, skv + pkv, 1)

    n_q = (sq + pq) // bq
    n_kv = (skv + pkv) // bkv
    g = h // kvh

    kernel = functools.partial(
        _l2r_kernel, bq=bq, bkv=bkv, n_kv=n_kv, causal=causal,
        window=window, scale=scale, kv_len=skv,
        slices=tuple(msdf_level_slices(d, levels)), dh=dh,
    )
    kv_map = (lambda bh, qi, kj, g=g, kvh=kvh:
              ((bh // g // kvh) * kvh + (bh // g) % kvh, kj, 0))
    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d * dh), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bkv, d * dh), kv_map),
            pl.BlockSpec((1, bkv, dh), kv_map),
            pl.BlockSpec((1, bq, 1), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bkv, 1), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq + pq, dh), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, qst, kst)
    out = out.reshape(b, h, sq + pq, dh).transpose(0, 2, 1, 3)
    return out[:, :sq]
