"""Jit wrapper selecting the flash kernel (TPU) or oracle (CPU tests)."""

from __future__ import annotations

import jax

from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention"]


def flash_attention(q, k, v, causal=True, window=None, scale=None,
                    use_pallas: bool = True, interpret: bool = True):
    """Drop-in attention. On TPU call with interpret=False (compiled
    Pallas); this CPU container validates the kernel in interpret mode."""
    if not use_pallas:
        return attention_ref(q, k, v, causal, window, scale)
    return flash_attention_pallas(q, k, v, causal, window, scale,
                                  interpret=interpret)
