"""Jit wrapper selecting the flash kernel (TPU) or oracle (CPU tests)."""

from __future__ import annotations

from repro.kernels.l2r_gemm.ops import resolve_backend

from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention"]


def flash_attention(q, k, v, causal=True, window=None, scale=None,
                    backend=None):
    """Drop-in attention behind the shared backend dispatch rule.

    Selection is ``resolve_backend`` (explicit arg > $REPRO_L2R_BACKEND >
    platform default) — the same rule as the L2R GEMM entry points, so
    one env var steers the whole kernel family: ``jnp`` runs the jitted
    oracle (the production path off-TPU), ``pallas-interpret`` the kernel
    body on CPU (validation only), ``pallas-tpu`` the compiled kernel.
    An explicit ``pallas-tpu`` off-TPU is rejected at resolve time with
    the hinted error.  This entry used to default to interpret-mode
    Pallas unconditionally — a validation configuration, orders of
    magnitude slower than the oracle it was bit-checking — so the
    platform default silently made every caller pay interpreter speed.
    """
    resolved = resolve_backend(backend)
    if resolved == "jnp":
        return attention_ref(q, k, v, causal, window, scale)
    return flash_attention_pallas(q, k, v, causal, window, scale,
                                  interpret=(resolved == "pallas-interpret"))
