from .kernel import flash_attention_l2r_pallas, flash_attention_pallas
from .ops import flash_attention
from .ref import attention_ref
