from .kernel import cipu_array_pallas
from .ops import simulate_pe_array
from .ref import cipu_array_ref, int_sop_ref
