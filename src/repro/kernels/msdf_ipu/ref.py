"""Oracle for the PE-array kernel: the scalar CIPU golden model."""

import jax
import jax.numpy as jnp

from repro.core.ipu import simulate_cipu

__all__ = ["cipu_array_ref", "int_sop_ref"]


def cipu_array_ref(a, b, n_bits: int = 8):
    return simulate_cipu(a, b, n_bits).final


@jax.jit
def int_sop_ref(a, b):
    return jnp.sum(a.astype(jnp.int32) * b.astype(jnp.int32), axis=-1)
