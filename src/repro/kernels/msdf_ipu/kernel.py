"""Pallas TPU kernel: PE-array simulation of the composite IPU.

The paper's accelerator is a T_r x T_c array of PEs, each streaming one
SOP through the carry-save CIPU datapath (core/ipu.py is the scalar
golden model).  This kernel runs the *cycle-accurate register-level
simulation itself* data-parallel on the vector unit: one grid cell
simulates a (bm,)-batch of PEs, the n^2-cycle loop lives in VMEM
registers (PPR/residual carry-save pairs as vectors).

Use cases: RTL-free design-space sweeps of the unit (n, k, radix) at
millions of SOPs/s, and regression oracles for the hardware team — the
outputs are bit-identical to core/ipu.py (tested).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cipu_array_pallas"]


def _kernel(a_ref, b_ref, out_ref, *, n_bits: int, k: int):
    n = n_bits
    a = a_ref[...].astype(jnp.int32)  # (bm, k)
    b = b_ref[...].astype(jnp.int32)
    bm = a.shape[0]

    def csa(x, y, z):
        return x ^ y ^ z, ((x & y) | (x & z) | (y & z)) << 1

    def cycle(c, state):
        ppr_s, ppr_c, res_s, res_c = state
        i = c // n + 1
        j = c % n + 1
        a_bits = (a >> (n - i)) & 1
        b_bits = (b >> (n - j)) & 1
        cnt = jnp.sum(a_bits & b_bits, axis=-1)  # counter circuit, (bm,)
        wrap = j == n
        res_in_s = jnp.where(wrap, res_s << 1, 0)
        res_in_c = jnp.where(wrap, res_c << 1, 0)
        s0, c0 = csa(ppr_s << 1, ppr_c << 1, cnt)
        s1, c1 = csa(res_in_s, res_in_c, jnp.zeros_like(cnt))
        s2, c2 = csa(s0, c0, s1)
        s3, c3 = csa(s2, c1, c2)
        new_ppr_s = jnp.where(wrap, 0, s3)
        new_ppr_c = jnp.where(wrap, 0, c3)
        new_res_s = jnp.where(wrap, s3, res_s)
        new_res_c = jnp.where(wrap, c3, res_c)
        return new_ppr_s, new_ppr_c, new_res_s, new_res_c

    zeros = jnp.zeros((bm,), jnp.int32)
    state = (zeros, zeros, zeros, zeros)
    state = jax.lax.fori_loop(0, n * n, cycle, state)
    out_ref[...] = state[2] + state[3]


@functools.partial(jax.jit, static_argnames=("n_bits", "bm", "interpret"))
def cipu_array_pallas(a: jax.Array, b: jax.Array, n_bits: int = 8,
                      bm: int = 256, interpret: bool = True) -> jax.Array:
    """a, b: (M, k) unsigned operands -> (M,) exact SOPs, simulated at
    the register level.  M must divide into bm-sized PE batches (padded
    here)."""
    m, k = a.shape
    pad = (-m) % bm
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    kernel = functools.partial(_kernel, n_bits=n_bits, k=k)
    out = pl.pallas_call(
        kernel,
        grid=((m + pad) // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0)),
                  pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m + pad,), jnp.int32),
        interpret=interpret,
    )(a.astype(jnp.int32), b.astype(jnp.int32))
    return out[:m]
