"""Jit wrapper for the PE-array CIPU simulator."""

from .kernel import cipu_array_pallas
from .ref import cipu_array_ref, int_sop_ref

__all__ = ["simulate_pe_array", "cipu_array_ref", "int_sop_ref"]


def simulate_pe_array(a, b, n_bits: int = 8, use_pallas: bool = True,
                      interpret: bool = True):
    """Simulate M independent CIPU PEs.  a, b: (M, k) unsigned."""
    if not use_pallas:
        return cipu_array_ref(a, b, n_bits)
    return cipu_array_pallas(a, b, n_bits, interpret=interpret)
