"""L2R digit-plane GEMM: Pallas TPU kernel + jit wrappers + jnp oracle."""
from .kernel import l2r_gemm_pallas
from .ops import l2r_gemm, l2r_matmul_f, pad_to
from .ref import l2r_gemm_ref, int_gemm_ref

__all__ = ["l2r_gemm_pallas", "l2r_gemm", "l2r_matmul_f", "pad_to", "l2r_gemm_ref", "int_gemm_ref"]
