"""L2R digit-plane GEMM: Pallas TPU kernels + backend dispatch + oracles."""
from .kernel import (l2r_gemm_pallas, l2r_gemm_pallas_stacked,
                     l2r_gemm_pallas_stacked_planes,
                     l2r_gemm_pallas_streaming,
                     l2r_gemm_pallas_streaming_planes, stacked_schedule,
                     streaming_schedule)
from .ops import (BACKENDS, BACKEND_ENV_VAR, SCHEDULES, PlaneOperands,
                  l2r_conv2d, l2r_conv2d_progressive,
                  l2r_conv2d_progressive_while, l2r_gemm,
                  l2r_gemm_progressive, l2r_matmul_f, pad_to,
                  resolve_backend)
from .ref import int_gemm_ref, l2r_gemm_ref, l2r_gemm_ref_stacked

__all__ = [
    "l2r_gemm_pallas", "l2r_gemm_pallas_stacked",
    "l2r_gemm_pallas_stacked_planes", "l2r_gemm_pallas_streaming",
    "l2r_gemm_pallas_streaming_planes",
    "stacked_schedule", "streaming_schedule", "PlaneOperands",
    "l2r_gemm", "l2r_gemm_progressive", "l2r_matmul_f", "l2r_conv2d",
    "l2r_conv2d_progressive", "l2r_conv2d_progressive_while", "pad_to",
    "resolve_backend", "BACKENDS", "BACKEND_ENV_VAR", "SCHEDULES",
    "l2r_gemm_ref", "l2r_gemm_ref_stacked", "int_gemm_ref",
]
