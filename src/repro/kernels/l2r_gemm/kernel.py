"""Pallas TPU kernel: L2R digit-plane GEMM (the composite IPU on the MXU).

Hardware mapping (DESIGN.md §2):

  * the paper's 8x8 PE array x (3x3 window x 8 channels)  ->  the Pallas
    grid (M/bm, N/bn) of output tiles x a bk-deep contraction block: the
    systolic MXU contraction plays the counter circuit's role;
  * the digit-serial schedule  ->  a static, MSDF-ordered loop over digit
    plane pairs (i, j); each pair is one small-int MXU pass
    `acc += (A_i @ B_j) << b(i+j)`;
  * PPR/residual carry-save pair -> the int32 VMEM accumulator (carry-free
    at matmul granularity: no intermediate rounding or carry propagation);
  * progressive precision (`levels`) -> truncating the plane-pair loop to
    the most significant levels, the analogue of reading the unit's MSDs
    after the online delay.

VMEM budget at the default (bm, bk, bn) = (128, 256, 128), radix 4:
  A tile 32 KiB + B tile 32 KiB + 2 x D plane copies (256 KiB)
  + int32 acc 64 KiB  ~= 0.4 MiB  << 16 MiB/core VMEM; M/N tiles are
  MXU-aligned (128) and the int8 K tile is a multiple of 32 lanes.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.online import msdf_pairs

__all__ = ["l2r_gemm_pallas"]


def _plane(x: jax.Array, i: int, n_planes: int, log2_radix: int) -> jax.Array:
    """Digit plane i of an int8 tile (int32 workspace, exact for 2's comp)."""
    xi = x.astype(jnp.int32)
    if i == n_planes - 1:
        return xi >> (log2_radix * i)  # signed top digit
    return (xi >> (log2_radix * i)) & ((1 << log2_radix) - 1)


def _l2r_gemm_kernel(
    a_ref, b_ref, o_ref, acc_ref,
    *, pairs: Sequence[tuple[int, int]], log2_radix: int, n_planes: int,
    k_steps: int,
):
    """One (bm, bn) output tile; grid = (M/bm, N/bn, K/bk), K innermost."""
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]  # (bm, bk) int8
    b = b_ref[...]  # (bk, bn) int8

    # MSDF-ordered composite accumulation: one MXU pass per plane pair.
    acc = acc_ref[...]
    for (i, j) in pairs:
        ai = _plane(a, i, n_planes, log2_radix)
        bj = _plane(b, j, n_planes, log2_radix)
        term = jax.lax.dot_general(
            ai, bj, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc + (term << (log2_radix * (i + j)))
    acc_ref[...] = acc

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "bm", "bk", "bn", "interpret"),
)
def l2r_gemm_pallas(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    bm: int = 128,
    bk: int = 256,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """MSDF digit-plane int GEMM. aq: (M, K) int8, bq: (K, N) int8 -> int32.

    Shapes must be multiples of the block sizes (ops.py pads — zero
    padding is exact for matmul).  `interpret=True` runs the kernel body
    on CPU for validation (this container has no TPU).
    """
    m, k = aq.shape
    k2, n = bq.shape
    assert k == k2, (aq.shape, bq.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k2},{n}) not padded to blocks ({bm},{bk},{bn})"
    )
    d = n_bits // log2_radix
    pairs = tuple(msdf_pairs(d, levels))
    k_steps = k // bk

    kernel = functools.partial(
        _l2r_gemm_kernel,
        pairs=pairs, log2_radix=log2_radix, n_planes=d, k_steps=k_steps,
    )
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(aq, bq)
