"""Pallas TPU kernels: L2R digit-plane GEMM (the composite IPU on the MXU).

Two schedules are provided:

``l2r_gemm_pallas`` — the original pair-loop schedule (one small MXU pass
per digit-plane pair, D² passes per K-step, planes re-extracted in VMEM
every step).  Kept as the comparison baseline and a second oracle.

``l2r_gemm_pallas_stacked`` — the production **significance-level plane
stacking** schedule.  Hardware mapping:

  * digit planes are extracted ONCE, outside the grid, and pre-shifted to
    their significance (``A'_i = A_i << b*i``, ``B'_j = B_j << b*j`` —
    each shifted plane is a bit-field of the operand, so it stays in the
    operand's n-bit dtype).  The planes are stacked along the contraction
    axis: ``A_stack (M, D*K)`` ascending, ``B_rev (D*K, N)`` descending;
  * the paper's composite counter circuit -> ONE K-stacked MXU
    contraction per significance level ``s = i + j``: the level's pair
    set {(i, s-i)} is a contiguous column slice of ``A_stack`` against a
    contiguous row slice of ``B_rev``, so the D² pair matmuls collapse to
    2D-1 level matmuls and the kernel inner loop is a single
    ``acc += A_blk @ B_blk`` per grid step — no plane extraction, no
    shifts (the pre-shift makes every product land at its final weight);
  * the MSDF schedule -> a static (level, k-block) walk enumerated
    host-side and fed through **scalar prefetch**: two int32 index
    vectors give each grid step its block coordinates into the stacked
    operands, and the BlockSpec index maps read them (this is the
    block-sparse / grouped-matmul Pallas idiom);
  * PPR/residual carry-save pair -> the int32 VMEM accumulator (carry-
    free at matmul granularity);
  * progressive precision (``levels``) -> truncating the schedule vector
    to the top levels; the processed pair set is identical to
    ``online.msdf_pairs(d, levels)``, so truncated results are
    bit-identical to the pair loop (validated against
    ``core/online.py:tail_bound`` semantics in the tests).

VMEM budget, stacked schedule, default (bm, bk, bn) = (128, 256, 128):
  A block 32 KiB (int8) + B block 32 KiB + int32 acc 64 KiB = 128 KiB
  (~256 KiB with double buffering) << 16 MiB/core — 3x leaner than the
  pair-loop kernel, which additionally held 2 x D int32 plane workspaces
  (256 KiB at radix 4).  M/N tiles are MXU-aligned (128); the int8 K
  block is a multiple of 32 lanes.  HBM traffic: the stacked operands are
  D x the int8 payload, but each block is read exactly once per output
  tile — the same per-pair traffic the pair loop paid, now amortized over
  MXU passes that are D x deeper on average.

Backend selection (jnp / pallas-interpret / pallas-tpu) lives in ops.py.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.online import msdf_level_slices, msdf_pairs
from repro.core.quant import stack_planes_lhs, stack_planes_rhs

__all__ = ["l2r_gemm_pallas", "l2r_gemm_pallas_stacked",
           "l2r_gemm_pallas_stacked_planes", "l2r_gemm_pallas_streaming",
           "l2r_gemm_pallas_streaming_planes", "stacked_schedule",
           "streaming_schedule"]


# --------------------------------------------------------------- pair loop
def _plane(x: jax.Array, i: int, n_planes: int, log2_radix: int) -> jax.Array:
    """Digit plane i of an int8 tile (int32 workspace, exact for 2's comp)."""
    xi = x.astype(jnp.int32)
    if i == n_planes - 1:
        return xi >> (log2_radix * i)  # signed top digit
    return (xi >> (log2_radix * i)) & ((1 << log2_radix) - 1)


def _l2r_gemm_kernel(
    a_ref, b_ref, o_ref, acc_ref,
    *, pairs: Sequence[tuple[int, int]], log2_radix: int, n_planes: int,
    k_steps: int,
):
    """One (bm, bn) output tile; grid = (M/bm, N/bn, K/bk), K innermost."""
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]  # (bm, bk) int8
    b = b_ref[...]  # (bk, bn) int8

    # MSDF-ordered composite accumulation: one MXU pass per plane pair.
    acc = acc_ref[...]
    for (i, j) in pairs:
        ai = _plane(a, i, n_planes, log2_radix)
        bj = _plane(b, j, n_planes, log2_radix)
        term = jax.lax.dot_general(
            ai, bj, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc + (term << (log2_radix * (i + j)))
    acc_ref[...] = acc

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "bm", "bk", "bn", "interpret"),
)
def l2r_gemm_pallas(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    bm: int = 128,
    bk: int = 256,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Pair-loop MSDF GEMM (baseline). aq: (M, K) int8, bq: (K, N) -> int32.

    Shapes must be multiples of the block sizes (ops.py pads — zero
    padding is exact for matmul).  `interpret=True` runs the kernel body
    on CPU for validation (this container has no TPU).
    """
    m, k = aq.shape
    k2, n = bq.shape
    assert k == k2, (aq.shape, bq.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k2},{n}) not padded to blocks ({bm},{bk},{bn})"
    )
    d = n_bits // log2_radix
    pairs = tuple(msdf_pairs(d, levels))
    k_steps = k // bk

    kernel = functools.partial(
        _l2r_gemm_kernel,
        pairs=pairs, log2_radix=log2_radix, n_planes=d, k_steps=k_steps,
    )
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(aq, bq)


# ------------------------------------------------------ level-stacked
def stacked_schedule(
    d: int, k_blocks: int, levels: int | None
) -> tuple[np.ndarray, np.ndarray]:
    """Static (level, k-block) walk of the stacked operands, MSDF order.

    Returns two int32 vectors of length T = n_pairs(levels) * k_blocks:
    ``a_blocks[t]`` is the block-column into A_stack (plane i, k-chunk c
    -> i * k_blocks + c) and ``b_blocks[t]`` the block-row into B_rev
    (plane j = s - i lives at reversed offset (d-1-j) * k_blocks).
    Consumed via scalar prefetch by the stacked kernel's index maps.
    """
    a_blocks: list[int] = []
    b_blocks: list[int] = []
    for (s, i_lo, i_hi) in msdf_level_slices(d, levels):
        for i in range(i_lo, i_hi + 1):
            for c in range(k_blocks):
                a_blocks.append(i * k_blocks + c)
                b_blocks.append((d - 1 - s + i) * k_blocks + c)
    return (np.asarray(a_blocks, np.int32), np.asarray(b_blocks, np.int32))


def _l2r_stacked_kernel(a_idx_ref, b_idx_ref, a_ref, b_ref, o_ref, acc_ref,
                        *, t_steps: int):
    """One (bm, bn) output tile; grid = (M/bm, N/bn, T), schedule innermost.

    The whole MSDF structure lives in the prefetched index vectors: the
    body is a single int8 MXU pass per step — ``acc += A_blk @ B_blk`` —
    with no plane extraction and no shifts (operands are pre-shifted).
    """
    del a_idx_ref, b_idx_ref  # consumed by the BlockSpec index maps

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == t_steps - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "bm", "bk", "bn", "interpret"),
)
def l2r_gemm_pallas_stacked_planes(
    a_stack: jax.Array,
    b_rev: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    bm: int = 128,
    bk: int = 256,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Level-stacked MSDF GEMM over PRE-STACKED plane operands.

    The pre-stacked kernel entry: operands are the already-extracted,
    PRE-SHIFTED plane stacks — ``a_stack (M, D*K)`` ascending
    (quant.py:stack_planes_lhs), ``b_rev (D*K, N)`` descending
    (stack_planes_rhs) — exactly D plane chunks each (no streaming
    window padding), every chunk's K a multiple of ``bk`` and M/N
    multiples of ``bm``/``bn`` (ops.py block-pads per chunk).  Callers
    that feed one tensor through many GEMMs (the fused conv's kh*kw
    taps, per-decode-step weight matmuls) extract planes once and call
    this entry per GEMM — the hoist the jnp backend already performs,
    now available to the TPU kernel (ROADMAP follow-up).
    """
    m, dk = a_stack.shape
    dk2, n = b_rev.shape
    d = n_bits // log2_radix
    assert dk == dk2 and dk % d == 0, (a_stack.shape, b_rev.shape, d)
    k = dk // d
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"plane stacks ({m},{d}x{k})x({d}x{k},{n}) not padded to blocks "
        f"({bm},{bk},{bn})"
    )
    k_blocks = k // bk
    a_idx, b_idx = stacked_schedule(d, k_blocks, levels)
    t_steps = int(a_idx.shape[0])
    if t_steps == 0:  # levels=0: empty MSDF prefix
        return jnp.zeros((m, n), jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m // bm, n // bn, t_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t, ai, bi: (i, ai[t])),
            pl.BlockSpec((bk, bn), lambda i, j, t, ai, bi: (bi[t], j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t, ai, bi: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
    )
    return pl.pallas_call(
        functools.partial(_l2r_stacked_kernel, t_steps=t_steps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(jnp.asarray(a_idx), jnp.asarray(b_idx), a_stack, b_rev)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "bm", "bk", "bn", "interpret"),
)
def l2r_gemm_pallas_stacked(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    bm: int = 128,
    bk: int = 256,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Level-stacked MSDF GEMM. aq: (M, K), bq: (K, N) small ints -> int32.

    Bit-identical to ``core.l2r_gemm.l2r_matmul_int`` for exact and
    truncated ``levels``.  Shapes must be multiples of the block sizes
    (ops.py pads; zero padding is exact).  Plane extraction happens here,
    once, outside the grid, and the stacks feed the pre-stacked entry
    (:func:`l2r_gemm_pallas_stacked_planes`) — the kernel streams
    pre-shifted plane blocks.
    """
    m, k = aq.shape
    k2, n = bq.shape
    assert k == k2, (aq.shape, bq.shape)
    a_stack = stack_planes_lhs(aq, n_bits, log2_radix)  # (M, D*K)
    b_rev = stack_planes_rhs(bq, n_bits, log2_radix)    # (D*K, N)
    return l2r_gemm_pallas_stacked_planes(
        a_stack, b_rev, n_bits, log2_radix, levels, bm, bk, bn,
        interpret=interpret)


# ------------------------------------------------------------- streaming
def streaming_schedule(
    d: int, k_blocks: int, levels: int | None
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The stacked (level, k-block) walk plus each step's level index.

    The block walk IS :func:`stacked_schedule` (same arrays — that is
    what makes per-level prefixes bit-identical to stacked truncation);
    the third vector routes every step's output write to its level's
    snapshot plane."""
    a_blocks, b_blocks = stacked_schedule(d, k_blocks, levels)
    steps_per_level = [(i_hi - i_lo + 1) * k_blocks
                       for (_, i_lo, i_hi) in msdf_level_slices(d, levels)]
    lv_idx = np.repeat(np.arange(len(steps_per_level), dtype=np.int32),
                       steps_per_level)
    return a_blocks, b_blocks, np.asarray(lv_idx, np.int32)


def _l2r_streaming_kernel(a_idx_ref, b_idx_ref, lv_idx_ref, cnt_ref,
                          a_ref, b_ref, o_ref, acc_ref):
    """One (bm, bn) tile of the per-level snapshot stream.

    Same single-MXU-pass body as the stacked kernel; the running
    accumulator is additionally written to the current level's output
    plane every step — when the walk crosses a level boundary the block
    index map moves to the next plane and the last write left behind IS
    that level's prefix snapshot (the revisit-then-advance output idiom:
    per output tile the level index is non-decreasing in t, never
    revisited).

    ``cnt_ref`` is the dynamic level-count scalar: grid steps whose level
    index is >= the count skip BOTH the MXU pass and the output write —
    the grid-level analogue of the jnp while-loop's early exit (the grid
    itself still iterates; a Mosaic grid cannot shrink at runtime, but
    skipped steps cost a scalar compare instead of an MXU pass + HBM
    write)."""
    del a_idx_ref, b_idx_ref  # consumed by the index maps

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(lv_idx_ref[pl.program_id(2)] < cnt_ref[0])
    def _work():
        acc_ref[...] += jax.lax.dot_general(
            a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        o_ref[0] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "bm", "bk", "bn",
                     "interpret"),
)
def l2r_gemm_pallas_streaming_planes(
    a_stack: jax.Array,
    b_rev: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    bm: int = 128,
    bk: int = 256,
    bn: int = 128,
    interpret: bool = False,
    level_count: jax.Array | int | None = None,
) -> jax.Array:
    """Per-level snapshot stream over PRE-STACKED plane operands.

    The streaming analogue of :func:`l2r_gemm_pallas_stacked_planes`:
    operands are the already-extracted PRE-SHIFTED stacks (``a_stack
    (M, D*K)`` ascending, ``b_rev (D*K, N)`` descending, exactly D
    chunks, chunk K padded to ``bk`` and M/N to ``bm``/``bn``), the
    output the ``(L, M, N)`` snapshot stream.  ``level_count`` semantics
    as in :func:`l2r_gemm_pallas_streaming`.
    """
    m, dk = a_stack.shape
    dk2, n = b_rev.shape
    d = n_bits // log2_radix
    assert dk == dk2 and dk % d == 0, (a_stack.shape, b_rev.shape, d)
    k = dk // d
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"plane stacks ({m},{d}x{k})x({d}x{k},{n}) not padded to blocks "
        f"({bm},{bk},{bn})"
    )
    a_idx, b_idx, lv_idx = streaming_schedule(d, k // bk, levels)
    t_steps = int(a_idx.shape[0])
    n_levels = int(lv_idx[-1]) + 1 if t_steps else 0
    if t_steps == 0:  # levels=0: empty MSDF prefix
        return jnp.zeros((0, m, n), jnp.int32)
    if level_count is None:
        level_count = n_levels
    cnt = jnp.asarray(level_count, jnp.int32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(m // bm, n // bn, t_steps),
        in_specs=[
            pl.BlockSpec((bm, bk),
                         lambda i, j, t, ai, bi, li, ct: (i, ai[t])),
            pl.BlockSpec((bk, bn),
                         lambda i, j, t, ai, bi, li, ct: (bi[t], j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn),
                               lambda i, j, t, ai, bi, li, ct: (li[t], i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
    )
    return pl.pallas_call(
        _l2r_streaming_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_levels, m, n), jnp.int32),
        interpret=interpret,
    )(jnp.asarray(a_idx), jnp.asarray(b_idx), jnp.asarray(lv_idx), cnt,
      a_stack, b_rev)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "bm", "bk", "bn",
                     "interpret"),
)
def l2r_gemm_pallas_streaming(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    bm: int = 128,
    bk: int = 256,
    bn: int = 128,
    interpret: bool = False,
    level_count: jax.Array | int | None = None,
) -> jax.Array:
    """Per-level snapshot stream of the stacked MSDF GEMM: (L, M, N) int32.

    Level l of the output is bit-identical to the stacked schedule
    truncated at ``levels=l+1`` — the Pallas realization of the streaming
    emitter (core/progressive.py) for on-TPU progressive serving.  Shapes
    must be multiples of the block sizes (ops.py pads).  Plane extraction
    happens once here and feeds the pre-stacked entry
    (:func:`l2r_gemm_pallas_streaming_planes`).

    ``level_count`` is a DYNAMIC int32 scalar (no recompilation when it
    changes, unlike the static ``levels``): grid steps at levels >= the
    count skip their MXU pass and output write, so a consumer that has
    already decided (e.g. the while-loop early exit on the jnp backend)
    can stop the snapshot stream short at runtime.  Output planes at
    levels >= ``level_count`` are left unwritten (unspecified); planes
    below it are bit-identical to the full run.  ``None`` processes every
    scheduled level."""
    m, k = aq.shape
    k2, n = bq.shape
    assert k == k2, (aq.shape, bq.shape)
    a_stack = stack_planes_lhs(aq, n_bits, log2_radix)  # (M, D*K)
    b_rev = stack_planes_rhs(bq, n_bits, log2_radix)    # (D*K, N)
    return l2r_gemm_pallas_streaming_planes(
        a_stack, b_rev, n_bits, log2_radix, levels, bm, bk, bn,
        interpret=interpret, level_count=level_count)
