"""Public L2R GEMM/conv ops: backend dispatch, padding, quant/dequant.

This is the production entry point for the model stack (models/cnn.py,
models/common.py:dense, serve/engine.py).  Three backends:

  * ``jnp``             — the level-stacked pure-jnp schedule
                          (core/l2r_gemm.py); fastest off-TPU, no padding;
  * ``pallas-interpret``— the Pallas kernel body interpreted on CPU
                          (validation only — slow, but exercises the real
                          kernel dataflow);
  * ``pallas-tpu``      — the compiled Pallas kernel (requires a TPU).

Selection: explicit ``backend=`` argument > ``REPRO_L2R_BACKEND`` env var
> platform default (``pallas-tpu`` on TPU hosts, ``jnp`` elsewhere).
``schedule`` picks ``stacked`` (production, 2D-1 level matmuls) or
``pairs`` (the D²-pass baseline, kept for regression benchmarks).

The fused ``l2r_conv2d`` performs implicit im2col: the kh*kw taps of the
window stream through the digit-plane GEMM as shifted views of the
feature map, so the (B*H*W, cin*kh*kw) patch matrix is never
materialized in HBM.  On the jnp backend the activation digit planes are
additionally hoisted out of the tap loop (extracted once per feature
map); the Pallas backends still extract planes inside each per-tap
kernel call — hoisting them behind a pre-stacked kernel entry point is a
noted ROADMAP follow-up for real-TPU tuning.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.l2r_gemm import (l2r_matmul_int_stacked, stacked_gemm_planes)
from repro.core.quant import (QuantConfig, QuantizedWeights, quantize,
                              quantize_weights, stack_planes_lhs,
                              stack_planes_rhs)

from .kernel import l2r_gemm_pallas, l2r_gemm_pallas_stacked
from .ref import l2r_gemm_ref

__all__ = ["l2r_gemm", "l2r_matmul_f", "l2r_conv2d", "pad_to",
           "resolve_backend", "BACKENDS", "BACKEND_ENV_VAR"]

BACKENDS = ("jnp", "pallas-interpret", "pallas-tpu")
BACKEND_ENV_VAR = "REPRO_L2R_BACKEND"


def resolve_backend(backend: str | None = None) -> str:
    """Dispatch rule: explicit arg > $REPRO_L2R_BACKEND > platform default.

    The platform default is ``pallas-tpu`` when jax runs on TPU and the
    ``jnp`` level-stacked schedule everywhere else (interpret-mode Pallas
    is a validation tool, never a production default).
    """
    chosen = backend or os.environ.get(BACKEND_ENV_VAR, "").strip() or "auto"
    if chosen == "auto":
        return "pallas-tpu" if jax.default_backend() == "tpu" else "jnp"
    if chosen not in BACKENDS:
        raise ValueError(
            f"unknown L2R backend {chosen!r}; expected one of {BACKENDS} or 'auto'")
    return chosen


def pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = []
    for dim, mult in zip(x.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "bm", "bk", "bn",
                     "schedule", "backend"),
)
def _l2r_gemm_backend(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int,
    log2_radix: int,
    levels: int | None,
    bm: int,
    bk: int,
    bn: int,
    schedule: str,
    backend: str,
) -> jax.Array:
    """Backend-resolved integer GEMM (backend is a static, already-resolved
    string here so the trace cache keys on it)."""
    if backend == "jnp":
        if schedule == "stacked":
            return l2r_matmul_int_stacked(aq, bq, n_bits, log2_radix, levels)
        return l2r_gemm_ref(aq, bq, n_bits, log2_radix, levels)
    m, k = aq.shape
    n = bq.shape[1]
    ap = pad_to(aq, (bm, bk))
    bp = pad_to(bq, (bk, bn))
    fn = l2r_gemm_pallas_stacked if schedule == "stacked" else l2r_gemm_pallas
    out = fn(ap, bp, n_bits, log2_radix, levels, bm, bk, bn,
             interpret=(backend == "pallas-interpret"))
    return out[:m, :n]


def l2r_gemm(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    bm: int = 128,
    bk: int = 256,
    bn: int = 128,
    schedule: str = "stacked",
    backend: str | None = None,
) -> jax.Array:
    """Integer MSDF GEMM with backend dispatch. (M,K)x(K,N) -> int32.

    Any shape is accepted (Pallas backends zero-pad to blocks — exact for
    matmul).  Bit-identical across backends and schedules, including
    truncated ``levels``.
    """
    assert schedule in ("stacked", "pairs"), schedule
    return _l2r_gemm_backend(aq, bq, n_bits, log2_radix, levels,
                             bm, bk, bn, schedule, resolve_backend(backend))


def l2r_matmul_f(
    x: jax.Array,
    w: jax.Array | None,
    cfg: QuantConfig = QuantConfig(),
    levels: int | None = None,
    w_q: QuantizedWeights | tuple[jax.Array, jax.Array] | None = None,
    backend: str | None = None,
    schedule: str = "stacked",
) -> jax.Array:
    """Float -> quantize -> dispatched MSDF GEMM -> dequantized float.

    ``w_q`` (core/quant.py:QuantizedWeights, built once at load) skips
    the per-forward weight quantization; ``w`` may then be None.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    # per-row (per-token) activation scales commute with the K-contraction
    xq, xs = quantize(x2, cfg, axis=0 if cfg.per_channel else None)
    if w_q is None:
        wq, ws = quantize(w, cfg, axis=-1)  # per-out-channel: (1, N)
    elif isinstance(w_q, QuantizedWeights):
        wq, ws = w_q.q, w_q.scale
    else:
        wq, ws = w_q
    out = l2r_gemm(xq, wq, cfg.n_bits, cfg.log2_radix, levels,
                   schedule=schedule, backend=backend)
    out = out.astype(jnp.float32) * xs * ws.reshape(1, -1)
    return out.astype(x.dtype).reshape(*lead, wq.shape[-1])


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "backend"),
)
def _l2r_conv2d_int(
    xq: jax.Array,
    wq: jax.Array,
    n_bits: int,
    log2_radix: int,
    levels: int | None,
    backend: str,
) -> jax.Array:
    """Integer core of the fused conv: implicit im2col over kh*kw taps.

    xq: (B, H, W, cin) small ints; wq: (kh, kw, cin, cout) small ints;
    "SAME" padding, stride 1.  Bit-identical to quantized im2col +
    l2r_matmul_int on the same operands: the contraction over
    (kh, kw, cin) splits into kh*kw independent cin-contractions, and
    per-significance-level partial sums add across taps exactly.
    """
    bsz, h, w_, cin = xq.shape
    kh, kw, _, cout = wq.shape
    ph_lo, pw_lo = (kh - 1) // 2, (kw - 1) // 2
    xp = jnp.pad(xq, ((0, 0), (ph_lo, kh - 1 - ph_lo),
                      (pw_lo, kw - 1 - pw_lo), (0, 0)))
    acc = jnp.zeros((bsz, h, w_, cout), jnp.int32)
    if backend == "jnp":
        # hoist plane extraction out of the tap loop: one LHS stack for
        # the whole feature map, one reversed RHS stack for all taps
        # (raw digits -> the guarded f32 BLAS fast path)
        xsp = stack_planes_lhs(xp, n_bits, log2_radix, shifted=False)
        wrev = stack_planes_rhs(wq, n_bits, log2_radix, axis=-2,
                                shifted=False)
        for dy in range(kh):
            for dx in range(kw):
                a = xsp[:, dy:dy + h, dx:dx + w_, :]
                acc = acc + stacked_gemm_planes(
                    a, wrev[dy, dx], cin, n_bits, log2_radix, levels,
                    shifted=False)
        return acc
    # per-tap K is only cin: shrink the contraction block to the smallest
    # 128-lane multiple so shallow layers (cin=3) don't pad 9 taps to 256
    bk = min(256, -(-cin // 128) * 128)
    for dy in range(kh):
        for dx in range(kw):
            a = xp[:, dy:dy + h, dx:dx + w_, :].reshape(-1, cin)
            t = _l2r_gemm_backend(a, wq[dy, dx], n_bits, log2_radix, levels,
                                  128, bk, 128, "stacked", backend)
            acc = acc + t.reshape(bsz, h, w_, cout)
    return acc


def l2r_conv2d(
    x: jax.Array,
    w: jax.Array | None,
    b: jax.Array | None = None,
    cfg: QuantConfig = QuantConfig(),
    levels: int | None = None,
    w_q: QuantizedWeights | None = None,
    backend: str | None = None,
) -> jax.Array:
    """Fused L2R conv2d, NHWC/HWIO, stride 1, "SAME" padding.

    The composite-IPU conv without the HBM patch matrix: activations are
    quantized per image (scales commute with the window contraction),
    digit planes are extracted once, and each kernel tap streams a
    shifted view of the feature map through the level-stacked GEMM.
    ``w_q`` reuses a load-time weight cache; otherwise ``w`` (kh, kw,
    cin, cout) is quantized per output channel here.
    """
    if w_q is None:
        w_q = quantize_weights(w, cfg)  # (kh,kw,cin,cout), scale (1,1,1,cout)
    xq, xs = quantize(x, cfg, axis=0)  # per-image scales (B,1,1,1)
    out = _l2r_conv2d_int(xq, w_q.q, cfg.n_bits, cfg.log2_radix, levels,
                          resolve_backend(backend))
    out = out.astype(jnp.float32) * xs * w_q.scale.reshape(1, 1, 1, -1)
    out = out.astype(x.dtype)
    if b is not None:
        out = out + b.astype(out.dtype)
    return out
