"""Jitted public wrappers around the L2R digit-plane GEMM kernel.

Handles padding to MXU-aligned blocks, batching, quantize/dequantize and
CPU fallback (interpret mode — this container has no TPU; on real
hardware `interpret=False` compiles the Pallas kernel).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig, quantize

from .kernel import l2r_gemm_pallas
from .ref import l2r_gemm_ref

__all__ = ["l2r_gemm", "l2r_matmul_f", "pad_to"]


def pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = []
    for dim, mult in zip(x.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "bm", "bk", "bn", "use_pallas", "interpret"),
)
def l2r_gemm(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    bm: int = 128,
    bk: int = 256,
    bn: int = 128,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Integer MSDF GEMM with automatic zero padding. (M,K)x(K,N)->int32."""
    m, k = aq.shape
    n = bq.shape[1]
    if not use_pallas:
        return l2r_gemm_ref(aq, bq, n_bits, log2_radix, levels)
    ap = pad_to(aq, (bm, bk))
    bp = pad_to(bq, (bk, bn))
    out = l2r_gemm_pallas(
        ap, bp, n_bits, log2_radix, levels, bm, bk, bn, interpret=interpret
    )
    return out[:m, :n]


def l2r_matmul_f(
    x: jax.Array,
    w: jax.Array,
    cfg: QuantConfig = QuantConfig(),
    levels: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Float -> quantize -> Pallas MSDF GEMM -> dequantized float."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xq, xs = quantize(x2, cfg, axis=0)  # per-row scales
    wq, ws = quantize(w, cfg, axis=-1)  # per-col scales
    out = l2r_gemm(xq, wq, cfg.n_bits, cfg.log2_radix, levels)
    return (out.astype(jnp.float32) * xs * ws).astype(x.dtype).reshape(*lead, w.shape[-1])
