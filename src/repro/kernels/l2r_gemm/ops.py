"""Public L2R GEMM/conv ops: backend dispatch, padding, quant/dequant.

This is the production entry point for the model stack (models/cnn.py,
models/common.py:dense, serve/engine.py).  Three backends:

  * ``jnp``             — the level-stacked pure-jnp schedule
                          (core/l2r_gemm.py); fastest off-TPU, no padding;
  * ``pallas-interpret``— the Pallas kernel body interpreted on CPU
                          (validation only — slow, but exercises the real
                          kernel dataflow);
  * ``pallas-tpu``      — the compiled Pallas kernel (requires a TPU).

Selection: explicit ``backend=`` argument > ``REPRO_L2R_BACKEND`` env var
> platform default (``pallas-tpu`` on TPU hosts, ``jnp`` elsewhere).
``schedule`` picks ``stacked`` (production, 2D-1 level matmuls),
``streaming`` (the same level walk emitted as a per-level prefix stream —
scan-based, progressive-precision consumers fold over it; bit-identical
to ``stacked`` at every truncation depth) or ``pairs`` (the D²-pass
baseline, kept for regression benchmarks).  ``l2r_gemm_progressive`` /
``l2r_conv2d_progressive`` expose the per-level snapshots + tail bounds
(core/progressive.py) behind the same backend dispatch.

The fused ``l2r_conv2d`` performs implicit im2col: the kh*kw taps of the
window stream through the digit-plane GEMM as shifted views of the
feature map, so the (B*H*W, cin*kh*kw) patch matrix is never
materialized in HBM.

**Pre-stacked plane operands** (``PlaneOperands``, core/quant.py): the
digit-plane stacks — not the raw int tensors — are the real operands of
every schedule, so the stacks are a first-class API.  ``l2r_gemm`` (and
the streaming consumers in core/progressive.py) accept a
``PlaneOperands`` in place of either raw operand on every backend;
``l2r_conv2d`` / ``l2r_conv2d_progressive*`` consume the
``QuantizedWeights.planes`` load-time weight-stack cache (built by
``quantize_weights(..., prestack=True)``).  The operand story:

  * activations: plane extraction is hoisted ONCE per feature map on
    EVERY backend — the jnp conv stacks raw digits (f32 BLAS fast path),
    the Pallas conv stacks pre-shifted bit-fields and each tap feeds a
    shifted view straight into the pre-stacked kernel entries
    (kernel.py:l2r_gemm_pallas_stacked_planes / _streaming_planes), so
    the kh*kw taps share one extraction instead of paying one each;
  * weights: ``QuantizedWeights`` caches the reversed RHS stack at model
    load (raw-digit layout — converts to the pre-shifted Pallas layout
    with exact chunk shifts) — weight planes are extracted exactly once
    per process instead of once per call/decode step;
  * all prestacked paths are bit-identical to inline extraction (the
    inline paths build the very same stacks; swept in
    tests/test_prestacked.py).
"""

from __future__ import annotations

import functools
import os
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.l2r_attention import (attn_scores_stacked,
                                      attn_scores_streaming_scan,
                                      attn_scores_streaming_while)
from repro.core.l2r_gemm import (l2r_matmul_int_stacked, stacked_gemm_planes)
from repro.core.progressive import (ProgressiveResult, l2r_matmul_int_streaming,
                                    level_bounds, progressive_matmul)
from repro.core.quant import (PlaneOperands, QuantConfig, QuantizedWeights,
                              plane_count, quantize, quantize_weights,
                              stack_planes_lhs, stack_planes_rhs)

from .kernel import (l2r_gemm_pallas, l2r_gemm_pallas_stacked,
                     l2r_gemm_pallas_stacked_planes,
                     l2r_gemm_pallas_streaming,
                     l2r_gemm_pallas_streaming_planes)
from .ref import l2r_gemm_ref

__all__ = ["l2r_gemm", "l2r_gemm_progressive", "l2r_attn_scores",
           "l2r_matmul_f", "l2r_conv2d",
           "l2r_conv2d_progressive", "l2r_conv2d_progressive_while",
           "pad_to", "resolve_backend", "PlaneOperands",
           "BACKENDS", "BACKEND_ENV_VAR", "SCHEDULES"]

SCHEDULES = ("stacked", "pairs", "streaming")

BACKENDS = ("jnp", "pallas-interpret", "pallas-tpu")
BACKEND_ENV_VAR = "REPRO_L2R_BACKEND"


def resolve_backend(backend: str | None = None) -> str:
    """Dispatch rule: explicit arg > $REPRO_L2R_BACKEND > platform default.

    The platform default is ``pallas-tpu`` when jax runs on TPU and the
    ``jnp`` level-stacked schedule everywhere else (interpret-mode Pallas
    is a validation tool, never a production default).

    An explicit ``pallas-tpu`` on a host whose jax platform is not TPU is
    rejected HERE, with a clear message — previously the mismatch
    surfaced as an opaque Mosaic lowering error deep inside the first
    ``pallas_call``.  A typo'd ``$REPRO_L2R_BACKEND`` is rejected here
    too, naming the env var and the valid backends — resolve time is the
    ONE place a bad env value can fail early instead of surfacing as an
    arbitrary downstream error.
    """
    source = "backend argument"
    chosen = backend
    if not chosen:
        env = os.environ.get(BACKEND_ENV_VAR, "").strip()
        if env:
            chosen, source = env, f"${BACKEND_ENV_VAR} env var"
    chosen = chosen or "auto"
    if chosen == "auto":
        return "pallas-tpu" if jax.default_backend() == "tpu" else "jnp"
    if chosen not in BACKENDS:
        raise ValueError(
            f"unknown L2R backend {chosen!r} (from the {source}); valid "
            f"backends: {', '.join(BACKENDS)}, or 'auto' for the platform "
            f"default")
    if chosen == "pallas-tpu" and jax.default_backend() != "tpu":
        raise RuntimeError(
            f"backend='pallas-tpu' requires a TPU host, but jax is running "
            f"on {jax.default_backend()!r}.  Use backend='pallas-interpret' "
            f"to validate the kernel dataflow on this host (slow, "
            f"correctness only), backend='jnp' for the production CPU/GPU "
            f"path, or unset ${BACKEND_ENV_VAR} for the platform default.")
    return chosen


def pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    """Zero-pad every dim of ``x`` up to a multiple of ``mults`` (exact for
    matmul operands).  ``mults`` must name every dim: a shorter (or
    longer) tuple used to be silently zip-truncated, leaving trailing
    dims unpadded with no error — now a ValueError.
    """
    if len(mults) != x.ndim:
        raise ValueError(
            f"pad_to: mults {mults!r} has rank {len(mults)} but x has rank "
            f"{x.ndim} (shape {x.shape}); every dim needs a multiple — "
            f"pass 1 for dims that should stay unpadded")
    pads = []
    for dim, mult in zip(x.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def _lhs_stack_blocked(a, n_bits: int, log2_radix: int, bm: int, bk: int):
    """Pre-shifted LHS plane stack block-padded for the Pallas kernels.

    ``a`` is a raw (M, K) operand (padded then stacked — identical to
    stacking the padded operand) or a :class:`PlaneOperands` (its core
    stack is chunk-padded: zero digits of zero values, exact).  Returns
    ``(stack (Mp, D*Kp), m)``.
    """
    d = plane_count(n_bits, log2_radix)
    if isinstance(a, PlaneOperands):
        st = a.core_stack(shifted=True)
        m, k = st.shape[-2], a.k
        r = st.reshape(m, d, k)
        r = jnp.pad(r, (((0, (-m) % bm), (0, 0), (0, (-k) % bk))))
        return r.reshape(r.shape[0], -1), m
    m = a.shape[0]
    return stack_planes_lhs(pad_to(a, (bm, bk)), n_bits, log2_radix), m


def _rhs_stack_blocked(b, n_bits: int, log2_radix: int, bk: int, bn: int):
    """Pre-shifted (descending) RHS plane stack block-padded per chunk.
    Returns ``(stack (D*Kp, Np), n)``; accepts raw (K, N) or a 2-D
    :class:`PlaneOperands`."""
    d = plane_count(n_bits, log2_radix)
    if isinstance(b, PlaneOperands):
        st = b.core_stack(shifted=True)
        k, n = b.k, st.shape[-1]
        r = st.reshape(d, k, n)
        r = jnp.pad(r, ((0, 0), (0, (-k) % bk), (0, (-n) % bn)))
        return r.reshape(-1, r.shape[-1]), n
    n = b.shape[1]
    return stack_planes_rhs(pad_to(b, (bk, bn)), n_bits, log2_radix), n


def _gemm_mk(a) -> tuple[int, int]:
    if isinstance(a, PlaneOperands):
        return a.stack.shape[-2], a.k
    return a.shape


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "bm", "bk", "bn",
                     "schedule", "backend", "early_exit"),
)
def _l2r_gemm_backend(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int,
    log2_radix: int,
    levels: int | None,
    bm: int,
    bk: int,
    bn: int,
    schedule: str,
    backend: str,
    early_exit: bool = False,
) -> jax.Array:
    """Backend-resolved integer GEMM (backend is a static, already-resolved
    string here so the trace cache keys on it).  Either operand may be a
    pre-stacked :class:`PlaneOperands` (schedule "stacked"/"streaming")."""
    a_pre = isinstance(aq, PlaneOperands)
    b_pre = isinstance(bq, PlaneOperands)
    if backend == "jnp":
        if schedule == "stacked":
            if not (a_pre or b_pre):
                return l2r_matmul_int_stacked(aq, bq, n_bits, log2_radix,
                                              levels)
            # raw-digit layout whenever every operand allows it (the f32
            # BLAS fast path); a pre-shifted cache pulls both sides to
            # the shift-free int-dot layout instead of being unshifted
            shifted = (a_pre and aq.shifted) or (b_pre and bq.shifted)
            a_st = aq.core_stack(shifted) if a_pre else stack_planes_lhs(
                aq, n_bits, log2_radix, shifted=shifted)
            b_st = bq.core_stack(shifted) if b_pre else stack_planes_rhs(
                bq, n_bits, log2_radix, shifted=shifted)
            k = aq.k if a_pre else aq.shape[-1]
            return stacked_gemm_planes(a_st, b_st, k, n_bits, log2_radix,
                                       levels, shifted=shifted)
        if schedule == "streaming":
            return l2r_matmul_int_streaming(aq, bq, n_bits, log2_radix,
                                            levels, early_exit)
        return l2r_gemm_ref(aq, bq, n_bits, log2_radix, levels)
    interpret = backend == "pallas-interpret"
    m, _ = _gemm_mk(aq)
    if schedule == "pairs":  # raw-only baseline (validated in l2r_gemm)
        n = bq.shape[1]
        out = l2r_gemm_pallas(pad_to(aq, (bm, bk)), pad_to(bq, (bk, bn)),
                              n_bits, log2_radix, levels, bm, bk, bn,
                              interpret=interpret)
        return out[:m, :n]
    # schedule="streaming" asks only for the FINAL prefix: the stacked
    # kernel walks the identical (level, k-block) schedule, so it IS that
    # prefix — writing the (L, M, N) snapshot planes
    # (l2r_gemm_pallas_streaming, used by l2r_gemm_progressive) would
    # spend L x the output HBM on a bit-identical result.
    a_stack, m = _lhs_stack_blocked(aq, n_bits, log2_radix, bm, bk)
    b_rev, n = _rhs_stack_blocked(bq, n_bits, log2_radix, bk, bn)
    out = l2r_gemm_pallas_stacked_planes(a_stack, b_rev, n_bits, log2_radix,
                                         levels, bm, bk, bn,
                                         interpret=interpret)
    return out[:m, :n]


def _describe_operand(x) -> str:
    if isinstance(x, PlaneOperands):
        return x.describe()
    return f"array(shape={tuple(x.shape)}, dtype={x.dtype})"


def _check_plane_operand(x, side: str, n_bits: int, log2_radix: int,
                         other=None) -> None:
    if not isinstance(x, PlaneOperands):
        return
    paired = "" if other is None \
        else f" (other operand: {_describe_operand(other)})"
    if x.side != side:
        raise ValueError(
            f"{x.describe()} prepared as {x.side!r} passed as the {side} "
            f"operand (LHS stacks ascend, RHS stacks descend — they are "
            f"not interchangeable){paired}")
    if (x.n_bits, x.log2_radix) != (n_bits, log2_radix):
        raise ValueError(
            f"{x.describe()} does not match the call "
            f"(n_bits={n_bits}, log2_radix={log2_radix}){paired}; "
            f"re-prepare the stack for this config")


def l2r_gemm(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    bm: int = 128,
    bk: int = 256,
    bn: int = 128,
    schedule: str = "stacked",
    backend: str | None = None,
    early_exit: bool = False,
) -> jax.Array:
    """Integer MSDF GEMM with backend dispatch. (M,K)x(K,N) -> int32.

    Any shape is accepted (Pallas backends zero-pad to blocks — exact for
    matmul).  Bit-identical across backends and schedules, including
    truncated ``levels``.

    Either operand may be a pre-stacked
    :class:`~repro.core.quant.PlaneOperands` (``PlaneOperands.prepare_lhs``
    / ``prepare_rhs``, or the ``QuantizedWeights.planes`` load-time
    cache) on every backend — plane extraction then happens exactly once
    where the operand was prepared, not once per call, with bit-identical
    results.  The ``pairs`` baseline schedule consumes raw int tensors
    only.

    ``early_exit`` (``schedule="streaming"``, jnp backend) runs the level
    walk as the ``lax.while_loop`` emitter instead of the fixed scan —
    bit-identical result here (with no consumer fold every level runs; it
    is the control flow early-exit consumers terminate inside, see
    core/progressive.py).  Schedules/backends that cannot honor the flag
    REJECT it: the pairs/stacked schedules have no level loop to stop,
    and the Pallas grids cannot shrink at runtime — their analogue is the
    streaming kernel's dynamic ``level_count`` scalar
    (kernel.py:l2r_gemm_pallas_streaming).
    """
    assert schedule in SCHEDULES, schedule
    if early_exit and schedule != "streaming":
        raise ValueError(
            f"early_exit is a streaming-schedule control flow; "
            f"schedule={schedule!r} has no level loop to stop short "
            f"(it would be silently dropped)")
    resolved = resolve_backend(backend)
    if early_exit and resolved != "jnp":
        raise ValueError(
            f"early_exit=True is the jnp while-loop emitter; the "
            f"{resolved!r} backend cannot shrink its grid at runtime and "
            f"would silently drop the flag — use the streaming kernel's "
            f"dynamic level_count scalar "
            f"(l2r_gemm_pallas_streaming(level_count=...)) for grid-level "
            f"stop-short on Pallas")
    _check_plane_operand(aq, "lhs", n_bits, log2_radix, other=bq)
    _check_plane_operand(bq, "rhs", n_bits, log2_radix, other=aq)
    if schedule == "pairs" and (isinstance(aq, PlaneOperands)
                                or isinstance(bq, PlaneOperands)):
        raise TypeError(
            "schedule='pairs' (the D²-pass baseline) consumes raw int "
            "operands; pre-stacked PlaneOperands are a stacked/streaming-"
            "schedule format")
    # trace-time int32 soundness certificate (analysis/overflow.py):
    # K is static here, so unsound digit configs are caught before any
    # tensor flows.  Deferred import: analysis pulls in core modules.
    from repro.analysis.overflow import check_or_raise as _certify
    k = aq.k if isinstance(aq, PlaneOperands) else (
        bq.k if isinstance(bq, PlaneOperands) else int(aq.shape[-1]))
    _certify(n_bits, log2_radix, int(k), levels=levels, where="l2r_gemm")
    return _l2r_gemm_backend(aq, bq, n_bits, log2_radix, levels,
                             bm, bk, bn, schedule, resolved,
                             early_exit)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "bm", "bk", "bn",
                     "backend"),
)
def _l2r_gemm_progressive_backend(aq, bq, n_bits, log2_radix, levels,
                                  bm, bk, bn, backend):
    if backend == "jnp":
        return progressive_matmul(aq, bq, n_bits, log2_radix, levels)
    m, k = _gemm_mk(aq)
    a_stack, m = _lhs_stack_blocked(aq, n_bits, log2_radix, bm, bk)
    b_rev, n = _rhs_stack_blocked(bq, n_bits, log2_radix, bk, bn)
    stream = l2r_gemm_pallas_streaming_planes(
        a_stack, b_rev, n_bits, log2_radix, levels, bm, bk, bn,
        interpret=(backend == "pallas-interpret"))
    bounds = level_bounds(plane_count(n_bits, log2_radix), log2_radix, k,
                          levels)
    return ProgressiveResult(partial=stream[:, :m, :n], tail_bound=bounds.f32,
                             bound_i32=bounds.i32, decidable=bounds.decidable)


def l2r_gemm_progressive(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    bm: int = 128,
    bk: int = 256,
    bn: int = 128,
    backend: str | None = None,
) -> ProgressiveResult:
    """Per-level MSDF snapshot stream with backend dispatch.

    Level l of ``result.partial`` is bit-identical to
    ``l2r_gemm(..., levels=l+1, schedule="stacked")`` on every backend;
    bounds come with the int32 exactness guard (core/progressive.py).
    Either operand may be a pre-stacked :class:`PlaneOperands` (as in
    :func:`l2r_gemm`).  Consumers that only need a fold over the stream
    (early-exit serving) should use
    ``core.progressive.streaming_matmul_scan`` instead — this entry
    materializes the ``(L, M, N)`` stack it returns.
    """
    _check_plane_operand(aq, "lhs", n_bits, log2_radix, other=bq)
    _check_plane_operand(bq, "rhs", n_bits, log2_radix, other=aq)
    return _l2r_gemm_progressive_backend(aq, bq, n_bits, log2_radix, levels,
                                         bm, bk, bn, resolve_backend(backend))


def _attn_pallas_scores(q_po: PlaneOperands, k_po: PlaneOperands,
                        n_bits: int, log2_radix: int, levels: int | None,
                        interpret: bool) -> jax.Array:
    """Attention scores through the pre-stacked Pallas GEMM kernel.

    The score walk is a batch of independent (Q*G, dh) x (dh, S) GEMMs —
    one per (batch, kv-head) — and each one IS the level-stacked kernel's
    problem, so the route is an unrolled loop of
    ``l2r_gemm_pallas_stacked_planes`` calls over pre-shifted slices of
    the SAME stacks the jnp schedule consumes (the cache's descending
    head-dim blocks transpose to the kernel's (D*K, N) layout exactly —
    plane-major descending either way).  Validation-oriented: the batch
    loop is python-unrolled, so this is for parity runs and small decode
    shapes, not the production serving path (which is jnp off-TPU).
    """
    d = plane_count(n_bits, log2_radix)
    dh = q_po.k
    qs = q_po.core_stack(shifted=True)   # (B, Q, Kv, G, D*dh) ascending
    ks = k_po.core_stack(shifted=True)   # (B, S, Kv, D*dh) descending
    b_, q_, kv, g = qs.shape[:4]
    s_ = ks.shape[1]
    bk = min(256, -(-dh // 128) * 128)
    dhp = dh + (-dh) % bk
    m0 = q_ * g
    rows = []
    for bi in range(b_):
        cols = []
        for kvi in range(kv):
            a = qs[bi, :, kvi].reshape(m0, d, dh)
            a = jnp.pad(a, (((0, (-m0) % 128), (0, 0), (0, dhp - dh))))
            kb = ks[bi, :, kvi].reshape(s_, d, dh).transpose(1, 2, 0)
            kb = jnp.pad(kb, ((0, 0), (0, dhp - dh), (0, (-s_) % 128)))
            t = l2r_gemm_pallas_stacked_planes(
                a.reshape(a.shape[0], -1), kb.reshape(-1, kb.shape[-1]),
                n_bits, log2_radix, levels, 128, bk, 128,
                interpret=interpret)
            cols.append(t[:m0, :s_].reshape(q_, g, s_).transpose(1, 0, 2))
        rows.append(jnp.stack(cols, axis=0))
    return jnp.stack(rows, axis=0)  # (B, Kv, G, Q, S)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "schedule", "backend",
                     "early_exit"),
)
def _l2r_attn_scores_backend(qq, kq, n_bits, log2_radix, levels, schedule,
                             backend, early_exit):
    if backend == "jnp":
        if schedule == "streaming":
            if early_exit:
                acc, _, _ = attn_scores_streaming_while(
                    qq, kq, n_bits=n_bits, log2_radix=log2_radix,
                    levels=levels)
            else:
                acc, _, _ = attn_scores_streaming_scan(
                    qq, kq, n_bits=n_bits, log2_radix=log2_radix,
                    levels=levels)
            return acc
        return attn_scores_stacked(qq, kq, n_bits, log2_radix, levels)
    # schedule="streaming" asks only for the FINAL prefix here, and the
    # stacked kernel walks the identical (level, k-block) schedule — same
    # argument as _l2r_gemm_backend's streaming-on-Pallas route.
    q_po = qq if isinstance(qq, PlaneOperands) \
        else PlaneOperands.prepare_lhs(qq, n_bits, log2_radix)
    k_po = kq if isinstance(kq, PlaneOperands) \
        else PlaneOperands.prepare_rhs(kq, n_bits, log2_radix, axis=-1)
    return _attn_pallas_scores(q_po, k_po, n_bits, log2_radix, levels,
                               backend == "pallas-interpret")


def l2r_attn_scores(
    qq,
    kq,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    schedule: str = "stacked",
    backend: str | None = None,
    early_exit: bool = False,
) -> jax.Array:
    """Digit-serial QK^T scores with backend dispatch: int32 (B,Kv,G,Q,S).

    ``qq`` is the grouped query block (B, Q, Kv, G, dh) as signed ints or
    a prepared LHS :class:`PlaneOperands`; ``kq`` the cached keys
    (B, S, Kv, dh) as signed ints or the KV cache's incrementally
    stacked RHS operand (models/attention.py:kv_plane_operands — plane
    extraction then happened at append time, not per decode step).
    Bit-identical across backends and schedules at every ``levels``
    truncation, by the same contract as :func:`l2r_gemm`; softmax and PV
    stay float outside this entry (core/l2r_attention.py).

    ``schedule="streaming"`` runs the level walk as the per-level prefix
    emitter (jnp; on Pallas the stacked kernel IS the final prefix);
    ``early_exit`` additionally swaps in the ``lax.while_loop`` emitter —
    control-flow-only here (no consumer fold, every level runs), rejected
    off the jnp streaming path exactly as in :func:`l2r_gemm`.  Consumers
    that fold the stream (margin-bounded progressive decode) use
    ``core.l2r_attention.attn_scores_streaming_while`` directly.
    """
    if schedule not in ("stacked", "streaming"):
        raise ValueError(
            f"l2r_attn_scores schedule must be 'stacked' or 'streaming', "
            f"got {schedule!r} (the pairs baseline is a GEMM-only "
            f"regression schedule)")
    if early_exit and schedule != "streaming":
        raise ValueError(
            f"early_exit is a streaming-schedule control flow; "
            f"schedule={schedule!r} has no level loop to stop short "
            f"(it would be silently dropped)")
    resolved = resolve_backend(backend)
    if early_exit and resolved != "jnp":
        raise ValueError(
            f"early_exit=True is the jnp while-loop emitter; the "
            f"{resolved!r} backend cannot shrink its grid at runtime and "
            f"would silently drop the flag")
    _check_plane_operand(qq, "lhs", n_bits, log2_radix, other=kq)
    _check_plane_operand(kq, "rhs", n_bits, log2_radix, other=qq)
    from repro.analysis.overflow import check_or_raise as _certify
    dh = qq.k if isinstance(qq, PlaneOperands) else (
        kq.k if isinstance(kq, PlaneOperands) else int(qq.shape[-1]))
    _certify(n_bits, log2_radix, int(dh), levels=levels,
             where="l2r_attn_scores")
    return _l2r_attn_scores_backend(qq, kq, n_bits, log2_radix, levels,
                                    schedule, resolved, early_exit)


def l2r_matmul_f(
    x: jax.Array,
    w: jax.Array | None,
    cfg: QuantConfig = QuantConfig(),
    levels: int | None = None,
    w_q: QuantizedWeights | tuple[jax.Array, jax.Array] | None = None,
    backend: str | None = None,
    schedule: str = "stacked",
) -> jax.Array:
    """Float -> quantize -> dispatched MSDF GEMM -> dequantized float.

    ``w_q`` (core/quant.py:QuantizedWeights, built once at load) skips
    the per-forward weight quantization; ``w`` may then be None.  When
    the cache also carries its pre-stacked RHS plane stack
    (``quantize_weights(..., prestack=True)``) and the layout matches
    this call's config, the GEMM consumes the stack directly — weight
    plane extraction then happened exactly once at load time.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    # per-row (per-token) activation scales commute with the K-contraction
    xq, xs = quantize(x2, cfg, axis=0 if cfg.per_channel else None)
    w_in = None
    if w_q is None:
        wq, ws = quantize(w, cfg, axis=-1)  # per-out-channel: (1, N)
    elif isinstance(w_q, QuantizedWeights):
        wq, ws = w_q.q, w_q.scale
        p = w_q.planes
        if (p is not None and schedule != "pairs"
                and p.matches(cfg.n_bits, cfg.log2_radix, ndim=2,
                              side="rhs")):
            w_in = p
    else:
        wq, ws = w_q
    out = l2r_gemm(xq, wq if w_in is None else w_in, cfg.n_bits,
                   cfg.log2_radix, levels, schedule=schedule, backend=backend)
    out = out.astype(jnp.float32) * xs * ws.reshape(1, -1)
    return out.astype(x.dtype).reshape(*lead, wq.shape[-1])


def _conv_same_geometry(h: int, w_: int, kh: int, kw: int,
                        stride: tuple[int, int], dilation: tuple[int, int]):
    """Output size + per-edge padding of a "SAME" conv (XLA/TF convention:
    total pad = max((out-1)*stride + eff_k - in, 0), low edge gets the
    floor half — matches lax.conv_general_dilated("SAME"))."""
    sh, sw = stride
    dh, dw = dilation
    oh, ow = -(-h // sh), -(-w_ // sw)
    eff_kh, eff_kw = (kh - 1) * dh + 1, (kw - 1) * dw + 1
    ph = max((oh - 1) * sh + eff_kh - h, 0)
    pw = max((ow - 1) * sw + eff_kw - w_, 0)
    return oh, ow, (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2)


def _tap_view(xp: jax.Array, dy: int, dx: int, oh: int, ow: int,
              stride: tuple[int, int], dilation: tuple[int, int]) -> jax.Array:
    """Shifted (strided) view of the padded map feeding tap (dy, dx):
    out[y, x] consumes xp[y*sh + dy*dh, x*sw + dx*dw]."""
    sh, sw = stride
    dh, dw = dilation
    return xp[:, dy * dh:dy * dh + (oh - 1) * sh + 1:sh,
              dx * dw:dx * dw + (ow - 1) * sw + 1:sw]


def _conv_w_geom(w_in) -> tuple[int, int, int, int]:
    """(kh, kw, cin, cout) of a raw conv weight or its PlaneOperands cache."""
    if isinstance(w_in, PlaneOperands):
        kh, kw = w_in.stack.shape[0], w_in.stack.shape[1]
        return kh, kw, w_in.k, w_in.stack.shape[-1]
    return w_in.shape


def _conv_wrev(w_in, n_bits: int, log2_radix: int, shifted: bool) -> jax.Array:
    """Reversed RHS plane stack (kh, kw, D*cin, cout) of the conv weight —
    from the load-time cache when present (exact layout conversion),
    extracted here otherwise."""
    if isinstance(w_in, PlaneOperands):
        return w_in.core_stack(shifted)
    return stack_planes_rhs(w_in, n_bits, log2_radix, axis=-2,
                            shifted=shifted)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "backend", "stride",
                     "dilation"),
)
def _l2r_conv2d_int(
    xq: jax.Array,
    w_in,
    n_bits: int,
    log2_radix: int,
    levels: int | None,
    backend: str,
    stride: tuple[int, int] = (1, 1),
    dilation: tuple[int, int] = (1, 1),
) -> jax.Array:
    """Integer core of the fused conv: implicit im2col over kh*kw taps.

    xq: (B, H, W, cin) small ints; ``w_in``: (kh, kw, cin, cout) small
    ints OR the pre-stacked :class:`PlaneOperands` weight cache;
    "SAME" padding, arbitrary stride/dilation (each tap reads a
    step-sliced shifted view — no patch matrix for any geometry).
    Bit-identical to quantized im2col + l2r_matmul_int on the same
    operands: the contraction over (kh, kw, cin) splits into kh*kw
    independent cin-contractions, and per-significance-level partial
    sums add across taps exactly.

    Activation plane extraction is hoisted out of the tap loop on EVERY
    backend — one stack per feature map (raw digits on jnp for the f32
    BLAS fast path, pre-shifted bit-fields feeding the pre-stacked
    Pallas kernel entry) — and the weight stack comes from the load-time
    cache when provided, so a cached 3x3 layer performs exactly one
    activation extraction and zero weight extractions per call.
    """
    bsz, h, w_, cin = xq.shape
    kh, kw, _, cout = _conv_w_geom(w_in)
    oh, ow, (ph_lo, ph_hi), (pw_lo, pw_hi) = _conv_same_geometry(
        h, w_, kh, kw, stride, dilation)
    xp = jnp.pad(xq, ((0, 0), (ph_lo, ph_hi), (pw_lo, pw_hi), (0, 0)))
    acc = jnp.zeros((bsz, oh, ow, cout), jnp.int32)
    d = plane_count(n_bits, log2_radix)
    if backend == "jnp":
        # hoist plane extraction out of the tap loop: one LHS stack for
        # the whole feature map, one reversed RHS stack for all taps
        # (raw digits -> the guarded f32 BLAS fast path)
        xsp = stack_planes_lhs(xp, n_bits, log2_radix, shifted=False)
        wrev = _conv_wrev(w_in, n_bits, log2_radix, shifted=False)
        for dy in range(kh):
            for dx in range(kw):
                a = _tap_view(xsp, dy, dx, oh, ow, stride, dilation)
                acc = acc + stacked_gemm_planes(
                    a, wrev[dy, dx], cin, n_bits, log2_radix, levels,
                    shifted=False)
        return acc
    # Pallas: the same per-feature-map hoist, in the kernels' pre-shifted
    # layout — each tap view of the stacked map feeds the pre-stacked
    # kernel entry directly (channels-last stacking commutes with the
    # spatial tap slicing), instead of re-extracting planes per tap.
    # Per-tap K is only cin: shrink the contraction block to the smallest
    # 128-lane multiple so shallow layers (cin=3) don't pad 9 taps to 256.
    bk = min(256, -(-cin // 128) * 128)
    ckp = cin + (-cin) % bk
    xsp = stack_planes_lhs(xp, n_bits, log2_radix)  # (B, H', W', D*cin)
    wrev = _conv_wrev(w_in, n_bits, log2_radix, shifted=True)
    wrev = jnp.pad(wrev.reshape(kh, kw, d, cin, cout),
                   ((0, 0), (0, 0), (0, 0), (0, ckp - cin),
                    (0, (-cout) % 128)))
    wrev = wrev.reshape(kh, kw, d * ckp, -1)
    interpret = backend == "pallas-interpret"
    for dy in range(kh):
        for dx in range(kw):
            a = _tap_view(xsp, dy, dx, oh, ow, stride, dilation)
            a2 = a.reshape(-1, d, cin)
            m0 = a2.shape[0]
            a2 = jnp.pad(a2, (((0, (-m0) % 128), (0, 0), (0, ckp - cin))))
            t = l2r_gemm_pallas_stacked_planes(
                a2.reshape(a2.shape[0], -1), wrev[dy, dx], n_bits,
                log2_radix, levels, 128, bk, 128, interpret=interpret)
            acc = acc + t[:m0, :cout].reshape(bsz, oh, ow, cout)
    return acc


def l2r_conv2d(
    x: jax.Array,
    w: jax.Array | None,
    b: jax.Array | None = None,
    cfg: QuantConfig = QuantConfig(),
    levels: int | None = None,
    w_q: QuantizedWeights | None = None,
    backend: str | None = None,
    stride: int | tuple[int, int] = 1,
    dilation: int | tuple[int, int] = 1,
) -> jax.Array:
    """Fused L2R conv2d, NHWC/HWIO, "SAME" padding, any stride/dilation.

    The composite-IPU conv without the HBM patch matrix: activations are
    quantized per image (scales commute with the window contraction),
    digit planes are extracted once per feature map on every backend,
    and each kernel tap streams a shifted (stride-stepped,
    dilation-spaced) view of the feature map through the level-stacked
    GEMM.  ``w_q`` reuses a load-time weight cache — when it carries the
    pre-stacked plane stack (``quantize_weights(..., prestack=True,
    plane_axis=-2)``) the conv consumes that stack directly and performs
    no weight plane extraction at all; otherwise ``w`` (kh, kw, cin,
    cout) is quantized per output channel here.
    """
    if w_q is None:
        w_q = quantize_weights(w, cfg)  # (kh,kw,cin,cout), scale (1,1,1,cout)
    from repro.analysis.overflow import check_or_raise as _certify
    kh, kw, cin, _ = w_q.q.shape
    _certify(cfg.n_bits, cfg.log2_radix, int(cin), levels=levels,
             taps=int(kh * kw), where="l2r_conv2d")
    xq, xs = quantize(x, cfg, axis=0)  # per-image scales (B,1,1,1)
    out = _l2r_conv2d_int(xq, _conv_w_in(w_q, cfg), cfg.n_bits,
                          cfg.log2_radix, levels,
                          resolve_backend(backend), _pair(stride),
                          _pair(dilation))
    out = out.astype(jnp.float32) * xs * w_q.scale.reshape(1, 1, 1, -1)
    out = out.astype(x.dtype)
    if b is not None:
        out = out + b.astype(out.dtype)
    return out


def _pair(v: int | tuple[int, int]) -> tuple[int, int]:
    return (v, v) if isinstance(v, int) else tuple(v)


def _conv_w_in(w_q: QuantizedWeights, cfg: QuantConfig):
    """The conv weight operand: the cached plane stack when its layout
    matches this call's config (contraction axis -2), the raw int weight
    otherwise (inline extraction — bit-identical)."""
    p = w_q.planes
    if p is not None and p.matches(cfg.n_bits, cfg.log2_radix, ndim=4,
                                   side="rhs", contract_axis=2):
        return p
    return w_q.q


# ------------------------------------------------------- progressive conv
def _conv_level_term(xq, w_in, n_bits, log2_radix, stride, dilation):
    """Per-level term of the progressive conv's jnp paths: hoisted
    zero-padded plane stacks + a ``term(ao, bo)`` closure summing the tap
    contributions of one significance level.  Shared by the fixed scan
    AND the early-exit while loop — identical ops in identical order is
    what keeps the two control flows bit-identical.  ``w_in`` may be the
    pre-stacked weight cache (its window stack IS the padded ``wrev``
    built here — zero extraction, bit-identical stream)."""
    from repro.core.l2r_gemm import _f32_dot_exact

    bsz, h, w_, cin = xq.shape
    kh, kw, _, cout = _conv_w_geom(w_in)
    d = n_bits // log2_radix
    oh, ow, (ph_lo, ph_hi), (pw_lo, pw_hi) = _conv_same_geometry(
        h, w_, kh, kw, stride, dilation)
    xp = jnp.pad(xq, ((0, 0), (ph_lo, ph_hi), (pw_lo, pw_hi), (0, 0)))
    xsp = stack_planes_lhs(xp, n_bits, log2_radix, shifted=False)
    pad = (d - 1) * cin
    xsp = jnp.pad(xsp, ((0, 0), (0, 0), (0, 0), (0, pad)))
    if isinstance(w_in, PlaneOperands):
        wrev = w_in.window_stack()
    else:
        wrev = stack_planes_rhs(w_in, n_bits, log2_radix, axis=-2,
                                shifted=False)
        wrev = jnp.pad(wrev, ((0, 0), (0, 0), (0, pad), (0, 0)))
    use_f32 = _f32_dot_exact(cin, d, log2_radix)
    if use_f32:
        xsp = xsp.astype(jnp.float32)
        wrev = wrev.astype(jnp.float32)
    width = d * cin

    def term(ao, bo):
        t_sum = jnp.zeros((bsz, oh, ow, cout), jnp.int32)
        for dy in range(kh):
            for dx in range(kw):
                a = _tap_view(xsp, dy, dx, oh, ow, stride, dilation)
                a_l = jax.lax.dynamic_slice_in_dim(a, ao * cin, width,
                                                   axis=a.ndim - 1)
                b_l = jax.lax.dynamic_slice_in_dim(wrev[dy, dx], bo * cin,
                                                   width, axis=0)
                t = jax.lax.dot_general(
                    a_l, b_l,
                    ((((a_l.ndim - 1),), ((0,))), ((), ())),
                    preferred_element_type=jnp.float32 if use_f32
                    else jnp.int32,
                    precision=jax.lax.Precision.HIGHEST if use_f32 else None,
                )
                t_sum = t_sum + t.astype(jnp.int32)
        return t_sum

    return term, (bsz, oh, ow, cout)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "backend", "stride",
                     "dilation"),
)
def _l2r_conv2d_progressive_int(
    xq: jax.Array,
    w_in,
    n_bits: int,
    log2_radix: int,
    levels: int | None,
    backend: str,
    stride: tuple[int, int] = (1, 1),
    dilation: tuple[int, int] = (1, 1),
) -> jax.Array:
    """Per-level prefix stream of the fused conv: (L, B, OH, OW, cout).

    Level l is bit-identical to ``_l2r_conv2d_int(..., levels=l+1)``: the
    taps share each significance level, so the per-level conv term is the
    tap sum of per-level GEMM terms.  The jnp path is the streaming scan
    of core/progressive.py with the tap loop inside the level step;
    Pallas backends sum the per-tap snapshot streams of the streaming
    kernel.  Activation planes are hoisted once per feature map on every
    backend, and ``w_in`` may be the pre-stacked weight cache (zero
    weight extraction).
    """
    from repro.core.progressive import _level_walk

    bsz, h, w_, cin = xq.shape
    kh, kw, _, cout = _conv_w_geom(w_in)
    d = n_bits // log2_radix
    oh, ow, (ph_lo, ph_hi), (pw_lo, pw_hi) = _conv_same_geometry(
        h, w_, kh, kw, stride, dilation)
    a_off, b_off, svals = _level_walk(d, levels)
    n_steps = int(svals.shape[0])
    if n_steps == 0:
        return jnp.zeros((0, bsz, oh, ow, cout), jnp.int32)
    if backend != "jnp":
        xp = jnp.pad(xq, ((0, 0), (ph_lo, ph_hi), (pw_lo, pw_hi), (0, 0)))
        bk = min(256, -(-cin // 128) * 128)
        ckp = cin + (-cin) % bk
        xsp = stack_planes_lhs(xp, n_bits, log2_radix)  # once per map
        wrev = _conv_wrev(w_in, n_bits, log2_radix, shifted=True)
        wrev = jnp.pad(wrev.reshape(kh, kw, d, cin, cout),
                       ((0, 0), (0, 0), (0, 0), (0, ckp - cin),
                        (0, (-cout) % 128)))
        wrev = wrev.reshape(kh, kw, d * ckp, -1)
        acc = jnp.zeros((n_steps, bsz, oh, ow, cout), jnp.int32)
        for dy in range(kh):
            for dx in range(kw):
                a = _tap_view(xsp, dy, dx, oh, ow, stride, dilation)
                a2 = a.reshape(-1, d, cin)
                m0 = a2.shape[0]
                a2 = jnp.pad(a2,
                             (((0, (-m0) % 128), (0, 0), (0, ckp - cin))))
                t = l2r_gemm_pallas_streaming_planes(
                    a2.reshape(a2.shape[0], -1), wrev[dy, dx], n_bits,
                    log2_radix, levels, 128, bk, 128,
                    interpret=(backend == "pallas-interpret"))
                t = t[:, :m0, :cout]
                acc = acc + t.reshape(n_steps, bsz, oh, ow, cout)
        return acc

    term, out_shape = _conv_level_term(xq, w_in, n_bits, log2_radix, stride,
                                       dilation)

    def step(acc, xs):
        ao, bo, s = xs
        acc = acc + (term(ao, bo) << (log2_radix * s))
        return acc, acc

    acc0 = jnp.zeros(out_shape, jnp.int32)
    xs = (jnp.asarray(a_off), jnp.asarray(b_off), jnp.asarray(svals))
    _, stack = jax.lax.scan(step, acc0, xs)
    return stack


def l2r_conv2d_progressive_while(
    x: jax.Array,
    w: jax.Array | None = None,
    cfg: QuantConfig = QuantConfig(),
    fold: Callable | None = None,
    init=None,
    done_fn: Callable | None = None,
    levels: int | None = None,
    w_q: QuantizedWeights | None = None,
    backend: str | None = None,
    stride: int | tuple[int, int] = 1,
    dilation: int | tuple[int, int] = 1,
):
    """Early-exit fused conv stream: the progressive conv's level loop run
    as a ``lax.while_loop`` carrying the consumer's fold state.

    The per-level arithmetic is the SAME tap-summed term the fixed scan
    of :func:`l2r_conv2d_progressive` executes (shared closure), so after
    ``levels_run`` iterations the integer prefix is bit-identical to
    ``result.partial[levels_run - 1]`` of the scan path.  ``fold(carry,
    partial, level_index) -> carry`` consumes each integer prefix;
    ``done_fn(fold_carry) -> scalar bool`` stops the loop (``None`` runs
    every level — control-flow-only).  jnp backend only: the grid-level
    analogue on Pallas is the streaming kernel's ``level_count`` scalar.

    Returns ``(prefix (B, OH, OW, cout) int32, fold_carry, levels_run
    () int32, scale (B, 1, 1, cout))`` — ``prefix * scale`` is the float
    feature-map prefix at the exit level.
    """
    assert resolve_backend(backend) == "jnp", (
        "l2r_conv2d_progressive_while: jnp backend only (use the streaming "
        "kernel's level_count scalar for grid-level shortening)")
    if w_q is None:
        w_q = quantize_weights(w, cfg)
    xq, xs = quantize(x, cfg, axis=0)  # per-image scales (B,1,1,1)
    from repro.core.progressive import _level_walk, _while_emitter

    a_off, b_off, svals = _level_walk(cfg.planes, levels)
    scale = xs * w_q.scale.reshape(1, 1, 1, -1)
    term, out_shape = _conv_level_term(xq, _conv_w_in(w_q, cfg), cfg.n_bits,
                                       cfg.log2_radix,
                                       _pair(stride), _pair(dilation))
    acc0 = jnp.zeros(out_shape, jnp.int32)
    if int(svals.shape[0]) == 0:
        return acc0, init, jnp.int32(0), scale
    t, acc, fold_c = _while_emitter(term, a_off, b_off, svals,
                                    cfg.log2_radix, acc0, fold, init,
                                    done_fn)
    return acc, fold_c, t, scale


def l2r_conv2d_progressive(
    x: jax.Array,
    w: jax.Array | None = None,
    cfg: QuantConfig = QuantConfig(),
    levels: int | None = None,
    w_q: QuantizedWeights | None = None,
    backend: str | None = None,
    stride: int | tuple[int, int] = 1,
    dilation: int | tuple[int, int] = 1,
):
    """Progressive-precision fused conv: per-level snapshots + tail bounds.

    Returns ``(result, scale)``: ``result`` is a
    :class:`~repro.core.progressive.ProgressiveResult` whose
    ``partial[l]`` is the integer conv truncated after l+1 MSDF levels
    (bit-identical to ``l2r_conv2d``'s core at ``levels=l+1``), with tail
    bounds for the conv's effective contraction K = kh*kw*cin; ``scale``
    is the (B, 1, 1, cout) dequantization factor (per-image activation
    scale x per-channel weight scale) — ``partial[l] * scale`` is the
    float feature map prefix, and ``tail_bound[l] * scale`` bounds its
    distance from the exact W8A8 conv.
    """
    if w_q is None:
        w_q = quantize_weights(w, cfg)
    xq, xs = quantize(x, cfg, axis=0)  # per-image scales (B,1,1,1)
    kh, kw, cin, _ = w_q.q.shape
    stack = _l2r_conv2d_progressive_int(
        xq, _conv_w_in(w_q, cfg), cfg.n_bits, cfg.log2_radix, levels,
        resolve_backend(backend), _pair(stride), _pair(dilation))
    bounds = level_bounds(cfg.planes, cfg.log2_radix, kh * kw * cin, levels)
    result = ProgressiveResult(partial=stack, tail_bound=bounds.f32,
                               bound_i32=bounds.i32,
                               decidable=bounds.decidable)
    return result, xs * w_q.scale.reshape(1, 1, 1, -1)
