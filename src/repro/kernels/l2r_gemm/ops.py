"""Public L2R GEMM/conv ops: backend dispatch, padding, quant/dequant.

This is the production entry point for the model stack (models/cnn.py,
models/common.py:dense, serve/engine.py).  Three backends:

  * ``jnp``             — the level-stacked pure-jnp schedule
                          (core/l2r_gemm.py); fastest off-TPU, no padding;
  * ``pallas-interpret``— the Pallas kernel body interpreted on CPU
                          (validation only — slow, but exercises the real
                          kernel dataflow);
  * ``pallas-tpu``      — the compiled Pallas kernel (requires a TPU).

Selection: explicit ``backend=`` argument > ``REPRO_L2R_BACKEND`` env var
> platform default (``pallas-tpu`` on TPU hosts, ``jnp`` elsewhere).
``schedule`` picks ``stacked`` (production, 2D-1 level matmuls),
``streaming`` (the same level walk emitted as a per-level prefix stream —
scan-based, progressive-precision consumers fold over it; bit-identical
to ``stacked`` at every truncation depth) or ``pairs`` (the D²-pass
baseline, kept for regression benchmarks).  ``l2r_gemm_progressive`` /
``l2r_conv2d_progressive`` expose the per-level snapshots + tail bounds
(core/progressive.py) behind the same backend dispatch.

The fused ``l2r_conv2d`` performs implicit im2col: the kh*kw taps of the
window stream through the digit-plane GEMM as shifted views of the
feature map, so the (B*H*W, cin*kh*kw) patch matrix is never
materialized in HBM.  On the jnp backend the activation digit planes are
additionally hoisted out of the tap loop (extracted once per feature
map); the Pallas backends still extract planes inside each per-tap
kernel call — hoisting them behind a pre-stacked kernel entry point is a
noted ROADMAP follow-up for real-TPU tuning.
"""

from __future__ import annotations

import functools
import os
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.l2r_gemm import (l2r_matmul_int_stacked, stacked_gemm_planes)
from repro.core.progressive import (ProgressiveResult, l2r_matmul_int_streaming,
                                    level_bounds, progressive_matmul)
from repro.core.quant import (QuantConfig, QuantizedWeights, plane_count,
                              quantize, quantize_weights, stack_planes_lhs,
                              stack_planes_rhs)

from .kernel import (l2r_gemm_pallas, l2r_gemm_pallas_stacked,
                     l2r_gemm_pallas_streaming)
from .ref import l2r_gemm_ref

__all__ = ["l2r_gemm", "l2r_gemm_progressive", "l2r_matmul_f", "l2r_conv2d",
           "l2r_conv2d_progressive", "l2r_conv2d_progressive_while",
           "pad_to", "resolve_backend",
           "BACKENDS", "BACKEND_ENV_VAR", "SCHEDULES"]

SCHEDULES = ("stacked", "pairs", "streaming")

BACKENDS = ("jnp", "pallas-interpret", "pallas-tpu")
BACKEND_ENV_VAR = "REPRO_L2R_BACKEND"


def resolve_backend(backend: str | None = None) -> str:
    """Dispatch rule: explicit arg > $REPRO_L2R_BACKEND > platform default.

    The platform default is ``pallas-tpu`` when jax runs on TPU and the
    ``jnp`` level-stacked schedule everywhere else (interpret-mode Pallas
    is a validation tool, never a production default).
    """
    chosen = backend or os.environ.get(BACKEND_ENV_VAR, "").strip() or "auto"
    if chosen == "auto":
        return "pallas-tpu" if jax.default_backend() == "tpu" else "jnp"
    if chosen not in BACKENDS:
        raise ValueError(
            f"unknown L2R backend {chosen!r}; expected one of {BACKENDS} or 'auto'")
    return chosen


def pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = []
    for dim, mult in zip(x.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "bm", "bk", "bn",
                     "schedule", "backend", "early_exit"),
)
def _l2r_gemm_backend(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int,
    log2_radix: int,
    levels: int | None,
    bm: int,
    bk: int,
    bn: int,
    schedule: str,
    backend: str,
    early_exit: bool = False,
) -> jax.Array:
    """Backend-resolved integer GEMM (backend is a static, already-resolved
    string here so the trace cache keys on it)."""
    if backend == "jnp":
        if schedule == "stacked":
            return l2r_matmul_int_stacked(aq, bq, n_bits, log2_radix, levels)
        if schedule == "streaming":
            return l2r_matmul_int_streaming(aq, bq, n_bits, log2_radix,
                                            levels, early_exit)
        return l2r_gemm_ref(aq, bq, n_bits, log2_radix, levels)
    m, k = aq.shape
    n = bq.shape[1]
    ap = pad_to(aq, (bm, bk))
    bp = pad_to(bq, (bk, bn))
    interpret = backend == "pallas-interpret"
    # schedule="streaming" asks only for the FINAL prefix: the stacked
    # kernel walks the identical (level, k-block) schedule, so it IS that
    # prefix — writing the (L, M, N) snapshot planes
    # (l2r_gemm_pallas_streaming, used by l2r_gemm_progressive) would
    # spend L x the output HBM on a bit-identical result.
    fn = l2r_gemm_pallas if schedule == "pairs" else l2r_gemm_pallas_stacked
    out = fn(ap, bp, n_bits, log2_radix, levels, bm, bk, bn,
             interpret=interpret)
    return out[:m, :n]


def l2r_gemm(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    bm: int = 128,
    bk: int = 256,
    bn: int = 128,
    schedule: str = "stacked",
    backend: str | None = None,
    early_exit: bool = False,
) -> jax.Array:
    """Integer MSDF GEMM with backend dispatch. (M,K)x(K,N) -> int32.

    Any shape is accepted (Pallas backends zero-pad to blocks — exact for
    matmul).  Bit-identical across backends and schedules, including
    truncated ``levels``.

    ``early_exit`` (``schedule="streaming"``, jnp backend) runs the level
    walk as the ``lax.while_loop`` emitter instead of the fixed scan —
    bit-identical result here (with no consumer fold every level runs; it
    is the control flow early-exit consumers terminate inside, see
    core/progressive.py).  Pallas backends ignore the flag: their stacked
    walk already IS the final prefix, and runtime shortening is the
    streaming kernel's ``level_count`` scalar.
    """
    assert schedule in SCHEDULES, schedule
    assert not early_exit or schedule == "streaming", \
        "early_exit is a streaming-schedule control flow; " \
        f"schedule={schedule!r} does not read it"
    return _l2r_gemm_backend(aq, bq, n_bits, log2_radix, levels,
                             bm, bk, bn, schedule, resolve_backend(backend),
                             early_exit)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "bm", "bk", "bn",
                     "backend"),
)
def _l2r_gemm_progressive_backend(aq, bq, n_bits, log2_radix, levels,
                                  bm, bk, bn, backend):
    if backend == "jnp":
        return progressive_matmul(aq, bq, n_bits, log2_radix, levels)
    m, k = aq.shape
    n = bq.shape[1]
    ap = pad_to(aq, (bm, bk))
    bp = pad_to(bq, (bk, bn))
    stream = l2r_gemm_pallas_streaming(ap, bp, n_bits, log2_radix, levels,
                                       bm, bk, bn,
                                       interpret=(backend == "pallas-interpret"))
    bounds = level_bounds(plane_count(n_bits, log2_radix), log2_radix, k,
                          levels)
    return ProgressiveResult(partial=stream[:, :m, :n], tail_bound=bounds.f32,
                             bound_i32=bounds.i32, decidable=bounds.decidable)


def l2r_gemm_progressive(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
    bm: int = 128,
    bk: int = 256,
    bn: int = 128,
    backend: str | None = None,
) -> ProgressiveResult:
    """Per-level MSDF snapshot stream with backend dispatch.

    Level l of ``result.partial`` is bit-identical to
    ``l2r_gemm(..., levels=l+1, schedule="stacked")`` on every backend;
    bounds come with the int32 exactness guard (core/progressive.py).
    Consumers that only need a fold over the stream (early-exit serving)
    should use ``core.progressive.streaming_matmul_scan`` instead — this
    entry materializes the ``(L, M, N)`` stack it returns.
    """
    return _l2r_gemm_progressive_backend(aq, bq, n_bits, log2_radix, levels,
                                         bm, bk, bn, resolve_backend(backend))


def l2r_matmul_f(
    x: jax.Array,
    w: jax.Array | None,
    cfg: QuantConfig = QuantConfig(),
    levels: int | None = None,
    w_q: QuantizedWeights | tuple[jax.Array, jax.Array] | None = None,
    backend: str | None = None,
    schedule: str = "stacked",
) -> jax.Array:
    """Float -> quantize -> dispatched MSDF GEMM -> dequantized float.

    ``w_q`` (core/quant.py:QuantizedWeights, built once at load) skips
    the per-forward weight quantization; ``w`` may then be None.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    # per-row (per-token) activation scales commute with the K-contraction
    xq, xs = quantize(x2, cfg, axis=0 if cfg.per_channel else None)
    if w_q is None:
        wq, ws = quantize(w, cfg, axis=-1)  # per-out-channel: (1, N)
    elif isinstance(w_q, QuantizedWeights):
        wq, ws = w_q.q, w_q.scale
    else:
        wq, ws = w_q
    out = l2r_gemm(xq, wq, cfg.n_bits, cfg.log2_radix, levels,
                   schedule=schedule, backend=backend)
    out = out.astype(jnp.float32) * xs * ws.reshape(1, -1)
    return out.astype(x.dtype).reshape(*lead, wq.shape[-1])


def _conv_same_geometry(h: int, w_: int, kh: int, kw: int,
                        stride: tuple[int, int], dilation: tuple[int, int]):
    """Output size + per-edge padding of a "SAME" conv (XLA/TF convention:
    total pad = max((out-1)*stride + eff_k - in, 0), low edge gets the
    floor half — matches lax.conv_general_dilated("SAME"))."""
    sh, sw = stride
    dh, dw = dilation
    oh, ow = -(-h // sh), -(-w_ // sw)
    eff_kh, eff_kw = (kh - 1) * dh + 1, (kw - 1) * dw + 1
    ph = max((oh - 1) * sh + eff_kh - h, 0)
    pw = max((ow - 1) * sw + eff_kw - w_, 0)
    return oh, ow, (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2)


def _tap_view(xp: jax.Array, dy: int, dx: int, oh: int, ow: int,
              stride: tuple[int, int], dilation: tuple[int, int]) -> jax.Array:
    """Shifted (strided) view of the padded map feeding tap (dy, dx):
    out[y, x] consumes xp[y*sh + dy*dh, x*sw + dx*dw]."""
    sh, sw = stride
    dh, dw = dilation
    return xp[:, dy * dh:dy * dh + (oh - 1) * sh + 1:sh,
              dx * dw:dx * dw + (ow - 1) * sw + 1:sw]


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "backend", "stride",
                     "dilation"),
)
def _l2r_conv2d_int(
    xq: jax.Array,
    wq: jax.Array,
    n_bits: int,
    log2_radix: int,
    levels: int | None,
    backend: str,
    stride: tuple[int, int] = (1, 1),
    dilation: tuple[int, int] = (1, 1),
) -> jax.Array:
    """Integer core of the fused conv: implicit im2col over kh*kw taps.

    xq: (B, H, W, cin) small ints; wq: (kh, kw, cin, cout) small ints;
    "SAME" padding, arbitrary stride/dilation (each tap reads a
    step-sliced shifted view — no patch matrix for any geometry).
    Bit-identical to quantized im2col + l2r_matmul_int on the same
    operands: the contraction over (kh, kw, cin) splits into kh*kw
    independent cin-contractions, and per-significance-level partial
    sums add across taps exactly.
    """
    bsz, h, w_, cin = xq.shape
    kh, kw, _, cout = wq.shape
    oh, ow, (ph_lo, ph_hi), (pw_lo, pw_hi) = _conv_same_geometry(
        h, w_, kh, kw, stride, dilation)
    xp = jnp.pad(xq, ((0, 0), (ph_lo, ph_hi), (pw_lo, pw_hi), (0, 0)))
    acc = jnp.zeros((bsz, oh, ow, cout), jnp.int32)
    if backend == "jnp":
        # hoist plane extraction out of the tap loop: one LHS stack for
        # the whole feature map, one reversed RHS stack for all taps
        # (raw digits -> the guarded f32 BLAS fast path)
        xsp = stack_planes_lhs(xp, n_bits, log2_radix, shifted=False)
        wrev = stack_planes_rhs(wq, n_bits, log2_radix, axis=-2,
                                shifted=False)
        for dy in range(kh):
            for dx in range(kw):
                a = _tap_view(xsp, dy, dx, oh, ow, stride, dilation)
                acc = acc + stacked_gemm_planes(
                    a, wrev[dy, dx], cin, n_bits, log2_radix, levels,
                    shifted=False)
        return acc
    # per-tap K is only cin: shrink the contraction block to the smallest
    # 128-lane multiple so shallow layers (cin=3) don't pad 9 taps to 256
    bk = min(256, -(-cin // 128) * 128)
    for dy in range(kh):
        for dx in range(kw):
            a = _tap_view(xp, dy, dx, oh, ow, stride, dilation)
            t = _l2r_gemm_backend(a.reshape(-1, cin), wq[dy, dx], n_bits,
                                  log2_radix, levels, 128, bk, 128,
                                  "stacked", backend)
            acc = acc + t.reshape(bsz, oh, ow, cout)
    return acc


def l2r_conv2d(
    x: jax.Array,
    w: jax.Array | None,
    b: jax.Array | None = None,
    cfg: QuantConfig = QuantConfig(),
    levels: int | None = None,
    w_q: QuantizedWeights | None = None,
    backend: str | None = None,
    stride: int | tuple[int, int] = 1,
    dilation: int | tuple[int, int] = 1,
) -> jax.Array:
    """Fused L2R conv2d, NHWC/HWIO, "SAME" padding, any stride/dilation.

    The composite-IPU conv without the HBM patch matrix: activations are
    quantized per image (scales commute with the window contraction),
    digit planes are extracted once, and each kernel tap streams a
    shifted (stride-stepped, dilation-spaced) view of the feature map
    through the level-stacked GEMM.  ``w_q`` reuses a load-time weight
    cache; otherwise ``w`` (kh, kw, cin, cout) is quantized per output
    channel here.
    """
    if w_q is None:
        w_q = quantize_weights(w, cfg)  # (kh,kw,cin,cout), scale (1,1,1,cout)
    xq, xs = quantize(x, cfg, axis=0)  # per-image scales (B,1,1,1)
    out = _l2r_conv2d_int(xq, w_q.q, cfg.n_bits, cfg.log2_radix, levels,
                          resolve_backend(backend), _pair(stride),
                          _pair(dilation))
    out = out.astype(jnp.float32) * xs * w_q.scale.reshape(1, 1, 1, -1)
    out = out.astype(x.dtype)
    if b is not None:
        out = out + b.astype(out.dtype)
    return out


def _pair(v: int | tuple[int, int]) -> tuple[int, int]:
    return (v, v) if isinstance(v, int) else tuple(v)


# ------------------------------------------------------- progressive conv
def _conv_level_term(xq, wq, n_bits, log2_radix, stride, dilation):
    """Per-level term of the progressive conv's jnp paths: hoisted
    zero-padded plane stacks + a ``term(ao, bo)`` closure summing the tap
    contributions of one significance level.  Shared by the fixed scan
    AND the early-exit while loop — identical ops in identical order is
    what keeps the two control flows bit-identical."""
    from repro.core.l2r_gemm import _f32_dot_exact

    bsz, h, w_, cin = xq.shape
    kh, kw, _, cout = wq.shape
    d = n_bits // log2_radix
    oh, ow, (ph_lo, ph_hi), (pw_lo, pw_hi) = _conv_same_geometry(
        h, w_, kh, kw, stride, dilation)
    xp = jnp.pad(xq, ((0, 0), (ph_lo, ph_hi), (pw_lo, pw_hi), (0, 0)))
    xsp = stack_planes_lhs(xp, n_bits, log2_radix, shifted=False)
    wrev = stack_planes_rhs(wq, n_bits, log2_radix, axis=-2, shifted=False)
    pad = (d - 1) * cin
    xsp = jnp.pad(xsp, ((0, 0), (0, 0), (0, 0), (0, pad)))
    wrev = jnp.pad(wrev, ((0, 0), (0, 0), (0, pad), (0, 0)))
    use_f32 = _f32_dot_exact(cin, d, log2_radix)
    if use_f32:
        xsp = xsp.astype(jnp.float32)
        wrev = wrev.astype(jnp.float32)
    width = d * cin

    def term(ao, bo):
        t_sum = jnp.zeros((bsz, oh, ow, cout), jnp.int32)
        for dy in range(kh):
            for dx in range(kw):
                a = _tap_view(xsp, dy, dx, oh, ow, stride, dilation)
                a_l = jax.lax.dynamic_slice_in_dim(a, ao * cin, width,
                                                   axis=a.ndim - 1)
                b_l = jax.lax.dynamic_slice_in_dim(wrev[dy, dx], bo * cin,
                                                   width, axis=0)
                t = jax.lax.dot_general(
                    a_l, b_l,
                    ((((a_l.ndim - 1),), ((0,))), ((), ())),
                    preferred_element_type=jnp.float32 if use_f32
                    else jnp.int32,
                    precision=jax.lax.Precision.HIGHEST if use_f32 else None,
                )
                t_sum = t_sum + t.astype(jnp.int32)
        return t_sum

    return term, (bsz, oh, ow, cout)


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "log2_radix", "levels", "backend", "stride",
                     "dilation"),
)
def _l2r_conv2d_progressive_int(
    xq: jax.Array,
    wq: jax.Array,
    n_bits: int,
    log2_radix: int,
    levels: int | None,
    backend: str,
    stride: tuple[int, int] = (1, 1),
    dilation: tuple[int, int] = (1, 1),
) -> jax.Array:
    """Per-level prefix stream of the fused conv: (L, B, OH, OW, cout).

    Level l is bit-identical to ``_l2r_conv2d_int(..., levels=l+1)``: the
    taps share each significance level, so the per-level conv term is the
    tap sum of per-level GEMM terms.  The jnp path is the streaming scan
    of core/progressive.py with the tap loop inside the level step
    (activation planes hoisted once per feature map); Pallas backends sum
    the per-tap snapshot streams of the streaming kernel.
    """
    from repro.core.progressive import _level_walk

    bsz, h, w_, cin = xq.shape
    kh, kw, _, cout = wq.shape
    d = n_bits // log2_radix
    oh, ow, (ph_lo, ph_hi), (pw_lo, pw_hi) = _conv_same_geometry(
        h, w_, kh, kw, stride, dilation)
    a_off, b_off, svals = _level_walk(d, levels)
    n_steps = int(svals.shape[0])
    if n_steps == 0:
        return jnp.zeros((0, bsz, oh, ow, cout), jnp.int32)
    if backend != "jnp":
        xp = jnp.pad(xq, ((0, 0), (ph_lo, ph_hi), (pw_lo, pw_hi), (0, 0)))
        bk = min(256, -(-cin // 128) * 128)
        acc = jnp.zeros((n_steps, bsz, oh, ow, cout), jnp.int32)
        for dy in range(kh):
            for dx in range(kw):
                a = _tap_view(xp, dy, dx, oh, ow, stride, dilation)
                ap = pad_to(a.reshape(-1, cin), (128, bk))
                bp = pad_to(wq[dy, dx], (bk, 128))
                t = l2r_gemm_pallas_streaming(
                    ap, bp, n_bits, log2_radix, levels, 128, bk, 128,
                    interpret=(backend == "pallas-interpret"))
                t = t[:, :bsz * oh * ow, :cout]
                acc = acc + t.reshape(n_steps, bsz, oh, ow, cout)
        return acc

    term, out_shape = _conv_level_term(xq, wq, n_bits, log2_radix, stride,
                                       dilation)

    def step(acc, xs):
        ao, bo, s = xs
        acc = acc + (term(ao, bo) << (log2_radix * s))
        return acc, acc

    acc0 = jnp.zeros(out_shape, jnp.int32)
    xs = (jnp.asarray(a_off), jnp.asarray(b_off), jnp.asarray(svals))
    _, stack = jax.lax.scan(step, acc0, xs)
    return stack


def l2r_conv2d_progressive_while(
    x: jax.Array,
    w: jax.Array | None = None,
    cfg: QuantConfig = QuantConfig(),
    fold: Callable | None = None,
    init=None,
    done_fn: Callable | None = None,
    levels: int | None = None,
    w_q: QuantizedWeights | None = None,
    backend: str | None = None,
    stride: int | tuple[int, int] = 1,
    dilation: int | tuple[int, int] = 1,
):
    """Early-exit fused conv stream: the progressive conv's level loop run
    as a ``lax.while_loop`` carrying the consumer's fold state.

    The per-level arithmetic is the SAME tap-summed term the fixed scan
    of :func:`l2r_conv2d_progressive` executes (shared closure), so after
    ``levels_run`` iterations the integer prefix is bit-identical to
    ``result.partial[levels_run - 1]`` of the scan path.  ``fold(carry,
    partial, level_index) -> carry`` consumes each integer prefix;
    ``done_fn(fold_carry) -> scalar bool`` stops the loop (``None`` runs
    every level — control-flow-only).  jnp backend only: the grid-level
    analogue on Pallas is the streaming kernel's ``level_count`` scalar.

    Returns ``(prefix (B, OH, OW, cout) int32, fold_carry, levels_run
    () int32, scale (B, 1, 1, cout))`` — ``prefix * scale`` is the float
    feature-map prefix at the exit level.
    """
    assert resolve_backend(backend) == "jnp", (
        "l2r_conv2d_progressive_while: jnp backend only (use the streaming "
        "kernel's level_count scalar for grid-level shortening)")
    if w_q is None:
        w_q = quantize_weights(w, cfg)
    xq, xs = quantize(x, cfg, axis=0)  # per-image scales (B,1,1,1)
    from repro.core.progressive import _level_walk, _while_emitter

    a_off, b_off, svals = _level_walk(cfg.planes, levels)
    scale = xs * w_q.scale.reshape(1, 1, 1, -1)
    term, out_shape = _conv_level_term(xq, w_q.q, cfg.n_bits, cfg.log2_radix,
                                       _pair(stride), _pair(dilation))
    acc0 = jnp.zeros(out_shape, jnp.int32)
    if int(svals.shape[0]) == 0:
        return acc0, init, jnp.int32(0), scale
    t, acc, fold_c = _while_emitter(term, a_off, b_off, svals,
                                    cfg.log2_radix, acc0, fold, init,
                                    done_fn)
    return acc, fold_c, t, scale


def l2r_conv2d_progressive(
    x: jax.Array,
    w: jax.Array | None = None,
    cfg: QuantConfig = QuantConfig(),
    levels: int | None = None,
    w_q: QuantizedWeights | None = None,
    backend: str | None = None,
    stride: int | tuple[int, int] = 1,
    dilation: int | tuple[int, int] = 1,
):
    """Progressive-precision fused conv: per-level snapshots + tail bounds.

    Returns ``(result, scale)``: ``result`` is a
    :class:`~repro.core.progressive.ProgressiveResult` whose
    ``partial[l]`` is the integer conv truncated after l+1 MSDF levels
    (bit-identical to ``l2r_conv2d``'s core at ``levels=l+1``), with tail
    bounds for the conv's effective contraction K = kh*kw*cin; ``scale``
    is the (B, 1, 1, cout) dequantization factor (per-image activation
    scale x per-channel weight scale) — ``partial[l] * scale`` is the
    float feature map prefix, and ``tail_bound[l] * scale`` bounds its
    distance from the exact W8A8 conv.
    """
    if w_q is None:
        w_q = quantize_weights(w, cfg)
    xq, xs = quantize(x, cfg, axis=0)  # per-image scales (B,1,1,1)
    kh, kw, cin, _ = w_q.q.shape
    stack = _l2r_conv2d_progressive_int(
        xq, w_q.q, cfg.n_bits, cfg.log2_radix, levels,
        resolve_backend(backend), _pair(stride), _pair(dilation))
    bounds = level_bounds(cfg.planes, cfg.log2_radix, kh * kw * cin, levels)
    result = ProgressiveResult(partial=stack, tail_bound=bounds.f32,
                               bound_i32=bounds.i32,
                               decidable=bounds.decidable)
    return result, xs * w_q.scale.reshape(1, 1, 1, -1)
