"""Pure-jnp oracle for the L2R digit-plane GEMM kernel.

This is the reference the Pallas kernel is validated against (exact
integer equality — the kernel computes in int32 end to end, so there is
no tolerance: outputs must match bit for bit).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.online import msdf_pairs
from repro.core.quant import digit_planes

__all__ = ["l2r_gemm_ref", "l2r_gemm_ref_stacked", "int_gemm_ref"]


@partial(jax.jit, static_argnames=("n_bits", "log2_radix", "levels"))
def l2r_gemm_ref(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
) -> jax.Array:
    """MSDF digit-plane matmul, significance-ordered, int32 accumulate.

    aq: (M, K) signed ints; bq: (K, N) signed ints.
    levels=None -> exact == int_gemm_ref; otherwise the progressive
    prefix over the first `levels` significance levels.
    """
    d = n_bits // log2_radix
    ap = digit_planes(aq, n_bits, log2_radix)  # (D, M, K)
    bp = digit_planes(bq, n_bits, log2_radix)  # (D, K, N)
    acc = jnp.zeros((aq.shape[0], bq.shape[1]), jnp.int32)
    for (i, j) in msdf_pairs(d, levels):
        term = jax.lax.dot_general(
            ap[i], bp[j],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc + (term << (log2_radix * (i + j)))
    return acc


@partial(jax.jit, static_argnames=("n_bits", "log2_radix", "levels"))
def l2r_gemm_ref_stacked(
    aq: jax.Array,
    bq: jax.Array,
    n_bits: int = 8,
    log2_radix: int = 2,
    levels: int | None = None,
) -> jax.Array:
    """Level-stacked schedule oracle (2D-1 fused matmuls); must be
    bit-identical to :func:`l2r_gemm_ref` for every (n_bits, log2_radix,
    levels) — the pair loop and the stacking are the same pair set."""
    from repro.core.l2r_gemm import l2r_matmul_int_stacked

    return l2r_matmul_int_stacked(aq, bq, n_bits, log2_radix, levels)


@jax.jit
def int_gemm_ref(aq: jax.Array, bq: jax.Array) -> jax.Array:
    """Plain int32 matmul (ground truth for the full-precision case)."""
    return jax.lax.dot_general(
        aq.astype(jnp.int32), bq.astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
