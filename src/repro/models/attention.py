"""Attention math: RoPE / M-RoPE, GQA, chunked (flash-style) attention,
full and ring (sliding-window) KV caches.

Memory discipline: train/prefill attention never materializes the full
(S, S) score matrix — a static python loop over query chunks (exact
static KV ranges: no wasted FLOPs on causal/local masks) wraps an inner
lax.scan over KV chunks with online-softmax accumulation.  Decode (q=1)
attends directly against the cache.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.l2r_attention import (attn_scores_stacked,
                                      attn_scores_streaming_while,
                                      quantize_per_vector)
from repro.core.policy import LevelPolicy, attn_walk_machinery
from repro.core.progressive import level_bounds
from repro.core.quant import PlaneOperands, QuantConfig, _symmetric_quant

__all__ = [
    "apply_rope",
    "chunked_attention",
    "decode_attention",
    "attn_exit_tap",
    "KVCache",
    "init_kv_cache",
    "update_kv_cache",
    "kv_plane_operands",
]


# ------------------------------------------------- progressive exit-level tap
_EXIT_TAP: list | None = None


@contextlib.contextmanager
def attn_exit_tap():
    """Collect per-call decode-attention exit levels (EAGER calls only).

    Yields a list; every eager ``decode_attention(..., early_exit=True)``
    call inside the context appends its levels-run scalar (int).  The
    tap is a demo/diagnostic hook (examples/progressive_attention.py),
    not an aux output channel — exit levels under ``jit`` are tracers
    with no runtime value, so a TRACED call inside an active tap raises
    ``RuntimeError`` instead of silently recording nothing (run the
    tapped call eagerly, e.g. under ``jax.disable_jit()``).  Call order
    is evaluation order, i.e. layer order for a single decode step.
    """
    global _EXIT_TAP
    prev, records = _EXIT_TAP, []
    _EXIT_TAP = records
    try:
        yield records
    finally:
        _EXIT_TAP = prev


# ----------------------------------------------------------------- RoPE
def _rope_angles(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    """positions (...,) -> angles (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    theta: float = 10_000.0,
    mode: str = "standard",
    sections: tuple[int, int, int] = (16, 24, 24),
) -> jax.Array:
    """Rotary embedding.

    x: (B, S, H, dh).  positions: (B, S) for standard RoPE, or (3, B, S)
    for M-RoPE (qwen2-vl: temporal/height/width position streams, each
    rotating its own slice of the frequency spectrum).
    """
    if mode == "none":
        return x
    b, s, h, dh = x.shape
    half = dh // 2
    if mode == "mrope":
        assert positions.shape[0] == 3, "mrope expects (3, B, S) positions"
        angles = _rope_angles(positions, dh, theta)  # (3, B, S, half)
        sec = jnp.cumsum(jnp.asarray(sections))
        idx = jnp.searchsorted(sec, jnp.arange(half), side="right")  # 0/1/2
        angles = jnp.take_along_axis(
            jnp.moveaxis(angles, 0, -1),  # (B, S, half, 3)
            idx[None, None, :, None].astype(jnp.int32),
            axis=-1,
        )[..., 0]  # (B, S, half)
    else:
        angles = _rope_angles(positions, dh, theta)  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)  # (B, S, 1, half)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ------------------------------------------------------ chunked attention
def _block_scores(q, k, scale, softcap, score_dtype=jnp.float32):
    """q (B, qc, Kv, G, dh), k (B, kc, Kv, dh) -> (B, Kv, G, qc, kc).

    score_dtype=bf16 keeps MXU f32 accumulation but stores score blocks
    (the dominant HBM tensor at long S) in bf16; softmax statistics stay
    f32 downstream."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k, preferred_element_type=score_dtype)
    s = s * jnp.asarray(scale, score_dtype)
    if softcap is not None:
        s = (jnp.tanh(s / softcap) * softcap).astype(score_dtype)
    return s


def default_chunks(sq: int) -> tuple[int, int]:
    """(q_chunk, kv_chunk) balancing HLO size (unrolled q chunks) against
    live score-block memory: ~8 query chunks, 2k KV blocks."""
    q = max(1024, sq // 8)
    kv = max(1024, min(2048, sq // 8))
    return q, kv


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    softcap: float | None = None,
    q_chunk: int | None = None,
    kv_chunk: int | None = None,
    q_offset: int = 0,
    score_dtype=jnp.float32,
    head_shard: bool = False,
    l2r: QuantConfig | None = None,
    levels: int | None = None,
) -> jax.Array:
    """GQA flash-style attention.

    q: (B, Sq, H, dh); k, v: (B, Skv, Kv, dh) with H % Kv == 0.
    Static query-chunk loop -> exact static KV ranges (no masked-out
    FLOPs beyond boundary chunks); inner lax.scan with online softmax.
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill
    continuation); causal masks compare absolute positions.

    ``l2r`` routes the QK^T contraction through the digit-serial score
    walk (core/l2r_attention.py): q rows and k slots quantize with
    per-vector scales (chunking-independent — prefill scores agree with
    any decode-step recomputation of the same tokens), planes are
    extracted ONCE per call, and ``levels`` truncates the MSDF stream
    (None = exact W8A8 scores).  Softmax and PV stay float (the exact
    first cut); quantized scores accumulate in f32 regardless of
    ``score_dtype``.
    """
    b, sq, h, dh = q.shape
    _, skv, kv_heads, _ = k.shape
    g = h // kv_heads
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    dq, dkv = default_chunks(sq)
    q_chunk = min(q_chunk or dq, sq)
    kv_chunk = min(kv_chunk or dkv, skv)
    n_q = (sq + q_chunk - 1) // q_chunk

    # pad KV to a chunk multiple so dynamic_slice never clamps (clamped
    # starts would silently misalign data vs. the position mask).
    pad_kv = (-skv) % kv_chunk
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))

    q = q.reshape(b, sq, kv_heads, g, dh)
    if head_shard:
        # shard attention math on the KV-head dim (uneven counts padded
        # by GSPMD): per-chip score traffic drops by ~n_kv/axis_size and
        # the softmax stays chip-local (§Perf hillclimb A).
        from repro.sharding.ctx import hint_uneven
        q = hint_uneven(q, None, None, "model", None, None)
        k = hint_uneven(k, None, None, "model", None)
        v = hint_uneven(v, None, None, "model", None)
    neg = jnp.float32(-1e30)  # finite sentinel: -inf breeds NaNs in
    #                           fully-masked boundary blocks
    q_po = k_po = qs = ks_t = None
    if l2r is not None:
        # per-vector quantization + ONE plane extraction per call; the
        # seq axes slice through both stacks (plane blocks live on the
        # head dim), so chunk slicing below never re-extracts
        qq, qs = quantize_per_vector(q, l2r)
        kq, ks = quantize_per_vector(k, l2r)
        q_po = PlaneOperands.prepare_lhs(qq, l2r.n_bits, l2r.log2_radix)
        k_po = PlaneOperands.prepare_rhs(kq, l2r.n_bits, l2r.log2_radix,
                                         axis=-1)
        ks_t = ks[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
    outs = []
    for qi in range(n_q):
        q_start = qi * q_chunk
        qc = min(q_chunk, sq - q_start)
        q_blk = jax.lax.slice_in_dim(q, q_start, q_start + qc, axis=1)
        if l2r is not None:
            q_blk_po = dataclasses.replace(
                q_po, stack=jax.lax.slice_in_dim(
                    q_po.stack, q_start, q_start + qc, axis=1))
            qs_t = jax.lax.slice_in_dim(
                qs, q_start, q_start + qc, axis=1).transpose(0, 2, 3, 1, 4)
        q_abs_end = q_offset + q_start + qc - 1  # last query position
        # static KV range for this query chunk
        hi = min(skv, q_abs_end + 1) if causal else skv
        lo = 0
        if window is not None:
            lo = max(0, q_offset + q_start - window + 1)
        lo_c, hi_c = lo // kv_chunk, (hi + kv_chunk - 1) // kv_chunk
        kv_idx = jnp.arange(lo_c, hi_c)

        q_pos = q_offset + q_start + jnp.arange(qc)  # (qc,)

        def body(carry, kc_i):
            acc, m, l = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, kc_i * kv_chunk, kv_chunk, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, kc_i * kv_chunk, kv_chunk, axis=1)
            if l2r is None:
                s = _block_scores(q_blk, k_blk, scale, softcap, score_dtype)
            else:
                k_blk_po = dataclasses.replace(
                    k_po, stack=jax.lax.dynamic_slice_in_dim(
                        k_po.stack, kc_i * kv_chunk, kv_chunk, axis=1))
                ks_blk = jax.lax.dynamic_slice_in_dim(
                    ks_t, kc_i * kv_chunk, kv_chunk, axis=ks_t.ndim - 1)
                s_int = attn_scores_stacked(q_blk_po, k_blk_po, l2r.n_bits,
                                            l2r.log2_radix, levels)
                s = s_int.astype(jnp.float32) * qs_t * ks_blk \
                    * jnp.float32(scale)
                if softcap is not None:
                    s = jnp.tanh(s / softcap) * softcap
            kv_pos = kc_i * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.ones((qc, kv_chunk), bool)
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            mask &= kv_pos[None, :] < skv  # tail padding guard
            s = jnp.where(mask[None, None, None], s, jnp.asarray(neg, s.dtype))
            # softmax statistics in f32 regardless of score storage dtype
            m_new = jnp.maximum(m, s.max(-1).astype(jnp.float32))
            p = jnp.exp(s.astype(jnp.float32) - m_new[..., None])
            # zero contributions where the whole row was masked (p == 1
            # only when s == m_new == sentinel; real blocks zero it via
            # alpha, but kill it eagerly to keep l exact):
            p = jnp.where(mask[None, None, None], p, 0.0)
            # materialize p once, in the value dtype (the exp fusion emits
            # it directly); the row-sum accumulates in f32 from that copy
            p = p.astype(v.dtype)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, -1, dtype=jnp.float32)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, v_blk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, kv_heads, g, qc, dh), jnp.float32)
        m0 = jnp.full((b, kv_heads, g, qc), neg, jnp.float32)
        l0 = jnp.zeros((b, kv_heads, g, qc), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), kv_idx)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        outs.append(jnp.moveaxis(out, 3, 1))  # (B, qc, Kv, G, dh)
    o = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return o.reshape(b, sq, h, dh).astype(v.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    kv_positions: jax.Array,
    q_position: jax.Array,
    *,
    window: int | None = None,
    scale: float | None = None,
    softcap: float | None = None,
    l2r: QuantConfig | None = None,
    levels: int | None = None,
    early_exit: bool = False,
    exit_tol: float = 1e-4,
    k_planes: jax.Array | PlaneOperands | None = None,
    k_scale: jax.Array | None = None,
    policy: LevelPolicy | None = None,
) -> jax.Array:
    """Single-token attention against a (possibly ring) cache.

    q: (B, 1, H, dh); caches: (B, L, Kv, dh); kv_positions: (B, L) int32
    absolute positions (-1 = empty slot); q_position: (B,) int32.

    ``l2r`` routes QK^T through the digit-serial score walk
    (core/l2r_attention.py) with exact softmax + float PV; ``levels``
    truncates the MSDF stream.  ``k_planes``/``k_scale`` feed the
    incrementally plane-stacked KV cache (:func:`update_kv_cache` with a
    quant config): the per-slot key planes/scales are then consumed
    directly — NO per-step plane re-extraction over the history —
    bit-identical to quantizing ``k_cache`` here (both quantize the
    stored cache values with the same per-vector formula).

    ``early_exit=True`` runs the margin-bounded progressive walk: a
    ``lax.while_loop`` over significance levels that stops once every
    (batch, kv-head, group) score row has BOTH its running max decided
    (the argmax margin beats the scaled tail bound —
    core/policy.py:decision_state) and its normalizer pinned (every
    unmasked score known to within ``exit_tol``, so softmax weights are
    stable at the tolerance).  Rows that never decide consume the whole
    stream, making the output exactly the full-depth quantized result;
    decided rows return softmax over the exit-level prefix.  Incompatible
    with ``softcap``.

    ``policy`` (core/policy.py:LevelPolicy, one row per BATCH entry)
    runs the walk with per-row precision classes instead of the
    batch-global knobs: ``bounded(tol)`` rows use their own normalizer
    tolerance (``bounded(exit_tol)`` == the legacy early-exit walk bit
    for bit), ``budget(L)`` rows SNAPSHOT their int32 score prefix at
    level L — their softmax sees exactly the ``levels=L`` scores, bit-
    identical to a truncated run, even when batch-mates stream deeper —
    and ``exact`` rows never early-commit (the loop runs full depth for
    them, output == the full stacked schedule).  Bounded rows keep the
    batch-coupled legacy semantics: their softmax runs over the prefix
    at the GLOBAL stop level, so non-argmax weights can move within the
    tolerance relative to a solo run (the decision, not the score bits,
    is the guarantee).  Implies the progressive walk; requires ``l2r``.
    """
    b, _, h, dh = q.shape
    kv_heads = k_cache.shape[2]
    g = h // kv_heads
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    qg = q.reshape(b, 1, kv_heads, g, dh)
    valid = (kv_positions >= 0) & (kv_positions <= q_position[:, None])
    if window is not None:
        valid &= kv_positions > (q_position[:, None] - window)
    valid_b = valid[:, None, None, None, :]  # (B, 1, 1, 1, L)

    if l2r is None:
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                       preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(valid_b, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32)
        return o.reshape(b, 1, h, dh).astype(v_cache.dtype)

    # ---- digit-serial QK^T -------------------------------------------
    qq, qs = quantize_per_vector(qg, l2r)
    qs_t = qs.transpose(0, 2, 3, 1, 4)  # (B, Kv, G, 1, 1)
    if k_planes is not None:
        assert k_scale is not None, \
            "plane-stacked cache: k_planes and k_scale travel together"
        k_op = k_planes if isinstance(k_planes, PlaneOperands) else \
            PlaneOperands(k_planes, "rhs", l2r.n_bits, l2r.log2_radix,
                          dh, -1, False, l2r.planes - 1)
        ks = k_scale
    else:
        kq, ks3 = quantize_per_vector(k_cache, l2r)
        k_op, ks = kq, ks3[..., 0]
    ks_t = ks.transpose(0, 2, 1)[:, :, None, None, :]  # (B, Kv, 1, 1, L)
    sf = jnp.float32(scale)

    def dequant(acc):
        return acc.astype(jnp.float32) * qs_t * ks_t * sf

    if not early_exit and policy is None:
        s_int = attn_scores_stacked(qq, k_op, l2r.n_bits, l2r.log2_radix,
                                    levels)
        s = dequant(s_int)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(valid_b, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32)
        return o.reshape(b, 1, h, dh).astype(v_cache.dtype)

    # ---- margin-bounded progressive walk -----------------------------
    if softcap is not None:
        raise ValueError("progressive attention (early_exit/policy) does "
                         "not compose with softcap: tanh re-scales the "
                         "score margins the tail bounds are stated in")
    bounds = level_bounds(l2r.planes, l2r.log2_radix, dh, levels)
    n_levels = int(bounds.f32.shape[0])
    fold, init, done_fn = attn_walk_machinery(
        bounds.f32, dequant, valid_b,
        qs_t[:, :, :, 0, :] * ks_t[:, :, :, 0, :] * sf,
        rows_shape=(b, kv_heads, g), n_levels=n_levels,
        exit_tol=exit_tol, policy=policy,
        score_shape=(b, kv_heads, g, 1, k_cache.shape[1]))
    acc, carry, levels_run = attn_scores_streaming_while(
        qq, k_op, fold, init, done_fn,
        l2r.n_bits, l2r.log2_radix, levels)
    if policy is None:
        _done, lv = carry
        s_int = acc
    else:
        _done, lv, forced_any, s_commit = carry
        # budget rows committed at their clamp level: serve THEIR softmax
        # from the snapshotted prefix so mixed batches stay bit-identical
        # to a solo levels=L run even when batch-mates stream deeper.
        s_int = jnp.where(forced_any[..., None, None], s_commit, acc)
    if _EXIT_TAP is not None:
        if isinstance(levels_run, jax.core.Tracer):
            raise RuntimeError(
                "attn_exit_tap() cannot record under jit: levels_run is a "
                "tracer, so the tap would silently capture nothing. Run the "
                "tapped call eagerly (e.g. under jax.disable_jit()) or drop "
                "the tap around traced code.")
        _EXIT_TAP.append({"levels_run": int(levels_run),
                          "exit_levels": np.asarray(lv)})
    s = jnp.where(valid_b, dequant(s_int), -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, dh).astype(v_cache.dtype)


# ------------------------------------------------------------- KV caches
class KVCache(NamedTuple):
    """Full or ring KV cache. ``length`` is the allocated size (the
    window for ring caches); positions tracks absolute token positions.

    ``k_planes``/``k_scale`` (present iff the cache was built with a
    quant config) are the **incrementally plane-stacked** key cache:
    every update also quantizes the new keys per slot and writes their
    raw-digit descending plane stack — window-padded to 2D-1 blocks, the
    ``PlaneOperands.prepare_rhs(axis=-1, window_pad=True)`` layout — so
    decode-step digit-serial QK^T consumes a ready operand instead of
    re-extracting planes over the whole history each step (the attention
    analogue of the window-padded LM-head weight cache).  ``None``
    fields are empty pytree nodes: existing cache trees are unchanged.
    """

    k: jax.Array  # (B, L, Kv, dh)
    v: jax.Array  # (B, L, Kv, dh)
    positions: jax.Array  # (B, L) int32, -1 = empty
    k_planes: jax.Array | None = None  # (B, L, Kv, (2D-1)*dh) int8
    k_scale: jax.Array | None = None   # (B, L, Kv) f32 per-slot scales


def init_kv_cache(batch: int, length: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16,
                  quant: QuantConfig | None = None) -> KVCache:
    k_planes = k_scale = None
    if quant is not None:
        d = quant.planes
        k_planes = jnp.zeros(
            (batch, length, kv_heads, (2 * d - 1) * head_dim), jnp.int8)
        # empty slots carry the scale a zero key vector quantizes to, so
        # the whole stacked cache — used slots or not — is bit-identical
        # to re-extracting planes from the (zero-initialized) float cache
        _, s0 = _symmetric_quant(jnp.zeros((), jnp.float32),
                                 jnp.zeros((), jnp.float32), quant)
        k_scale = jnp.full((batch, length, kv_heads), s0, jnp.float32)
    return KVCache(
        k=jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        positions=jnp.full((batch, length), -1, jnp.int32),
        k_planes=k_planes,
        k_scale=k_scale,
    )


def update_kv_cache(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                    positions: jax.Array,
                    quant: QuantConfig | None = None) -> KVCache:
    """Insert S new entries at slots positions % L (ring semantics; for a
    full-length cache L >= max position this is plain indexed write).

    k_new/v_new: (B, S, Kv, dh); positions: (B, S) absolute.

    A plane-stacked cache (``init_kv_cache(..., quant=...)``) must pass
    the same ``quant`` here: the new keys' digit planes append into the
    pre-allocated stack incrementally.  The quantized value is the key
    AS STORED in the float cache (after the cache-dtype cast), so the
    incremental stack is bit-identical to re-extracting planes from the
    full float cache at any later step.
    """
    length = cache.k.shape[1]
    slots = positions % length  # (B, S)
    def write(buf, new):
        return jax.vmap(lambda b, s, n: b.at[s].set(n))(buf, slots, new)
    k_planes, k_scale = cache.k_planes, cache.k_scale
    if k_planes is not None:
        assert quant is not None, \
            "plane-stacked KV cache: pass the QuantConfig that built it"
        from repro.core.quant import stack_planes_rhs
        d = quant.planes
        dh = cache.k.shape[-1]
        kq, ks = quantize_per_vector(k_new.astype(cache.k.dtype), quant)
        new_stack = stack_planes_rhs(kq, quant.n_bits, quant.log2_radix,
                                     axis=-1, shifted=False)
        new_stack = jnp.pad(
            new_stack, [(0, 0)] * (new_stack.ndim - 1) + [(0, (d - 1) * dh)])
        k_planes = write(k_planes, new_stack)
        k_scale = write(k_scale, ks[..., 0])
    return KVCache(
        k=write(cache.k, k_new.astype(cache.k.dtype)),
        v=write(cache.v, v_new.astype(cache.v.dtype)),
        positions=jax.vmap(lambda p, s, n: p.at[s].set(n))(
            cache.positions, slots, positions
        ),
        k_planes=k_planes,
        k_scale=k_scale,
    )


def kv_plane_operands(cache: KVCache, quant: QuantConfig) -> PlaneOperands:
    """View the cache's incremental plane stack as the RHS operand the
    score walks consume (raw digits, descending on the head dim,
    window-padded — zero per-step operand prep)."""
    assert cache.k_planes is not None, \
        "cache has no plane stack: init_kv_cache(..., quant=...)"
    return PlaneOperands(cache.k_planes, "rhs", quant.n_bits,
                         quant.log2_radix, cache.k.shape[-1], -1, False,
                         quant.planes - 1)
