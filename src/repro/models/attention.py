"""Attention math: RoPE / M-RoPE, GQA, chunked (flash-style) attention,
full and ring (sliding-window) KV caches.

Memory discipline: train/prefill attention never materializes the full
(S, S) score matrix — a static python loop over query chunks (exact
static KV ranges: no wasted FLOPs on causal/local masks) wraps an inner
lax.scan over KV chunks with online-softmax accumulation.  Decode (q=1)
attends directly against the cache.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "apply_rope",
    "chunked_attention",
    "decode_attention",
    "KVCache",
    "init_kv_cache",
    "update_kv_cache",
]


# ----------------------------------------------------------------- RoPE
def _rope_angles(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    """positions (...,) -> angles (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    theta: float = 10_000.0,
    mode: str = "standard",
    sections: tuple[int, int, int] = (16, 24, 24),
) -> jax.Array:
    """Rotary embedding.

    x: (B, S, H, dh).  positions: (B, S) for standard RoPE, or (3, B, S)
    for M-RoPE (qwen2-vl: temporal/height/width position streams, each
    rotating its own slice of the frequency spectrum).
    """
    if mode == "none":
        return x
    b, s, h, dh = x.shape
    half = dh // 2
    if mode == "mrope":
        assert positions.shape[0] == 3, "mrope expects (3, B, S) positions"
        angles = _rope_angles(positions, dh, theta)  # (3, B, S, half)
        sec = jnp.cumsum(jnp.asarray(sections))
        idx = jnp.searchsorted(sec, jnp.arange(half), side="right")  # 0/1/2
        angles = jnp.take_along_axis(
            jnp.moveaxis(angles, 0, -1),  # (B, S, half, 3)
            idx[None, None, :, None].astype(jnp.int32),
            axis=-1,
        )[..., 0]  # (B, S, half)
    else:
        angles = _rope_angles(positions, dh, theta)  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)  # (B, S, 1, half)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ------------------------------------------------------ chunked attention
def _block_scores(q, k, scale, softcap, score_dtype=jnp.float32):
    """q (B, qc, Kv, G, dh), k (B, kc, Kv, dh) -> (B, Kv, G, qc, kc).

    score_dtype=bf16 keeps MXU f32 accumulation but stores score blocks
    (the dominant HBM tensor at long S) in bf16; softmax statistics stay
    f32 downstream."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k, preferred_element_type=score_dtype)
    s = s * jnp.asarray(scale, score_dtype)
    if softcap is not None:
        s = (jnp.tanh(s / softcap) * softcap).astype(score_dtype)
    return s


def default_chunks(sq: int) -> tuple[int, int]:
    """(q_chunk, kv_chunk) balancing HLO size (unrolled q chunks) against
    live score-block memory: ~8 query chunks, 2k KV blocks."""
    q = max(1024, sq // 8)
    kv = max(1024, min(2048, sq // 8))
    return q, kv


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    softcap: float | None = None,
    q_chunk: int | None = None,
    kv_chunk: int | None = None,
    q_offset: int = 0,
    score_dtype=jnp.float32,
    head_shard: bool = False,
) -> jax.Array:
    """GQA flash-style attention.

    q: (B, Sq, H, dh); k, v: (B, Skv, Kv, dh) with H % Kv == 0.
    Static query-chunk loop -> exact static KV ranges (no masked-out
    FLOPs beyond boundary chunks); inner lax.scan with online softmax.
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill
    continuation); causal masks compare absolute positions.
    """
    b, sq, h, dh = q.shape
    _, skv, kv_heads, _ = k.shape
    g = h // kv_heads
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    dq, dkv = default_chunks(sq)
    q_chunk = min(q_chunk or dq, sq)
    kv_chunk = min(kv_chunk or dkv, skv)
    n_q = (sq + q_chunk - 1) // q_chunk

    # pad KV to a chunk multiple so dynamic_slice never clamps (clamped
    # starts would silently misalign data vs. the position mask).
    pad_kv = (-skv) % kv_chunk
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))

    q = q.reshape(b, sq, kv_heads, g, dh)
    if head_shard:
        # shard attention math on the KV-head dim (uneven counts padded
        # by GSPMD): per-chip score traffic drops by ~n_kv/axis_size and
        # the softmax stays chip-local (§Perf hillclimb A).
        from repro.sharding.ctx import hint_uneven
        q = hint_uneven(q, None, None, "model", None, None)
        k = hint_uneven(k, None, None, "model", None)
        v = hint_uneven(v, None, None, "model", None)
    neg = jnp.float32(-1e30)  # finite sentinel: -inf breeds NaNs in
    #                           fully-masked boundary blocks
    outs = []
    for qi in range(n_q):
        q_start = qi * q_chunk
        qc = min(q_chunk, sq - q_start)
        q_blk = jax.lax.slice_in_dim(q, q_start, q_start + qc, axis=1)
        q_abs_end = q_offset + q_start + qc - 1  # last query position
        # static KV range for this query chunk
        hi = min(skv, q_abs_end + 1) if causal else skv
        lo = 0
        if window is not None:
            lo = max(0, q_offset + q_start - window + 1)
        lo_c, hi_c = lo // kv_chunk, (hi + kv_chunk - 1) // kv_chunk
        kv_idx = jnp.arange(lo_c, hi_c)

        q_pos = q_offset + q_start + jnp.arange(qc)  # (qc,)

        def body(carry, kc_i):
            acc, m, l = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, kc_i * kv_chunk, kv_chunk, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, kc_i * kv_chunk, kv_chunk, axis=1)
            s = _block_scores(q_blk, k_blk, scale, softcap, score_dtype)
            kv_pos = kc_i * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.ones((qc, kv_chunk), bool)
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            mask &= kv_pos[None, :] < skv  # tail padding guard
            s = jnp.where(mask[None, None, None], s, jnp.asarray(neg, s.dtype))
            # softmax statistics in f32 regardless of score storage dtype
            m_new = jnp.maximum(m, s.max(-1).astype(jnp.float32))
            p = jnp.exp(s.astype(jnp.float32) - m_new[..., None])
            # zero contributions where the whole row was masked (p == 1
            # only when s == m_new == sentinel; real blocks zero it via
            # alpha, but kill it eagerly to keep l exact):
            p = jnp.where(mask[None, None, None], p, 0.0)
            # materialize p once, in the value dtype (the exp fusion emits
            # it directly); the row-sum accumulates in f32 from that copy
            p = p.astype(v.dtype)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, -1, dtype=jnp.float32)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, v_blk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, kv_heads, g, qc, dh), jnp.float32)
        m0 = jnp.full((b, kv_heads, g, qc), neg, jnp.float32)
        l0 = jnp.zeros((b, kv_heads, g, qc), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), kv_idx)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        outs.append(jnp.moveaxis(out, 3, 1))  # (B, qc, Kv, G, dh)
    o = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return o.reshape(b, sq, h, dh).astype(v.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    kv_positions: jax.Array,
    q_position: jax.Array,
    *,
    window: int | None = None,
    scale: float | None = None,
    softcap: float | None = None,
) -> jax.Array:
    """Single-token attention against a (possibly ring) cache.

    q: (B, 1, H, dh); caches: (B, L, Kv, dh); kv_positions: (B, L) int32
    absolute positions (-1 = empty slot); q_position: (B,) int32.
    """
    b, _, h, dh = q.shape
    kv_heads = k_cache.shape[2]
    g = h // kv_heads
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    qg = q.reshape(b, 1, kv_heads, g, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    valid = (kv_positions >= 0) & (kv_positions <= q_position[:, None])
    if window is not None:
        valid &= kv_positions > (q_position[:, None] - window)
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, dh).astype(v_cache.dtype)


# ------------------------------------------------------------- KV caches
class KVCache(NamedTuple):
    """Full or ring KV cache. ``length`` is the allocated size (the
    window for ring caches); positions tracks absolute token positions."""

    k: jax.Array  # (B, L, Kv, dh)
    v: jax.Array  # (B, L, Kv, dh)
    positions: jax.Array  # (B, L) int32, -1 = empty


def init_kv_cache(batch: int, length: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        positions=jnp.full((batch, length), -1, jnp.int32),
    )


def update_kv_cache(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                    positions: jax.Array) -> KVCache:
    """Insert S new entries at slots positions % L (ring semantics; for a
    full-length cache L >= max position this is plain indexed write).

    k_new/v_new: (B, S, Kv, dh); positions: (B, S) absolute.
    """
    length = cache.k.shape[1]
    slots = positions % length  # (B, S)
    def write(buf, new):
        return jax.vmap(lambda b, s, n: b.at[s].set(n))(buf, slots, new)
    return KVCache(
        k=write(cache.k, k_new.astype(cache.k.dtype)),
        v=write(cache.v, v_new.astype(cache.v.dtype)),
        positions=jax.vmap(lambda p, s, n: p.at[s].set(n))(
            cache.positions, slots, positions
        ),
    )
