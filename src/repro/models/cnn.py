"""VGG-16 — the paper's evaluation network, with the L2R conv path.

Convolutions run either as plain float (lax.conv) or through the paper's
composite inner-product pipeline: im2col -> quantize -> MSDF digit-plane
GEMM (core/l2r_gemm.py; on TPU the Pallas kernel kernels/l2r_gemm).  With
all significance levels the L2R path is bit-exact W8A8 integer conv; with
fewer levels it is the progressive-precision (online early output) mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.l2r_gemm import l2r_matmul
from repro.core.quant import QuantConfig
from repro.core.cycle_model import VGG16_CONV_LAYERS

from .common import Param, materialize

__all__ = ["vgg16_build", "vgg16_apply", "VGG16_CONV_LAYERS"]


def vgg16_build(n_classes: int = 1000, in_channels: int = 3) -> dict:
    params: dict = {}
    c_in = in_channels
    for layer in VGG16_CONV_LAYERS:
        params[layer.name] = {
            "w": Param((layer.k, layer.k, c_in, layer.M), (None, None, None, "ffn")),
            "b": Param((layer.M,), ("ffn",), init="zeros"),
        }
        c_in = layer.M
    params["fc6"] = {"w": Param((512 * 7 * 7, 4096), (None, "ffn")),
                     "b": Param((4096,), ("ffn",), init="zeros")}
    params["fc7"] = {"w": Param((4096, 4096), ("ffn", "ffn")),
                     "b": Param((4096,), ("ffn",), init="zeros")}
    params["fc8"] = {"w": Param((4096, n_classes), ("ffn", "vocab")),
                     "b": Param((n_classes,), ("vocab",), init="zeros")}
    return params


def _conv_float(x, w, b):
    out = jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b.astype(x.dtype)


def _conv_l2r(x, w, b, cfg: QuantConfig, levels):
    """im2col + MSDF digit-plane GEMM (the composite IPU mapping)."""
    kh, kw, cin, cout = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (B, H, W, cin*kh*kw)
    bsz, h, ww, pdim = patches.shape
    flat = patches.reshape(bsz * h * ww, pdim)
    # lax patches order the channel dim as (cin, kh, kw)
    wmat = w.transpose(2, 0, 1, 3).reshape(pdim, cout)
    out = l2r_matmul(flat, wmat, cfg, levels)
    return out.reshape(bsz, h, ww, cout) + b.astype(out.dtype)


def vgg16_apply(
    params: dict,
    images: jax.Array,  # (B, H, W, 3)
    l2r: QuantConfig | None = None,
    levels: int | None = None,
    n_dense_pool: int = 5,
) -> jax.Array:
    """Forward pass.  Returns logits (B, n_classes).

    Works for any input size that survives 5 pools >= 1 pixel; the FC
    head adapts via average pooling to 7x7 (or the remaining size).
    """
    x = images
    conv = (lambda x, w, b: _conv_l2r(x, w, b, l2r, levels)) if l2r else _conv_float
    stage_splits = {1: 2, 3: 2, 6: 2, 9: 2, 12: 2}  # pool after these conv idxs
    for i, layer in enumerate(VGG16_CONV_LAYERS):
        p = params[layer.name]
        x = jax.nn.relu(conv(x, p["w"], p["b"]))
        if i in stage_splits:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            )
    # adaptive head: resize feature map to the canonical 7x7 so the FC
    # head works for any input resolution (smoke tests use 32x32 images)
    bsz, h, w_, c = x.shape
    if (h, w_) != (7, 7):
        x = jax.image.resize(x, (bsz, 7, 7, c), "linear")
    flat = x.reshape(bsz, -1)
    mm = (lambda a, wt: l2r_matmul(a, wt, l2r, levels)) if l2r else (
        lambda a, wt: a @ wt.astype(a.dtype))
    x = jax.nn.relu(mm(flat, params["fc6"]["w"]) + params["fc6"]["b"])
    x = jax.nn.relu(mm(x, params["fc7"]["w"]) + params["fc7"]["b"])
    return mm(x, params["fc8"]["w"]) + params["fc8"]["b"]
