"""VGG-16 — the paper's evaluation network, with the L2R conv path.

Convolutions run either as plain float (lax.conv) or through the paper's
composite inner-product pipeline via the **fused** conv op
(kernels/l2r_gemm/ops.py:l2r_conv2d): digit planes are extracted once per
feature map and each kernel tap streams a shifted view through the
level-stacked MSDF GEMM — no (B*H*W, cin*kh*kw) patch matrix in HBM.
The backend (jnp / pallas-interpret / pallas-tpu) is chosen by the
dispatcher (ops.py:resolve_backend).  With all significance levels the
L2R path is exact W8A8 integer conv; with fewer levels it is the
progressive-precision (online early output) mode.

Weights quantize ONCE per model load: build the cache with
:func:`vgg16_quantize_weights` and pass it to :func:`vgg16_apply` —
per-forward weight quantization then disappears from the traces.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cycle_model import VGG16_CONV_LAYERS
from repro.core.progressive import streaming_argmax
from repro.core.quant import (QuantConfig, QuantizedWeights, quantize,
                              quantize_weights)
from repro.kernels.l2r_gemm.ops import l2r_conv2d, l2r_matmul_f

from .common import Param

__all__ = ["vgg16_build", "vgg16_apply", "vgg16_classify_progressive",
           "vgg16_quantize_weights", "VGG16_CONV_LAYERS"]


def vgg16_build(n_classes: int = 1000, in_channels: int = 3) -> dict:
    params: dict = {}
    c_in = in_channels
    for layer in VGG16_CONV_LAYERS:
        params[layer.name] = {
            "w": Param((layer.k, layer.k, c_in, layer.M), (None, None, None, "ffn")),
            "b": Param((layer.M,), ("ffn",), init="zeros"),
        }
        c_in = layer.M
    params["fc6"] = {"w": Param((512 * 7 * 7, 4096), (None, "ffn")),
                     "b": Param((4096,), ("ffn",), init="zeros")}
    params["fc7"] = {"w": Param((4096, 4096), ("ffn", "ffn")),
                     "b": Param((4096,), ("ffn",), init="zeros")}
    params["fc8"] = {"w": Param((4096, n_classes), ("ffn", "vocab")),
                     "b": Param((n_classes,), ("vocab",), init="zeros")}
    return params


def vgg16_quantize_weights(params: dict, cfg: QuantConfig = QuantConfig(),
                           prestack: bool = True, mesh=None
                           ) -> dict[str, QuantizedWeights]:
    """The L2R weight cache: every matmul/conv weight -> int8 + per-
    out-channel scale, built exactly once at model load.

    ``prestack=True`` (default) also caches each layer's reversed RHS
    digit-plane stack (core/quant.py:PlaneOperands — contraction axis
    -2 for conv weights, 0 for the FC head) so the conv taps and the
    streamed fc8 head consume pre-extracted planes: weight planes are
    extracted exactly once per process instead of once per call.  Costs
    D x the int8 weight bytes; pass False to keep extract-per-call.

    ``mesh`` (default: the installed ``sharding.ctx`` mesh) shards the
    fc8 head cache — int8 weight, scales, window-padded plane stack —
    over the ``model`` axis on the class dim, the layout the
    ``shard_map``ped consensus stream of
    :func:`vgg16_classify_progressive` consumes directly.  The trunk
    caches stay replicated (the trunk runs exactly; only the streamed
    head is vocab-sharded).  Values are unchanged either way.
    """
    if mesh is None:
        from repro.sharding import ctx

        mesh = ctx.get_mesh()
    return {name: quantize_weights(
                p["w"], cfg, prestack=prestack,
                plane_axis=-2 if len(p["w"].shape) == 4 else 0,
                window_pad=prestack and name == "fc8",
                shard=(None, "model") if name == "fc8" and mesh is not None
                else None,
                mesh=mesh if name == "fc8" else None)
            for name, p in params.items()}


def _conv_float(x, w, b):
    out = jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b.astype(x.dtype)


def vgg16_apply(
    params: dict,
    images: jax.Array,  # (B, H, W, 3)
    l2r: QuantConfig | None = None,
    levels: int | None = None,
    weights_q: dict[str, QuantizedWeights] | None = None,
    backend: str | None = None,
    n_dense_pool: int = 5,
) -> jax.Array:
    """Forward pass.  Returns logits (B, n_classes).

    Works for any input size that survives 5 pools >= 1 pixel; the FC
    head adapts via average pooling to 7x7 (or the remaining size).
    ``weights_q`` is the load-time cache from
    :func:`vgg16_quantize_weights`; when omitted on the L2R path it is
    built here (once per call — callers that jit or loop should build it
    themselves so weights quantize once per model load, not per forward).
    """
    x, weights_q = _vgg16_trunk(params, images, l2r, levels, weights_q,
                                backend)
    if l2r is not None:
        return l2r_matmul_f(x, None, l2r, levels, w_q=weights_q["fc8"],
                            backend=backend) + params["fc8"]["b"]
    return x @ params["fc8"]["w"].astype(x.dtype) + params["fc8"]["b"]


def _vgg16_trunk(params, images, l2r, levels, weights_q, backend):
    """Everything up to the fc8 classifier head: (fc7 activations,
    weights_q).  Shared by the one-shot and progressive classify paths."""
    x = images
    if l2r is not None and weights_q is None:
        weights_q = vgg16_quantize_weights(params, l2r)
    if l2r is not None:
        conv = lambda x, p, name: l2r_conv2d(
            x, None, p["b"], l2r, levels, w_q=weights_q[name], backend=backend)
    else:
        conv = lambda x, p, name: _conv_float(x, p["w"], p["b"])
    stage_splits = {1: 2, 3: 2, 6: 2, 9: 2, 12: 2}  # pool after these conv idxs
    for i, layer in enumerate(VGG16_CONV_LAYERS):
        x = jax.nn.relu(conv(x, params[layer.name], layer.name))
        if i in stage_splits:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            )
    # adaptive head: resize feature map to the canonical 7x7 so the FC
    # head works for any input resolution (smoke tests use 32x32 images)
    bsz, h, w_, c = x.shape
    if (h, w_) != (7, 7):
        x = jax.image.resize(x, (bsz, 7, 7, c), "linear")
    flat = x.reshape(bsz, -1)
    if l2r is not None:
        mm = lambda a, name: l2r_matmul_f(
            a, None, l2r, levels, w_q=weights_q[name], backend=backend)
    else:
        mm = lambda a, name: a @ params[name]["w"].astype(a.dtype)
    x = jax.nn.relu(mm(flat, "fc6") + params["fc6"]["b"])
    x = jax.nn.relu(mm(x, "fc7") + params["fc7"]["b"])
    return x, weights_q


def vgg16_classify_progressive(
    params: dict,
    images: jax.Array,
    l2r: QuantConfig = QuantConfig(),
    weights_q: dict[str, QuantizedWeights] | None = None,
    backend: str | None = None,
    early_exit: bool = False,
    mesh=None,
):
    """Classification with online early exit on the fc8 logit stream.

    The trunk (convs + fc6/fc7) runs exactly (all MSDF levels); the fc8
    head streams level by level and each image commits its class as soon
    as the top-1 logit margin beats the scaled tail bound on the unseen
    digits — the paper's "most significant digits decide first" property
    as a serving primitive.  The committed class ALWAYS equals
    ``argmax(vgg16_apply(..., l2r=l2r))`` (undecided rows fall back to
    the full stream).

    ``early_exit=True`` stops the head's level loop once EVERY image in
    the batch has decided (the while-loop emitter): classes and exit
    levels stay bit-identical, the saved levels become saved wall-clock,
    and the returned logits are the dequantized prefix at the exit level
    (full-depth values only when some image needed the whole stream).

    Returns ``(pred (B,) int32, exit_level (B,) int32, logits (B, C))``;
    exit_level counts MSDF levels consumed (2D-2 = needed everything).

    When a mesh is installed (sharding/ctx.py, or the explicit ``mesh=``
    override), the head stream runs as the ``shard_map``ped consensus
    walk — images batch-sharded over the data axes, fc8 classes over
    ``model``, early exit at the fleet-wide slowest image — with
    predictions, exit levels, and logits bit-identical to the
    single-device stream.
    """
    x, weights_q = _vgg16_trunk(params, images, l2r, None, weights_q, backend)
    w_q = weights_q["fc8"]
    # quantize the head activations exactly as l2r_matmul_f does, so the
    # streamed accumulator is bit-identical to the one-shot fc8 matmul
    xq, xs = quantize(x, l2r, axis=0 if l2r.per_channel else None)
    # the load-time plane-stack cache feeds the stream directly (the
    # stream is bit-identical either way — the inline path extracts the
    # very same stack per call)
    p = w_q.planes
    wq_in = p if (p is not None and p.matches(l2r.n_bits, l2r.log2_radix,
                                              ndim=2, side="rhs")) else w_q.q
    logits, pred, exit_level = streaming_argmax(
        xq, wq_in, xs, w_q.scale, l2r.n_bits, l2r.log2_radix,
        bias=params["fc8"]["b"], out_dtype=x.dtype, early_exit=early_exit,
        mesh=mesh)
    return pred, exit_level, logits
