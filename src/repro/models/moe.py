"""Mixture-of-Experts FFN: top-k routing with capacity, scatter dispatch,
shared experts (DeepSeekMoE) and top-1 routed + shared (Llama-4 style).

Dispatch is scatter/gather based — token t's i-th choice of expert e gets
slot p = (number of earlier assignments to e); assignments beyond the
static capacity C are dropped (standard capacity dropping).  This avoids
the (tokens, experts, capacity) one-hot einsum blow-up and maps onto an
all-to-all when experts are sharded over the "model" mesh axis.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import Param, dense
from .config import ModelConfig

__all__ = ["moe_build", "moe_apply", "moe_capacity"]


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    ideal = cfg.experts_per_token * n_tokens / max(cfg.n_experts, 1)
    cap = int(math.ceil(ideal * cfg.capacity_factor))
    return max(8, min(cap, n_tokens))


def moe_build(cfg: ModelConfig) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff or cfg.d_ff
    glu = cfg.ffn_kind in ("swiglu", "geglu")
    params = {
        "router": Param((d, e), ("embed", None), scale=0.02),
        "wi": Param((e, d, 2, f) if glu else (e, d, f),
                    ("experts", "embed", None, "ffn") if glu else ("experts", "embed", "ffn")),
        "wo": Param((e, f, d), ("experts", "ffn", "embed")),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        params["shared_wi"] = Param(
            (d, 2, fs) if glu else (d, fs),
            ("embed", None, "ffn") if glu else ("embed", "ffn"),
        )
        params["shared_wo"] = Param((fs, d), ("ffn", "embed"))
    return params


def _expert_ffn(cfg: ModelConfig, wi, wo, xb: jax.Array) -> jax.Array:
    """xb: (E, C, d) -> (E, C, d); per-expert GLU/GELU FFN.

    With the L2R switch on, expert matmuls run through the **backend
    dispatcher** (kernels/l2r_gemm/ops.py:l2r_matmul_f) vmapped over
    experts: they pick up the level-stacked schedule, the guarded f32
    BLAS fast path, and the ``REPRO_L2R_BACKEND`` override exactly like
    the dense stack — per-expert activation/weight scales come from the
    quantization happening inside the vmapped call."""
    glu = cfg.ffn_kind in ("swiglu", "geglu")
    if cfg.l2r is not None:
        from repro.kernels.l2r_gemm.ops import l2r_matmul_f

        wi2 = wi.reshape(wi.shape[0], wi.shape[1], -1)
        h = jax.vmap(lambda xe, we: l2r_matmul_f(xe, we, cfg.l2r, cfg.l2r_levels))(
            xb, wi2
        ).reshape(xb.shape[0], xb.shape[1], *wi.shape[2:])
    else:
        h = jnp.einsum("ecd,ed...f->ec...f", xb, wi.astype(xb.dtype))
    if glu:
        gate, up = h[..., 0, :], h[..., 1, :]
        act = jax.nn.silu(gate) if cfg.ffn_kind == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jax.nn.gelu(h)
    if cfg.l2r is not None:
        from repro.kernels.l2r_gemm.ops import l2r_matmul_f

        return jax.vmap(lambda he, we: l2r_matmul_f(he, we, cfg.l2r, cfg.l2r_levels))(
            h, wo
        )
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(xb.dtype))


def _dp_groups(t: int) -> int:
    """Number of shard-local dispatch groups = total device count (the
    flat token dim is sharded over dp x model); 1 without a mesh."""
    from repro.sharding.ctx import get_mesh

    mesh = get_mesh()
    if mesh is None:
        return 1
    n = mesh.size
    return n if n > 1 and t % n == 0 else 1


def moe_apply(cfg: ModelConfig, params: dict, x: jax.Array):
    """x: (B, S, d) -> (out, aux_loss).  Routed top-k + optional shared."""
    if cfg.moe_dp_local and _dp_groups(x.shape[0] * x.shape[1]) > 1:
        return moe_apply_dp_local(cfg, params, x)
    b, s, d = x.shape
    t = b * s
    e = cfg.n_experts
    k = cfg.experts_per_token
    cap = moe_capacity(cfg, t)
    xt = x.reshape(t, d)

    from repro.sharding.ctx import hint, hint_dp

    xt = hint_dp(xt)  # tokens stay DP-sharded through routing
    logits = dense(xt, params["router"]).astype(jnp.float32)  # (T, E)
    logits = hint_dp(logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # slot assignment: position of each (t, i) among assignments to its
    # expert, in token order (cumsum of one-hot counts).
    flat_e = expert_idx.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot  # exclusive prefix count
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]  # (T*k,)
    keep = slot < cap
    gates = gate_vals.reshape(-1) * keep

    # dispatch: (E, C, d) expert buffers (all-to-all under expert sharding)
    buf = jnp.zeros((e, cap, d), x.dtype)
    safe_slot = jnp.where(keep, slot, cap - 1)
    src = jnp.repeat(jnp.arange(t), k)
    contrib = hint_dp(jnp.where(keep[:, None], xt[src], 0))
    buf = buf.at[flat_e, safe_slot].add(contrib, mode="drop")
    buf = hint(buf, "model")  # experts live on the model axis

    yb = _expert_ffn(cfg, params["wi"], params["wo"], buf)  # (E, C, d)
    yb = hint(yb, "model")

    # combine: gather each kept assignment back, weighted by its gate
    y_tok = yb[flat_e, safe_slot]  # (T*k, d)
    y = jnp.zeros((t, d), jnp.float32).at[src].add(
        y_tok.astype(jnp.float32) * gates[:, None]
    )
    out = hint_dp(y).astype(x.dtype)

    if cfg.n_shared_experts:
        glu = cfg.ffn_kind in ("swiglu", "geglu")
        h = dense(xt, params["shared_wi"], cfg.l2r, cfg.l2r_levels)
        if glu:
            g_, u_ = h[..., 0, :], h[..., 1, :]
            h = (jax.nn.silu(g_) if cfg.ffn_kind == "swiglu" else jax.nn.gelu(g_)) * u_
        else:
            h = jax.nn.gelu(h)
        out = out + dense(h, params["shared_wo"], cfg.l2r, cfg.l2r_levels)

    # Switch-style load-balance aux loss
    me = probs.mean(0)  # (E,) mean router prob
    ce = jnp.zeros((e,), jnp.float32).at[flat_e].add(keep.astype(jnp.float32)) / max(t * k, 1)
    aux = e * jnp.sum(me * ce) * cfg.router_aux_weight
    return out.reshape(b, s, d), aux


def moe_apply_dp_local(cfg: ModelConfig, params: dict, x: jax.Array):
    """DP-local-capacity MoE (§Perf hillclimb B).

    Tokens are DP-major (the batch dim is sharded over ("pod","data")),
    so reshaping to (DP, T_local) aligns group g with data shard g.  Slot
    assignment and the dispatch scatter then happen *inside* each shard
    (zero communication); the single cross-device movement is the
    (DP, E, C_local, d) -> (E, DP*C_local, d) transpose, which GSPMD
    lowers to the canonical MoE all-to-all.  Capacity is per shard
    (C_local = ceil(k*T_local/E * factor)): dropping is shard-local,
    the standard behavior of production MoE systems.
    """
    from repro.sharding.ctx import hint, hint_dp

    b, s, d = x.shape
    t = b * s
    e = cfg.n_experts
    k = cfg.experts_per_token
    dp = _dp_groups(t)
    t_local = t // dp
    cap = moe_capacity(cfg, t_local)

    # flattened rows are (batch x seq)-major: batch is DP-sharded AND the
    # sequence is model-sharded between blocks (Megatron-SP), so the flat
    # token dim must be pinned over BOTH axes — dropping this constraint
    # (hillclimb B5) regressed 14.9s -> 18.2s: GSPMD then gathers rows
    # over "model" for the router/shared-expert matmuls.
    all_axes = ("pod", "data", "model")
    xt = hint(x.reshape(t, d), all_axes)
    logits = dense(xt, params["router"]).astype(jnp.float32)
    logits = hint(logits, all_axes)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    xg = xt.reshape(dp, t_local, d)
    eg = expert_idx.reshape(dp, t_local, k)
    gg = gate_vals.reshape(dp, t_local, k)

    def dispatch_one(x_l, e_l, g_l):
        flat_e = e_l.reshape(-1)  # (T_l*k,)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        keep = slot < cap
        gates = g_l.reshape(-1) * keep
        safe_slot = jnp.where(keep, slot, cap - 1)
        src = jnp.repeat(jnp.arange(t_local), k)
        contrib = jnp.where(keep[:, None], x_l[src], 0)
        buf = jnp.zeros((e, cap, d), x_l.dtype).at[flat_e, safe_slot].add(
            contrib, mode="drop")
        return buf, flat_e, safe_slot, gates, src, keep

    bufs, flat_e, safe_slot, gates, src, keep = jax.vmap(dispatch_one)(
        xg, eg, gg)  # bufs: (G, E, C, d), one group per device
    # dispatch is device-local: group dim pinned over ALL mesh axes
    bufs = hint(bufs, all_axes)
    # the all-to-all happens HERE: regroup so each chip holds its dp-row's
    # groups for its "model"-axis expert slice; the expert FFN is vmapped
    # over the group dim — no reshape/transpose of sharded dims, so GSPMD
    # never materializes a gathered copy.
    bufs = hint(bufs, ("pod", "data"), "model")
    yb = jax.vmap(
        lambda b_: _expert_ffn(cfg, params["wi"], params["wo"], b_))(bufs)
    yb = hint(yb, ("pod", "data"), "model")
    # all-to-all back: groups return to their owning device for combine
    ybg = hint(yb, all_axes)  # (G, E, C, d)

    def combine_one(y_l, fe, ss, g_l, src_l):
        y_tok = y_l[fe, ss]  # (T_l*k, d)
        out = jnp.zeros((t_local, d), jnp.float32).at[src_l].add(
            y_tok.astype(jnp.float32) * g_l[:, None])
        return out

    y = jax.vmap(combine_one)(ybg, flat_e, safe_slot, gates, src)
    out = hint(y.reshape(t, d), all_axes).astype(x.dtype)

    if cfg.n_shared_experts:
        glu = cfg.ffn_kind in ("swiglu", "geglu")
        h = dense(xt, params["shared_wi"], cfg.l2r, cfg.l2r_levels)
        if glu:
            g_, u_ = h[..., 0, :], h[..., 1, :]
            h = (jax.nn.silu(g_) if cfg.ffn_kind == "swiglu" else jax.nn.gelu(g_)) * u_
        else:
            h = jax.nn.gelu(h)
        out = out + dense(h, params["shared_wo"], cfg.l2r, cfg.l2r_levels)

    me = probs.mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[flat_e.reshape(-1)].add(
        keep.reshape(-1).astype(jnp.float32)) / max(t * k, 1)
    aux = e * jnp.sum(me * ce) * cfg.router_aux_weight
    return out.reshape(b, s, d), aux
