"""Encoder-decoder transformer (whisper-base backbone).

Per the assignment, the conv/mel audio frontend is a STUB: input_specs()
supplies precomputed frame embeddings (B, encoder_seq, d_model).  The
encoder is bidirectional self-attention; the decoder is causal
self-attention + cross-attention whose K/V are computed once per layer
from the encoder output at prefill time and cached.

Whisper idioms kept: LayerNorm, GELU MLP, learned position embeddings,
no RoPE.  (The decode_32k cell runs the decoder with a 32k-entry
position table — architecturally valid, beyond whisper's trained 448
positions; a lowering/sharding exercise per the assignment.)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .attention import chunked_attention, decode_attention, init_kv_cache, update_kv_cache
from .common import Param, dense, layer_norm
from .config import ModelConfig
from .mlp import mlp_build, mlp_apply
from .transformer import attn_build

__all__ = ["encdec_build", "encdec_forward", "init_encdec_state", "EncDecState",
           "encode", "MAX_DEC_POSITIONS"]

MAX_DEC_POSITIONS = 32_768


def _ln(cfg, x, g):
    return layer_norm(x, 1.0 + g, jnp.zeros_like(g), cfg.norm_eps)


def _enc_layer_build(cfg: ModelConfig) -> dict:
    return {
        "attn_norm": Param((cfg.d_model,), ("embed",), init="zeros"),
        "attn": attn_build(cfg),
        "ffn_norm": Param((cfg.d_model,), ("embed",), init="zeros"),
        "ffn": mlp_build(cfg),
    }


def _dec_layer_build(cfg: ModelConfig) -> dict:
    return {
        "self_norm": Param((cfg.d_model,), ("embed",), init="zeros"),
        "self": attn_build(cfg),
        "cross_norm": Param((cfg.d_model,), ("embed",), init="zeros"),
        "cross": attn_build(cfg),
        "ffn_norm": Param((cfg.d_model,), ("embed",), init="zeros"),
        "ffn": mlp_build(cfg),
    }


def _stack(n: int, tree):
    def s(p: Param) -> Param:
        return Param((n, *p.shape), ("layers", *p.axes), init=p.init,
                     scale=p.scale, dtype=p.dtype)
    return jax.tree.map(s, tree, is_leaf=lambda x: isinstance(x, Param))


def encdec_build(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "enc_pos": Param((cfg.encoder_seq, d), (None, "embed"), scale=0.02),
        "enc_stack": _stack(cfg.encoder_layers, _enc_layer_build(cfg)),
        "enc_norm": Param((d,), ("embed",), init="zeros"),
        "embed": Param((cfg.vocab, d), ("vocab", "embed"), init="embed"),
        "dec_pos": Param((MAX_DEC_POSITIONS, d), (None, "embed"), scale=0.02),
        "dec_stack": _stack(cfg.n_layers, _dec_layer_build(cfg)),
        "dec_norm": Param((d,), ("embed",), init="zeros"),
    }


def _mha(cfg, p, xq, xkv, *, causal, mode="train", cache=None, positions=None):
    """Simple (non-RoPE) MHA used by both encoder and decoder."""
    b, sq, _ = xq.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = dense(xq, p["wq"], cfg.l2r, cfg.l2r_levels)
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
    q = q.reshape(b, sq, h, dh)
    k = dense(xkv, p["wk"], cfg.l2r, cfg.l2r_levels)
    v = dense(xkv, p["wv"], cfg.l2r, cfg.l2r_levels)
    if "bk" in p:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    k = k.reshape(b, -1, kv, dh)
    v = v.reshape(b, -1, kv, dh)
    if mode == "decode":
        cache = update_kv_cache(cache, k, v, positions)
        out = decode_attention(q, cache.k, cache.v, cache.positions,
                               positions[:, 0], scale=cfg.attn_scale)
    else:
        if mode == "prefill":
            cache = update_kv_cache(cache, k, v, positions)
        out = chunked_attention(q, k, v, causal=causal, scale=cfg.attn_scale)
    return dense(out.reshape(b, sq, h * dh), p["wo"], cfg.l2r, cfg.l2r_levels), cache


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, encoder_seq, d) precomputed embeddings (frontend stub)."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + params["enc_pos"][None, : x.shape[1]].astype(x.dtype)

    def block(x, lp):
        h, _ = _mha(cfg, lp["attn"], _ln(cfg, x, lp["attn_norm"]),
                    _ln(cfg, x, lp["attn_norm"]), causal=False)
        x = x + h
        x = x + mlp_apply(cfg, lp["ffn"], _ln(cfg, x, lp["ffn_norm"]))
        return x, None

    x, _ = jax.lax.scan(block, x, params["enc_stack"])
    return _ln(cfg, x, params["enc_norm"])


@dataclasses.dataclass
class EncDecState:
    self_cache: Any  # stacked KVCache over decoder layers
    cross_k: jax.Array  # (L, B, S_enc, Kv, dh)
    cross_v: jax.Array
    pos: jax.Array  # (B,)


jax.tree_util.register_dataclass(
    EncDecState,
    data_fields=["self_cache", "cross_k", "cross_v", "pos"],
    meta_fields=[],
)


def init_encdec_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> EncDecState:
    l = cfg.n_layers
    c = init_kv_cache(batch, max_len, cfg.n_kv, cfg.head_dim, dtype)
    return EncDecState(
        self_cache=jax.tree.map(lambda x: jnp.stack([x] * l), c),
        cross_k=jnp.zeros((l, batch, cfg.encoder_seq, cfg.n_kv, cfg.head_dim), dtype),
        cross_v=jnp.zeros((l, batch, cfg.encoder_seq, cfg.n_kv, cfg.head_dim), dtype),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def encdec_forward(
    cfg: ModelConfig,
    params: dict,
    *,
    tokens: jax.Array,
    frames: jax.Array | None = None,
    enc_out: jax.Array | None = None,
    mode: str = "train",
    state: EncDecState | None = None,
    resid_shard=lambda x: x,
    remat: bool = False,
):
    """Decoder forward (runs the encoder when enc_out not given).

    Returns (hidden, new_state, aux=0).  In decode mode the cross K/V
    come from the state (computed at prefill); in train/prefill they are
    computed from enc_out per layer.
    """
    compute_dtype = jnp.dtype(cfg.compute_dtype)
    b, s = tokens.shape
    if mode != "decode" and enc_out is None:
        assert frames is not None, "encoder frames required"
        enc_out = encode(cfg, params, frames)

    if state is not None:
        positions = state.pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    x = params["embed"].astype(compute_dtype)[tokens]
    x = x + jnp.take(params["dec_pos"].astype(compute_dtype), positions, axis=0)

    kv, dh = cfg.n_kv, cfg.head_dim

    def block(carry, xs):
        x = carry
        lp, caches = xs
        self_c, ck, cv = caches
        h, self_c = _mha(cfg, lp["self"], _ln(cfg, x, lp["self_norm"]),
                         _ln(cfg, x, lp["self_norm"]), causal=True,
                         mode=mode, cache=self_c, positions=positions)
        x = x + h
        # cross attention
        xq = _ln(cfg, x, lp["cross_norm"])
        q = dense(xq, lp["cross"]["wq"], cfg.l2r, cfg.l2r_levels)
        if "bq" in lp["cross"]:
            q = q + lp["cross"]["bq"].astype(q.dtype)
        q = q.reshape(b, s, cfg.n_heads, dh)
        if mode == "decode":
            k_enc, v_enc = ck, cv
        else:
            k_enc = dense(enc_out, lp["cross"]["wk"], cfg.l2r, cfg.l2r_levels)
            v_enc = dense(enc_out, lp["cross"]["wv"], cfg.l2r, cfg.l2r_levels)
            if "bk" in lp["cross"]:
                k_enc = k_enc + lp["cross"]["bk"].astype(k_enc.dtype)
                v_enc = v_enc + lp["cross"]["bv"].astype(v_enc.dtype)
            k_enc = k_enc.reshape(b, -1, kv, dh)
            v_enc = v_enc.reshape(b, -1, kv, dh)
        attn = chunked_attention(q, k_enc.astype(x.dtype), v_enc.astype(x.dtype),
                                 causal=False, scale=cfg.attn_scale)
        x = x + dense(attn.reshape(b, s, cfg.n_heads * dh), lp["cross"]["wo"],
                      cfg.l2r, cfg.l2r_levels)
        x = x + mlp_apply(cfg, lp["ffn"], _ln(cfg, x, lp["ffn_norm"]))
        x = resid_shard(x)
        new_caches = (self_c, k_enc, v_enc) if state is not None else 0
        return x, new_caches

    block_fn = jax.checkpoint(block) if remat else block
    if state is not None:
        xs = (params["dec_stack"], (state.self_cache, state.cross_k, state.cross_v))
    else:
        xs = (params["dec_stack"], (None, None, None))  # cache-less train scan
    x, ys = jax.lax.scan(block_fn, x, xs)
    x = _ln(cfg, x, params["dec_norm"])

    new_state = None
    if state is not None:
        self_c, ck, cv = ys
        new_state = EncDecState(self_cache=self_c, cross_k=ck, cross_v=cv,
                                pos=state.pos + s)
    return x, new_state, jnp.zeros((), jnp.float32)
