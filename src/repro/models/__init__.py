"""Model zoo: decoder LMs (dense/MoE/SSM/hybrid), enc-dec, VLM backbone,
and the paper's VGG-16."""

from .config import ModelConfig
from .common import Param, materialize, abstract, partition_specs, count_params, dense
from .transformer import lm_build, lm_forward, logits_from_hidden, init_lm_state, LMState
from .encdec import encdec_build, encdec_forward, init_encdec_state, EncDecState
from .cnn import vgg16_build, vgg16_apply

__all__ = [
    "ModelConfig", "Param", "materialize", "abstract", "partition_specs",
    "count_params", "dense", "lm_build", "lm_forward", "logits_from_hidden",
    "init_lm_state", "LMState", "encdec_build", "encdec_forward",
    "init_encdec_state", "EncDecState", "vgg16_build", "vgg16_apply",
]
