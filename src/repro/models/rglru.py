"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block structure (per the Griffin paper): two parallel branches from the
input — a GeLU gate branch and a recurrence branch (linear -> causal
temporal conv1d -> RG-LRU) — multiplied and projected back.

RG-LRU recurrence (elementwise — outside the paper's inner-product unit,
kept in floating point; see DESIGN.md §4):

    r_t = sigmoid(W_a xi_t + b_a)            recurrence gate
    i_t = sigmoid(W_x xi_t + b_x)            input gate
    log a_t = -c * softplus(Lambda) * r_t    (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * xi_t)

Train/prefill evaluate the linear recurrence with an associative scan
(log-depth); decode is the O(1) step — the bounded state that makes
`long_500k` tractable for recurrentgemma-2b.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import Param, dense
from .config import ModelConfig

__all__ = ["rglru_build", "rglru_apply", "rglru_decode", "init_rglru_state"]

_C = 8.0


def rglru_build(cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    return {
        "gate_proj": Param((d, w), ("embed", "ffn")),
        "rec_proj": Param((d, w), ("embed", "ffn")),
        "conv_w": Param((cfg.conv1d_width, w), (None, "ffn"), scale=0.1),
        "conv_b": Param((w,), ("ffn",), init="zeros"),
        "w_a": Param((w, w), ("ffn", None), scale=0.02),
        "b_a": Param((w,), (None,), init="zeros"),
        "w_x": Param((w, w), ("ffn", None), scale=0.02),
        "b_x": Param((w,), (None,), init="zeros"),
        "lam": Param((w,), (None,), init="ones"),  # Lambda (softplus'd)
        "out_proj": Param((w, cfg.d_model), ("ffn", "embed")),
    }


def _conv1d(x, w, b, state=None):
    width = w.shape[0]
    pad = (
        jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
        if state is None
        else state.astype(x.dtype)
    )
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None].astype(x.dtype)
        for i in range(width)
    ) + b.astype(x.dtype)
    return y, xp[:, -(width - 1):, :]


def _gates(params, xi):
    r = jax.nn.sigmoid(
        dense(xi, params["w_a"]).astype(jnp.float32) + params["b_a"].astype(jnp.float32)
    )
    i = jax.nn.sigmoid(
        dense(xi, params["w_x"]).astype(jnp.float32) + params["b_x"].astype(jnp.float32)
    )
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * xi.astype(jnp.float32)
    )
    return a, b


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), dtype),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype),
    }


def rglru_apply(cfg: ModelConfig, params: dict, u: jax.Array,
                state: dict | None = None):
    """u: (B, S, d_model) -> (out, new_state)."""
    gate = jax.nn.gelu(dense(u, params["gate_proj"], cfg.l2r, cfg.l2r_levels))
    xi = dense(u, params["rec_proj"], cfg.l2r, cfg.l2r_levels)
    conv_state = None if state is None else state["conv"]
    xi, new_conv = _conv1d(xi, params["conv_w"], params["conv_b"], conv_state)
    a, b = _gates(params, xi)  # (B, S, W) f32

    if state is not None:
        # fold carried state into the first step: h_0' = a_0 h_in + b_0
        b = b.at[:, 0].add(a[:, 0] * state["h"].astype(jnp.float32))

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(u.dtype) * gate)
    out = dense(y, params["out_proj"], cfg.l2r, cfg.l2r_levels)
    return out, {"h": h[:, -1], "conv": new_conv}


def rglru_decode(cfg: ModelConfig, params: dict, u: jax.Array, state: dict):
    """u: (B, 1, d_model); O(1) recurrent step."""
    gate = jax.nn.gelu(dense(u, params["gate_proj"], cfg.l2r, cfg.l2r_levels))
    xi = dense(u, params["rec_proj"], cfg.l2r, cfg.l2r_levels)
    xi, new_conv = _conv1d(xi, params["conv_w"], params["conv_b"], state["conv"])
    a, b = _gates(params, xi)  # (B, 1, W)
    h = a[:, 0] * state["h"].astype(jnp.float32) + b[:, 0]
    y = h[:, None].astype(u.dtype) * gate
    out = dense(y, params["out_proj"], cfg.l2r, cfg.l2r_levels)
    return out, {"h": h, "conv": new_conv}
