"""Mamba-2 (SSD — state-space duality) mixer, chunked scan formulation.

Train/prefill use the chunked SSD algorithm (intra-chunk quadratic term +
inter-chunk state recurrence); decode is the O(1) per-token recurrence —
this is what makes the `long_500k` shape tractable for mamba2-130m.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import Param, dense, rms_norm
from .config import ModelConfig

__all__ = [
    "ssm_build",
    "ssm_apply",
    "ssm_decode",
    "init_ssm_state",
    "ssd_chunked",
]


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    n = cfg.ssm_state
    conv_dim = d_inner + 2 * n  # x, B, C share the temporal conv
    return d_inner, heads, n, conv_dim


def ssm_build(cfg: ModelConfig) -> dict:
    d_inner, heads, n, conv_dim = _dims(cfg)
    d_in_proj = 2 * d_inner + 2 * n + heads  # z, xBC, dt
    return {
        "in_proj": Param((cfg.d_model, d_in_proj), ("embed", "ffn")),
        "conv_w": Param((cfg.ssm_conv, conv_dim), (None, "ffn"), scale=0.1),
        "conv_b": Param((conv_dim,), ("ffn",), init="zeros"),
        "a_log": Param((heads,), (None,), init="ones"),
        "d_skip": Param((heads,), (None,), init="ones"),
        "dt_bias": Param((heads,), (None,), init="zeros"),
        "norm": Param((d_inner,), ("ffn",), init="zeros"),
        "out_proj": Param((d_inner, cfg.d_model), ("ffn", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv along S.  x: (B, S, C); w: (W, C).

    Returns (y, new_state) with state = last W-1 inputs (decode carry).
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, C)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None].astype(x.dtype)
        for i in range(width)
    ) + b.astype(x.dtype)
    new_state = xp[:, -(width - 1):, :]
    return y, new_state


def _segsum_scores(ca: jax.Array) -> jax.Array:
    """ca: (..., Q, H) within-chunk inclusive cumsum of a.
    Returns decay (..., H, Q, Q): exp(ca_i - ca_j) for j <= i else 0."""
    q = ca.shape[-2]
    diff = ca[..., :, None, :] - ca[..., None, :, :]  # (.., i, j, H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    diff = jnp.moveaxis(diff, -1, -3)  # (.., H, i, j)
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, a, b, c, chunk: int):
    """Chunked SSD.

    x: (B, S, H, P) inputs (pre-scaled by nothing; dt applied inside),
    dt: (B, S, H) softplus'd step sizes, a: (B, S, H) = -exp(A_log)*dt,
    b, c: (B, S, N) (single group, shared across heads).
    Returns y: (B, S, H, P), final_state: (B, H, N, P).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    ac = a.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, n)
    cc = c.reshape(bsz, nc, chunk, n)

    ca = jnp.cumsum(ac, axis=2)  # (B, NC, Q, H) inclusive
    dtx = xc * dtc[..., None]  # (B, NC, Q, H, P)

    # ---- intra-chunk (quadratic within chunk) ----
    decay = _segsum_scores(ca)  # (B, NC, H, Q, Q)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # (B, NC, Q, Q)
    scores = cb[:, :, None] * decay  # (B, NC, H, Q, Q)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", scores, dtx)

    # ---- chunk summary states: S_c = sum_j exp(ca_last - ca_j) B_j dtx_j^T
    last = ca[:, :, -1:, :]  # (B, NC, 1, H)
    w_end = jnp.exp(last - ca)  # (B, NC, Q, H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bc, w_end, dtx)

    # ---- inter-chunk recurrence over NC (sequential scan) ----
    chunk_decay = jnp.exp(last[:, :, 0, :])  # (B, NC, H) total chunk decay

    def step(r_prev, inp):
        s_c, dec = inp  # (B,H,N,P), (B,H)
        r = r_prev * dec[..., None, None] + s_c
        return r, r_prev  # emit state *entering* the chunk

    init = jnp.zeros((bsz, h, n, p), x.dtype)
    final, r_in = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    r_in = jnp.moveaxis(r_in, 0, 1)  # (B, NC, H, N, P) state entering chunk

    # ---- inter-chunk contribution: y2_i = C_i * exp(ca_i) . R_in
    w_in = jnp.exp(ca)  # decay from chunk start to position i
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", cc, w_in, r_in)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y, final


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_inner, heads, n, conv_dim = _dims(cfg)
    return {
        "ssd": jnp.zeros((batch, heads, n, cfg.ssm_head_dim), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    d_inner, heads, n, conv_dim = _dims(cfg)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    return z, xbc, dt


def ssm_apply(cfg: ModelConfig, params: dict, u: jax.Array,
              state: dict | None = None):
    """Full-sequence SSD mixer.  u: (B, S, d_model).

    Returns (y, new_state); state in/out enables chunked prefill
    continuation and hands decode its carry.
    """
    d_inner, heads, n, conv_dim = _dims(cfg)
    bsz, s, _ = u.shape
    zxbcdt = dense(u, params["in_proj"], cfg.l2r, cfg.l2r_levels)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    x, b, c = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32)) * dt  # (B,S,H)

    x4 = x.reshape(bsz, s, heads, cfg.ssm_head_dim)
    pad = (-s) % cfg.ssm_chunk
    if pad:
        x4 = jnp.pad(x4, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    y, final = ssd_chunked(
        x4.astype(jnp.float32), dt, a,
        b.astype(jnp.float32), c.astype(jnp.float32), cfg.ssm_chunk,
    )
    if pad:
        y = y[:, :s]
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * x4[:, :s].astype(jnp.float32)
    y = y.reshape(bsz, s, d_inner).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = dense(y, params["out_proj"], cfg.l2r, cfg.l2r_levels)
    new_state = {"ssd": final, "conv": new_conv}
    return out, new_state


def ssm_decode(cfg: ModelConfig, params: dict, u: jax.Array, state: dict):
    """One-token step.  u: (B, 1, d_model); O(1) state update."""
    d_inner, heads, n, conv_dim = _dims(cfg)
    bsz = u.shape[0]
    zxbcdt = dense(u, params["in_proj"], cfg.l2r, cfg.l2r_levels)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], state["conv"])
    xbc = jax.nn.silu(xbc)
    x, b, c = jnp.split(xbc[:, 0], [d_inner, d_inner + n], axis=-1)  # (B, .)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = jnp.exp(-jnp.exp(params["a_log"].astype(jnp.float32)) * dt)  # (B,H)

    xh = x.reshape(bsz, heads, cfg.ssm_head_dim).astype(jnp.float32)
    dtx = xh * dt[..., None]
    s_new = state["ssd"] * a[..., None, None] + jnp.einsum(
        "bn,bhp->bhnp", b.astype(jnp.float32), dtx
    )
    y = jnp.einsum("bn,bhnp->bhp", c.astype(jnp.float32), s_new)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bsz, 1, d_inner).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = dense(y, params["out_proj"], cfg.l2r, cfg.l2r_levels)
    return out, {"ssd": s_new, "conv": new_conv}
