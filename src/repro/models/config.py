"""ModelConfig — one declarative record per architecture."""

from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.quant import QuantConfig

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # None -> d_model // n_heads

    # --- mixer pattern (cycled over layers) ---
    layer_pattern: Tuple[str, ...] = ("global",)  # global|local|rec|ssd
    window: int = 4096  # local attention window
    rope_theta: float = 10_000.0
    rope_mode: str = "standard"  # standard | mrope | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    logit_softcap: float | None = None
    attn_scale: float | None = None  # None -> 1/sqrt(head_dim)
    attn_score_dtype: str = "float32"  # "bfloat16": flash-style bf16
    # score blocks (f32 MXU accumulation, f32 softmax stats) — halves the
    # dominant HBM term of long-sequence attention
    attn_head_shard: bool = False  # shard attention math on the KV-head
    # dim (uneven counts padded by GSPMD) — §Perf hillclimb A

    # --- ffn ---
    ffn_kind: str = "swiglu"  # swiglu | geglu | gelu
    ffn_pattern: Tuple[str, ...] = ("mlp",)  # mlp | moe (cycled)
    first_k_dense: int = 0  # leading layers forced to dense mlp (deepseek)
    dense_d_ff: int = 0  # hidden width of dense layers inside MoE models

    # --- moe ---
    n_experts: int = 0
    experts_per_token: int = 1
    n_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden; d_ff is the dense-layer hidden
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_dp_local: bool = False  # DP-local capacity dispatch: shard-local
    # scatters + one all-to-all instead of global-capacity scatters that
    # GSPMD resolves with whole-buffer all-reduces (§Perf hillclimb B)

    # --- ssm (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # --- rg-lru (recurrentgemma) ---
    lru_width: int = 0
    conv1d_width: int = 4

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # precomputed frame count (1500 for whisper)

    # --- modality stub ---
    embeds_input: bool = False  # input_specs supplies embeddings directly

    # --- misc ---
    tie_embeddings: bool = True
    scale_embeddings: bool = False  # gemma-family sqrt(d_model) embed scale
    norm_eps: float = 1e-6
    use_layer_norm: bool = False  # whisper uses LN, others RMS
    qkv_bias: bool = False

    # --- the paper's technique as a first-class switch ---
    l2r: QuantConfig | None = None
    l2r_levels: int | None = None

    # --- digit-serial attention (models/attention.py) ---
    attn_l2r: QuantConfig | None = None  # quantized QK^T through the L2R
    # score walk, on an incrementally plane-stacked KV cache; softmax/PV
    # stay float
    attn_levels: int | None = None  # MSDF truncation of the score stream
    attn_early_exit: bool = False  # margin-bounded progressive decode
    # attention: the per-row score walk stops once max+normalizer are
    # decided within attn_exit_tol
    attn_exit_tol: float = 1e-4

    # --- precision policy ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ----- derived -----
    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim

    def mixer_kinds(self) -> Tuple[str, ...]:
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def ffn_kinds(self) -> Tuple[str, ...]:
        p = self.ffn_pattern
        out = [p[i % len(p)] for i in range(self.n_layers)]
        for i in range(min(self.first_k_dense, self.n_layers)):
            out[i] = "mlp"
        return tuple(out)

    def layer_kinds(self) -> Tuple[Tuple[str, str], ...]:
        return tuple(zip(self.mixer_kinds(), self.ffn_kinds()))

    def block_grouping(self) -> tuple[tuple[tuple[str, str], ...], int, tuple[tuple[str, str], ...], tuple[tuple[str, str], ...]]:
        """Group layers for lax.scan: (prefix, (unit, repeats), suffix).

        prefix = leading layers that break periodicity (first_k_dense);
        unit   = smallest repeating (mixer, ffn) block;
        suffix = trailing remainder layers (unrolled).
        """
        kinds = list(self.layer_kinds())
        prefix = tuple(kinds[: self.first_k_dense])
        body = kinds[self.first_k_dense:]
        if not body:
            return prefix, 0, (), ()
        # smallest repeating unit of the body
        unit_len = 1
        for cand in range(1, len(body) + 1):
            if all(body[i] == body[i % cand] for i in range(len(body))):
                unit_len = cand
                break
        repeats = len(body) // unit_len
        unit = tuple(body[:unit_len])
        suffix = tuple(body[unit_len * repeats:])
        return prefix, repeats, unit, suffix
