"""Decoder-LM assembly: pattern-based layers, scan-grouped blocks, caches.

An architecture is a per-layer sequence of (mixer, ffn) kinds
(ModelConfig.layer_kinds): mixers are 'global' / 'local' attention,
'ssd' (Mamba-2), 'rec' (RG-LRU); ffns are 'mlp' / 'moe'.  Layers are
grouped into the smallest repeating unit and executed under lax.scan
(one traced copy per unit — compile time and HLO size stay bounded for
62-layer models), with aperiodic prefix/suffix layers unrolled.

Three modes:
  train   — full sequence, no cache, remat per scanned block;
  prefill — full sequence, writes caches/states;
  decode  — one token against caches/states (O(1) state for ssd/rec/local).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .attention import (
    KVCache,
    apply_rope,
    chunked_attention,
    decode_attention,
    init_kv_cache,
    update_kv_cache,
)
from .common import Param, dense, rms_norm, layer_norm
from .config import ModelConfig
from .mlp import mlp_build, mlp_apply
from .moe import moe_build, moe_apply
from .rglru import init_rglru_state, rglru_apply, rglru_build, rglru_decode
from .ssm import init_ssm_state, ssm_apply, ssm_build, ssm_decode

__all__ = [
    "lm_build",
    "lm_forward",
    "logits_from_hidden",
    "init_lm_state",
    "LMState",
]


# --------------------------------------------------------------- attention
def attn_build(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    p = {
        "wq": Param((d, h * dh), ("embed", "qkv")),
        "wk": Param((d, kv * dh), ("embed", "qkv")),
        "wv": Param((d, kv * dh), ("embed", "qkv")),
        "wo": Param((h * dh, d), ("qkv", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = Param((h * dh,), ("qkv",), init="zeros")
        p["bk"] = Param((kv * dh,), ("qkv",), init="zeros")
        p["bv"] = Param((kv * dh,), ("qkv",), init="zeros")
    return p


def attn_apply(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    *,
    mode: str,
    rope_positions: jax.Array,
    positions: jax.Array,
    cache: KVCache | None,
    window: int | None,
    cross_kv: tuple[jax.Array, jax.Array] | None = None,
):
    """Self- or cross-attention layer.  Returns (out, new_cache)."""
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv, cfg.head_dim

    from repro.sharding.ctx import hint

    q = dense(x, p["wq"], cfg.l2r, cfg.l2r_levels)
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
    q = hint(q, None, None, "model")  # keep TP over the fused head dim
    q = q.reshape(b, s, h, dh)

    if cross_kv is not None:  # cross-attention: kv precomputed from encoder
        k_all, v_all = cross_kv
        out = chunked_attention(
            q, k_all, v_all, causal=False, scale=cfg.attn_scale,
            softcap=cfg.logit_softcap,
        )
        return dense(out.reshape(b, s, h * dh), p["wo"], cfg.l2r, cfg.l2r_levels), cache

    k = dense(x, p["wk"], cfg.l2r, cfg.l2r_levels)
    v = dense(x, p["wv"], cfg.l2r, cfg.l2r_levels)
    if "bk" in p:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    k = k.reshape(b, s, kv, dh)
    v = v.reshape(b, s, kv, dh)

    q = apply_rope(q, rope_positions, cfg.rope_theta, cfg.rope_mode, cfg.mrope_sections)
    k = apply_rope(k, rope_positions, cfg.rope_theta, cfg.rope_mode, cfg.mrope_sections)

    if mode == "decode":
        cache = update_kv_cache(cache, k, v, positions, quant=cfg.attn_l2r)
        out = decode_attention(
            q, cache.k, cache.v, cache.positions, positions[:, 0],
            window=window, scale=cfg.attn_scale, softcap=cfg.logit_softcap,
            l2r=cfg.attn_l2r, levels=cfg.attn_levels,
            early_exit=cfg.attn_early_exit, exit_tol=cfg.attn_exit_tol,
            k_planes=cache.k_planes, k_scale=cache.k_scale,
        )
    else:
        if mode == "prefill":
            # a plane-stacked cache fills incrementally here too: decode
            # steps after this prefill consume a ready operand
            cache = update_kv_cache(cache, k, v, positions,
                                    quant=cfg.attn_l2r)
        out = chunked_attention(
            q, k, v, causal=True, window=window, scale=cfg.attn_scale,
            softcap=cfg.logit_softcap,
            score_dtype=jnp.dtype(cfg.attn_score_dtype),
            head_shard=cfg.attn_head_shard,
            l2r=cfg.attn_l2r, levels=cfg.attn_levels,
        )
    out = hint(out.reshape(b, s, h * dh), None, None, "model")
    return dense(out, p["wo"], cfg.l2r, cfg.l2r_levels), cache


# ------------------------------------------------------------ layer dispatch
def _mixer_build(cfg: ModelConfig, kind: str) -> dict:
    if kind in ("global", "local"):
        return attn_build(cfg)
    if kind == "ssd":
        return ssm_build(cfg)
    if kind == "rec":
        return rglru_build(cfg)
    raise ValueError(kind)


def _ffn_build(cfg: ModelConfig, kind: str, layer_idx: int) -> dict:
    if kind == "moe":
        return moe_build(cfg)
    # deepseek-style MoE models use a wider hidden on their dense layers
    if cfg.n_experts and cfg.dense_d_ff:
        return mlp_build(cfg, d_ff=cfg.dense_d_ff)
    return mlp_build(cfg)


def layer_build(cfg: ModelConfig, kinds: tuple[str, str], layer_idx: int) -> dict:
    mixer, ffn = kinds
    out = {
        "mixer_norm": Param((cfg.d_model,), ("embed",), init="zeros"),
        "mixer": _mixer_build(cfg, mixer),
    }
    if ffn != "none":
        out["ffn_norm"] = Param((cfg.d_model,), ("embed",), init="zeros")
        out["ffn"] = _ffn_build(cfg, ffn, layer_idx)
    return out


def _mixer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype):
    if kind == "global":
        return init_kv_cache(batch, max_len, cfg.n_kv, cfg.head_dim, dtype,
                             quant=cfg.attn_l2r)
    if kind == "local":
        return init_kv_cache(batch, min(cfg.window, max_len), cfg.n_kv,
                             cfg.head_dim, dtype, quant=cfg.attn_l2r)
    if kind == "ssd":
        return init_ssm_state(cfg, batch)
    if kind == "rec":
        return init_rglru_state(cfg, batch)
    raise ValueError(kind)


def layer_apply(
    cfg: ModelConfig,
    params: dict,
    kinds: tuple[str, str],
    x: jax.Array,
    *,
    mode: str,
    rope_positions,
    positions,
    cache,
):
    """One (mixer + ffn) residual layer. Returns (x, new_cache, aux)."""
    mixer_kind, ffn_kind = kinds
    norm = layer_norm_fn(cfg)
    h = norm(x, params["mixer_norm"])
    if mixer_kind in ("global", "local"):
        window = cfg.window if mixer_kind == "local" else None
        mixed, new_cache = attn_apply(
            cfg, params["mixer"], h, mode=mode, rope_positions=rope_positions,
            positions=positions, cache=cache, window=window,
        )
    elif mixer_kind == "ssd":
        if mode == "decode":
            mixed, new_cache = ssm_decode(cfg, params["mixer"], h, cache)
        else:
            mixed, new_cache = ssm_apply(cfg, params["mixer"], h,
                                         cache if mode == "prefill" else None)
    elif mixer_kind == "rec":
        if mode == "decode":
            mixed, new_cache = rglru_decode(cfg, params["mixer"], h, cache)
        else:
            mixed, new_cache = rglru_apply(cfg, params["mixer"], h,
                                           cache if mode == "prefill" else None)
    else:
        raise ValueError(mixer_kind)
    x = x + mixed

    aux = jnp.zeros((), jnp.float32)
    if ffn_kind != "none":
        h = norm(x, params["ffn_norm"])
        if ffn_kind == "moe":
            out, aux = moe_apply(cfg, params["ffn"], h)
        else:
            out = mlp_apply(cfg, params["ffn"], h)
        x = x + out
    return x, new_cache, aux


def layer_norm_fn(cfg: ModelConfig) -> Callable:
    if cfg.use_layer_norm:
        # beta folded to zero-init gamma pair is overkill; whisper uses LN
        # with both; we store a single gamma and zero beta for simplicity.
        return lambda x, g: layer_norm(x, 1.0 + g, jnp.zeros_like(g), cfg.norm_eps)
    return lambda x, g: rms_norm(x, g, cfg.norm_eps)


# --------------------------------------------------------------- LM assembly
def lm_build(cfg: ModelConfig) -> dict:
    prefix, repeats, unit, suffix = cfg.block_grouping()
    params: dict[str, Any] = {
        "embed": Param((cfg.vocab, cfg.d_model), ("vocab", "embed"), init="embed"),
        "final_norm": Param((cfg.d_model,), ("embed",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        params["head"] = Param((cfg.d_model, cfg.vocab), ("embed", "vocab"), scale=0.02)

    li = 0
    pre = []
    for kk in prefix:
        pre.append(layer_build(cfg, kk, li))
        li += 1
    params["prefix"] = pre

    if repeats:
        unit_params = []
        for u_idx, kk in enumerate(unit):
            unit_params.append(layer_build(cfg, kk, li + u_idx))
        # stack: every leaf gets a leading "layers" axis of size `repeats`
        def stack_param(p: Param) -> Param:
            return Param((repeats, *p.shape), ("layers", *p.axes),
                         init=p.init, scale=p.scale, dtype=p.dtype)
        params["stack"] = jax.tree.map(
            stack_param, unit_params,
            is_leaf=lambda x: isinstance(x, Param),
        )
        li += repeats * len(unit)

    suf = []
    for kk in suffix:
        suf.append(layer_build(cfg, kk, li))
        li += 1
    params["suffix"] = suf
    return params


@dataclasses.dataclass
class LMState:
    """Serving state: caches grouped like the params + next position."""

    prefix: list
    stack: Any  # leaves have leading (repeats,) axis
    suffix: list
    pos: jax.Array  # (B,) next position to write


jax.tree_util.register_dataclass(
    LMState, data_fields=["prefix", "stack", "suffix", "pos"], meta_fields=[]
)


def init_lm_state(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> LMState:
    prefix, repeats, unit, suffix = cfg.block_grouping()
    mk = lambda kk: _mixer_cache(cfg, kk[0], batch, max_len, dtype)
    stack = None
    if repeats:
        unit_caches = [mk(kk) for kk in unit]
        stack = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *([unit_caches] * repeats),
        ) if repeats > 1 else jax.tree.map(lambda x: x[None], unit_caches)
    return LMState(
        prefix=[mk(kk) for kk in prefix],
        stack=stack,
        suffix=[mk(kk) for kk in suffix],
        pos=jnp.zeros((batch,), jnp.int32),
    )


def lm_forward(
    cfg: ModelConfig,
    params: dict,
    *,
    tokens: jax.Array | None = None,
    embeds: jax.Array | None = None,
    rope_positions: jax.Array | None = None,
    mode: str = "train",
    state: LMState | None = None,
    resid_shard: Callable[[jax.Array], jax.Array] = lambda x: x,
    remat: bool = False,
):
    """Backbone forward.

    Returns (hidden (B,S,d), new_state, aux_loss).  `tokens` xor `embeds`
    (modality-stub archs feed embeddings per the assignment).
    """
    prefix_k, repeats, unit, suffix_k = cfg.block_grouping()
    compute_dtype = jnp.dtype(cfg.compute_dtype)

    if embeds is None:
        x = params["embed"].astype(compute_dtype)[tokens]
    else:
        x = embeds.astype(compute_dtype)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)

    b, s = x.shape[:2]
    if state is not None:
        positions = state.pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if rope_positions is None:
        rope_positions = positions

    aux_total = jnp.zeros((), jnp.float32)

    def run_layer(x, lp, kinds, cache):
        return layer_apply(
            cfg, lp, kinds, x, mode=mode, rope_positions=rope_positions,
            positions=positions, cache=cache,
        )

    new_prefix = []
    for i, kk in enumerate(prefix_k):
        c = state.prefix[i] if state is not None else None
        x, c2, aux = run_layer(x, params["prefix"][i], kk, c)
        x = resid_shard(x)
        new_prefix.append(c2)
        aux_total += aux

    new_stack = None
    if repeats:
        # Caches ride the scan CARRY and are updated in place with
        # dynamic_update_index_in_dim: XLA aliases while-loop carries, so
        # decode/prefill never copies the full stacked KV cache (the
        # xs/ys formulation materialized a whole-cache copy per step —
        # 42% of baseline decode HBM traffic; EXPERIMENTS.md §Perf).
        def block(carry, lp):
            x, aux_acc, caches_all, blk_i = carry
            if caches_all is not None:
                caches = jax.tree.map(
                    lambda buf: jax.lax.dynamic_index_in_dim(
                        buf, blk_i, 0, keepdims=False),
                    caches_all)
            new_caches = []
            for u_idx, kk in enumerate(unit):
                x, c2, aux = run_layer(
                    x, lp[u_idx], kk,
                    caches[u_idx] if caches_all is not None else None)
                new_caches.append(c2)
                aux_acc = aux_acc + aux
            x = resid_shard(x)
            if caches_all is not None:
                caches_all = jax.tree.map(
                    lambda buf, new: jax.lax.dynamic_update_index_in_dim(
                        buf, new.astype(buf.dtype), blk_i, 0),
                    caches_all, new_caches)
            return (x, aux_acc, caches_all, blk_i + 1), None

        block_fn = jax.checkpoint(block) if remat else block
        caches_in = state.stack if state is not None else None
        (x, aux_total, new_stack, _), _ = jax.lax.scan(
            block_fn,
            (x, aux_total, caches_in, jnp.zeros((), jnp.int32)),
            params["stack"],
        )

    new_suffix = []
    for i, kk in enumerate(suffix_k):
        c = state.suffix[i] if state is not None else None
        x, c2, aux = run_layer(x, params["suffix"][i], kk, c)
        x = resid_shard(x)
        new_suffix.append(c2)
        aux_total += aux

    x = layer_norm_fn(cfg)(x, params["final_norm"])

    new_state = None
    if state is not None:
        new_state = LMState(
            prefix=new_prefix, stack=new_stack, suffix=new_suffix,
            pos=state.pos + s,
        )
    return x, new_state, aux_total


def logits_from_hidden(cfg: ModelConfig, params: dict, hidden: jax.Array) -> jax.Array:
    """LM head.  With an L2R config the head matmul runs through the
    digit-plane pipeline like every other matmul — which also makes it
    streamable level-by-level (serve/engine.py progressive decode commits
    tokens bit-identically to this full evaluation).  A ``head_q`` cache
    entry (serve/engine.py:prepare_params) skips the per-step head-weight
    quantization on serving paths."""
    if cfg.l2r is not None and "head_q" in params:
        return dense(hidden, params["head_q"], cfg.l2r, cfg.l2r_levels)
    if cfg.tie_embeddings:
        w = params["embed"].T
    else:
        w = params["head"]
    return dense(hidden, w.astype(hidden.dtype), cfg.l2r, cfg.l2r_levels)
