"""Feed-forward blocks: SwiGLU / GeGLU / GELU, with L2R-quantized matmuls
when the config enables the paper's technique."""

from __future__ import annotations

import jax

from .common import Param, dense
from .config import ModelConfig

__all__ = ["mlp_build", "mlp_apply"]


def mlp_build(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d_ff = d_ff if d_ff is not None else cfg.d_ff
    if cfg.ffn_kind in ("swiglu", "geglu"):
        return {
            "wi": Param((cfg.d_model, 2, d_ff), ("embed", None, "ffn")),
            "wo": Param((d_ff, cfg.d_model), ("ffn", "embed")),
        }
    return {
        "wi": Param((cfg.d_model, d_ff), ("embed", "ffn")),
        "wo": Param((d_ff, cfg.d_model), ("ffn", "embed")),
    }


def mlp_apply(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    from repro.sharding.ctx import hint

    if cfg.ffn_kind in ("swiglu", "geglu"):
        h = dense(x, params["wi"], cfg.l2r, cfg.l2r_levels)  # (..., 2, d_ff)
        gate, up = h[..., 0, :], h[..., 1, :]
        act = jax.nn.silu(gate) if cfg.ffn_kind == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jax.nn.gelu(dense(x, params["wi"], cfg.l2r, cfg.l2r_levels))
    # Megatron column->row parallelism: pin the hidden activation to the
    # model axis so GSPMD never "helpfully" all-gathers the weights (it
    # does exactly that for small decode batches — §Perf hillclimb C).
    h = hint(h, *([None] * (h.ndim - 1)), "model")
    return dense(h, params["wo"], cfg.l2r, cfg.l2r_levels)
