"""Parameter descriptors, initialization, norms and the dense primitive.

Models are written as pairs of pure functions:

    build(cfg)  -> pytree of Param descriptors (shape/dtype/logical axes)
    apply(cfg, params, ...) -> activations

The descriptor tree is materialized three ways:
  * materialize(tree, rng)      -> real arrays (training / CPU smoke tests)
  * abstract(tree)              -> jax.ShapeDtypeStruct (multi-pod dry-run:
                                   no allocation of 400B-parameter models)
  * partition_specs(tree,rules) -> PartitionSpec tree for pjit shardings.

Every matmul in the stack goes through :func:`dense`, which dispatches to
the paper's L2R digit-plane pipeline when the config carries a
QuantConfig — making the technique a first-class switch on all
architectures.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.l2r_gemm import l2r_dense
from repro.core.quant import QuantConfig, QuantizedWeights, quantize_weights
from repro.kernels.l2r_gemm.ops import l2r_matmul_f

__all__ = [
    "Param",
    "materialize",
    "abstract",
    "partition_specs",
    "dense",
    "quantize_tree",
    "rms_norm",
    "layer_norm",
    "count_params",
]


@dataclasses.dataclass(frozen=True)
class Param:
    """Declarative parameter: shape, logical axes, init recipe."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float | None = None  # stddev override; default fan-in
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_param(x) -> bool:
    return isinstance(x, Param)


def materialize(tree, rng: jax.Array, param_dtype=jnp.float32):
    """Instantiate real arrays for a descriptor tree."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_param)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for key, p in zip(keys, leaves):
        dtype = param_dtype if p.dtype == jnp.float32 else p.dtype
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, dtype))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, dtype))
        else:
            fan_in = p.shape[0] if len(p.shape) >= 2 else max(p.shape[-1], 1)
            if p.init == "embed":
                std = p.scale if p.scale is not None else 0.02
            else:
                std = p.scale if p.scale is not None else 1.0 / math.sqrt(fan_in)
            out.append((jax.random.normal(key, p.shape, jnp.float32) * std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def abstract(tree, param_dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins (dry-run: no device allocation)."""
    def f(p: Param):
        dtype = param_dtype if p.dtype == jnp.float32 else p.dtype
        return jax.ShapeDtypeStruct(p.shape, dtype)
    return jax.tree.map(f, tree, is_leaf=_is_param)


def partition_specs(tree, rules: dict[str, Any]):
    """Map logical axes -> mesh axes.  rules values: str | tuple | None."""
    def f(p: Param):
        return P(*(rules.get(a, None) if a is not None else None for a in p.axes))
    return jax.tree.map(f, tree, is_leaf=_is_param)


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=_is_param)
    return sum(math.prod(p.shape) for p in leaves)


def dense(
    x: jax.Array,
    w,
    l2r: QuantConfig | None = None,
    l2r_levels: int | None = None,
) -> jax.Array:
    """x @ w with optional L2R digit-plane arithmetic (the paper's unit).

    w may have >2 dims (e.g. fused qkv (d, 3, h*dh)); trailing dims are
    flattened for the contraction and restored after.

    w may also be pre-quantized (built ONCE at model load):
      * :class:`~repro.core.quant.QuantizedWeights` (quantize_tree /
        serve.engine.prepare_params) — the L2R weight cache.  With an
        ``l2r`` config the activations stream through the dispatched
        level-stacked digit-plane kernel against the cached int8 weights
        (no per-forward weight quantization in the trace); without one it
        is plain W8A8 integer dense.
      * a legacy {"q": int8, "scale"} record (quantize_desc/
        quantize_params, the checkpoint codec): W8A8 serving arithmetic.
    Weights stored in int8 halve the HBM weight traffic that dominates
    decode; the integer product is exactly what the L2R composite IPU
    computes digit-serially (bit equality proven in
    tests/test_kernel_l2r_gemm.py).
    """
    if isinstance(w, QuantizedWeights):
        trail = w.q.shape[1:]
        wq = w.q.reshape(w.q.shape[0], -1) if w.q.ndim > 2 else w.q
        ws = jnp.broadcast_to(w.scale, (1, *trail)).reshape(1, -1)
        if l2r is not None:
            planes = w.planes
            if planes is not None and planes.stack.ndim > 2:
                # flatten trailing output dims of the cached RHS stack the
                # same way as q (the contraction axis is leading, so the
                # plane layout is untouched)
                planes = dataclasses.replace(
                    planes, stack=planes.stack.reshape(
                        planes.stack.shape[0], -1), axis=-2)
            out = l2r_matmul_f(x, None, l2r, l2r_levels,
                               w_q=QuantizedWeights(wq, ws, planes))
            return out.reshape(*x.shape[:-1], *trail)
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        from repro.core.quant import quantize

        xq, xs = quantize(x2, QuantConfig(), axis=0)  # per-row act scales
        out = jax.lax.dot_general(
            xq, wq, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        out = out.astype(jnp.float32) * xs * ws
        return out.astype(x.dtype).reshape(*lead, *trail)
    if isinstance(w, dict) and "q" in w:
        wq, scale = w["q"], w["scale"]
        trail = wq.shape[1:]
        if wq.ndim > 2:
            wq = wq.reshape(wq.shape[0], -1)
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        from repro.core.quant import quantize

        xq, xs = quantize(x2, QuantConfig(), axis=0)  # per-row act scales
        out = jax.lax.dot_general(
            xq, wq, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        out = out.astype(jnp.float32) * xs * scale.reshape(()).astype(jnp.float32)
        return out.astype(x.dtype).reshape(*lead, *trail)
    if w.ndim > 2:
        out = dense(x, w.reshape(w.shape[0], -1), l2r, l2r_levels)
        return out.reshape(*x.shape[:-1], *w.shape[1:])
    if l2r is not None:
        # production L2R path: the backend-dispatched level-stacked kernel
        # (kernels/l2r_gemm), not the pure-jnp core pair loop
        return l2r_matmul_f(x, w, l2r, l2r_levels)
    return l2r_dense(x, w, l2r, l2r_levels)


def _quantizable(p: Param) -> bool:
    """Matmul weights eligible for int8 storage: 2D+ normal-init params
    that are not embedding/vocab tables (lookup + tied logits stay f32).
    Routed-expert stacks are excluded for now (their einsum path takes
    raw arrays; per-expert int8 goes through kernels/l2r_gemm instead)."""
    return (p.init == "normal" and len(p.shape) >= 2
            and "vocab" not in p.axes and "experts" not in p.axes)


def quantize_desc(desc_tree):
    """Descriptor transform: eligible Param -> {"q": int8, "scale": f32}.

    One scale per (stacked layer x) tensor; dense() dispatches on the
    record.  This is the serving-time storage format of the L2R pipeline:
    the Pallas kernel consumes exactly these int8 operands and streams
    their digit planes MSDF in VMEM.
    """
    def f(p: Param):
        if not _quantizable(p):
            return p
        stacked = p.axes and p.axes[0] == "layers"
        sshape = (p.shape[0],) + (1,) * (len(p.shape) - 1) if stacked \
            else (1,) * len(p.shape)
        saxes = ("layers",) + (None,) * (len(p.shape) - 1) if stacked \
            else (None,) * len(p.shape)
        return {
            "q": Param(p.shape, p.axes, init=p.init, scale=p.scale,
                       dtype=jnp.int8),
            "scale": Param(sshape, saxes, init="ones"),
        }
    return jax.tree.map(f, desc_tree, is_leaf=_is_param)


def quantize_params(desc_tree, params):
    """Materialized f32 params -> int8 records matching quantize_desc."""
    from repro.core.quant import QuantConfig, quantize

    def f(p: Param, w):
        if not _quantizable(p):
            return w
        wf = w.astype(jnp.float32)
        stacked = p.axes and p.axes[0] == "layers"
        if stacked:  # one scale per stacked layer
            amax = jnp.max(jnp.abs(wf), axis=tuple(range(1, wf.ndim)),
                           keepdims=True)
        else:
            amax = jnp.max(jnp.abs(wf)).reshape((1,) * wf.ndim)
        scale = jnp.maximum(amax, 1e-30) / 127.0
        q = jnp.clip(jnp.round(wf / scale), -127, 127)
        return {"q": q.astype(jnp.int8), "scale": scale}
    return jax.tree.map(f, desc_tree, params, is_leaf=_is_param)


def quantize_tree(desc_tree, params, cfg: QuantConfig = QuantConfig(),
                  prestack: bool = False):
    """Materialized f32 params -> :class:`QuantizedWeights` leaves.

    The load-time L2R weight cache for full model trees: every eligible
    matmul weight (same eligibility as quantize_desc) is quantized ONCE,
    per out-channel (and per stacked layer), so serving traces carry no
    weight quantization ops.  dense() consumes the records directly.

    ``prestack=True`` additionally caches each weight's reversed RHS
    digit-plane stack (core/quant.py:PlaneOperands, contraction axis 0 —
    axis 1 for stacked-layer weights, whose leading layer axis the
    forward scan strips) so the serving traces carry no weight plane
    extraction either: D x the int8 weight bytes buys
    extract-once-per-process operands.
    """
    def f(p: Param, w):
        if not _quantizable(p):
            return w
        stacked = p.axes and p.axes[0] == "layers"
        axes = (0, -1) if stacked else (-1,)
        return quantize_weights(w, cfg, channel_axes=axes, prestack=prestack,
                                plane_axis=1 if stacked else 0)
    return jax.tree.map(f, desc_tree, params, is_leaf=_is_param)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return out.astype(dtype)
