"""Synthetic decisive-margin prototype head.

Early-exit demos and benchmarks need a classifier whose logit margins
clear the MSDF tail bound mid-stream — an untrained random head has
exchangeable logits (top-1 margins ~0, nothing ever exits early), while
a trained classifier operates in the decisive-margin regime.  The
construction here reproduces that regime synthetically: class c's weight
column is the unit-normalized prototype vector of class c, and queries
are noisy copies of prototypes, so the true-class logit dominates by a
margin set by the noise level.  Shared by benchmarks/run.py and
examples/progressive_precision.py so the two stay in sync.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.quant import QuantConfig, quantize, quantize_weights

__all__ = ["prototype_head"]


def prototype_head(rng: np.random.Generator, k: int, classes: int,
                   rows: int, noise: float = 0.05,
                   cfg: QuantConfig = QuantConfig()):
    """Quantized operands of a decisive-margin head matmul.

    Returns ``(xq, xs, w_q, labels)``: per-row-quantized query
    activations ``xq (rows, k)`` with scales ``xs``, the quantized
    unit-norm prototype weights ``w_q`` (``(k, classes)`` +
    per-out-channel scale), and the true class of each query row.
    """
    proto = rng.standard_normal((classes, k)).astype(np.float32)
    labels = rng.integers(0, classes, rows)
    x = proto[labels] + noise * rng.standard_normal(
        (rows, k)).astype(np.float32)
    xq, xs = quantize(jnp.asarray(x), cfg, axis=0)
    w_q = quantize_weights(jnp.asarray(
        proto.T / np.linalg.norm(proto.T, axis=0, keepdims=True)), cfg)
    return xq, xs, w_q, labels
