"""Static sync-cost certification of the sharded level walks.

The sharding auditor (analysis/sharding.py) verifies WHAT the consensus
walk reduces; this module prices it.  From the verified jaxpr schedule
— one :class:`CollectiveRecord` per traced cross-shard reduction — and
the entry's declared mesh, fold launch/roofline.py's ring models into a
per-(entry x mesh) **sync-cost certificate**:

* static and per-walk collective counts (per-level records fire once
  per level of the stream, per-walk records once),
* bytes-on-wire per chip under the ring all-reduce model (pmax / pmin /
  psum all lower to all-reduce: ``2 (n-1)/n * S`` for a group of n),
* predicted wall-clock share against the compute/memory roofline terms
  of the compiled module (optional — needs the HLO text),
* the projected **sync-every-k** savings table for k in {1,2,4,8}:
  ROADMAP item 5's relaxation decides locally and reduces every k-th
  level, so per-walk sync count drops from ``n_levels`` to
  ``ceil(n_levels / k)`` firings of the per-level schedule.

The certificate is emitted into the ``l2r_lint --json`` report and
gated in CI by the per-entry collective-count budget on the
:class:`~repro.analysis.sharding.ShardingContract` — a new collective
in the schedule is a build failure, not a silent perf regression.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.launch.hlo_analysis import ring_wire_bytes
from repro.launch.roofline import ICI_LINK_BW, LINKS_PER_CHIP, roofline_terms

__all__ = ["CollectiveRecord", "sync_cost_certificate"]


@dataclasses.dataclass(frozen=True)
class CollectiveRecord:
    """One cross-shard reduction traced from a walk jaxpr.

    ``in_loop`` separates the per-level schedule (inside the level
    scan/while) from the per-walk finalize reductions; ``tag`` is the
    ``l2r_coll_*`` named-scope tag (core/policy.py) matching the record
    back to its declaration site; ``taint`` is the merged exactness
    taint of the reduced operands (``"int"`` / ``"f32exact"`` /
    ``"deq"`` / None — see analysis/sharding.py)."""

    prim: str                 # psum | pmax | pmin
    axes: tuple               # mesh axis names reduced over
    dtype: str                # numpy dtype name of the reduced value
    shape: tuple              # per-shard shape of the reduced value
    in_loop: bool             # inside the level loop (per-level) or not
    tag: str = ""             # l2r_coll_* named-scope tag ("" = untagged)
    taint: str | None = None  # merged operand taint at the reduction

    def result_bytes(self) -> float:
        n = 1
        for d in self.shape:
            n *= int(d)
        return float(n) * np.dtype(self.dtype).itemsize

    def wire_bytes(self, axis_sizes: dict) -> float:
        """Ring all-reduce bytes-on-wire per chip for this reduction
        over its mesh axes (psum/pmax/pmin all lower to all-reduce)."""
        group = 1
        for a in self.axes:
            group *= int(axis_sizes.get(a, 1))
        return ring_wire_bytes("all-reduce", self.result_bytes(), group)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["axes"] = list(self.axes)
        d["shape"] = [int(x) for x in self.shape]
        return d


def _bucket(records: list, axis_sizes: dict) -> dict:
    by: dict[str, int] = {}
    for r in records:
        key = f"{r.prim}[{r.tag or 'untagged'}]"
        by[key] = by.get(key, 0) + 1
    return {
        "count": len(records),
        "wire_bytes": sum(r.wire_bytes(axis_sizes) for r in records),
        "by_reduction": by,
    }


def sync_cost_certificate(records: list, mesh_axes: tuple, n_levels: int,
                          *, ks: tuple = (1, 2, 4, 8),
                          hlo_text: str | None = None) -> dict:
    """Fold a verified schedule into the per-(entry x mesh) certificate.

    ``records`` are the :class:`CollectiveRecord`s of one walk trace,
    ``mesh_axes`` the contract's ``(name, size)`` pairs, ``n_levels``
    the stream depth the per-level schedule fires at.  With
    ``hlo_text`` the certificate also carries the roofline terms of the
    compiled module and the collective term's wall-clock share."""
    axis_sizes = dict(mesh_axes)
    chips = 1
    for _, s in mesh_axes:
        chips *= int(s)
    per_level = [r for r in records if r.in_loop]
    per_walk = [r for r in records if not r.in_loop]
    lvl = _bucket(per_level, axis_sizes)
    wlk = _bucket(per_walk, axis_sizes)

    def totals(sync_levels: int) -> tuple[int, float, float]:
        count = sync_levels * lvl["count"] + wlk["count"]
        wire = sync_levels * lvl["wire_bytes"] + wlk["wire_bytes"]
        return count, wire, wire / (LINKS_PER_CHIP * ICI_LINK_BW)

    count1, wire1, secs1 = totals(n_levels)
    cert = {
        "mesh": {a: int(s) for a, s in mesh_axes},
        "chips": chips,
        "n_levels": n_levels,
        "per_level": lvl,
        "per_walk": wlk,
        "collectives_per_walk": count1,
        "wire_bytes_per_walk": wire1,
        "collective_s": secs1,
        "sync_every_k": [],
    }
    for k in ks:
        sync_levels = math.ceil(n_levels / k)
        count, wire, secs = totals(sync_levels)
        cert["sync_every_k"].append({
            "k": int(k), "sync_levels": sync_levels,
            "collectives": count, "wire_bytes": wire, "collective_s": secs,
            "savings_frac": 0.0 if secs1 <= 0 else 1.0 - secs / secs1,
        })
    if hlo_text is not None:
        from repro.launch import hlo_analysis

        ana = hlo_analysis.analyze(hlo_text)
        # wire bytes from the VERIFIED schedule (n_levels x per-level +
        # finalize), not the raw HLO census — the certificate prices
        # what the contract declares
        rf = roofline_terms(ana["flops"], ana["bytes"], wire1, chips)
        serial = rf.compute_s + rf.memory_s + rf.collective_s
        cert["roofline"] = rf.asdict()
        cert["collective_share"] = (
            rf.collective_s / serial if serial > 0 else 0.0)
    return cert
