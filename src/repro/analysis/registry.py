"""Registry of claimed-exact entry points.

The exactness audit (analysis/exactness.py) is only as good as its
coverage: a new schedule that never declares itself is never linted.
This registry is the declaration point — every walk that claims the
repo's bit-exactness contract registers an :class:`ExactEntry` binding

* a **build** thunk returning ``(fn, args)`` — a traceable callable and
  small representative operands (tracing is shape-driven, so tiny shapes
  certify the same graph structure the production shapes run),
* an :class:`~repro.analysis.exactness.ExactnessContract` describing
  what the entry promises (digit config, contraction length, whether
  the guarded f32 fast path may appear, taint vs kernel-int mode).

``tools/l2r_lint.py`` runs every registered entry through all passes;
adding a schedule without registering it here is the reviewable gap the
ROADMAP's invariant-registry section calls out.

Out-of-tree schedules register with::

    from repro.analysis import registry
    registry.register(registry.ExactEntry(
        name="gemm/my-schedule/jnp",
        build=lambda: (my_walk_fn, (aq, bq)),
        contract=ExactnessContract(n_bits=8, log2_radix=2, k=K),
    ))

shard_mapped entries additionally declare a
:class:`~repro.analysis.sharding.ShardingContract` (mesh shape, the
exact per-level/per-walk reduction schedule with its ``l2r_coll`` tags,
expected input PartitionSpecs, the static collective-count budget) —
the sharding pass lowers them under the declared mesh and verifies the
partitioned module.  ``contract=None`` marks a sharding-only entry (a
full-model trace whose backbone is not itself a claimed-exact walk);
the exactness/overflow passes skip those.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import numpy as np

from repro.analysis.exactness import ExactnessContract

__all__ = ["ExactEntry", "register", "iter_entries", "default_entries"]


@dataclasses.dataclass(frozen=True)
class ExactEntry:
    name: str
    build: Callable[[], tuple]  # () -> (fn, args)
    contract: ExactnessContract | None = None  # None: sharding-only entry
    tags: tuple = ()
    skip: str | None = None  # present-but-unavailable (e.g. needs devices)
    sharding: object | None = None  # ShardingContract for shard_mapped entries


_EXTRA: list[ExactEntry] = []


def register(entry: ExactEntry) -> ExactEntry:
    """Declare an additional claimed-exact entry point (idempotent per
    name: re-registration replaces)."""
    _EXTRA[:] = [e for e in _EXTRA if e.name != entry.name]
    _EXTRA.append(entry)
    return entry


# ------------------------------------------------------------- builders
def _gemm_operands(m=4, k=24, n=16, seed=0):
    rng = np.random.default_rng(seed)
    aq = rng.integers(-128, 128, (m, k)).astype(np.int8)
    bq = rng.integers(-128, 128, (k, n)).astype(np.int8)
    return aq, bq


def _attn_operands(b=1, q=2, kv=1, g=2, dh=8, s=5, seed=1):
    rng = np.random.default_rng(seed)
    qq = rng.integers(-128, 128, (b, q, kv, g, dh)).astype(np.int8)
    kq = rng.integers(-128, 128, (b, s, kv, dh)).astype(np.int8)
    return qq, kq


def _head_operands(m=4, k=16, n=12, seed=2):
    rng = np.random.default_rng(seed)
    xq = rng.integers(-128, 128, (m, k)).astype(np.int8)
    wq = rng.integers(-128, 128, (k, n)).astype(np.int8)
    xs = np.abs(rng.standard_normal((m, 1))).astype(np.float32) + 0.1
    ws = np.abs(rng.standard_normal((1, n))).astype(np.float32) + 0.1
    return xq, wq, xs, ws


def _gemm_entry(schedule: str, backend: str, early_exit: bool = False,
                levels: int | None = None, mode: str = "taint"):
    name = f"gemm/{schedule}{'-while' if early_exit else ''}/{backend}"
    if levels is not None:
        name += f"/levels-{levels}"

    def build():
        from repro.kernels.l2r_gemm.ops import l2r_gemm
        aq, bq = _gemm_operands()
        fn = functools.partial(l2r_gemm, n_bits=8, log2_radix=2,
                               levels=levels, schedule=schedule,
                               backend=backend, early_exit=early_exit)
        return fn, (aq, bq)

    return ExactEntry(
        name=name, build=build, tags=("gemm", backend),
        contract=ExactnessContract(n_bits=8, log2_radix=2, k=24,
                                   levels=levels, mode=mode))


def _attn_entry(kind: str):
    def build():
        from repro.core import l2r_attention as la
        fn = {"stacked": la.attn_scores_stacked,
              "streaming-scan": la.attn_scores_streaming_scan,
              "streaming-while": la.attn_scores_streaming_while}[kind]
        return fn, _attn_operands()

    return ExactEntry(
        name=f"attn/{kind}", build=build, tags=("attention",),
        contract=ExactnessContract(n_bits=8, log2_radix=2, k=8))


def _head_entry(early_exit: bool):
    def build():
        from repro.core.progressive import streaming_argmax
        fn = functools.partial(streaming_argmax, early_exit=early_exit)
        return fn, _head_operands()

    return ExactEntry(
        name=f"head/streaming-{'while' if early_exit else 'scan'}",
        build=build, tags=("head",),
        contract=ExactnessContract(n_bits=8, log2_radix=2, k=16))


def _mesh_shape() -> tuple[int, int]:
    """(data, model) of the audit mesh this host can carry — the same
    adaptive split every sharded builder below uses, so build and
    contract always agree."""
    n_dev = len(jax.devices())
    model = 4 if n_dev % 4 == 0 and n_dev > 4 else 2
    return max(n_dev // model, 1), model


def _local_mesh(data: int, model: int):
    from jax.sharding import Mesh
    devs = np.array(jax.devices())[:data * model]
    return Mesh(devs.reshape(data, model), ("data", "model"))


def _consensus_contract(data: int, model: int, early_exit: bool):
    """The consensus walk's declared schedule: per level the decision
    triple reduced over ``model`` as 4 pmax (abs-max envelope, global
    top, winner lower bound, runner-up upper bound) + 1 pmin (first-
    occurrence index tie-break), plus the early-exit consensus psum over
    the data axes; per walk the finalize fallback's pmax/pmin pair."""
    from repro.analysis.sharding import ReductionSpec, ShardingContract
    from repro.core.policy import (COLL_TAG_CONSENSUS, COLL_TAG_MAX,
                                   COLL_TAG_MIN)

    per_level = (ReductionSpec("pmax", 4, COLL_TAG_MAX),
                 ReductionSpec("pmin", 1, COLL_TAG_MIN))
    if early_exit:
        per_level += (ReductionSpec("psum", 1, COLL_TAG_CONSENSUS),)
    return ShardingContract(
        mesh_axes=(("data", data), ("model", model)),
        per_level=per_level,
        per_walk=(ReductionSpec("pmax", 1, COLL_TAG_MAX),
                  ReductionSpec("pmin", 1, COLL_TAG_MIN)),
        in_specs=(("data", None), (None, "model"),
                  ("data", None), (None, "model")),
        n_levels=7)  # n_bits=8, radix-4: 2D-1 levels


def _sharded_entry(early_exit: bool = False):
    n_dev = len(jax.devices())
    data, model = _mesh_shape()
    skip = None if n_dev >= 2 else \
        f"sharded consensus walk needs >= 2 devices (have {n_dev})"

    def build():
        from repro.core.progressive import streaming_argmax
        mesh = _local_mesh(data, model)
        fn = functools.partial(streaming_argmax, mesh=mesh,
                               early_exit=early_exit)
        return fn, _head_operands(m=data * 2, n=model * 3)

    return ExactEntry(
        name="head/sharded-consensus" + ("-while" if early_exit else ""),
        build=build, tags=("head", "sharded"), skip=skip,
        contract=ExactnessContract(n_bits=8, log2_radix=2, k=16),
        sharding=_consensus_contract(data, model, early_exit))


def _sharded_cache_entry():
    """The sharded quantized-weight cache: building a vocab-sharded
    plane stack is slicing, never communication — its partitioned
    module must contain ZERO collectives (budget 0)."""
    n_dev = len(jax.devices())
    data, model = _mesh_shape()
    skip = None if n_dev >= 2 else \
        f"sharded weight cache needs >= 2 devices (have {n_dev})"

    def build():
        from repro.core.quant import QuantConfig, quantize_weights
        mesh = _local_mesh(data, model)
        cfg = QuantConfig(n_bits=8, log2_radix=2)

        def cache(w):
            qw = quantize_weights(w, cfg, prestack=True, window_pad=True,
                                  shard=(None, "model"), mesh=mesh)
            return qw.q, qw.scale, qw.planes.stack

        rng = np.random.default_rng(3)
        w = rng.standard_normal((16, model * 3)).astype(np.float32)
        return cache, (w,)

    from repro.analysis.sharding import ShardingContract
    return ExactEntry(
        name="cache/sharded-weights", build=build,
        tags=("cache", "sharded"), skip=skip,
        # sharding-only: the quantizer consumes a FLOAT weight (taint
        # starts at its int8 output), so the forward-taint exactness
        # pass has nothing to say about this entry
        contract=None,
        sharding=ShardingContract(
            mesh_axes=(("data", data), ("model", model)),
            in_specs=(None,), n_levels=1, max_collectives=0))


def _sharded_decode_entry():
    """The mesh-placed replicated-backbone decode trace: the full smoke
    LM decode step with ``backbone_hints=False`` (the PR 5 fix) — its
    partitioned module must contain EXACTLY the head consensus walk's
    reductions and nothing else.  Sharding-only (``contract=None``):
    the backbone is not itself a claimed-exact walk."""
    n_dev = len(jax.devices())
    data, model = _mesh_shape()
    skip = None if n_dev >= 2 else \
        f"sharded decode trace needs >= 2 devices (have {n_dev})"

    def build():
        from repro.configs import get_smoke
        from repro.core.quant import QuantConfig
        from repro.models.common import materialize
        from repro.models.transformer import init_lm_state, lm_build
        from repro.serve.engine import make_decode_step, prepare_params

        cfg = dataclasses.replace(get_smoke("smollm-135m"),
                                  l2r=QuantConfig())
        params = prepare_params(cfg, materialize(lm_build(cfg),
                                                 jax.random.PRNGKey(0)))
        mesh = _local_mesh(data, model)
        step = make_decode_step(cfg, progressive=True,
                                backbone_hints=False, mesh=mesh)
        batch = data * 2
        state = init_lm_state(cfg, batch, 32)
        toks = np.zeros((batch, 1), np.int32)
        return step, (params, state, toks)

    contract = _consensus_contract(data, model, early_exit=False)
    contract = dataclasses.replace(contract, in_specs=())  # params pytree
    return ExactEntry(
        name="serve/sharded-decode-backbone", build=build,
        tags=("serve", "sharded"), skip=skip,
        contract=None, sharding=contract)


def default_entries() -> list[ExactEntry]:
    """The in-tree claimed-exact walks: head + attention, all three
    schedules, across the backends available on this host."""
    entries = [
        _gemm_entry("stacked", "jnp"),
        _gemm_entry("pairs", "jnp"),
        _gemm_entry("streaming", "jnp"),
        _gemm_entry("streaming", "jnp", early_exit=True),
        _gemm_entry("stacked", "jnp", levels=3),
        _gemm_entry("stacked", "pallas-interpret", mode="kernel-int"),
        _gemm_entry("streaming", "pallas-interpret", mode="kernel-int"),
        _attn_entry("stacked"),
        _attn_entry("streaming-scan"),
        _attn_entry("streaming-while"),
        _head_entry(early_exit=False),
        _head_entry(early_exit=True),
        _sharded_entry(),
        _sharded_entry(early_exit=True),
        _sharded_cache_entry(),
        _sharded_decode_entry(),
    ]
    if jax.default_backend() == "tpu":
        entries.insert(6, _gemm_entry("stacked", "pallas-tpu",
                                      mode="kernel-int"))
    return entries


def iter_entries(tags: tuple | None = None) -> list[ExactEntry]:
    out = default_entries() + list(_EXTRA)
    if tags:
        out = [e for e in out if set(tags) & set(e.tags)]
    return out
