"""Registry of claimed-exact entry points.

The exactness audit (analysis/exactness.py) is only as good as its
coverage: a new schedule that never declares itself is never linted.
This registry is the declaration point — every walk that claims the
repo's bit-exactness contract registers an :class:`ExactEntry` binding

* a **build** thunk returning ``(fn, args)`` — a traceable callable and
  small representative operands (tracing is shape-driven, so tiny shapes
  certify the same graph structure the production shapes run),
* an :class:`~repro.analysis.exactness.ExactnessContract` describing
  what the entry promises (digit config, contraction length, whether
  the guarded f32 fast path may appear, taint vs kernel-int mode).

``tools/l2r_lint.py`` runs every registered entry through all passes;
adding a schedule without registering it here is the reviewable gap the
ROADMAP's invariant-registry section calls out.

Out-of-tree schedules register with::

    from repro.analysis import registry
    registry.register(registry.ExactEntry(
        name="gemm/my-schedule/jnp",
        build=lambda: (my_walk_fn, (aq, bq)),
        contract=ExactnessContract(n_bits=8, log2_radix=2, k=K),
    ))
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import numpy as np

from repro.analysis.exactness import ExactnessContract

__all__ = ["ExactEntry", "register", "iter_entries", "default_entries"]


@dataclasses.dataclass(frozen=True)
class ExactEntry:
    name: str
    build: Callable[[], tuple]  # () -> (fn, args)
    contract: ExactnessContract
    tags: tuple = ()
    skip: str | None = None  # present-but-unavailable (e.g. needs devices)


_EXTRA: list[ExactEntry] = []


def register(entry: ExactEntry) -> ExactEntry:
    """Declare an additional claimed-exact entry point (idempotent per
    name: re-registration replaces)."""
    _EXTRA[:] = [e for e in _EXTRA if e.name != entry.name]
    _EXTRA.append(entry)
    return entry


# ------------------------------------------------------------- builders
def _gemm_operands(m=4, k=24, n=16, seed=0):
    rng = np.random.default_rng(seed)
    aq = rng.integers(-128, 128, (m, k)).astype(np.int8)
    bq = rng.integers(-128, 128, (k, n)).astype(np.int8)
    return aq, bq


def _attn_operands(b=1, q=2, kv=1, g=2, dh=8, s=5, seed=1):
    rng = np.random.default_rng(seed)
    qq = rng.integers(-128, 128, (b, q, kv, g, dh)).astype(np.int8)
    kq = rng.integers(-128, 128, (b, s, kv, dh)).astype(np.int8)
    return qq, kq


def _head_operands(m=4, k=16, n=12, seed=2):
    rng = np.random.default_rng(seed)
    xq = rng.integers(-128, 128, (m, k)).astype(np.int8)
    wq = rng.integers(-128, 128, (k, n)).astype(np.int8)
    xs = np.abs(rng.standard_normal((m, 1))).astype(np.float32) + 0.1
    ws = np.abs(rng.standard_normal((1, n))).astype(np.float32) + 0.1
    return xq, wq, xs, ws


def _gemm_entry(schedule: str, backend: str, early_exit: bool = False,
                levels: int | None = None, mode: str = "taint"):
    name = f"gemm/{schedule}{'-while' if early_exit else ''}/{backend}"
    if levels is not None:
        name += f"/levels-{levels}"

    def build():
        from repro.kernels.l2r_gemm.ops import l2r_gemm
        aq, bq = _gemm_operands()
        fn = functools.partial(l2r_gemm, n_bits=8, log2_radix=2,
                               levels=levels, schedule=schedule,
                               backend=backend, early_exit=early_exit)
        return fn, (aq, bq)

    return ExactEntry(
        name=name, build=build, tags=("gemm", backend),
        contract=ExactnessContract(n_bits=8, log2_radix=2, k=24,
                                   levels=levels, mode=mode))


def _attn_entry(kind: str):
    def build():
        from repro.core import l2r_attention as la
        fn = {"stacked": la.attn_scores_stacked,
              "streaming-scan": la.attn_scores_streaming_scan,
              "streaming-while": la.attn_scores_streaming_while}[kind]
        return fn, _attn_operands()

    return ExactEntry(
        name=f"attn/{kind}", build=build, tags=("attention",),
        contract=ExactnessContract(n_bits=8, log2_radix=2, k=8))


def _head_entry(early_exit: bool):
    def build():
        from repro.core.progressive import streaming_argmax
        fn = functools.partial(streaming_argmax, early_exit=early_exit)
        return fn, _head_operands()

    return ExactEntry(
        name=f"head/streaming-{'while' if early_exit else 'scan'}",
        build=build, tags=("head",),
        contract=ExactnessContract(n_bits=8, log2_radix=2, k=16))


def _sharded_entry():
    n_dev = len(jax.devices())
    skip = None if n_dev >= 2 else \
        f"sharded consensus walk needs >= 2 devices (have {n_dev})"

    def build():
        from jax.sharding import Mesh

        from repro.core.progressive import streaming_argmax
        devs = np.array(jax.devices())
        model = 4 if devs.size % 4 == 0 and devs.size > 4 else 2
        mesh = Mesh(devs.reshape(-1, model), ("data", "model"))
        fn = functools.partial(streaming_argmax, mesh=mesh)
        return fn, _head_operands(m=devs.size // model * 2, n=model * 3)

    return ExactEntry(
        name="head/sharded-consensus", build=build,
        tags=("head", "sharded"), skip=skip,
        contract=ExactnessContract(n_bits=8, log2_radix=2, k=16))


def default_entries() -> list[ExactEntry]:
    """The in-tree claimed-exact walks: head + attention, all three
    schedules, across the backends available on this host."""
    entries = [
        _gemm_entry("stacked", "jnp"),
        _gemm_entry("pairs", "jnp"),
        _gemm_entry("streaming", "jnp"),
        _gemm_entry("streaming", "jnp", early_exit=True),
        _gemm_entry("stacked", "jnp", levels=3),
        _gemm_entry("stacked", "pallas-interpret", mode="kernel-int"),
        _gemm_entry("streaming", "pallas-interpret", mode="kernel-int"),
        _attn_entry("stacked"),
        _attn_entry("streaming-scan"),
        _attn_entry("streaming-while"),
        _head_entry(early_exit=False),
        _head_entry(early_exit=True),
        _sharded_entry(),
    ]
    if jax.default_backend() == "tpu":
        entries.insert(6, _gemm_entry("stacked", "pallas-tpu",
                                      mode="kernel-int"))
    return entries


def iter_entries(tags: tuple | None = None) -> list[ExactEntry]:
    out = default_entries() + list(_EXTRA)
    if tags:
        out = [e for e in out if set(tags) & set(e.tags)]
    return out
