"""Static exactness audit of the L2R walk jaxprs (and compiled HLO).

The repo's bit-exactness claims (streaming prefix == truncated stacked,
committed token == full depth, shard consensus == replicated walk) all
reduce to one structural invariant: **between digit-plane extraction and
the level accumulator, every op is exact**.  Concretely, on the claimed-
exact path

* every op is integer-typed (or the guarded f32 BLAS fast path below),
* every integer ``dot_general`` accumulates in int32
  (``preferred_element_type=int32`` — never the operand dtype),
* no float op touches a value derived from the digit planes before the
  int32 accumulator is dequantized (``convert int32 -> float`` is the
  legitimate region exit),
* the only float excursion allowed is the guarded BLAS fast path
  (core/l2r_gemm.py:_f32_dot_exact): ``convert int8 -> f32`` feeding a
  ``dot_general`` with ``precision=HIGHEST`` whose products fit the f32
  mantissa, converted straight back to int32 — bit-exact by the guard.

This module checks the invariant *statically* on the jaxpr, by forward
taint propagation from integer sources through the whole graph
(recursing into scan/while/cond/pjit sub-jaxprs), before any tensor
flows.  It is the static analogue of the parity tests — the class of
bug it catches is the PR 5 GSPMD float-reassociation regression, where
a float op silently appeared on a claimed-exact path.

Taint lattice per value: ``None`` (not derived from the digit stream),
``"int"`` (on the exact integer path), ``"f32exact"`` (inside the
guarded fast path — only layout ops, the HIGHEST-precision dot, and the
convert back to int32 are allowed).  Exits: ``convert int32 -> float``
(dequantization), comparisons (bool decisions), and argmax/argmin
(index decisions) end the tainted region.

:func:`audit_hlo_text` re-checks the *compiled* artifact with the
``launch/hlo_analysis.py`` parser: after XLA/GSPMD rewrites, any float
``dot``/``convolution`` in the module must still be the guarded f32
fast path (f32 only, and only when the contract's guard holds).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import numpy as np
from jax.extend import core as jex_core

from repro.core.l2r_gemm import _f32_dot_exact
from repro.core.online import msdf_level_slices

__all__ = [
    "ExactnessContract",
    "Violation",
    "ExactnessReport",
    "f32_guard_holds",
    "audit_jaxpr",
    "audit_exactness",
    "audit_hlo_text",
]

_HIGHEST = jax.lax.Precision.HIGHEST

#: value-preserving / value-selecting ops: the only primitives (besides
#: the guarded dot and the converts) allowed to touch fast-path f32
#: values — they move digits around without rounding.
_LAYOUT_PRIMS = {
    "slice", "dynamic_slice", "reshape", "transpose", "broadcast_in_dim",
    "concatenate", "pad", "squeeze", "expand_dims", "rev", "gather",
    "copy", "stop_gradient", "select_n",
}

#: index/decision reductions: outputs are positions, not accumulator
#: values — taint does not flow through them.
_DECISION_PRIMS = {"argmax", "argmin", "reduce_and", "reduce_or"}


def f32_guard_holds(n_bits: int, log2_radix: int, k: int,
                    levels: int | None = None) -> bool:
    """Recompute the BLAS fast-path guard for a walk's widest level."""
    d = n_bits // log2_radix
    slices = msdf_level_slices(d, levels)
    if not slices:
        return True
    width = max(hi - lo + 1 for _, lo, hi in slices)
    return _f32_dot_exact(k, width, log2_radix)


@dataclasses.dataclass(frozen=True)
class ExactnessContract:
    """What a claimed-exact entry point promises.

    ``mode="taint"`` is the full forward-taint audit (jnp walks);
    ``mode="kernel-int"`` is the stricter all-integer scan used for the
    Pallas kernels, whose bodies must not contain ANY float op (their
    dataflow never leaves the integer domain).  ``allow_f32`` permits
    the guarded BLAS fast path — the auditor still independently
    recomputes the guard from (k, levels) and rejects f32 dots when it
    does not hold.
    """

    n_bits: int = 8
    log2_radix: int = 2
    k: int = 0
    levels: int | None = None
    allow_f32: bool = True
    mode: str = "taint"  # taint | kernel-int

    @property
    def f32_ok(self) -> bool:
        return self.allow_f32 and f32_guard_holds(
            self.n_bits, self.log2_radix, self.k, self.levels)


@dataclasses.dataclass(frozen=True)
class Violation:
    entry: str
    primitive: str
    reason: str
    detail: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ExactnessReport:
    entry: str
    violations: list
    eqns_checked: int = 0
    tainted_eqns: int = 0
    int_dots: int = 0
    f32_fastpath_dots: int = 0

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> dict:
        return {
            "entry": self.entry, "ok": self.ok,
            "eqns_checked": self.eqns_checked,
            "tainted_eqns": self.tainted_eqns,
            "int_dots": self.int_dots,
            "f32_fastpath_dots": self.f32_fastpath_dots,
            "violations": [v.to_json() for v in self.violations],
        }


# ------------------------------------------------------------------ util
def _aval_dtype(aval):
    aval = getattr(aval, "inner_aval", aval)  # pallas Ref
    return getattr(aval, "dtype", None)


def _is_float(dt) -> bool:
    return dt is not None and np.issubdtype(dt, np.floating)


def _is_int(dt) -> bool:
    return dt is not None and np.issubdtype(dt, np.integer)


# "deq" is the sharding auditor's provenance extension (see
# analysis/sharding.py): floats downstream of the legitimate int32
# dequantization exit.  The base auditor never produces it — its
# dequant_taint() hook returns None — but the lattice knows the rank so
# subclass merges stay monotone.
_RANKS = {"int": 3, "f32exact": 2, "deq": 1, None: 0}


def _rank(t):
    return _RANKS[t]


def _merge(a, b):
    return a if _rank(a) >= _rank(b) else b


def _sub_closed(params, *keys):
    for key in keys:
        sub = params.get(key)
        if sub is not None:
            return sub
    return None


# ------------------------------------------------------------ taint walk
class _Auditor:
    def __init__(self, contract: ExactnessContract, entry: str):
        self.c = contract
        self.entry = entry
        self.rep = ExactnessReport(entry=entry, violations=[])

    def dequant_taint(self):
        """Taint of the legitimate ``convert int32 -> float``
        dequantization exit.  None here (the region ends); the sharding
        auditor overrides with ``"deq"`` to keep tracking provenance of
        decision floats into the cross-shard reductions."""
        return None

    def flag(self, eqn, reason: str):
        ins = ",".join(str(_aval_dtype(v.aval))
                       for v in eqn.invars
                       if not isinstance(v, jex_core.Literal))
        outs = ",".join(str(_aval_dtype(v.aval)) for v in eqn.outvars)
        self.rep.violations.append(Violation(
            entry=self.entry, primitive=eqn.primitive.name, reason=reason,
            detail=f"in=({ins}) out=({outs})"))

    # ---- main propagation over one (sub)jaxpr
    def propagate(self, jaxpr, in_taint, record: bool):
        env: dict = {}

        def read(atom):
            if isinstance(atom, jex_core.Literal):
                return None
            return env.get(atom)

        def write(var, taint):
            if taint is not None:
                env[var] = _merge(env.get(var), taint)

        for var, t in zip(jaxpr.invars, in_taint):
            write(var, t)
        for eqn in jaxpr.eqns:
            if record:
                self.rep.eqns_checked += 1
            out_t = self.eqn_taint(eqn, [read(a) for a in eqn.invars], record)
            for var, t in zip(eqn.outvars, out_t):
                write(var, t)
        return [read(v) for v in jaxpr.outvars]

    def _fixpoint(self, body_jaxpr, in_taint, carry_lo: int, carry_hi: int,
                  out_carry_lo: int):
        """Iterate a loop body's carry taint to a fixed point (taint only
        grows, so this terminates in <= len(carry) steps)."""
        cur = list(in_taint)
        for _ in range(max(2, carry_hi - carry_lo + 1)):
            out = self.propagate(body_jaxpr, cur, record=False)
            changed = False
            for i in range(carry_hi - carry_lo):
                new = _merge(cur[carry_lo + i], out[out_carry_lo + i])
                if new != cur[carry_lo + i]:
                    cur[carry_lo + i] = new
                    changed = True
            if not changed:
                break
        return cur

    # ---- per-eqn rules
    def eqn_taint(self, eqn, in_t, record: bool):
        prim = eqn.primitive.name
        params = eqn.params
        n_out = len(eqn.outvars)

        # --- structured control flow / calls: recurse
        if prim == "scan":
            nc, ncar = params["num_consts"], params["num_carry"]
            body = params["jaxpr"].jaxpr
            cur = self._fixpoint(body, in_t, nc, nc + ncar, 0)
            out = self.propagate(body, cur, record)
            # outputs: carries then stacked ys — same taint as body outs
            return out[:n_out]
        if prim == "while":
            cn, bn = params["cond_nconsts"], params["body_nconsts"]
            cond, body = params["cond_jaxpr"].jaxpr, params["body_jaxpr"].jaxpr
            carry = in_t[cn + bn:]
            body_in = in_t[cn:cn + bn] + carry
            cur = self._fixpoint(body, body_in, bn, bn + len(carry), 0)
            self.propagate(cond, in_t[:cn] + cur[bn:], record)
            out = self.propagate(body, cur, record)
            return out[:n_out]
        if prim == "cond":
            branches = params["branches"]
            outs = [self.propagate(b.jaxpr, in_t[1:], record)
                    for b in branches]
            return [dataclasses_reduce_merge(col) for col in zip(*outs)] \
                if outs else [None] * n_out
        sub = _sub_closed(params, "jaxpr", "call_jaxpr")
        if prim == "pallas_call":
            if record and self.c.mode == "kernel-int":
                self.kernel_scan(params.get("jaxpr"))
            # opaque from the taint side: int32 out of tainted ints
            tainted = any(t is not None for t in in_t)
            return ["int" if tainted else None] * n_out
        if sub is not None and prim not in ("custom_vjp_call_jaxpr",):
            inner = getattr(sub, "jaxpr", sub)
            n_in = len(inner.invars)
            # align trailing invars (leading extras are consts/tangents)
            pad = [None] * max(0, n_in - len(in_t))
            out = self.propagate(inner, (pad + list(in_t))[-n_in:], record)
            return out[:n_out]

        # --- leaf eqns
        any_int = "int" in in_t
        any_f32x = "f32exact" in in_t
        if not (any_int or any_f32x):
            return [None] * n_out
        if record:
            self.rep.tainted_eqns += 1
        out_dts = [_aval_dtype(v.aval) for v in eqn.outvars]

        if prim == "convert_element_type":
            src = next((v for v in eqn.invars
                        if not isinstance(v, jex_core.Literal)), None)
            src_dt = _aval_dtype(src.aval) if src is not None else None
            dst = out_dts[0]
            if any_int:
                if _is_int(dst) or dst == np.bool_:
                    return ["int"]
                if _is_float(dst):
                    if _is_int(src_dt) and np.dtype(src_dt).itemsize >= 4:
                        # int32 accumulator dequantized: exit
                        return [self.dequant_taint()]
                    if self.c.f32_ok and np.dtype(dst) == np.float32:
                        return ["f32exact"]
                    if record:
                        self.flag(eqn, "digit-stream int converted to float "
                                       "outside the guarded f32 fast path")
                    return [None]
                return [None]
            # f32exact source
            if _is_int(dst):
                return ["int"]  # fast-path accumulator back to int32
            if dst is not None and np.dtype(dst) == np.float32:
                return ["f32exact"]
            if record:
                self.flag(eqn, f"guarded f32 fast-path value converted to "
                               f"{dst} (loses exactness)")
            return [None]

        if prim in ("dot_general", "conv_general_dilated"):
            if any_int and any_f32x:
                if record:
                    self.flag(eqn, "contraction mixes integer-path and "
                                   "f32-fast-path operands")
                return [None]
            if any_int:
                in_dts = [_aval_dtype(v.aval) for v in eqn.invars]
                out_dt = out_dts[0]
                if (all(_is_int(dt) for dt in in_dts)
                        and out_dt is not None
                        and np.dtype(out_dt).itemsize >= 4
                        and _is_int(out_dt)):
                    if record:
                        self.rep.int_dots += 1
                    return ["int"]
                if record:
                    self.flag(eqn, "integer contraction without int32 "
                                   "accumulation (preferred_element_type)")
                return [None]
            # f32 fast path dot
            prec = params.get("precision")
            precs = prec if isinstance(prec, tuple) else (prec,)
            if (self.c.f32_ok and all(p == _HIGHEST for p in precs)
                    and _is_float(out_dts[0])):
                if record:
                    self.rep.f32_fastpath_dots += 1
                return ["f32exact"]
            if record:
                self.flag(eqn, "f32 fast-path contraction without "
                               "precision=HIGHEST (not bit-exact)")
            return [None]

        if all(dt == np.bool_ for dt in out_dts):
            return [None] * n_out  # comparisons: decision exit
        if prim in _DECISION_PRIMS:
            return [None] * n_out  # index decisions: exit

        if any_f32x and not any_int:
            if prim in _LAYOUT_PRIMS:
                return ["f32exact" if _is_float(dt) else None
                        for dt in out_dts]
            if record:
                self.flag(eqn, "inexact op on a guarded f32 fast-path value")
            return [None] * n_out

        # integer path: int-out ops propagate, float-out ops are the bug
        out_taint = []
        for dt in out_dts:
            if _is_int(dt):
                out_taint.append("int")
            elif dt == np.bool_ or dt is None:
                out_taint.append(None)
            elif _is_float(dt):
                if record:
                    self.flag(eqn, "float-producing op on the claimed-exact "
                                   "integer path")
                out_taint.append(None)
            else:
                out_taint.append(None)
        return out_taint

    # ---- kernel-int mode: Pallas kernel bodies must be all-integer
    def kernel_scan(self, jaxpr):
        if jaxpr is None:
            return
        inner = getattr(jaxpr, "jaxpr", jaxpr)
        for eqn in inner.eqns:
            self.rep.eqns_checked += 1
            prim = eqn.primitive.name
            for key in ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr"):
                if key in eqn.params:
                    self.kernel_scan(eqn.params[key])
            if "branches" in eqn.params:
                for b in eqn.params["branches"]:
                    self.kernel_scan(b)
            dts = [_aval_dtype(v.aval) for v in eqn.invars
                   if not isinstance(v, jex_core.Literal)]
            dts += [_aval_dtype(v.aval) for v in eqn.outvars]
            if any(_is_float(dt) for dt in dts):
                self.flag(eqn, "float op inside an all-integer Pallas "
                               "kernel body")
            if prim in ("dot_general", "conv_general_dilated"):
                out_dt = _aval_dtype(eqn.outvars[0].aval)
                if not (_is_int(out_dt) and np.dtype(out_dt).itemsize >= 4):
                    self.flag(eqn, "kernel contraction without int32 "
                                   "accumulation")
                else:
                    self.rep.int_dots += 1


def dataclasses_reduce_merge(col):
    out = None
    for t in col:
        out = _merge(out, t)
    return out


# ------------------------------------------------------------ public API
def audit_jaxpr(closed_jaxpr, contract: ExactnessContract,
                entry: str = "<jaxpr>") -> ExactnessReport:
    """Audit a traced ClosedJaxpr against an exactness contract.

    Taint seeds: every integer-typed top-level input (the walks consume
    pre-quantized operands / plane stacks).  Constants are untainted —
    level indices, shift tables and trip counts are schedule data, not
    digit values.
    """
    aud = _Auditor(contract, entry)
    jaxpr = closed_jaxpr.jaxpr
    seeds = ["int" if _is_int(_aval_dtype(v.aval)) else None
             for v in jaxpr.invars]
    aud.propagate(jaxpr, seeds, record=True)
    return aud.rep


def audit_exactness(fn: Callable, args: tuple,
                    contract: ExactnessContract,
                    entry: str = "") -> ExactnessReport:
    """Trace ``fn(*args)`` and audit the jaxpr (trace-time only: no
    tensor data flows)."""
    name = entry or getattr(fn, "__name__", "<fn>")
    closed = jax.make_jaxpr(fn)(*args)
    return audit_jaxpr(closed, contract, entry=name)


def audit_hlo_text(text: str, contract: ExactnessContract,
                   entry: str = "<hlo>") -> list[Violation]:
    """Post-compilation re-check on optimized HLO text.

    XLA/GSPMD may rewrite the module (the PR 5 o-projection bug class);
    this asserts the only floating contractions that survive are f32
    (never bf16/f16 — those silently round) and only when the entry's
    guarded fast path is actually sound.
    """
    from repro.launch import hlo_analysis

    violations = []
    comps = hlo_analysis.parse_module(text)
    for comp in comps.values():
        for iname, rhs in comp["instrs"]:
            kind = hlo_analysis._op_kind(rhs)
            if kind not in ("dot", "convolution"):
                continue
            dt = rhs.split("[", 1)[0].strip().lstrip("(")
            if not dt.startswith(("f", "bf")):
                continue  # integer contraction: exact by construction
            if dt != "f32":
                violations.append(Violation(
                    entry=entry, primitive=kind,
                    reason=f"compiled module contains a {dt} contraction "
                           f"(sub-f32 floats round digit products)",
                    detail=f"{comp['name']}::{iname}"))
            elif not contract.f32_ok:
                violations.append(Violation(
                    entry=entry, primitive=kind,
                    reason="compiled module contains an f32 contraction "
                           "but the f32 fast-path guard does not hold "
                           "for this contract",
                    detail=f"{comp['name']}::{iname}"))
    return violations
