"""Sharding auditor: collective-schedule linting for shard_mapped walks.

The sharded consensus walk is the layer where this repo's one real
numeric bug lived: PR 5's GSPMD float-reassociation, where interior
sharding hints on a replicated backbone made GSPMD repartition a float
contraction into partial sums joined by a float ``add`` all-reduce —
bit-parity silently gone.  The exactness pass (exactness.py) cannot see
that class at trace time: GSPMD inserts its collectives during SPMD
partitioning, after the jaxpr.  This pass closes the gap statically,
per registered entry with a :class:`ShardingContract`:

a) **collective schedule** — the traced walk must contain exactly the
   declared cross-shard reductions (the per-level pmax/pmin decision
   triples + the consensus psum) and nothing else; jaxpr-level data
   movers (``all_gather`` & co) are violations outright, and in the
   partitioned HLO any GSPMD-inserted ``all-gather``/reshard on a
   plane-stack operand breaks the K-never-sharded invariant;
b) **exact-reduction taint** — reusing exactness.py's taint walk (with
   the ``"deq"`` provenance extension: dequantized decision floats stay
   tracked), every cross-shard reduction reached by plane-derived
   values must be max/min/int-sum; a float ``psum``/add all-reduce on a
   tainted value is precisely the PR 5 bug class, caught at lint time;
c) **layout conformance** — the compiled module's propagated input
   shardings match the declared specs (RHS vocab-sharded over
   ``model``, LHS batch-sharded, K replicated).

Schedule-to-source matching rides on the named-collective tags
(core/policy.py ``COLL_TAG_*`` + the walk scope in core/progressive.py):
the scope names land in ``source_info.name_stack`` (jaxpr) and
``metadata op_name`` (HLO), so an all-reduce WITHOUT a declared tag was
inserted by the partitioner, not the walk.  On top of the verified
schedule, analysis/collective_cost.py prices the sync cost per
(entry x mesh) — see :func:`audit_sharding`'s ``with_cost``.
"""

from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Callable

import jax
import numpy as np
from jax.extend import core as jex_core

from repro.analysis import exactness
from repro.analysis.collective_cost import (CollectiveRecord,
                                            sync_cost_certificate)
from repro.analysis.exactness import ExactnessContract, Violation

__all__ = [
    "ReductionSpec",
    "ShardingContract",
    "ShardingReport",
    "audit_sharding",
    "audit_partitioned_hlo",
    "audit_sharded_registry",
]

#: value-preserving cross-shard reductions the schedule may declare
_REDUCE_PRIMS = {"psum", "pmax", "pmin"}

#: jaxpr-level collectives that MOVE data between shards: the declared
#: consensus schedule is reductions-only, so any of these on a walk
#: path breaks the K-never-sharded invariant at trace time already
_FORBIDDEN_PRIMS = {"all_gather", "all_to_all", "ppermute", "pshuffle",
                    "pgather"}

#: HLO op kinds a verified partitioned module must not contain (a
#: contract can narrow/widen this via ``forbidden``)
DEFAULT_FORBIDDEN_KINDS = ("all-gather", "all-to-all", "collective-permute",
                           "reduce-scatter")


@dataclasses.dataclass(frozen=True)
class ReductionSpec:
    """One declared cross-shard reduction: primitive, multiplicity per
    scope (per level-loop iteration, or per walk), and the named-scope
    tag its trace carries (core/policy.py ``COLL_TAG_*``)."""

    prim: str       # psum | pmax | pmin
    count: int = 1
    tag: str = ""


@dataclasses.dataclass(frozen=True)
class ShardingContract:
    """What a shard_mapped entry promises about its SPMD lowering.

    ``mesh_axes`` declares the audit mesh as ``(name, size)`` pairs;
    ``per_level`` / ``per_walk`` the exact reduction schedule inside /
    outside the level loop; ``in_specs`` the expected PartitionSpec
    entries per top-level argument (None = unchecked);
    ``max_collectives`` the static collective-count budget of the
    partitioned module (None = the declared schedule's static count —
    a new collective is a build failure either way)."""

    mesh_axes: tuple
    per_level: tuple = ()
    per_walk: tuple = ()
    in_specs: tuple = ()
    n_levels: int = 1
    max_collectives: int | None = None
    forbidden: tuple = DEFAULT_FORBIDDEN_KINDS
    allow_float_psum: bool = False

    @property
    def declared_static(self) -> int:
        """Static collective count of the declared schedule (each spec
        appears once in the loop body + once per per-walk firing)."""
        return (sum(s.count for s in self.per_level)
                + sum(s.count for s in self.per_walk))

    @property
    def budget(self) -> int:
        return (self.declared_static if self.max_collectives is None
                else self.max_collectives)

    @property
    def declared_tags(self) -> tuple:
        return tuple(sorted({s.tag for s in self.per_level + self.per_walk
                             if s.tag}))

    def build_mesh(self):
        shape = tuple(int(s) for _, s in self.mesh_axes)
        names = tuple(a for a, _ in self.mesh_axes)
        n = 1
        for s in shape:
            n *= s
        devs = np.array(jax.devices()[:n]).reshape(shape)
        return jax.sharding.Mesh(devs, names)


@dataclasses.dataclass
class ShardingReport:
    entry: str
    violations: list
    schedule: dict          # traced reductions: per_level / per_walk
    collectives: dict       # partitioned-HLO census + records
    layout: list            # per-arg conformance rows
    cost: dict | None = None

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> dict:
        return {
            "entry": self.entry, "ok": self.ok,
            "schedule": self.schedule,
            "collectives": self.collectives,
            "layout": self.layout,
            "cost": self.cost,
            "violations": [v.to_json() for v in self.violations],
        }


# ---------------------------------------------------- jaxpr schedule walk
class _ScheduleAuditor(exactness._Auditor):
    """exactness' taint walk + collective recording.

    Every psum/pmax/pmin is recorded with axes / dtype / loop depth /
    named-scope tag and the merged operand taint; jaxpr-level data
    movers and float psums over plane-derived values are violations.
    Exactness verdicts are muted (``flag`` is a no-op) — they belong to
    the exactness pass, which sweeps the same entries; this walk only
    borrows its propagation rules and the ``"deq"`` dequant provenance
    (see :meth:`dequant_taint`)."""

    def __init__(self, contract: ExactnessContract | None,
                 sharding: ShardingContract, entry: str):
        super().__init__(contract or ExactnessContract(), entry)
        self.s = sharding
        self.records: list[CollectiveRecord] = []
        self.schedule_violations: list[Violation] = []
        self._depth = 0

    def dequant_taint(self):
        return "deq"

    def flag(self, eqn, reason):
        pass  # exactness rules are the exactness pass's job

    def _sflag(self, prim: str, reason: str, detail: str = ""):
        self.schedule_violations.append(Violation(
            entry=self.entry, primitive=prim, reason=reason, detail=detail))

    def _record(self, eqn, in_t):
        prim = eqn.primitive.name
        axes = eqn.params.get("axes") or ()
        if not isinstance(axes, tuple):
            axes = (axes,)
        axes = tuple(a for a in axes if isinstance(a, str))
        var = next((v for v in eqn.invars
                    if not isinstance(v, jex_core.Literal)), None)
        dt = exactness._aval_dtype(var.aval) if var is not None else None
        shape = tuple(getattr(var.aval, "shape", ())) if var is not None \
            else ()
        tag = ""
        for seg in re.split(r"[/()]", str(eqn.source_info.name_stack)):
            if seg.startswith("l2r_coll"):
                tag = seg
        taint = None
        for t in in_t:
            taint = exactness._merge(taint, t)
        self.records.append(CollectiveRecord(
            prim=prim, axes=axes,
            dtype=str(np.dtype(dt)) if dt is not None else "float32",
            shape=shape, in_loop=self._depth > 0, tag=tag, taint=taint))
        if (prim == "psum" and exactness._is_float(dt)
                and taint is not None and not self.s.allow_float_psum):
            self._sflag(prim,
                        "float cross-shard sum over a plane-derived value: "
                        "reduction order reassociates the float sum (the "
                        "PR 5 bug class) — cross-shard reductions on the "
                        "exact path must be max/min/int-sum",
                        detail=f"dtype={np.dtype(dt)} axes={axes} "
                               f"taint={taint}")

    def eqn_taint(self, eqn, in_t, record):
        prim = eqn.primitive.name
        n_out = len(eqn.outvars)
        if prim in _REDUCE_PRIMS:
            if record:
                self._record(eqn, in_t)
            # value-preserving reductions: taint passes through 1:1
            out = list(in_t)[:n_out]
            return out + [None] * (n_out - len(out))
        if prim in _FORBIDDEN_PRIMS:
            if record:
                self._sflag(prim,
                            f"cross-shard data mover `{prim}` in the walk "
                            "jaxpr: the declared schedule is reductions-"
                            "only (K is never sharded, plane stacks are "
                            "never gathered)")
            return [None] * n_out
        if prim in ("scan", "while"):
            self._depth += 1
            try:
                return super().eqn_taint(eqn, in_t, record)
            finally:
                self._depth -= 1
        out = super().eqn_taint(eqn, in_t, record)
        # "deq" provenance: dequantized floats keep flowing through
        # float ops (the base lattice drops them — exactness only cares
        # up to the dequant exit; the reduction-taint rule cares beyond)
        if "deq" in in_t and "int" not in in_t and "f32exact" not in in_t:
            out = ["deq" if t is None and exactness._is_float(
                       exactness._aval_dtype(v.aval)) else t
                   for v, t in zip(eqn.outvars, out)]
        return out


def _check_schedule(records: list, contract: ShardingContract, entry: str,
                    violations: list):
    for scope, specs in (("per-level", contract.per_level),
                         ("per-walk", contract.per_walk)):
        recs = [r for r in records if r.in_loop == (scope == "per-level")]
        want: Counter = Counter()
        for s in specs:
            want[(s.prim, s.tag)] += s.count
        got = Counter((r.prim, r.tag) for r in recs)
        for key in sorted(set(want) | set(got)):
            if want[key] == got[key]:
                continue
            prim, tag = key
            violations.append(Violation(
                entry=entry, primitive=prim,
                reason=f"{scope} schedule mismatch: traced {got[key]} x "
                       f"{prim}[{tag or 'untagged'}], declared {want[key]}",
                detail=f"scope={scope}"))


# ------------------------------------------------- partitioned-HLO checks
def audit_partitioned_hlo(text: str, contract: ShardingContract,
                          entry: str = "<hlo>") -> tuple[list, list]:
    """Check the SPMD-partitioned module against the contract.

    Returns ``(violations, collective_records)``.  Three rules:
    forbidden kinds (any ``all-gather``/reshard means GSPMD moved a
    sharded operand — the K-never-sharded invariant is gone), float
    ``add`` all-reduces (cross-shard float-sum reassociation, the PR 5
    class), and untagged all-reduces (no declared ``l2r_coll`` tag in
    the op_name metadata: the partitioner added a collective the
    schedule never declared).  Plus the static count budget."""
    from repro.launch import hlo_analysis

    recs = hlo_analysis.collective_records(text)
    violations: list[Violation] = []
    tags = contract.declared_tags
    for r in recs:
        where = f"{r['computation']}::{r['name']}"
        if r["kind"] in contract.forbidden:
            reason = (f"GSPMD-inserted {r['kind']} in the partitioned "
                      "module: a sharded operand is being moved between "
                      "shards")
            if r["kind"] == "all-gather":
                reason += (" — a plane-stack/K operand was resharded "
                           "(the K-never-sharded invariant is broken)")
            violations.append(Violation(entry, r["kind"], reason, where))
            continue
        if r["kind"] != "all-reduce":
            continue
        if (r["dtype"].startswith(("f", "bf")) and r["reduce_op"] == "add"
                and not contract.allow_float_psum):
            violations.append(Violation(
                entry, "all-reduce",
                f"float add all-reduce ({r['dtype']}): a partitioned "
                "float contraction's partial sums are reassociated "
                "across shards (the PR 5 reassociation bug class)",
                where))
        elif tags and not any(t in r["op_name"] for t in tags):
            violations.append(Violation(
                entry, "all-reduce",
                f"{r['dtype']} {r['reduce_op'] or '?'} all-reduce without "
                "a declared l2r_coll tag: the partitioner added a "
                "collective the schedule never declared "
                f"(op_name={r['op_name'] or '<none>'!r})", where))
    if len(recs) > contract.budget:
        violations.append(Violation(
            entry, "module",
            f"collective-count budget exceeded: {len(recs)} static "
            f"collectives in the partitioned module, budget "
            f"{contract.budget} — a new collective entered the schedule",
            detail=",".join(sorted({r['kind'] for r in recs}))))
    return violations, recs


# ----------------------------------------------------- layout conformance
def _audit_layout(compiled, args, contract: ShardingContract, mesh,
                  entry: str) -> tuple[list, list]:
    from jax.sharding import NamedSharding, PartitionSpec

    violations: list[Violation] = []
    rows: list[dict] = []
    if not contract.in_specs:
        return violations, rows
    try:
        shardings = compiled.input_shardings[0]
    except Exception:  # pragma: no cover - old jax layouts
        return violations, rows
    for i, spec in enumerate(contract.in_specs):
        if spec is None or i >= len(shardings) or i >= len(args):
            continue
        expected = NamedSharding(mesh, PartitionSpec(*spec))
        ok = bool(shardings[i].is_equivalent_to(expected, np.ndim(args[i])))
        rows.append({"arg": i, "expected": str(expected.spec), "ok": ok})
        if not ok:
            violations.append(Violation(
                entry, "input-sharding",
                f"arg {i}: propagated sharding {shardings[i]} does not "
                f"match the declared spec {expected.spec}",
                detail=f"arg={i}"))
    return violations, rows


# ------------------------------------------------------------- public API
def audit_sharding(fn: Callable, args: tuple, sharding: ShardingContract,
                   contract: ExactnessContract | None = None,
                   entry: str = "", *,
                   with_cost: bool = True) -> ShardingReport:
    """Audit one shard_mapped entry: trace, partition, certify.

    Runs the three checks of the module docstring — traced schedule +
    reduction taint (jaxpr), collective census vs contract (partitioned
    HLO), input-sharding conformance — and, with ``with_cost``, prices
    the verified schedule into the sync-cost certificate."""
    name = entry or getattr(fn, "__name__", "<fn>")
    closed = jax.make_jaxpr(fn)(*args)
    aud = _ScheduleAuditor(contract, sharding, name)
    seeds = ["int" if exactness._is_int(exactness._aval_dtype(v.aval))
             else None for v in closed.jaxpr.invars]
    aud.propagate(closed.jaxpr, seeds, record=True)
    violations = list(aud.schedule_violations)
    _check_schedule(aud.records, sharding, name, violations)

    compiled = jax.jit(fn).lower(*args).compile()
    text = compiled.as_text()
    hlo_v, hlo_recs = audit_partitioned_hlo(text, sharding, name)
    violations += hlo_v

    mesh = sharding.build_mesh()
    lay_v, lay_rows = _audit_layout(compiled, args, sharding, mesh, name)
    violations += lay_v

    census: dict[str, int] = {}
    for r in hlo_recs:
        census[r["kind"]] = census.get(r["kind"], 0) + 1
    cost = None
    if with_cost:
        cost = sync_cost_certificate(aud.records, sharding.mesh_axes,
                                     sharding.n_levels, hlo_text=text)
    return ShardingReport(
        entry=name, violations=violations,
        schedule={
            "per_level": [r.to_json() for r in aud.records if r.in_loop],
            "per_walk": [r.to_json() for r in aud.records if not r.in_loop],
        },
        collectives={"census": census, "records": hlo_recs},
        layout=lay_rows, cost=cost)


def audit_sharded_registry(entries=None, *, allow_skips: bool = False,
                           with_cost: bool = True) -> list[dict]:
    """Sweep every registered entry carrying a :class:`ShardingContract`.

    A skipped entry (too few devices) is a VIOLATION unless
    ``allow_skips``: the CI lint job runs under a virtual-8-device env
    (launch/mesh.py:virtual_device_env) precisely so the sharded
    entries cannot silently pass unaudited."""
    from repro.analysis import registry

    rows = []
    for e in (entries if entries is not None else registry.iter_entries()):
        if getattr(e, "sharding", None) is None:
            continue
        row: dict = {"entry": e.name, "tags": list(e.tags)}
        if e.skip:
            if allow_skips:
                row.update(status="skip", reason=e.skip)
            else:
                row.update(status="violation", ok=False, violations=[
                    Violation(
                        entry=e.name, primitive="registry",
                        reason=f"registered sharded entry SKIPPED "
                               f"({e.skip}) — the audit must not silently "
                               "pass; run under XLA_FLAGS="
                               "--xla_force_host_platform_device_count=8 "
                               "(launch.mesh.virtual_device_env) or pass "
                               "allow_skips explicitly").to_json()])
            rows.append(row)
            continue
        fn, args = e.build()
        rep = audit_sharding(fn, args, e.sharding, e.contract,
                             entry=e.name, with_cost=with_cost)
        row.update(status="ok" if rep.ok else "violation", **rep.to_json())
        rows.append(row)
    return rows
