"""Static int32 overflow certification for the L2R digit walks.

Every schedule in this repo (pairs / stacked / streaming, dense / conv /
attention) folds plane-pair partial products into **one int32
accumulator** per output element:

    acc = sum_{s in processed levels} sum_{i+j=s} <x_i, y_j> * radix**s

The walks are bit-identical to each other *modulo 2^32* no matter what —
int32 wraparound is deterministic and schedule-independent — but the
repo's headline claim is exactness against unbounded integer arithmetic,
and that only holds while ``|acc| < 2**31`` at **every** prefix of the
MSDF walk (progressive truncation commits from prefixes, so intermediate
magnitudes matter, not just the final value).

This module certifies that statically from the digit configuration:

* :func:`per_element_extremes` — for each MSDF prefix length, the exact
  min/max of the per-(x, y)-element partial sum over all representable
  n-bit operand pairs, found by exhaustive (vectorized) enumeration for
  ``n_bits <= 8`` and by a sound digit-interval bound above that.
* :func:`certify` — scales the per-element extreme by the contraction
  length ``k`` (and ``taps``, the conv window multiplier) and returns an
  :class:`OverflowCertificate` with the worst-case magnitude, whether it
  is exact (achievable, with a witness operand pair) or merely an upper
  bound, and whether it fits int32.
* :func:`check_or_raise` — the trace-time guard wired into the
  ``l2r_gemm`` dispatcher and ``quantize_weights``.  Mode comes from the
  ``L2R_CERTIFY`` env var: ``warn`` (default) emits an
  :class:`AccumulatorOverflowWarning` once per config, ``strict`` raises
  with the computed bound in the message, ``off`` skips the check.
* :func:`audit_registry` — sweeps every config in
  ``repro.configs.registry`` and certifies each L2R contraction it
  declares (head walk over ``d_model``, attention score walk over
  ``head_dim``).

Exactness of the k * M scaling: the per-element extreme M is achieved by
some representable operand pair (x*, y*) at some prefix t*; aligning all
``k`` contraction entries at (x*, y*) achieves k * M at the same prefix,
because every level's contribution scales linearly in the number of
aligned entries.  So for ``n_bits <= 8`` the certificate is *tight* — an
adversarial operand set achieving it exists (see
tests/test_analysis.py::test_certificate_bound_is_achievable).

``window_pad`` is accepted for interface completeness: window padding
contributes all-zero digit planes, which add nothing to any level, so it
never changes the bound.
"""

from __future__ import annotations

import dataclasses
import os
import warnings
from functools import lru_cache

import numpy as np

from repro.core.online import msdf_levels

__all__ = [
    "AccumulatorOverflowWarning",
    "OverflowCertificate",
    "PerElementExtremes",
    "per_element_extremes",
    "certify",
    "check_or_raise",
    "certify_mode",
    "audit_registry",
    "INT32_LIMIT",
]

INT32_LIMIT = 2**31 - 1

#: configs at or below this operand width are certified by exhaustive
#: enumeration (2^n x 2^n pairs); wider ones fall back to a sound
#: digit-interval bound.
_EXACT_MAX_BITS = 8


class AccumulatorOverflowWarning(UserWarning):
    """A digit config whose worst-case int32 accumulator can overflow."""


# --------------------------------------------------------------- extremes
@dataclasses.dataclass(frozen=True)
class PerElementExtremes:
    """Per-MSDF-prefix extremes of the single-element partial sum.

    ``lo[t]`` / ``hi[t]`` bound the partial sum after the first ``t + 1``
    significance levels, over all representable (x, y) operand pairs.
    When ``exact``, the bounds are achieved and ``witness(t)`` returns an
    achieving integer pair.
    """

    n_bits: int
    log2_radix: int
    lo: tuple  # per prefix, descending-level MSDF order
    hi: tuple
    exact: bool
    # achieving (x, y) per prefix; empty when not exact
    lo_wit: tuple = ()
    hi_wit: tuple = ()

    def magnitude(self, levels: int | None = None) -> int:
        """Max |partial sum| over the first ``levels`` prefixes (all
        2D-1 when None)."""
        t = len(self.lo) if levels is None else min(levels, len(self.lo))
        if t <= 0:
            return 0
        return max(max(abs(v) for v in self.lo[:t]),
                   max(abs(v) for v in self.hi[:t]))

    def witness(self, levels: int | None = None):
        """(x, y, prefix_levels) achieving :meth:`magnitude`; None when
        the extremes are interval bounds rather than enumerated."""
        if not self.exact:
            return None
        t_max = len(self.lo) if levels is None else min(levels, len(self.lo))
        best, arg = -1, None
        for t in range(t_max):
            for v, wit in ((self.lo[t], self.lo_wit[t]),
                           (self.hi[t], self.hi_wit[t])):
                if abs(v) > best:
                    best, arg = abs(v), (wit[0], wit[1], t + 1)
        return arg


def _digit_table(n_bits: int, log2_radix: int):
    """(D, 2**n) digit planes of every representable value, plus the
    value vector — same convention as core.quant.digit_planes (low
    planes masked-unsigned, top plane arithmetic shift)."""
    d = n_bits // log2_radix
    q = np.arange(-(1 << (n_bits - 1)), 1 << (n_bits - 1), dtype=np.int64)
    mask = (1 << log2_radix) - 1
    planes = [(q >> (log2_radix * i)) & mask for i in range(d - 1)]
    planes.append(q >> (log2_radix * (d - 1)))  # arithmetic: signed top
    return np.stack(planes), q


def _digit_ranges(n_bits: int, log2_radix: int):
    """[lo, hi] per digit plane (interval fallback for wide operands)."""
    d = n_bits // log2_radix
    r = 1 << log2_radix
    lo = [0] * (d - 1) + [-(r // 2)]
    hi = [r - 1] * (d - 1) + [r // 2 - 1]
    return lo, hi


@lru_cache(maxsize=None)
def per_element_extremes(n_bits: int, log2_radix: int) -> PerElementExtremes:
    if n_bits % log2_radix:
        raise ValueError(f"n_bits={n_bits} not divisible by "
                         f"log2_radix={log2_radix}")
    d = n_bits // log2_radix
    r = 1 << log2_radix
    if n_bits <= _EXACT_MAX_BITS:
        digs, q = _digit_table(n_bits, log2_radix)
        p = np.zeros((q.size, q.size), np.int64)
        lo, hi, lo_wit, hi_wit = [], [], [], []
        for s in msdf_levels(d):
            lvl = np.zeros_like(p)
            for i in range(d):
                j = s - i
                if 0 <= j < d:
                    lvl += np.outer(digs[i], digs[j])
            p += lvl * (r ** s)
            a_min = np.unravel_index(int(p.argmin()), p.shape)
            a_max = np.unravel_index(int(p.argmax()), p.shape)
            lo.append(int(p[a_min]))
            hi.append(int(p[a_max]))
            lo_wit.append((int(q[a_min[0]]), int(q[a_min[1]])))
            hi_wit.append((int(q[a_max[0]]), int(q[a_max[1]])))
        return PerElementExtremes(n_bits, log2_radix, tuple(lo), tuple(hi),
                                  exact=True, lo_wit=tuple(lo_wit),
                                  hi_wit=tuple(hi_wit))
    # interval fallback: digits vary independently inside their plane
    # ranges — sound (contains every representable pair) but the corners
    # need not correspond to a single representable operand.
    dlo, dhi = _digit_ranges(n_bits, log2_radix)
    acc_lo = acc_hi = 0
    lo, hi = [], []
    for s in msdf_levels(d):
        lvl_lo = lvl_hi = 0
        for i in range(d):
            j = s - i
            if 0 <= j < d:
                cands = [dlo[i] * dlo[j], dlo[i] * dhi[j],
                         dhi[i] * dlo[j], dhi[i] * dhi[j]]
                lvl_lo += min(cands) * (r ** s)
                lvl_hi += max(cands) * (r ** s)
        acc_lo += lvl_lo
        acc_hi += lvl_hi
        lo.append(acc_lo)
        hi.append(acc_hi)
    return PerElementExtremes(n_bits, log2_radix, tuple(lo), tuple(hi),
                              exact=False)


# ------------------------------------------------------------ certificate
@dataclasses.dataclass(frozen=True)
class OverflowCertificate:
    """Worst-case int32 accumulator magnitude for one digit config.

    ``bound = k * taps * per_element`` — the max |accumulator| over every
    MSDF prefix of the walk, every representable operand set, and every
    output element.  ``exact`` means the bound is achieved by a concrete
    operand pair (``witness``); otherwise it is a sound over-estimate.
    """

    n_bits: int
    log2_radix: int
    levels: int
    k: int
    taps: int
    per_element: int
    bound: int
    exact: bool
    witness: tuple | None  # (x, y, prefix_levels) achieving per_element
    limit: int = INT32_LIMIT

    @property
    def sound(self) -> bool:
        return self.bound <= self.limit

    @property
    def headroom_bits(self) -> float:
        """log2(limit / bound); negative when unsound."""
        if self.bound == 0:
            return float("inf")
        return float(np.log2(self.limit / self.bound))

    def describe(self) -> str:
        kind = "exact worst case" if self.exact else "interval bound"
        state = "fits int32" if self.sound else "OVERFLOWS int32"
        return (f"l2r config n_bits={self.n_bits} log2_radix="
                f"{self.log2_radix} levels={self.levels} k={self.k}"
                f"{f' taps={self.taps}' if self.taps != 1 else ''}: "
                f"worst-case |accumulator| = {self.bound} ({kind}) "
                f"vs limit {self.limit} -> {state}")

    def to_json(self) -> dict:
        return {
            "n_bits": self.n_bits, "log2_radix": self.log2_radix,
            "levels": self.levels, "k": self.k, "taps": self.taps,
            "per_element": self.per_element, "bound": self.bound,
            "limit": self.limit, "exact": self.exact, "sound": self.sound,
            "witness": list(self.witness) if self.witness else None,
        }


def certify(n_bits: int, log2_radix: int, k: int, levels: int | None = None,
            taps: int = 1, window_pad: int = 0) -> OverflowCertificate:
    """Certify the int32 accumulator of a (config, contraction) pair.

    ``k`` is the contraction length; ``taps`` multiplies it for conv
    windows (kh * kw); ``levels`` truncates the walk (None = full 2D-1).
    ``window_pad`` is bound-neutral (zero planes) and accepted only so
    call sites can forward their full config.
    """
    del window_pad  # zero digit planes: contributes nothing to any level
    if k < 0 or taps < 1:
        raise ValueError(f"need k >= 0 and taps >= 1, got k={k} taps={taps}")
    ext = per_element_extremes(n_bits, log2_radix)
    n_levels = len(ext.lo)
    lv = n_levels if levels is None else max(0, min(levels, n_levels))
    per = ext.magnitude(lv)
    return OverflowCertificate(
        n_bits=n_bits, log2_radix=log2_radix, levels=lv, k=k, taps=taps,
        per_element=per, bound=k * taps * per, exact=ext.exact,
        witness=ext.witness(lv))


# ------------------------------------------------------------ trace guard
def certify_mode() -> str:
    """Guard mode from ``L2R_CERTIFY``: off | warn (default) | strict."""
    mode = os.environ.get("L2R_CERTIFY", "warn").strip().lower()
    if mode not in ("off", "warn", "strict"):
        raise ValueError(f"L2R_CERTIFY must be off/warn/strict, got {mode!r}")
    return mode


_WARNED: set = set()


def check_or_raise(n_bits: int, log2_radix: int, k: int,
                   levels: int | None = None, taps: int = 1,
                   where: str = "l2r", mode: str | None = None,
                   ) -> OverflowCertificate | None:
    """Trace-time overflow guard for dispatch/quantize entry points.

    Returns the certificate (None in ``off`` mode).  Unsound configs
    raise OverflowError in ``strict`` mode and warn once per config in
    ``warn`` mode — warn is the default so existing mod-2^32 parity
    workloads (e.g. 16-bit schedule-equivalence tests) keep running
    while still surfacing that their exactness claim does not hold.
    """
    mode = certify_mode() if mode is None else mode
    if mode == "off":
        return None
    cert = certify(n_bits, log2_radix, k, levels=levels, taps=taps)
    if not cert.sound:
        msg = f"{where}: {cert.describe()}"
        if mode == "strict":
            raise OverflowError(msg)
        key = (where, n_bits, log2_radix, cert.levels, k, taps)
        if key not in _WARNED:
            _WARNED.add(key)
            warnings.warn(msg, AccumulatorOverflowWarning, stacklevel=3)
    return cert


# ---------------------------------------------------------- config sweep
def audit_registry() -> list[dict]:
    """Certify the L2R contractions of every config in the arch registry.

    The paper's technique is a first-class switch (``ModelConfig.l2r``
    / ``attn_l2r``): for each arch this certifies the digit config that
    switch runs — the declared ``QuantConfig`` when set, the default
    otherwise (``declared`` records which) — at the arch's real
    contraction lengths: the head walk over ``d_model``
    (serve.engine quantizes head weights with ``k = d_model``) and the
    attention score walk over ``head_dim``.  Returns one report row per
    (arch, site).
    """
    from repro.configs import registry  # deferred: configs pull in models

    rows = []
    for arch in registry.ARCHS:
        cfg = registry.get_config(arch)
        sites = [
            ("head", cfg.l2r, cfg.l2r_levels, cfg.d_model),
            ("attention", cfg.attn_l2r, cfg.attn_levels, cfg.head_dim),
        ]
        for site, qc, levels, k in sites:
            declared = qc is not None
            if qc is None:
                from repro.core.quant import QuantConfig
                qc = QuantConfig()
            cert = certify(qc.n_bits, qc.log2_radix, k, levels=levels)
            rows.append({"arch": arch, "site": site, "declared": declared,
                         **cert.to_json()})
    return rows
