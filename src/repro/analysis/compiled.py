"""Compiled-artifact audits: donation, AOT coverage, retrace budgets.

The serving fast path (PR 6/7) rests on three *compiled* facts that the
python source can only request, not guarantee:

* **decode-state donation** — ``donate_argnums`` is a hint; XLA only
  aliases buffers when layouts/shardings allow.  The proof is in the
  executable: the HLO module header's ``input_output_alias`` map must
  alias the state parameter, and after a real call the donated input
  buffer must actually be dead (``.is_deleted()``).  Without it, decode
  silently regresses to the pre-PR 6 copy-per-step behavior.
* **AOT prefill coverage** — every bucket the gateway can route to must
  hold a warmed executable, or the first request of that length eats a
  compile on the serving thread.
* **retrace budget** — serving a bucketed workload must leave the
  fallback ``jax.jit`` caches empty (gateway) / at exactly one trace
  (batcher): any growth means shapes leaked past the buckets.

These audits inspect live engine objects (``ServingGateway`` /
``ContinuousBatcher``) plus generic helpers usable on any
``jax.jit``/AOT artifact, so tests can seed a deliberately non-donated
step and prove the auditor catches it.
"""

from __future__ import annotations

import re
from typing import Any

import jax

__all__ = [
    "parse_input_output_alias",
    "donation_report",
    "probe_donation",
    "audit_gateway",
    "audit_batcher",
]

_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d,\s]*)\}\s*:\s*\((\d+)\s*,\s*\{([\d,\s]*)\}")


def _hlo_text(exe) -> str:
    return exe.as_text() if hasattr(exe, "as_text") else str(exe)


def parse_input_output_alias(text: str) -> list[dict]:
    """Alias entries from an HLO module header.

    Header form: ``input_output_alias={ {0}: (1, {0}, may-alias), ... }``
    — output tuple index -> (parameter number, parameter tuple index).
    Tuple-typed parameters produce multi-element index paths, which is
    exactly the donated pytree-state case.
    """
    m = re.search(r"input_output_alias=\{", text)
    if not m:
        return []
    depth, i = 1, m.end()
    while i < len(text) and depth:
        depth += {"{": 1, "}": -1}.get(text[i], 0)
        i += 1
    blob = text[m.end():i - 1]
    out = []
    for om, pnum, pidx in _ALIAS_ENTRY_RE.findall(blob):
        out.append({
            "output_index": tuple(int(x) for x in om.split(",") if x.strip()),
            "param": int(pnum),
            "param_index": tuple(int(x) for x in pidx.split(",")
                                 if x.strip()),
        })
    return out


def donation_report(exe) -> dict:
    """Which parameters of a compiled executable are donated (aliased
    into outputs), straight from the artifact."""
    aliases = parse_input_output_alias(_hlo_text(exe))
    return {
        "n_aliases": len(aliases),
        "aliased_params": sorted({a["param"] for a in aliases}),
        "aliases": aliases,
    }


def probe_donation(fn, args, donated: tuple[int, ...]) -> dict:
    """Dynamic donation probe: call ``fn(*args)`` and check the donated
    inputs' buffers are actually dead afterwards.

    ``args`` must be committed ``jax.Array``s (device_put them first);
    returns per-argnum liveness — a live donated buffer means XLA
    declined the alias (or the call path copies).
    """
    args = [jax.device_put(a) if not hasattr(a, "is_deleted") else a
            for a in args]
    fn(*args)
    return {i: bool(args[i].is_deleted()) for i in donated}


def _violation(entry: str, reason: str, detail: str = "") -> dict:
    return {"entry": entry, "reason": reason, "detail": detail}


def audit_gateway(gw, entry: str = "gateway") -> dict:
    """AOT coverage + donation + retrace budget of a ServingGateway.

    Call after (or instead of) serving traffic: triggers ``warmup()``
    itself when the caller has not.  The fallback-jit cache check is
    only meaningful after requests ran — a clean gateway trivially
    passes it.
    """
    if not gw._prefill_exe or gw._decode_exe is None:
        gw.warmup()
    violations = []
    missing = [b for b in gw.buckets if b not in gw._prefill_exe]
    if missing:
        violations.append(_violation(
            entry, "AOT prefill coverage hole: buckets without warmed "
                   "executables", f"missing={missing}"))
    rep = donation_report(gw._decode_exe)
    # arguments flatten to pytree leaves in the executable, so the
    # donated state pytree shows up as a block of aliased parameter
    # numbers (the model params, passed first, are never aliased) — an
    # empty alias map means XLA declined the donation entirely and
    # decode copies its state every step.
    if rep["n_aliases"] == 0:
        violations.append(_violation(
            entry, "decode state is NOT donated in the compiled decode "
                   "executable (empty input_output_alias) — "
                   "copy-per-step decode",
            "expected the state leaves aliased into the output"))
    budget = {
        "prefill_fallback_traces": int(gw._prefill_jit._cache_size()),
        "decode_fallback_traces": int(gw._decode_jit._cache_size()),
    }
    for key, n in budget.items():
        if n:
            violations.append(_violation(
                entry, f"retrace budget exceeded: {key}={n} (expected 0 "
                       f"— a shape leaked past the AOT buckets)"))
    return {
        "entry": entry, "ok": not violations, "violations": violations,
        "buckets": list(gw.buckets),
        "aot_prefill_buckets": sorted(gw._prefill_exe),
        "decode_donation": rep, **budget,
    }


def audit_batcher(b, entry: str = "batcher", step: bool = True) -> dict:
    """Donation + retrace budget of a live ContinuousBatcher.

    With ``step=True`` (requires at least one submitted request) the
    audit runs one decode step and proves the previous slot state was
    donated — its buffer is dead afterwards.  The retrace budget is one
    trace total: the decode step sees a constant batch shape.
    """
    violations: list[dict] = []
    donated: dict[str, Any] = {"checked": False}
    if step:
        leaves = [x for x in jax.tree.leaves(b.state)
                  if hasattr(x, "is_deleted")]
        b.step()
        dead = [bool(x.is_deleted()) for x in leaves]
        donated = {"checked": True, "n_leaves": len(dead),
                   "n_dead": sum(dead)}
        if not all(dead):
            violations.append(_violation(
                entry, "slot state was NOT donated: previous state "
                       "buffers still live after a decode step "
                       "(copy-per-step)",
                f"live={len(dead) - sum(dead)}/{len(dead)} leaves"))
    traces = int(b._decode._cache_size())
    if traces > 1:
        violations.append(_violation(
            entry, f"retrace budget exceeded: decode traced {traces}x "
                   f"(expected 1 — constant slot shape)"))
    return {"entry": entry, "ok": not violations, "violations": violations,
            "decode_traces": traces, "donation": donated}
