"""l2r-lint: static verification of the repo's exactness claims.

Three passes, one registry, one CLI (``tools/l2r_lint.py``):

* :mod:`repro.analysis.exactness` — jaxpr/HLO taint audit proving every
  claimed-exact walk keeps integer (or guarded-f32) arithmetic between
  plane extraction and the level accumulator;
* :mod:`repro.analysis.overflow` — worst-case int32 accumulator
  certification per digit config, with a trace-time guard in the GEMM
  dispatch and weight quantizer;
* :mod:`repro.analysis.compiled` — compiled-artifact audits (decode
  donation, AOT bucket coverage, retrace budgets);
* :mod:`repro.analysis.sharding` — collective-schedule linting of the
  shard_mapped entries (declared reductions only, no GSPMD resharding,
  no float cross-shard sums on plane-derived values, conformant input
  shardings), with :mod:`repro.analysis.collective_cost`'s static
  sync-cost certificate per (entry x mesh);
* :mod:`repro.analysis.registry` — the claimed-exact entry points every
  pass sweeps (new schedules declare their contract here).
"""

from repro.analysis.collective_cost import (CollectiveRecord,
                                            sync_cost_certificate)
from repro.analysis.exactness import (ExactnessContract, ExactnessReport,
                                      Violation, audit_exactness,
                                      audit_hlo_text, audit_jaxpr,
                                      f32_guard_holds)
from repro.analysis.overflow import (AccumulatorOverflowWarning,
                                     OverflowCertificate, audit_registry,
                                     certify, check_or_raise)
from repro.analysis.registry import ExactEntry, iter_entries, register
from repro.analysis.sharding import (ReductionSpec, ShardingContract,
                                     ShardingReport, audit_partitioned_hlo,
                                     audit_sharded_registry, audit_sharding)

__all__ = [
    "ExactnessContract", "ExactnessReport", "Violation",
    "audit_exactness", "audit_hlo_text", "audit_jaxpr", "f32_guard_holds",
    "AccumulatorOverflowWarning", "OverflowCertificate", "audit_registry",
    "certify", "check_or_raise",
    "ExactEntry", "iter_entries", "register",
    "ReductionSpec", "ShardingContract", "ShardingReport",
    "audit_sharding", "audit_partitioned_hlo", "audit_sharded_registry",
    "CollectiveRecord", "sync_cost_certificate",
]
