"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --global-batch 8 --seq-len 128 --smoke \
        --ckpt-dir /tmp/ckpt

On a real fleet this binary runs once per host (jax.distributed
initializes from the cluster env); on this container it runs single
process.  It wires together every substrate: config registry, sharded
data pipeline, train step (remat + seq-sharding + optional int8 EF
gradient compression), ZeRO-1 AdamW, async checkpointing with
auto-resume, and the fault-tolerance supervisor (straggler policy +
checkpoint/restart).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, get_smoke
from repro.data.pipeline import DataConfig, ShardedPipeline
from repro.models.common import materialize
from repro.models.encdec import encdec_build
from repro.models.transformer import lm_build
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.optim.compression import ef_init
from repro.runtime.fault import FaultTolerantLoop, StragglerPolicy
from repro.train.step import TrainConfig, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ef-compression", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    assert cfg.family != "encdec" or not args.smoke or True
    build = encdec_build if cfg.family == "encdec" else lm_build
    desc = build(cfg)
    params = materialize(desc, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    efs = ef_init(params) if args.ef_compression else None

    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                       total_steps=args.steps)
    tcfg = TrainConfig(remat=True, seq_shard=False,
                       xent_chunk=min(args.seq_len, 512),
                       microbatch=args.microbatch,
                       ef_compression=args.ef_compression)
    step_fn = jax.jit(make_train_step(cfg, ocfg, tcfg))

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.global_batch)
    pipe = ShardedPipeline(dcfg)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if mgr is not None:
        templates = {"params": params, "opt": opt}
        got = mgr.restore_latest(templates)
        if got[0] is not None:
            start_step, trees = got
            params, opt = trees["params"], trees["opt"]
            pipe.load_state_dict(mgr.manifest(start_step)["data"])
            print(f"[resume] restored step {start_step}")

    class State:
        pass

    st = {"params": params, "opt": opt, "ef": efs}

    def wrapped_step(state, batch):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.family == "encdec":
            b["frames"] = jnp.zeros(
                (b["tokens"].shape[0], cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.embeds_input and "tokens" in b and cfg.family != "encdec":
            rng = np.random.default_rng(0)
            b["embeds"] = jnp.asarray(rng.standard_normal(
                (b["tokens"].shape[0], b["tokens"].shape[1], cfg.d_model)),
                jnp.float32)
            del b["tokens"]
        if tcfg.ef_compression:
            p2, o2, e2, m = step_fn(state["params"], state["opt"], b, state["ef"])
            return {"params": p2, "opt": o2, "ef": e2}, m
        p2, o2, m = step_fn(state["params"], state["opt"], b)
        return {"params": p2, "opt": o2, "ef": None}, m

    def save_fn(step, state):
        if mgr is not None:
            mgr.save(step, {"params": state["params"], "opt": state["opt"]},
                     extra={"data": pipe.state_dict()})

    def restore_fn():
        if mgr is None:
            return None, None
        got = mgr.restore_latest({"params": params, "opt": opt})
        if got[0] is None:
            return None, None
        return got[0], {"params": got[1]["params"], "opt": got[1]["opt"],
                        "ef": efs}

    loop = FaultTolerantLoop(wrapped_step, save_fn, restore_fn, pipe,
                             ckpt_every=args.ckpt_every,
                             straggler=StragglerPolicy())

    t0 = time.time()
    losses = []

    orig_step = loop.step_fn

    def logging_step(state, batch):
        state, m = orig_step(state, batch)
        losses.append(float(m["loss"]))
        n = len(losses)
        if n % args.log_every == 0:
            dt = (time.time() - t0) / n
            print(f"step {n + start_step}: loss={losses[-1]:.4f} "
                  f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.2f} "
                  f"{dt*1e3:.0f} ms/step")
        return state, m

    loop.step_fn = logging_step
    st, history = loop.run(st, args.steps, start_step=start_step)
    if mgr is not None:
        save_fn(args.steps, st)
        mgr.wait()
    print(f"done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f} "
          f"({len(losses)} steps, {time.time()-t0:.1f}s)")
    return losses


if __name__ == "__main__":
    main()
