"""Roofline accounting from compiled dry-run artifacts.

Hardware constants (TPU v5e, per chip — the assignment's targets):
  197 TFLOP/s bf16; 819 GB/s HBM; ~50 GB/s/link ICI.

Per (arch x shape x mesh) cell, three terms in seconds:
  compute    = HLO_FLOPs / (chips * 197e12)
  memory     = HLO_bytes / (chips * 819e9)
  collective = sum over collective ops of ring-model bytes-on-wire
               / (links_per_chip * 50e9)        [per-chip wire time]

Collective bytes are not in cost_analysis(): we parse the compiled HLO
and apply the standard ring models per op (sizes are per-shard, i.e.
per-chip, since the module is SPMD-partitioned):
  all-gather(result S, group n):      (n-1)/n * S
  reduce-scatter(result S, group n):  (n-1) * S        (operand = n*S)
  all-reduce(result S, group n):      2 (n-1)/n * S
  all-to-all(result S, group n):      (n-1)/n * S
  collective-permute(result S):       S
On a 2D-torus axis each chip drives ~2 links per direction concurrently;
we credit links_per_chip=2 and state it here once.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_LINK_BW = 50e9  # bytes/s per link
LINKS_PER_CHIP = 2

def parse_collectives(hlo_text: str) -> dict[str, Any]:
    """Sum ring-model wire bytes per collective kind from HLO text.

    Thin fold over :func:`repro.launch.hlo_analysis.collective_records`
    — the ONE shared collective parser (also behind the sharding
    auditor's schedule checks), which dedupes async ``-start``/``-done``
    pairs and reads multi-group ``replica_groups`` lists correctly."""
    from repro.launch import hlo_analysis

    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = {k: 0 for k in out}
    for rec in hlo_analysis.collective_records(hlo_text):
        out[rec["kind"]] += rec["wire_bytes"]
        counts[rec["kind"]] += 1
    return {"wire_bytes": out, "counts": counts,
            "total_wire_bytes": sum(out.values())}


@dataclasses.dataclass(frozen=True)
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_hbm: float
    wire_bytes: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def asdict(self) -> dict:
        return {**dataclasses.asdict(self), "dominant": self.dominant,
                "bound_s": self.bound_s}


def roofline_terms(flops: float, bytes_hbm: float, wire_bytes: float,
                   chips: int) -> Roofline:
    """flops/bytes from cost_analysis are per-device for SPMD modules;
    wire bytes parsed from the partitioned HLO are per-chip too."""
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_hbm / HBM_BW,
        collective_s=wire_bytes / (LINKS_PER_CHIP * ICI_LINK_BW),
        flops=flops, bytes_hbm=bytes_hbm, wire_bytes=wire_bytes, chips=chips,
    )


def attn_decode_step_bytes(batch: int, cache_len: int, kv_heads: int,
                           head_dim: int, *, n_bits: int = 8,
                           log2_radix: int = 2, kv_dtype_bytes: int = 2,
                           levels: int | None = None) -> dict[str, Any]:
    """HBM bytes one decode step's attention moves per layer, per mode.

    Decode attention is memory-bound — the single-query GEMV does
    2*L*dh FLOPs per head against an L-slot cache read, far left of the
    ridge point — so bytes-per-step IS the roofline cost.  Four modes,
    matching ``models/attention.py:decode_attention``:

      float            read K + V from the float cache;
      quant_reextract  digit-serial scores WITHOUT the plane cache:
                       the float K cache is read every step to
                       re-quantize and re-extract planes (extraction is
                       on-chip, so HBM traffic equals the float path —
                       the waste is compute and cache-bandwidth, paid
                       once per step per layer);
      plane_cache      the incrementally plane-stacked cache: the score
                       walk reads the int8 window-padded plane stack
                       ((2D-1) blocks of head_dim int8 per slot) plus
                       one f32 scale per slot, and never touches the
                       float K cache; V is still read for PV;
      plane_cache_truncated
                       same, but a ``levels``-deep walk (truncation or
                       the margin-bounded early exit) touches only the
                       union of its sliding level windows:
                       min(D + levels - 1, 2D - 1) of the 2D-1 blocks.

    Returns per-mode ``{k_bytes, v_bytes, scale_bytes, total_bytes,
    memory_s}`` plus the config echo; ``memory_s`` uses the per-chip
    HBM bandwidth constant above.
    """
    d = n_bits // log2_radix
    n_blocks = 2 * d - 1
    slots = batch * cache_len * kv_heads
    v_bytes = slots * head_dim * kv_dtype_bytes
    k_float = slots * head_dim * kv_dtype_bytes
    k_planes_full = slots * n_blocks * head_dim  # int8
    scale_bytes = slots * 4  # f32 per-slot scale
    lv = n_blocks if levels is None else max(0, min(levels, n_blocks))
    touched = 0 if lv == 0 else min(d + lv - 1, n_blocks)
    k_planes_trunc = slots * touched * head_dim

    def mode(k_bytes: float, sc: float = 0.0) -> dict[str, float]:
        total = k_bytes + v_bytes + sc
        return {"k_bytes": k_bytes, "v_bytes": v_bytes, "scale_bytes": sc,
                "total_bytes": total, "memory_s": total / HBM_BW}

    modes = {
        "float": mode(k_float),
        "quant_reextract": mode(k_float),
        "plane_cache": mode(k_planes_full, scale_bytes),
        "plane_cache_truncated": mode(k_planes_trunc, scale_bytes),
    }
    return {
        "batch": batch, "cache_len": cache_len, "kv_heads": kv_heads,
        "head_dim": head_dim, "n_bits": n_bits, "log2_radix": log2_radix,
        "kv_dtype_bytes": kv_dtype_bytes, "levels": lv,
        "plane_blocks_touched": touched,
        "modes": modes,
        "plane_cache_vs_float":
            modes["plane_cache"]["total_bytes"] / modes["float"]["total_bytes"],
        "truncated_vs_plane_cache":
            (modes["plane_cache_truncated"]["total_bytes"]
             / modes["plane_cache"]["total_bytes"]),
    }


def model_flops(cfg, desc_tree, n_tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params
    (routed experts scaled by k/E), embedding lookup excluded, logit
    matmul included."""
    from repro.models.common import Param
    import jax

    total = 0.0
    routed = 0.0
    embed = 0.0
    for path, p in jax.tree_util.tree_flatten_with_path(
            desc_tree, is_leaf=lambda x: isinstance(x, Param))[0]:
        n = math.prod(p.shape)
        key = "/".join(str(x) for x in path)
        if "experts" in p.axes:
            routed += n
        if key.endswith("'embed']") and "vocab" in p.axes:
            embed += n
        total += n
    active = total - routed
    if cfg.n_experts:
        active += routed * cfg.experts_per_token / cfg.n_experts
    # tied embedding matrix is used by the logits matmul -> keep it; the
    # lookup itself is not a matmul. Untied: 'head' already counted.
    if not getattr(cfg, "tie_embeddings", True):
        active -= embed  # lookup-only table
    factor = 6.0 if kind == "train" else 2.0
    return factor * active * n_tokens
