"""Serving launcher: batched greedy decoding with optional W8A8 (L2R) weights.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --batch 4 --prompt-len 16 --steps 12 [--wq] [--l2r-levels 5] \
        [--gateway]

--wq stores matmul weights in int8 (the L2R serving format; on TPU the
digit-plane Pallas kernel consumes them MSDF); --l2r-levels enables the
progressive-precision mode through the jnp digit-plane path.

--gateway serves the same prompts through the request-queue gateway
(serve/gateway.py: bucketed AOT prefill, donated decode state, async
emit) instead of the static-batch loop — the ``--batch`` prompts become
queued requests, ``--batch`` also sizes the slot array, and the summary
reports gateway throughput/latency stats.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.core.quant import QuantConfig
from repro.models.common import materialize, quantize_params
from repro.models.transformer import lm_build
from repro.serve.engine import (make_decode_step, make_prefill_step,
                                prepare_params)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--wq", action="store_true", help="int8 weight storage")
    ap.add_argument("--l2r-levels", type=int, default=None,
                    help="progressive-precision MSDF levels (digit planes)")
    ap.add_argument("--gateway", action="store_true",
                    help="serve through the request-queue gateway "
                         "(bucketed AOT prefill, donated decode, async "
                         "emit) instead of the static-batch loop")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    assert cfg.family not in ("encdec",), "use examples for enc-dec serving"
    if args.l2r_levels is not None:
        cfg = dataclasses.replace(cfg, l2r=QuantConfig(),
                                  l2r_levels=args.l2r_levels)
    desc = lm_build(cfg)
    params = materialize(desc, jax.random.PRNGKey(0))
    if cfg.l2r is not None:
        # the L2R weight cache: quantize once at load, serve int8 weights
        # through the dispatched digit-plane kernel
        params = prepare_params(cfg, params, desc)
    elif args.wq:
        params = quantize_params(desc, params)

    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.steps
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
                         jnp.int32)

    if args.gateway:
        from repro.serve import Request, ServingGateway

        progressive = cfg.l2r is not None
        gw = ServingGateway(cfg, params, n_slots=args.batch,
                            max_len=max_len, progressive=progressive,
                            early_exit=progressive,
                            prefill_group=min(args.batch, 4))
        reqs = [Request(uid=i, prompt=np.asarray(prompt[i]),
                        max_new_tokens=args.steps)
                for i in range(args.batch)]
        gw.run(reqs)
        gw.close()
        st = gw.stats()
        print(f"gateway: {st['tokens']} tokens in {st['steps']} decode "
              f"dispatches + {st['prefills']} prefill dispatches "
              f"(buckets {st['buckets']}); {st['tokens_per_s']:.1f} tok/s, "
              f"ttft_p50 {st['ttft_p50_s'] * 1e3:.1f} ms, "
              f"tpot_p50 {st['tpot_p50_s'] * 1e3:.1f} ms")
        seqs = np.asarray([r.output for r in reqs])
        for i, row in enumerate(seqs):
            print(f"seq{i}: {row.tolist()}")
        return seqs
    prefill = jax.jit(make_prefill_step(cfg, max_len, cache_dtype=jnp.float32))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    state, logits = prefill(params, {"tokens": prompt})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.steps - 1):
        state, tok, _ = decode(params, state, tok)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = (time.time() - t0) / max(args.steps - 1, 1)
    seqs = np.asarray(jnp.concatenate(out, axis=1))
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill*1e3:.1f} ms "
          f"(incl. compile); decode: {t_decode*1e3:.1f} ms/token")
    for i, row in enumerate(seqs):
        print(f"seq{i}: {row.tolist()}")
    return seqs


if __name__ == "__main__":
    main()
