"""Re-run the trip-count/storage-dtype-aware HLO analysis over archived
compiled HLO (*.hlo.zst) and refresh the artifact JSONs — no recompile.

    PYTHONPATH=src python -m repro.launch.reanalyze [--dir artifacts/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

import zstandard

from repro.launch.hlo_analysis import analyze
from repro.launch.roofline import roofline_terms


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    for jpath in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        zpath = jpath.replace(".json", ".hlo.zst")
        if not os.path.exists(zpath):
            print(f"[skip] {os.path.basename(jpath)}: no archived HLO")
            continue
        hlo = zstandard.ZstdDecompressor().decompress(
            open(zpath, "rb").read()).decode()
        rec = json.load(open(jpath))
        ana = analyze(hlo)
        rl = roofline_terms(ana["flops"], ana["bytes"],
                            ana["total_wire_bytes"], rec["chips"])
        rec["collectives"] = {"wire_bytes": ana["collective_wire_bytes"],
                              "counts": ana["collective_counts"],
                              "total_wire_bytes": ana["total_wire_bytes"]}
        rec["roofline"] = rl.asdict()
        mfpc = rec.get("model_flops_per_chip")
        rec["useful_compute_ratio"] = (mfpc / ana["flops"]
                                       if mfpc and ana["flops"] else None)
        with open(jpath, "w") as fh:
            json.dump(rec, fh, indent=1)
        print(f"[ok] {os.path.basename(jpath)}: dominant={rl.dominant} "
              f"bound={rl.bound_s*1e3:.2f}ms")


if __name__ == "__main__":
    main()
