"""Trip-count-aware cost analysis of compiled HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts each ``while`` body
ONCE, so any scanned computation (our layer stacks, attention KV scans,
xent chunks) is dramatically under-counted.  The compiled HLO carries
``backend_config={"known_trip_count":{"n":...}}`` on every counted loop,
so we traverse the call graph from ENTRY and weight every computation by
the product of enclosing trip counts.

Counted per instruction:
  * FLOPs: dot (2 * prod(result dims) * prod(lhs contracting dims)) and
    convolution (2 * prod(result dims) * prod(kernel spatial*input feat));
  * HBM bytes: 2 x result bytes (write + one read) of every materialized
    op — fusions count at their surface only, which models a fused
    backend's traffic; parameter/constant/tuple plumbing is free;
    ENTRY arguments are charged once (weight reads).
  * Collective wire bytes: ring models per op kind (see
    launch/roofline.py) x enclosing trip counts.

This is a ~±20% traffic model, not a simulator; it is the profile the
§Perf hillclimb iterates against (the relative deltas are what matter).
"""

from __future__ import annotations

import re
from typing import Any

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
# full multi-group list: replica_groups={{0,1,2,3},{4,5,6,7}}
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\s*\{[^}]*\})*)\}")
# iota v2 form: replica_groups=[2,4]<=[8]  ->  2 groups of 4
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_NUM_PARTITIONS_RE = re.compile(r"num_partitions=(\d+)")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "reshape", "iota",
    "partition-id", "replica-id",
    # layout/elementwise ops the TPU backend fuses into consumers; the
    # CPU backend leaves them explicit and counting them would model CPU
    # (not v5e) traffic:
    "transpose", "copy", "convert", "broadcast", "compare", "select",
    "add", "subtract", "multiply", "divide", "exponential", "tanh",
    "maximum", "minimum", "negate", "rsqrt", "sqrt", "and", "or", "xor",
    "clamp", "floor", "sign", "log", "power", "abs", "reverse",
    "copy-start", "copy-done",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# Layouts may carry tiling / memory-space suffixes on sharded or TPU
# modules: `{1,0:T(8,128)}`, `{1,0:T(8,128)S(1)}` — one brace group with
# optional paren groups inside.
_LAYOUT = r"\{[^{}()]*(?:\([^()]*\)[^{}()]*)*\}"

_OP_RE = re.compile(
    r"(?:^|\)\s|\}\s|\]" + _LAYOUT + r"\s|\]\s)([a-z][a-z0-9\-]*)\(")

# Newer XLA prints operand types inline: `dot(f32[64,128]{1,0} %Arg_0.1,
# ...)`.  Operand-matching regexes accept an optional typed prefix —
# either a single array type (with any layout annotation) or a
# tuple-typed prefix `(f32[..]{..}, s32[..])` (get-tuple-element /
# loop-carry operands of sharded modules).
_TYPED_ONE = r"[a-z0-9]+\[[0-9,]*\](?:" + _LAYOUT + r")?"
_TYPED = (r"(?:(?:" + _TYPED_ONE + r"|\((?:" + _TYPED_ONE
          + r"(?:,\s*)?)*\))\s+)?")


def _shape_bytes(s: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1.0
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(s: str) -> list[int]:
    m = _SHAPE_RE.search(s)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


def _op_kind(rhs: str) -> str:
    """Extract the op name from an instruction right-hand side."""
    # rhs looks like: 'f32[4096,6144]{1,0} dot(%a, %b), ...'
    #             or: '(f32[..], f32[..]) fusion(%a), kind=kLoop, ...'
    m = _OP_RE.search(rhs)
    return m.group(1) if m else ""


def _result_type(rhs: str) -> str:
    """Result-type prefix of an instruction right-hand side.

    Array results end at the first space; tuple-typed results are
    paren-balanced (layouts like ``{1,0:T(8,128)}`` nest parens, so a
    naive ``index(") ")`` scan mis-splits sharded/tiled modules)."""
    if not rhs.startswith("("):
        return rhs.split(" ", 1)[0]
    depth = 0
    for i, ch in enumerate(rhs):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rhs[: i + 1]
    return rhs


def parse_module(text: str) -> dict[str, dict]:
    comps: dict[str, dict] = {}
    cur: dict | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            ls = re.sub(r"/\*.*?\*/", "", line.strip())  # strip /*index=N*/
            # computation headers end with '{' and contain '->' (tuple
            # params nest parens, so match only the leading name)
            if ls.endswith("{") and "->" in ls and "=" not in ls.split("->")[0]:
                m = _COMP_NAME_RE.match(ls)
                if m:
                    cur = {"name": m.group(1), "defs": {}, "rhs": {},
                           "instrs": [], "entry": ls.startswith("ENTRY")}
                    comps[m.group(1)] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        shape_str = _result_type(rhs)
        cur["defs"][name] = shape_str
        cur["rhs"][name] = rhs
        cur["instrs"].append((name, rhs))
    return comps


def _dot_flops(rhs: str, defs: dict[str, str]) -> float:
    out_dims = _shape_dims(rhs)
    m = re.search(r"dot\(" + _TYPED + r"%([\w\.\-]+),", rhs)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    if not (m and cm):
        return 0.0
    lhs_shape = defs.get(m.group(1))
    if lhs_shape is None:
        return 0.0
    ldims = _shape_dims(lhs_shape)
    k = 1.0
    for idx in cm.group(1).split(","):
        if idx != "":
            k *= ldims[int(idx)]
    n = 1.0
    for d in out_dims:
        n *= d
    return 2.0 * n * k


def _conv_flops(rhs: str, defs: dict[str, str]) -> float:
    out_dims = _shape_dims(rhs)
    m = re.search(
        r"convolution\(" + _TYPED + r"%([\w\.\-]+),\s*" + _TYPED + r"%([\w\.\-]+)\)",
        rhs)
    if not m:
        return 0.0
    k_shape = defs.get(m.group(2))
    if k_shape is None:
        return 0.0
    kdims = _shape_dims(k_shape)
    n = 1.0
    for d in out_dims:
        n *= d
    k = 1.0
    for d in kdims[:-1]:  # all but output-feature dim (HWIO-ish)
        k *= d
    return 2.0 * n * k


def _storage_bytes(opname: str, comp: dict) -> float:
    """Bytes of an operand *as stored in HBM*: the XLA CPU backend
    promotes bf16/int8 dot inputs to f32/s32 via explicit converts that a
    TPU backend performs inside the MXU feed.  One-hop trace: if the
    operand is convert(%x) (or a copy of one), charge %x's dtype."""
    own = _shape_bytes(comp["defs"].get(opname, ""))
    name = opname
    for _ in range(4):
        rhs = comp["rhs"].get(name, "")
        # bare convert/copy, or single-operand convert_*_fusion (the CPU
        # backend wraps its bf16->f32 promotion in kLoop fusions)
        m = re.search(r"\s(convert|copy)\(" + _TYPED + r"%([\w\.\-]+)\)", rhs)
        if m:
            kind, src = m.group(1), m.group(2)
        else:
            mf = re.search(r"\sfusion\(" + _TYPED + r"%([\w\.\-]+)\)", rhs)
            if mf and "convert" in name:
                kind, src = "convert", mf.group(1)
            else:
                return own
        if kind == "convert":
            src_sh = comp["defs"].get(src)
            if src_sh is not None and _shape_bytes(src_sh) > 0:
                return min(_shape_bytes(src_sh), own)
        name = src
    return own


# --------------------------------------------------- collective parsing
# ONE shared parser for every consumer of collective structure: the
# roofline accounting (launch/roofline.py:parse_collectives), the
# trip-count-weighted analyze() below, and the sharding auditor
# (analysis/sharding.py).  The two bugs this centralizes away:
#   * async split collectives: an ``all-reduce-start`` result is the
#     tuple ``(operand, result)`` — summing every array in the tuple
#     double-counts the transfer (and the paired ``-done`` must not be
#     counted at all);
#   * multi-group ``replica_groups={{0,1},{2,3}}`` lists — a
#     first-group-only regex reads the wrong group size whenever the
#     mesh has more than one slice of the reduced axis.

def module_num_partitions(text: str) -> int | None:
    """``num_partitions`` from the HloModule header (SPMD partition
    count), or None for unpartitioned modules."""
    m = _NUM_PARTITIONS_RE.search(text)
    return int(m.group(1)) if m else None


def _tuple_elems(rt: str) -> list[str]:
    """Top-level elements of a tuple result type (commas inside
    ``[dims]`` / ``{layout}`` / ``(tiling)`` do not split)."""
    if not rt.startswith("("):
        return [rt]
    body, depth, start, out = rt[1:-1], 0, 0, []
    for i, ch in enumerate(body):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(body[start:i].strip())
            start = i + 1
    out.append(body[start:].strip())
    return [e for e in out if e]


def parse_replica_groups(rhs: str,
                         num_partitions: int | None = None) -> tuple[int, int]:
    """``(group_size, n_groups)`` of a collective instruction.

    Handles all three forms XLA prints: the full (possibly multi-)group
    list ``{{0,1,2,3},{4,5,6,7}}``, the iota v2 form ``[2,4]<=[8]``
    (2 groups of 4), and the empty ``{}`` (one group of every
    partition — needs ``num_partitions`` from the module header)."""
    m = _GROUPS_LIST_RE.search(rhs)
    if m:
        groups = re.findall(r"\{([^}]*)\}", m.group(1))
        sizes = [len([x for x in g.split(",") if x.strip()]) for g in groups]
        return (max(sizes) if sizes else 2, len(sizes))
    m = _GROUPS_IOTA_RE.search(rhs)
    if m:
        return int(m.group(2)), int(m.group(1))
    if "replica_groups={}" in rhs:
        return (num_partitions or 2), 1
    return 2, 1


def collective_result_bytes(rhs: str, raw_kind: str) -> float:
    """Bytes of a collective's RESULT array.  Async ``-start`` forms
    return ``(operand, result[, context...])`` — take the payload
    element (max size: equals result for all-reduce/permute, the gathered
    result for all-gather; min for reduce-scatter, whose result is the
    operand's 1/n shard), never the tuple sum."""
    rt = _result_type(rhs)
    if raw_kind.endswith("-start") and rt.startswith("("):
        sizes = [_shape_bytes(e) for e in _tuple_elems(rt)]
        sizes = [s for s in sizes if s > 0]
        if sizes:
            return (min(sizes) if raw_kind.startswith("reduce-scatter")
                    else max(sizes))
    return _shape_bytes(rt)


def ring_wire_bytes(kind: str, size: float, n: int) -> float:
    """Standard ring-model bytes-on-wire per chip for a collective of
    result size ``size`` over a group of ``n`` (see launch/roofline.py
    for the constants); a group of 1 moves nothing."""
    if n <= 1:
        return 0.0
    if kind == "all-gather":
        return (n - 1) / n * size
    if kind == "reduce-scatter":
        return (n - 1) * size
    if kind == "all-reduce":
        return 2 * (n - 1) / n * size
    if kind == "all-to-all":
        return (n - 1) / n * size
    return size  # collective-permute


def _reduce_region_op(comps: dict, region: str) -> str:
    """Classify a reduction computation (``to_apply=%region``) by its
    combiner: 'add' | 'maximum' | 'minimum' | 'and' | 'or' | ... ('' if
    unresolvable)."""
    comp = comps.get(region)
    if comp is None:
        return ""
    for _, rhs in comp["instrs"]:
        kind = _op_kind(rhs)
        if kind in ("add", "maximum", "minimum", "multiply",
                    "and", "or", "xor"):
            return kind
    return ""


def collective_records(text: str) -> list[dict]:
    """Every collective in the module as a structured record::

        {name, computation, kind, dtype, result_bytes, wire_bytes,
         group_size, n_groups, reduce_op, op_name, is_async}

    ``kind`` is the base op (``-start`` stripped; the paired ``-done``
    is skipped so async pairs count once), ``reduce_op`` the resolved
    ``to_apply`` combiner for reductions, ``op_name`` the source
    metadata (named-scope tags land here)."""
    comps = parse_module(text)
    n_part = module_num_partitions(text)
    records = []
    for comp in comps.values():
        for iname, rhs in comp["instrs"]:
            raw = _op_kind(rhs)
            base = raw[:-6] if raw.endswith("-start") else raw
            if base not in _COLLECTIVES:
                continue
            size = collective_result_bytes(rhs, raw)
            gsz, ngroups = parse_replica_groups(rhs, n_part)
            reg = re.search(r"to_apply=%?([\w\.\-]+)", rhs)
            op_name = re.search(r'op_name="([^"]*)"', rhs)
            dt = re.search(r"([a-z][a-z0-9]*)\[", _result_type(rhs))
            records.append({
                "name": iname, "computation": comp["name"], "kind": base,
                "dtype": dt.group(1) if dt else "",
                "result_bytes": size,
                "wire_bytes": ring_wire_bytes(base, size, gsz),
                "group_size": gsz, "n_groups": ngroups,
                "reduce_op": (_reduce_region_op(comps, reg.group(1))
                              if reg else ""),
                "op_name": op_name.group(1) if op_name else "",
                "is_async": raw.endswith("-start"),
            })
    return records


def _collective_wire(rhs: str, raw_kind: str,
                     num_partitions: int | None = None) -> float:
    base = raw_kind[:-6] if raw_kind.endswith("-start") else raw_kind
    size = collective_result_bytes(rhs, raw_kind)
    n, _ = parse_replica_groups(rhs, num_partitions)
    return ring_wire_bytes(base, size, n)


def analyze(text: str) -> dict[str, Any]:
    comps = parse_module(text)
    n_part = module_num_partitions(text)
    entry = next((c for c in comps.values() if c["entry"]), None)
    assert entry is not None, "no ENTRY computation found"

    memo: dict[str, tuple] = {}

    def comp_cost(name: str, stack=()) -> tuple:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return (0.0, 0.0, {k: 0.0 for k in _COLLECTIVES},
                    {k: 0 for k in _COLLECTIVES})
        c = comps[name]
        flops = 0.0
        bytes_ = 0.0
        coll = {k: 0.0 for k in _COLLECTIVES}
        coll_n = {k: 0 for k in _COLLECTIVES}
        for iname, rhs in c["instrs"]:
            kind = _op_kind(rhs)
            if kind == "dot":
                flops += _dot_flops(rhs, c["defs"])
                # dots stream operands from HBM and write the result;
                # storage-dtype-aware (bf16/int8 stay narrow on TPU)
                for opm in re.finditer(
                        r"dot\(" + _TYPED + r"%([\w\.\-]+),\s*" + _TYPED
                        + r"%([\w\.\-]+)\)", rhs):
                    for nm in opm.groups():
                        bytes_ += _storage_bytes(nm, c)
                bytes_ += _shape_bytes(c["defs"][iname])
                continue
            if kind == "convolution":
                flops += _conv_flops(rhs, c["defs"])
                bytes_ += 2.0 * _shape_bytes(c["defs"][iname])
                continue
            # collectives (incl. async -start forms); when the input is a
            # one-hop convert from a narrower stored dtype, scale the wire
            # bytes down — on TPU the gather moves the stored dtype.
            base = kind[:-6] if kind.endswith("-start") else kind
            if base in _COLLECTIVES:
                wire = _collective_wire(rhs, kind, n_part)
                opm = re.search(base + r"(?:-start)?\(" + _TYPED + r"%([\w\.\-]+)",
                                rhs)
                if opm:
                    full = _shape_bytes(c["defs"].get(opm.group(1), ""))
                    stored = _storage_bytes(opm.group(1), c)
                    if full > 0 and stored < full:
                        wire *= stored / full
                coll[base] += wire
                coll_n[base] += 1
            # in-place update ops: XLA aliases the operand (donated
            # buffers), so traffic = the update region, not the result
            # (KV-cache writes would otherwise count the whole cache)
            if kind in ("scatter", "dynamic-update-slice"):
                ops = re.findall(r"%([\w\.\-]+)", rhs.split("(", 1)[1])
                upd_idx = 2 if kind == "scatter" else 1
                if len(ops) > upd_idx:
                    bytes_ += 2.0 * _shape_bytes(c["defs"].get(ops[upd_idx], ""))
                continue
            # same for update ops hidden inside kLoop fusions: charge the
            # non-aliased operands only (update + indices), not the buffer
            if kind == "fusion" and ("dynamic-update-slice" in iname
                                     or "scatter" in iname
                                     or "dynamic_update_slice" in iname):
                ops = re.findall(r"%([\w\.\-]+)",
                                 rhs.split("fusion(", 1)[1].split(")", 1)[0])
                sizes = sorted(
                    (_shape_bytes(c["defs"].get(o, "")) for o in ops),
                    reverse=True)
                bytes_ += 2.0 * sum(sizes[1:])  # all but the aliased buffer
                continue
            # bytes: write + one read of every materialized op surface
            if kind not in _SKIP_BYTES_OPS and not kind.endswith("-done"):
                bytes_ += 2.0 * _shape_bytes(c["defs"][iname])
            # children: (name, multiplier, fused?) — fused computations
            # contribute FLOPs (kOutput fusions wrap dots on CPU) but not
            # bytes (their surface is already counted above).
            children: list[tuple[str, float, bool]] = []
            if kind == "while":
                t = _TRIP_RE.search(rhs)
                mult = float(t.group(1)) if t else 1.0
                for key in ("body", "condition"):
                    mm = re.search(key + r"=%([\w\.\-]+)", rhs)
                    if mm:
                        children.append((mm.group(1), mult, False))
            elif kind == "call":
                mm = re.search(r"to_apply=%([\w\.\-]+)", rhs)
                if mm:
                    children.append((mm.group(1), 1.0, False))
            elif kind == "fusion":
                mm = re.search(r"calls=%([\w\.\-]+)", rhs)
                if mm:
                    children.append((mm.group(1), 1.0, True))
            elif kind == "conditional":
                for mm in re.finditer(r"(?:branch_computations=\{|true_computation=|false_computation=)%?([\w\.\-]+)", rhs):
                    children.append((mm.group(1), 1.0, False))
            for ch, mult, fused in children:
                f2, b2, c2, n2 = comp_cost(ch, stack + (name,))
                flops += mult * f2
                if not fused:
                    bytes_ += mult * b2
                for k in _COLLECTIVES:
                    coll[k] += mult * c2[k]
                    coll_n[k] += int(mult * n2[k])
        memo[name] = (flops, bytes_, coll, coll_n)
        return memo[name]

    flops, bytes_, coll, coll_n = comp_cost(entry["name"])
    return {
        "flops": flops,
        "bytes": bytes_,
        "collective_wire_bytes": coll,
        "collective_counts": coll_n,
        "total_wire_bytes": sum(coll.values()),
    }
