"""Production mesh construction (device state touched only inside fns)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "install_local_mesh",
           "VIRTUAL_DEVICES_FLAG", "virtual_device_env"]

# Host-platform virtual devices: the ONE way to get a multi-device CPU
# process (must be set before jax initializes — subprocess tests, the
# sharded bench rows, and the virtual-8-device CI job all use it).
VIRTUAL_DEVICES_FLAG = "--xla_force_host_platform_device_count={n}"


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: (data=16, model=16) = 256 chips; multi_pod adds a
    leading pod axis: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    return jax.make_mesh((data, model), ("data", "model"))


def install_local_mesh(data: int = 1, model: int = 1):
    """Build a local (data, model) mesh AND install it as the module
    mesh context (sharding/ctx.py) so the whole serving stack — the
    sharded consensus head walk, the weight-cache plane-stack sharding,
    the batcher's slot-state placement — routes through it.  Returns the
    mesh; ``sharding.ctx.set_mesh(None)`` uninstalls."""
    from repro.sharding import ctx

    mesh = make_local_mesh(data, model)
    ctx.set_mesh(mesh)
    return mesh


def virtual_device_env(n: int, env: dict | None = None) -> dict:
    """A copy of ``env`` (default os.environ) whose XLA_FLAGS force ``n``
    host-platform virtual devices — for SUBPROCESSES that need a
    multi-device CPU (the flag is read once at jax init, so the current
    process cannot apply it to itself).  Existing XLA_FLAGS are
    preserved; an existing device-count flag is overridden."""
    import os

    out = dict(os.environ if env is None else env)
    flags = [f for f in out.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    flags.append(VIRTUAL_DEVICES_FLAG.format(n=n))
    out["XLA_FLAGS"] = " ".join(flags)
    return out
