"""Production mesh construction (device state touched only inside fns)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: (data=16, model=16) = 256 chips; multi_pod adds a
    leading pod axis: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    return jax.make_mesh((data, model), ("data", "model"))
