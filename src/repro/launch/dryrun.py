import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first init).  This module is the ONLY place that forces
# 512 host devices — tests and benches see the real device count.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, and emit the roofline artifact per cell.

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both
    PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/dryrun

Artifacts (JSON, one per cell) carry: cost_analysis FLOPs/bytes,
memory_analysis, parsed collective wire bytes, roofline terms, and
MODEL_FLOPS — EXPERIMENTS.md §Dry-run/§Roofline are generated from them
(benchmarks/roofline_report.py)."""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, all_cells, cell_supported, get_config, input_specs
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import model_flops, roofline_terms
from repro.models.common import abstract, count_params
from repro.models.config import ModelConfig
from repro.models.encdec import encdec_build
from repro.models.transformer import lm_build
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.serve.engine import (abstract_state, make_decode_step,
                                make_prefill_step, state_specs)
from repro.sharding.axes import batch_spec, named, param_specs, safe_spec
from repro.train.step import (TrainConfig, make_train_step,
                              train_step_shardings)
from jax.sharding import PartitionSpec as P


def build_desc(cfg: ModelConfig):
    return encdec_build(cfg) if cfg.family == "encdec" else lm_build(cfg)


def _batch_shardings(mesh, specs: dict):
    out = {}
    for k, v in specs.items():
        if k == "rope_positions":
            out[k] = P(None, batch_spec(mesh, v.shape[1])[0], None)
        else:
            b = batch_spec(mesh, v.shape[0])[0]
            out[k] = P(b, *([None] * (len(v.shape) - 1)))
    return out


def lower_cell(arch: str, shape: str, multi_pod: bool,
               tcfg: TrainConfig | None = None, l2r: bool = False,
               score_bf16: bool = False, moe_hints: bool = False,
               wq: bool = False, kv_shard: str = "heads",
               moe_dp_local: bool = False, head_shard: bool = False):
    """Returns (lowered, compiled, meta) for one cell.

    Hillclimb switches (all default off -> paper-faithful baseline):
      score_bf16 — bf16 attention score blocks (f32 stats);
      moe_hints  — interior sharding hints on the MoE dispatch path;
      wq         — int8-stored weights (W8A8 L2R serving arithmetic).
    """
    import dataclasses as _dc

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if l2r:
        from repro.core.quant import QuantConfig
        cfg = _dc.replace(cfg, l2r=QuantConfig())
    if score_bf16:
        cfg = _dc.replace(cfg, attn_score_dtype="bfloat16")
    if head_shard:
        cfg = _dc.replace(cfg, attn_head_shard=True)
    if moe_dp_local:
        cfg = _dc.replace(cfg, moe_dp_local=True)
    if moe_hints or moe_dp_local or head_shard:
        from repro.sharding import ctx
        ctx.set_mesh(mesh)
    sp = SHAPES[shape]
    desc = build_desc(cfg)
    if wq:
        from repro.models.common import quantize_desc
        assert sp.kind != "train", "int8 weight storage is a serving mode"
        desc = quantize_desc(desc)
    specs = input_specs(arch, shape, cfg)
    tcfg = tcfg or TrainConfig()

    if sp.kind == "train":
        params_abs = abstract(desc, param_dtype=jnp.bfloat16)
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        step = make_train_step(cfg, AdamWConfig(), tcfg, mesh)
        ins, outs = train_step_shardings(cfg, mesh, desc, specs)
        fn = jax.jit(step, in_shardings=ins, out_shardings=outs,
                     donate_argnums=(0, 1))
        lowered = fn.lower(params_abs, opt_abs, specs)
        n_tokens = sp.global_batch * sp.seq_len
    elif sp.kind == "prefill":
        params_abs = abstract(desc, param_dtype=jnp.bfloat16)
        step = make_prefill_step(cfg, max_len=sp.seq_len)
        pspecs = named(mesh, param_specs(desc, mesh))
        bspecs = named(mesh, _batch_shardings(mesh, specs))
        sspecs = named(mesh, state_specs(cfg, mesh, sp.global_batch, sp.seq_len,
                                         kv_shard))
        lspec = named(mesh, safe_spec(
            (sp.global_batch, 1, cfg.vocab),
            P(batch_spec(mesh, sp.global_batch)[0], None, "model"), mesh))
        fn = jax.jit(step, in_shardings=(pspecs, bspecs),
                     out_shardings=(sspecs, lspec))
        lowered = fn.lower(params_abs, specs)
        n_tokens = sp.global_batch * sp.seq_len
    else:  # decode
        params_abs = abstract(desc, param_dtype=jnp.bfloat16)
        state_abs = abstract_state(cfg, sp.global_batch, sp.seq_len)
        step = make_decode_step(cfg)
        pspecs = named(mesh, param_specs(desc, mesh))
        sspecs = named(mesh, state_specs(cfg, mesh, sp.global_batch, sp.seq_len,
                                         kv_shard))
        bspec = batch_spec(mesh, sp.global_batch)[0]
        tok_in = named(mesh, P(bspec, None))
        lspec = named(mesh, safe_spec((sp.global_batch, 1, cfg.vocab),
                                      P(bspec, None, "model"), mesh))
        in_sh = (pspecs, sspecs, tok_in)
        args = (params_abs, state_abs, specs["tokens"])
        if "rope_positions" in specs:
            in_sh = in_sh + (named(mesh, P(None, bspec, None)),)
            args = args + (specs["rope_positions"],)
        fn = jax.jit(step, in_shardings=in_sh,
                     out_shardings=(sspecs, named(mesh, P(bspec, None)), lspec),
                     donate_argnums=(1,))
        lowered = fn.lower(*args)
        n_tokens = sp.global_batch  # one new token per sequence

    if moe_hints or moe_dp_local or head_shard:
        from repro.sharding import ctx
        ctx.set_mesh(None)
    meta = dict(arch=arch, shape=shape, kind=sp.kind,
                multi_pod=multi_pod, chips=mesh.size,
                params=count_params(desc),
                n_tokens=n_tokens, l2r=l2r,
                opts=dict(score_bf16=score_bf16, moe_hints=moe_hints, wq=wq))
    return lowered, cfg, desc, meta


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             tcfg: TrainConfig | None = None, l2r: bool = False,
             tag: str = "", skip_existing: bool = False,
             score_bf16: bool = False, moe_hints: bool = False,
             wq: bool = False, kv_shard: str = "heads",
             moe_dp_local: bool = False, head_shard: bool = False) -> dict:
    mp_name = "2pod" if multi_pod else "1pod"
    path = os.path.join(out_dir, f"{arch}_{shape}_{mp_name}{tag}.json")
    if skip_existing and os.path.exists(path):
        with open(path) as fh:
            rec = json.load(fh)
        print(f"[CACHED] {arch} x {shape} x {mp_name}{tag}")
        return rec
    t0 = time.time()
    lowered, cfg, desc, meta = lower_cell(arch, shape, multi_pod, tcfg, l2r,
                                          score_bf16, moe_hints, wq, kv_shard,
                                          moe_dp_local, head_shard)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            k: getattr(mem, k)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)
        }
    except Exception as e:  # CPU backend may not implement it
        mem_d = {"error": str(e)}

    hlo = compiled.as_text()
    # trip-count-aware analysis (XLA's cost_analysis counts while bodies
    # once; see launch/hlo_analysis.py) — this is the roofline source.
    ana = analyze(hlo)
    flops = ana["flops"]
    bytes_hbm = ana["bytes"]
    rl = roofline_terms(flops, bytes_hbm, ana["total_wire_bytes"], meta["chips"])
    mf = model_flops(cfg, desc, meta["n_tokens"], meta["kind"])

    rec = {
        **meta,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "cost_analysis_raw": {k: cost[k] for k in ("flops", "bytes accessed")
                              if k in cost},
        "memory_analysis": mem_d,
        "collectives": {"wire_bytes": ana["collective_wire_bytes"],
                        "counts": ana["collective_counts"],
                        "total_wire_bytes": ana["total_wire_bytes"]},
        "roofline": rl.asdict(),
        "model_flops_per_chip": mf / meta["chips"],
        "useful_compute_ratio": (mf / meta["chips"]) / flops if flops else None,
        "hlo_bytes": len(hlo),
    }
    os.makedirs(out_dir, exist_ok=True)
    mp = "2pod" if multi_pod else "1pod"
    name = f"{arch}_{shape}_{mp}{tag}.json"
    with open(os.path.join(out_dir, name), "w") as fh:
        json.dump(rec, fh, indent=1)
    try:  # archive compressed HLO: re-analysis without recompilation
        import zstandard
        with open(os.path.join(out_dir, name.replace(".json", ".hlo.zst")),
                  "wb") as fh:
            fh.write(zstandard.ZstdCompressor(level=3).compress(hlo.encode()))
    except Exception:
        pass
    print(f"[OK] {arch} x {shape} x {mp}{tag}: compile {t_compile:.1f}s "
          f"dominant={rl.dominant} bound={rl.bound_s*1e3:.2f}ms "
          f"useful={rec['useful_compute_ratio'] and round(rec['useful_compute_ratio'],3)}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--l2r", action="store_true",
                    help="enable the paper's digit-plane arithmetic in matmuls")
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--xent-chunk", type=int, default=512)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--score-bf16", action="store_true",
                    help="bf16 attention score blocks (hillclimb)")
    ap.add_argument("--moe-hints", action="store_true",
                    help="interior sharding hints on MoE dispatch (hillclimb)")
    ap.add_argument("--wq", action="store_true",
                    help="int8-stored weights: W8A8 L2R serving (hillclimb)")
    ap.add_argument("--kv-seq-shard", action="store_true",
                    help="shard KV caches on the sequence dim (hillclimb)")
    ap.add_argument("--moe-dp-local", action="store_true",
                    help="DP-local-capacity MoE dispatch (hillclimb)")
    ap.add_argument("--head-shard", action="store_true",
                    help="shard attention on the KV-head dim (hillclimb)")
    args = ap.parse_args()

    tcfg = TrainConfig(remat=not args.no_remat, seq_shard=not args.no_seq_shard,
                       xent_chunk=args.xent_chunk)
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]

    cells = []
    if args.all:
        for a, s, ok, why in all_cells():
            if ok:
                cells.append((a, s))
            else:
                print(f"[SKIP] {a} x {s}: {why}")
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        ok, why = cell_supported(args.arch, args.shape)
        if not ok:
            print(f"[SKIP] {args.arch} x {args.shape}: {why}")
            return
        cells.append((args.arch, args.shape))

    failures = []
    for (a, s) in cells:
        for mp in pods:
            try:
                run_cell(a, s, mp, args.out, tcfg, args.l2r, args.tag,
                         args.skip_existing, args.score_bf16,
                         args.moe_hints, args.wq,
                         "seq" if args.kv_seq_shard else "heads",
                         args.moe_dp_local, args.head_shard)
            except Exception:
                failures.append((a, s, mp))
                print(f"[FAIL] {a} x {s} x {'2pod' if mp else '1pod'}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cell(s) failed: {failures}")
    print("dry-run complete")


if __name__ == "__main__":
    main()
