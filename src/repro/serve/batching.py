"""Continuous batching: slot-based request scheduling over the decode step.

Production serving does not decode one static batch to completion — new
requests join as finished ones leave.  This engine keeps a fixed-size
slot array (the jitted decode step sees a constant batch shape, so XLA
never recompiles), tracks per-slot positions in the LMState, and:

  * admits queued requests into free slots by running a single-slot
    prefill and splicing its KV/state into the live batch state;
  * steps all active slots with one decode call (idle slots masked);
  * retires slots on EOS or max-token budget.

CPU-sized but structurally the real thing: slot splicing is pure
tree-surgery on the cache pytree (dynamic_update_slice on the batch
axis), exactly what a TPU serving binary does.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.policy import LevelPolicy, PrecisionClass
from repro.models.config import ModelConfig
from repro.models.transformer import init_lm_state
from .engine import (_bspec, bucket_for, make_bucket_prefill_step,
                     make_decode_step, make_prefill_step, prefill_buckets,
                     state_specs, supports_bucketed_prefill)

__all__ = ["Request", "ContinuousBatcher", "infer_batch_axes",
           "state_batch_axes", "latency_percentiles", "progressive_stats"]


def latency_percentiles(ttft: list, tpot: list) -> dict:
    """p50/p99 over per-request latency samples (seconds); 0.0 when no
    samples — the stats() schema stays fixed from construction on."""
    def p(xs, q):
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

    return {"ttft_p50_s": p(ttft, 50), "ttft_p99_s": p(ttft, 99),
            "tpot_p50_s": p(tpot, 50), "tpot_p99_s": p(tpot, 99)}


def progressive_stats(n_levels: int, exit_hist, prefill_exit_hist,
                      exit_hist_by_class: dict,
                      prefill_exit_hist_by_class: dict) -> dict:
    """The progressive saved-levels stats block, shared by
    `ContinuousBatcher.stats` and `ServingGateway.stats` so the
    histogram schema cannot drift between the two engines (they once
    disagreed on raw-int vs stringified level keys).

    Normalized schema, the ONE place it is defined:

      * level histograms are positional lists indexed by 0-based MSDF
        exit level (``hist[l]`` = tokens committed after ``l + 1``
        levels) — never level-keyed dicts;
      * per-class maps key on the precision class's
        :meth:`~repro.core.policy.PrecisionClass.label` STRING
        ("exact", "budget(3)", "bounded(0.0001)"), sorted, each value a
        positional level-hist list of the same length.
    """
    levels = np.arange(n_levels)
    total = int(np.sum(exit_hist))
    mean_exit = (float((exit_hist * levels).sum() / total)
                 if total else 0.0)
    total_p = int(np.sum(prefill_exit_hist))
    return dict(
        n_levels=n_levels,
        exit_level_hist=np.asarray(exit_hist).tolist(),
        mean_exit_level=mean_exit,
        mean_levels_saved=(float(n_levels - 1 - mean_exit)
                          if total else 0.0),
        prefill_exit_level_hist=np.asarray(prefill_exit_hist).tolist(),
        mean_prefill_exit_level=(
            float((prefill_exit_hist * levels).sum() / total_p)
            if total_p else 0.0),
        exit_level_hist_by_class={
            k: np.asarray(v).tolist()
            for k, v in sorted(exit_hist_by_class.items())},
        prefill_exit_level_hist_by_class={
            k: np.asarray(v).tolist()
            for k, v in sorted(prefill_exit_hist_by_class.items())},
    )


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (prompt_len,)
    max_new_tokens: int
    eos_id: int | None = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    # progressive mode: MSDF exit level of each decoded token (the levels
    # a digit-serial deployment would actually compute for that step)
    exit_levels: list = dataclasses.field(default_factory=list)
    # progressive mode: MSDF exit level of the streamed prefill head
    # (the first generated token, committed from the LAST prompt
    # position's logit stream)
    prefill_exit_level: int | None = None
    # progressive mode: this request's precision class (exact / budget /
    # bounded — core/policy.py).  None = the engine's default class.
    precision: PrecisionClass | None = None
    done: bool = False
    # latency timestamps (time.perf_counter seconds).  ``t_arrival`` is
    # stamped at submit() unless the caller pre-stamped it (traffic
    # replay: a Poisson generator stamps the synthetic arrival instant);
    # ``t_first_token`` when the first token is committed,
    # ``t_complete`` at retirement.  TTFT = t_first_token - t_arrival,
    # mean TPOT = (t_complete - t_first_token) / (len(output) - 1).
    t_arrival: float | None = None
    t_first_token: float | None = None
    t_complete: float | None = None


def infer_batch_axes(abstract_a, abstract_b):
    """Per-leaf batch-axis tree, derived from the state pytree STRUCTURE:
    the same init evaluated abstractly at two batch sizes; each leaf's
    batch axis is the unique axis whose size changed.  -1 = no batch axis
    (batch-independent leaf).

    This replaces the old shape-coincidence heuristic in `_splice`
    (``s.shape[0] == b.shape[0] and ... != 1``), which mis-located the
    batch axis for stacked ``(layers, batch, ...)`` leaves with
    ``n_layers == 1`` and for leaves where ``n_slots`` happened to equal
    a non-batch dim.
    """
    def ax(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y]
        if not diffs:
            return -1
        assert len(diffs) == 1, (
            f"ambiguous batch axis: {a.shape} vs {b.shape}")
        return diffs[0]

    return jax.tree.map(ax, abstract_a, abstract_b)


def state_batch_axes(cfg: ModelConfig, max_len: int,
                     cache_dtype=jnp.float32):
    """Batch-axis tree of the LM serving state (see infer_batch_axes)."""
    return infer_batch_axes(
        jax.eval_shape(lambda: init_lm_state(cfg, 1, max_len, cache_dtype)),
        jax.eval_shape(lambda: init_lm_state(cfg, 2, max_len, cache_dtype)))


def _splice(batch_tree, single_tree, slot: int, axes_tree):
    """Write `single` (batch=1 leaves) into `batch` at index `slot` of
    each leaf's EXPLICIT batch axis (`axes_tree`, from infer_batch_axes).

    Leaves may differ in non-batch dims (a fresh prefill cache is sized
    to the prompt): the update is placed at offset 0 of each non-batch
    dim, which is correct because positions beyond the prompt are marked
    empty (-1) in the donor cache.
    """
    def f(b, s, ax):
        if ax < 0:  # batch-independent leaf: nothing to splice
            return b
        start = tuple(slot if i == ax else 0 for i in range(b.ndim))
        upd = s
        want = tuple(1 if i == ax else d for i, d in enumerate(b.shape))
        if upd.shape != want:
            pads = [(0, 0) if i == ax else (0, bd - ud)
                    for i, (bd, ud) in enumerate(zip(b.shape, upd.shape))]
            upd = jnp.pad(upd, pads, constant_values=_pad_value(b))
        return jax.lax.dynamic_update_slice(b, upd.astype(b.dtype), start)

    return jax.tree.map(f, batch_tree, single_tree, axes_tree)


def _pad_value(b):
    """Empty sentinel for donor-cache padding.  Integer leaves carry
    position/validity semantics in this state tree (positions use -1 =
    empty), so EVERY integer dtype pads with the all-ones "empty"
    sentinel — keying on int32 alone left int8/int16/uint caches padded
    with 0, silently marking padded positions as valid.  Unsigned
    integers cannot hold -1 and saturate to their max (the same all-ones
    bit pattern); floats are data-only and pad with 0.
    """
    if jnp.issubdtype(b.dtype, jnp.unsignedinteger):
        return int(jnp.iinfo(b.dtype).max)
    if jnp.issubdtype(b.dtype, jnp.integer):
        return -1
    return 0


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, n_slots: int = 4,
                 max_len: int = 128, cache_dtype=jnp.float32,
                 progressive: bool = False, early_exit: bool = False,
                 mesh=None, state_sharding: str = "replicated",
                 donate_state: bool = True, bucketed: bool | None = None,
                 default_class: PrecisionClass | None = None):
        """``mesh`` (default: the installed ``sharding.ctx`` mesh) makes
        the engine mesh-aware: the progressive head stream runs the
        shard_mapped consensus walk (vocab over "model", slot rows over
        the data axes, early exit at the fleet-wide slowest slot) and
        the slot state is placed on the mesh per ``state_sharding``:

          * ``"replicated"`` (default) — the backbone state replicates;
            only the head walk is sharded (it batch-shards its rows
            internally, and integer arithmetic is immune to the
            partitioning).  Decode is bit-identical to the unmeshed
            engine end to end: tokens, exit levels, stats all match
            exactly.
          * ``"batch"`` — every state leaf shards its BATCH axis (the
            slot axis, over the data axes).  Scales slot memory across
            data; numerically equivalent but NOT bit-pinned: under
            combined data x model shardings GSPMD may repartition
            interior float contractions of the backbone (observed: the
            attention o-projection over the hint-sharded flattened
            heads axis), which reassociates float sums — hidden states,
            and hence MARGINAL early-exit levels, can move by a bit.
          * ``"specs"`` — the full ``engine.state_specs`` policy (kv
            heads / head_dim / SSM channels over "model"): the
            memory-scaling layout for caches that do not fit one
            device.  Partitioning attention's head_dim reassociates its
            float contraction directly — same numerics caveat as
            ``"batch"``, strictly more sharding.

        In every mode the streaming walk itself stays bit-exact for
        whatever hidden states it is fed (committed tokens always pass
        the same decision machinery).

        ``donate_state`` (default True) donates the slot state to the
        jitted decode step (``donate_argnums``): XLA writes the updated
        KV caches in place instead of copying the full cache pytree
        every token — the dominant decode-side memory traffic at real
        cache sizes.  The old reference is rebound to the output each
        step, so the donation is invisible to callers; pass False only
        to debug aliasing.

        ``bucketed`` routes admits through power-of-2 prompt-length
        buckets (engine.make_bucket_prefill_step): prompts right-pad to
        the smallest covering bucket so prefill traces once per BUCKET,
        not once per unique prompt length — the classic serving retrace
        leak.  Bit-exact (pad positions are masked out of the cache).
        Default None = auto: on for attention-mixer families (and, with
        local windows, when the cache bound fits the window), off
        otherwise.

        ``default_class`` (progressive mode) is the
        :class:`~repro.core.policy.PrecisionClass` applied to requests
        that do not carry their own ``Request.precision``, and to idle
        slot rows.  Default ``bounded(0.0)`` — bit-identical to the
        legacy batch-global early-exit walk, so a batcher constructed
        without policies serves exactly what it always served.  Each
        admitted request's class is spliced into the per-slot
        :class:`~repro.core.policy.LevelPolicy` rows, so one fused
        decode loop serves a heterogeneous exact/budget/bounded batch.
        """
        from repro.sharding import ctx

        assert state_sharding in ("replicated", "batch", "specs"), \
            state_sharding
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.progressive = progressive
        self.mesh = mesh if mesh is not None else ctx.get_mesh()
        self.state = init_lm_state(cfg, n_slots, max_len, cache_dtype)
        # explicit per-leaf batch axes for slot splicing (derived from the
        # state pytree structure, never from shape coincidences)
        self._axes = state_batch_axes(cfg, max_len, cache_dtype)
        if self.mesh is not None:
            if state_sharding == "specs":
                spec_tree = state_specs(cfg, self.mesh, n_slots, max_len)
                self._state_sh = jax.tree.map(
                    lambda s: NamedSharding(self.mesh, s), spec_tree,
                    is_leaf=lambda x: isinstance(x, P))
            elif state_sharding == "batch":
                b = _bspec(self.mesh, n_slots)
                self._state_sh = jax.tree.map(
                    lambda leaf, ax: NamedSharding(self.mesh, P(*(
                        b if i == ax else None for i in range(leaf.ndim)))),
                    self.state, self._axes)
            else:  # replicated: committed to the mesh, every leaf whole
                self._state_sh = jax.tree.map(
                    lambda leaf: NamedSharding(self.mesh, P()), self.state)
            self.state = jax.device_put(self.state, self._state_sh)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.cur_tok = jnp.zeros((n_slots, 1), jnp.int32)
        if self.mesh is not None:
            # replicated mode keeps the tokens whole too: a data-sharded
            # token input would batch-shard every backbone activation
            # behind it, re-opening the data x model repartitioning the
            # mode exists to avoid (the head walk row-shards internally)
            tok_spec = (P(None, None) if state_sharding == "replicated"
                        else P(_bspec(self.mesh, n_slots), None))
            self.cur_tok = jax.device_put(
                self.cur_tok, NamedSharding(self.mesh, tok_spec))
        self.queue: list[Request] = []
        # replicated backbone -> trace the steps with the interior
        # sharding hints scoped off (they would pin interior tensors of
        # a replicated computation onto model axes and float-reassociate
        # backbone contractions; see ctx.hints_disabled)
        hints = state_sharding != "replicated"
        self._decode = jax.jit(make_decode_step(cfg, progressive=progressive,
                                                early_exit=early_exit,
                                                backbone_hints=hints,
                                                mesh=self.mesh),
                               donate_argnums=(1,) if donate_state else ())
        self._prefill1 = jax.jit(make_prefill_step(
            cfg, max_len, cache_dtype, progressive=progressive,
            early_exit=early_exit, backbone_hints=hints, mesh=self.mesh))
        if bucketed is None:
            local = any(k == "local" for k, _ in cfg.layer_kinds())
            bucketed = supports_bucketed_prefill(cfg) and \
                (not local or max_len <= cfg.window)
        self.bucketed = bucketed
        if bucketed:
            self._buckets = prefill_buckets(max_len)
            self._bucket_prefill = jax.jit(make_bucket_prefill_step(
                cfg, max_len, cache_dtype, progressive=progressive,
                early_exit=early_exit, backbone_hints=hints, mesh=self.mesh))
        self.steps = 0
        # saved-levels accounting (progressive mode): histograms over the
        # MSDF exit level of every decoded token across all requests AND
        # of every streamed prefill head (the first generated token),
        # plus the same histograms split per precision class
        self.n_levels = (2 * cfg.l2r.planes - 1
                         if progressive and cfg.l2r is not None else 0)
        self.exit_hist = np.zeros(max(self.n_levels, 1), np.int64)
        self.prefill_exit_hist = np.zeros(max(self.n_levels, 1), np.int64)
        if default_class is not None and not progressive:
            raise ValueError("default_class steers the progressive head "
                             "walk: requires progressive=True")
        self.default_class = (default_class or PrecisionClass.bounded()
                              if progressive else None)
        self.slot_policy = (LevelPolicy.from_classes(
            [self.default_class] * n_slots) if progressive else None)
        seed = ({self.default_class.label():
                 np.zeros(max(self.n_levels, 1), np.int64)}
                if progressive else {})
        self.exit_hist_by_class = {k: v.copy() for k, v in seed.items()}
        self.prefill_exit_hist_by_class = dict(seed)
        # per-request latency samples, recorded at retirement (seconds)
        self._ttft: list[float] = []
        self._tpot: list[float] = []

    # ------------------------------------------------------------- api
    def submit(self, req: Request):
        if req.precision is not None and not self.progressive:
            raise ValueError("Request.precision steers the progressive "
                             "head walk: requires progressive=True")
        if req.t_arrival is None:
            req.t_arrival = time.perf_counter()
        self.queue.append(req)

    def _class_of(self, req: Request) -> PrecisionClass:
        return req.precision if req.precision is not None \
            else self.default_class

    def _class_hist(self, hists: dict, label: str) -> np.ndarray:
        if label not in hists:
            hists[label] = np.zeros(max(self.n_levels, 1), np.int64)
        return hists[label]

    def _prefill_request(self, req: Request):
        """One-sequence prefill, through the bucket pad when enabled.

        Bucketed: the prompt right-pads to its power-of-2 bucket and
        runs the bucket step with the true length — one trace per
        BUCKET shape instead of one per unique prompt length, and the
        returned state is bit-identical to the unpadded prefill (pad
        cache entries are masked empty, ``pos`` is the true length).

        Progressive: the request's precision class rides along as a
        one-row LevelPolicy (class VALUES are array contents, never
        trace shapes — mixing classes cannot retrace).
        """
        prompt = np.asarray(req.prompt, np.int32)
        pol1 = (LevelPolicy.from_classes([self._class_of(req)])
                if self.progressive else None)
        if self.bucketed:
            lb = bucket_for(len(prompt), self._buckets)
            padded = np.zeros((1, lb), np.int32)
            padded[0, :len(prompt)] = prompt
            return self._bucket_prefill(
                self.params, jnp.asarray(padded),
                jnp.asarray([len(prompt)], jnp.int32), pol1)
        return self._prefill1(self.params,
                              {"tokens": jnp.asarray(prompt[None, :])},
                              pol1)

    def _admit(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            if self.progressive:
                # batch-progressive prefill: the head streams the LAST
                # prompt position only, committing the first token at its
                # earliest sound level (under the request's class)
                st1, _, tok, lv = self._prefill_request(req)
                first = tok[0, 0]
                level = int(lv[0, 0])
                req.prefill_exit_level = level
                self.prefill_exit_hist[level] += 1
                cls = self._class_of(req)
                self._class_hist(self.prefill_exit_hist_by_class,
                                 cls.label())[level] += 1
                # splice the class into the live per-slot policy rows
                self.slot_policy = self.slot_policy.set_row(slot, cls)
            else:
                st1, logits = self._prefill_request(req)
                first = jnp.argmax(logits[0, -1]).astype(jnp.int32)
            # splice the single-sequence state into the live batch state
            self.state = _splice(self.state, st1, slot, self._axes)
            if self.mesh is not None:
                # the eager splice lets the output sharding drift toward
                # the (replicated) donor; re-pin the slot state layout
                self.state = jax.device_put(self.state, self._state_sh)
            self.cur_tok = self.cur_tok.at[slot, 0].set(first)
            req.output.append(int(first))
            req.t_first_token = time.perf_counter()
            self.slot_req[slot] = req

    def _retire(self):
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            eos = req.eos_id is not None and req.output and \
                req.output[-1] == req.eos_id
            full = len(req.output) >= req.max_new_tokens
            of_cache = int(self.state.pos[slot]) >= self.max_len - 1
            if eos or full or of_cache:
                req.done = True
                req.t_complete = time.perf_counter()
                if req.t_arrival is not None and req.t_first_token is not None:
                    self._ttft.append(req.t_first_token - req.t_arrival)
                    if len(req.output) > 1:
                        self._tpot.append(
                            (req.t_complete - req.t_first_token)
                            / (len(req.output) - 1))
                self.slot_req[slot] = None
                if self.progressive:
                    # idle rows revert to the default class so an
                    # `exact` occupant cannot pin the early-exit loop
                    # at full depth after retirement
                    self.slot_policy = self.slot_policy.set_row(
                        slot, self.default_class)

    def step(self):
        """One engine iteration: admit, decode all active slots, retire."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return False
        if self.progressive:
            self.state, nxt, _, lv = self._decode(self.params, self.state,
                                                  self.cur_tok, None,
                                                  self.slot_policy)
        else:
            self.state, nxt, _ = self._decode(self.params, self.state,
                                              self.cur_tok)
            lv = None
        self.cur_tok = nxt
        for slot, req in enumerate(self.slot_req):
            if req is not None:
                req.output.append(int(nxt[slot, 0]))
                if lv is not None:
                    level = int(lv[slot, 0])
                    req.exit_levels.append(level)
                    self.exit_hist[level] += 1
                    self._class_hist(self.exit_hist_by_class,
                                     self._class_of(req).label())[level] += 1
        self.steps += 1
        self._retire()
        return True

    def run(self, max_steps: int = 10_000):
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and self.steps < max_steps:
            if not self.step() and self.queue:
                continue
        return self.steps

    def stats(self, latency: bool = False) -> dict:
        """Engine counters; in progressive mode also the saved-levels
        histograms: exit_level_hist[l] tokens committed after l+1 MSDF
        levels during DECODE (a digit-serial deployment skips the
        remaining n_levels-1-l levels of head compute for those tokens),
        and prefill_exit_level_hist[l] streamed PREFILL heads (one per
        admitted request — the first generated token, committed from the
        last prompt position's stream).

        The progressive-mode schema is STABLE: ``n_levels``, the counts,
        both (zero-filled) histograms, and the means are present from
        construction on — they used to appear only once the first
        token/prefill landed, so monitoring consumers scraping stats()
        saw the dict change shape mid-run.  Means over zero events are
        reported as 0.0.  The histogram block (including the per-class
        split, string-label keys) is the shared `progressive_stats`
        schema — identical to `ServingGateway.stats`.

        ``latency=True`` additionally reports per-request wall-clock
        percentiles over RETIRED requests (completed count, p50/p99
        time-to-first-token and per-output-token seconds).  Opt-in
        because the default schema is deterministic for a fixed request
        set — tests and replica-consistency checks compare stats()
        dicts exactly, which wall-clock samples would break.
        """
        out = {"steps": self.steps, "progressive": self.progressive}
        if latency:
            out.update(completed=len(self._ttft),
                       **latency_percentiles(self._ttft, self._tpot))
        if self.progressive:
            out.update(
                tokens=int(self.exit_hist.sum()),
                prefills=int(self.prefill_exit_hist.sum()),
                **progressive_stats(self.n_levels, self.exit_hist,
                                    self.prefill_exit_hist,
                                    self.exit_hist_by_class,
                                    self.prefill_exit_hist_by_class),
            )
        return out
