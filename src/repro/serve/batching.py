"""Continuous batching: slot-based request scheduling over the decode step.

Production serving does not decode one static batch to completion — new
requests join as finished ones leave.  This engine keeps a fixed-size
slot array (the jitted decode step sees a constant batch shape, so XLA
never recompiles), tracks per-slot positions in the LMState, and:

  * admits queued requests into free slots by running a single-slot
    prefill and splicing its KV/state into the live batch state;
  * steps all active slots with one decode call (idle slots masked);
  * retires slots on EOS or max-token budget.

CPU-sized but structurally the real thing: slot splicing is pure
tree-surgery on the cache pytree (dynamic_update_slice on the batch
axis), exactly what a TPU serving binary does.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import init_lm_state
from .engine import make_decode_step, make_prefill_step

__all__ = ["Request", "ContinuousBatcher"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (prompt_len,)
    max_new_tokens: int
    eos_id: int | None = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    # progressive mode: MSDF exit level of each decoded token (the levels
    # a digit-serial deployment would actually compute for that step)
    exit_levels: list = dataclasses.field(default_factory=list)
    done: bool = False


def _splice(batch_tree, single_tree, slot: int):
    """Write `single` (batch=1 leaves) into `batch` at index `slot`.

    Leaves may differ in non-batch dims (a fresh prefill cache is sized
    to the prompt): the update is placed at offset 0 of each non-batch
    dim, which is correct because positions beyond the prompt are marked
    empty (-1) in the donor cache.
    """
    def f(b, s):
        if b.ndim == 0:
            return b
        # locate the batch axis: the first axis where sizes differ by
        # batch semantics — by construction it is axis 0 for pos and
        # axis 0/1 for stacked caches (leading 'layers' axis).
        if s.shape[0] == b.shape[0] and b.ndim > 1 and s.shape[0] != 1:
            # stacked (layers, batch, ...) leaf
            start = (0, slot) + (0,) * (b.ndim - 2)
            upd = s
            if upd.shape[2:] != b.shape[2:]:
                pads = [(0, 0), (0, 0)] + [
                    (0, bd - ud) for bd, ud in zip(b.shape[2:], upd.shape[2:])
                ]
                upd = jnp.pad(upd, pads, constant_values=_pad_value(b))
            return jax.lax.dynamic_update_slice(b, upd.astype(b.dtype), start)
        start = (slot,) + (0,) * (b.ndim - 1)
        upd = s
        if upd.shape[1:] != b.shape[1:]:
            pads = [(0, 0)] + [
                (0, bd - ud) for bd, ud in zip(b.shape[1:], upd.shape[1:])
            ]
            upd = jnp.pad(upd, pads, constant_values=_pad_value(b))
        return jax.lax.dynamic_update_slice(b, upd.astype(b.dtype), start)

    return jax.tree.map(f, batch_tree, single_tree)


def _pad_value(b):
    return -1 if b.dtype == jnp.int32 else 0


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, n_slots: int = 4,
                 max_len: int = 128, cache_dtype=jnp.float32,
                 progressive: bool = False):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.progressive = progressive
        self.state = init_lm_state(cfg, n_slots, max_len, cache_dtype)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.cur_tok = jnp.zeros((n_slots, 1), jnp.int32)
        self.queue: list[Request] = []
        self._decode = jax.jit(make_decode_step(cfg, progressive=progressive))
        self._prefill1 = jax.jit(make_prefill_step(cfg, max_len, cache_dtype))
        self.steps = 0
        # saved-levels accounting (progressive mode): histogram over the
        # MSDF exit level of every decoded token across all requests
        self.n_levels = (2 * cfg.l2r.planes - 1
                         if progressive and cfg.l2r is not None else 0)
        self.exit_hist = np.zeros(max(self.n_levels, 1), np.int64)

    # ------------------------------------------------------------- api
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
            st1, logits = self._prefill1(self.params, {"tokens": prompt})
            first = jnp.argmax(logits[0, -1]).astype(jnp.int32)
            # splice the single-sequence state into the live batch state
            self.state = _splice(self.state, st1, slot)
            # pos leaf is (B,): fix it explicitly (splice handles arrays,
            # but pos from st1 is scalar-per-seq)
            self.state.pos = self.state.pos.at[slot].set(int(st1.pos[0]))
            self.cur_tok = self.cur_tok.at[slot, 0].set(first)
            req.output.append(int(first))
            self.slot_req[slot] = req

    def _retire(self):
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            eos = req.eos_id is not None and req.output and \
                req.output[-1] == req.eos_id
            full = len(req.output) >= req.max_new_tokens
            of_cache = int(self.state.pos[slot]) >= self.max_len - 1
            if eos or full or of_cache:
                req.done = True
                self.slot_req[slot] = None

    def step(self):
        """One engine iteration: admit, decode all active slots, retire."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return False
        if self.progressive:
            self.state, nxt, _, lv = self._decode(self.params, self.state,
                                                  self.cur_tok)
        else:
            self.state, nxt, _ = self._decode(self.params, self.state,
                                              self.cur_tok)
            lv = None
        self.cur_tok = nxt
        for slot, req in enumerate(self.slot_req):
            if req is not None:
                req.output.append(int(nxt[slot, 0]))
                if lv is not None:
                    level = int(lv[slot, 0])
                    req.exit_levels.append(level)
                    self.exit_hist[level] += 1
        self.steps += 1
        self._retire()
        return True

    def run(self, max_steps: int = 10_000):
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and self.steps < max_steps:
            if not self.step() and self.queue:
                continue
        return self.steps

    def stats(self) -> dict:
        """Engine counters; in progressive mode also the saved-levels
        histogram: exit_level_hist[l] tokens committed after l+1 MSDF
        levels (a digit-serial deployment skips the remaining
        n_levels-1-l levels of head compute for those tokens)."""
        out = {"steps": self.steps, "progressive": self.progressive}
        if self.progressive and self.exit_hist.sum():
            total = int(self.exit_hist.sum())
            levels = np.arange(self.n_levels)
            mean_exit = float((self.exit_hist * levels).sum() / total)
            out.update(
                n_levels=self.n_levels,
                tokens=total,
                exit_level_hist=self.exit_hist.tolist(),
                mean_exit_level=mean_exit,
                mean_levels_saved=float(self.n_levels - 1 - mean_exit),
            )
        return out
