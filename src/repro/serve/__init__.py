from .engine import (make_prefill_step, make_decode_step, state_specs,
                     abstract_state, greedy_generate)
from .batching import ContinuousBatcher, Request
