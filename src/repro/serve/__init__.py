from .engine import (make_prefill_step, make_decode_step,
                     make_bucket_prefill_step, prefill_buckets, bucket_for,
                     supports_bucketed_prefill, state_specs,
                     abstract_state, greedy_generate)
from .batching import ContinuousBatcher, Request, latency_percentiles
from .gateway import ServingGateway
