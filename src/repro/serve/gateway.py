"""Serving gateway: bucketed AOT prefill, donated decode, async emit.

The JetStream-shaped front end over the continuous-batching engine
(ROADMAP item 1).  `ContinuousBatcher` is structurally correct but pays
three per-request / per-step taxes that dominate at fleet scale:

  * prefill retraces for every unique prompt length, and prefills one
    prompt at a time inline with decode;
  * the jitted decode step copies the full KV-cache pytree every token
    (no donation);
  * `step()` blocks the device loop on a host sync per slot
    (``int(nxt[slot, 0])``) before the next decode can dispatch.

`ServingGateway` removes all three:

  * **Bucketed, packed prefill** — prompts right-pad to power-of-2
    length buckets (`engine.prefill_buckets`) and up to
    ``prefill_group`` queued prompts share ONE prefill dispatch at a
    fixed ``(group, bucket)`` shape.  One executable per bucket, ever;
    bit-exact (pad cache entries are masked empty, the head reads the
    true last position — `engine.make_bucket_prefill_step`).
  * **AOT warmup + donated decode** — every per-bucket prefill
    executable and the decode step are compiled at startup via
    ``jit(...).lower(...).compile()`` (in/out shardings pinned by the
    lowered arrays), so the first request pays no trace; decode donates
    the slot state (``donate_argnums``), so XLA updates the KV caches
    in place instead of copying them every token.
  * **Async emit** — the device loop never reads a device value.  Token
    arrays stream through a bounded queue to an emit thread that does
    the host syncs (``np.asarray``), appends tokens to requests, stamps
    latency timestamps, and detects EOS.  Retirement on token budget is
    computed HOST-SIDE at admission (``min(max_new_tokens,
    max_len - prompt_len)`` tokens, exactly the plain batcher's
    semantics), so the loop frees slots without waiting on results; EOS
    retirement necessarily lags by the queue depth and is signalled
    back as a ``(slot, generation)`` pair — the generation counter
    keeps a stale signal from freeing a reassigned slot.

Output streams are bit-identical to `ContinuousBatcher` for the same
request set (tests/test_gateway.py): bucketed prefill is bit-exact,
rows of a packed prefill are independent, and decode rows are
independent, so batching composition cannot move a token.
"""

from __future__ import annotations

import queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.policy import LevelPolicy, PrecisionClass
from repro.models.config import ModelConfig
from repro.models.transformer import init_lm_state
from .batching import (Request, _splice, latency_percentiles,
                       progressive_stats, state_batch_axes)
from .engine import (bucket_for, make_bucket_prefill_step, make_decode_step,
                     prefill_buckets, supports_bucketed_prefill)

__all__ = ["ServingGateway"]


class _EmitThread:
    """Bounded-queue emit worker: drains (kind, entries, device-arrays)
    items, doing the host syncs (np.asarray) OFF the device loop.  A
    single FIFO drained by a single thread processes dispatches in
    device order, so each request's tokens append in sequence order.
    Worker exceptions are captured and re-raised at flush()/close()."""

    def __init__(self, process, depth: int):
        self._process = process
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: BaseException | None = None
        self._t = threading.Thread(target=self._run, daemon=True,
                                   name="gateway-emit")
        self._t.start()

    def put(self, item):
        self._q.put(item)

    def _run(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                if self._err is None:  # fail-stop: keep draining, no work
                    self._process(item)
            except BaseException as e:  # re-raised on the caller's thread
                self._err = e
            finally:
                self._q.task_done()

    def flush(self):
        """Block until every queued item is processed; re-raise worker
        errors on the calling thread."""
        self._q.join()
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def close(self):
        self.flush()
        self._q.put(None)
        self._t.join()


class _Slot:
    """Host-side per-slot bookkeeping: the owning request, the number of
    decode steps left (token-budget retirement, known at admission), and
    a generation counter so retirement signals for a PREVIOUS occupant
    cannot free the current one."""

    __slots__ = ("req", "rem", "gen")

    def __init__(self):
        self.req: Request | None = None
        self.rem = 0
        self.gen = 0


class ServingGateway:
    """Offline-inference driver and online request-queue server over the
    serving engine.  See the module docstring for the design; the public
    surface mirrors `ContinuousBatcher`:

        gw = ServingGateway(cfg, params, n_slots=8, max_len=128)
        gw.submit(Request(uid=0, prompt=..., max_new_tokens=32))
        gw.run()                  # offline: drain everything
        gw.run(realtime=True)     # online: honor Request.t_arrival stamps
        gw.stats()

    ``prefill_group`` is the packed-prefill width: up to that many
    queued prompts (sharing a length bucket) prefill in one dispatch;
    short groups pad with dummy rows (``true_len = 1``) whose outputs
    are ignored — the executable shape never varies.  ``aot_warmup``
    compiles every per-bucket prefill executable and the decode step at
    construction; ``async_emit=False`` degrades the emit thread to
    inline processing (debug aid — same code path, synchronous).

    ``mesh`` runs the engine mesh-aware with REPLICATED state (the
    batcher's ``state_sharding="replicated"`` mode): the progressive
    head streams through the sharded consensus walk, the backbone
    traces with interior sharding hints scoped off, and tokens/stats
    stay bit-identical to the unmeshed gateway.

    ``default_class`` mirrors `ContinuousBatcher`: the
    :class:`~repro.core.policy.PrecisionClass` for requests without
    their own ``Request.precision`` and for idle/dummy rows (default
    ``bounded(0.0)`` — the legacy walk bit for bit).  Admission splices
    each request's class into the per-slot
    :class:`~repro.core.policy.LevelPolicy` rows, packed prefills carry
    a per-row group policy, and the AOT executables lower the policy as
    a trailing positional argument — classes are array VALUES, so no
    class mix can trigger a trace.
    """

    def __init__(self, cfg: ModelConfig, params, n_slots: int = 8,
                 max_len: int = 128, cache_dtype=jnp.float32,
                 progressive: bool = False, early_exit: bool = False,
                 prefill_group: int = 4, buckets: tuple[int, ...] | None = None,
                 mesh=None, aot_warmup: bool = True, async_emit: bool = True,
                 emit_queue_depth: int = 8,
                 default_class: PrecisionClass | None = None):
        from repro.sharding import ctx

        assert supports_bucketed_prefill(cfg), \
            "gateway serving needs bucketed prefill: attention families only"
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.progressive = progressive
        self.prefill_group = prefill_group
        self.buckets = tuple(buckets) if buckets else prefill_buckets(max_len)
        assert self.buckets[-1] == max_len, \
            "the largest bucket must be the cache bound"
        self.mesh = mesh if mesh is not None else ctx.get_mesh()

        self.state = init_lm_state(cfg, n_slots, max_len, cache_dtype)
        self._axes = state_batch_axes(cfg, max_len, cache_dtype)
        self.cur_tok = jnp.zeros((n_slots, 1), jnp.int32)
        if self.mesh is not None:
            sh = jax.tree.map(
                lambda leaf: NamedSharding(self.mesh, P()), self.state)
            self.state = jax.device_put(self.state, sh)
            self.cur_tok = jax.device_put(
                self.cur_tok, NamedSharding(self.mesh, P(None, None)))

        if default_class is not None and not progressive:
            raise ValueError("default_class steers the progressive head "
                             "walk: requires progressive=True")
        self.default_class = (default_class or PrecisionClass.bounded()
                              if progressive else None)
        self.slot_policy = (LevelPolicy.from_classes(
            [self.default_class] * n_slots) if progressive else None)

        # replicated backbone -> interior sharding hints scoped off (see
        # ContinuousBatcher: they would float-reassociate contractions)
        hints = False if self.mesh is not None else True
        self._prefill_fn = make_bucket_prefill_step(
            cfg, max_len, cache_dtype, progressive=progressive,
            early_exit=early_exit, backbone_hints=hints, mesh=self.mesh)
        self._decode_fn = make_decode_step(
            cfg, progressive=progressive, early_exit=early_exit,
            backbone_hints=hints, mesh=self.mesh)
        # fallback jitted entry points (shape-keyed cache: still one
        # trace per bucket); AOT warmup swaps in Compiled executables
        self._prefill_jit = jax.jit(self._prefill_fn)
        self._decode_jit = jax.jit(self._decode_fn, donate_argnums=(1,))
        self._prefill_exe: dict[int, object] = {}
        self._decode_exe = None
        if aot_warmup:
            self.warmup()

        self._slots = [_Slot() for _ in range(n_slots)]
        self.queue: list[Request] = []
        self.steps = 0
        self.prefills = 0

        # emit-side accounting (owned by the emit thread; read after
        # flush())
        self.n_levels = (2 * cfg.l2r.planes - 1
                         if progressive and cfg.l2r is not None else 0)
        self.exit_hist = np.zeros(max(self.n_levels, 1), np.int64)
        self.prefill_exit_hist = np.zeros(max(self.n_levels, 1), np.int64)
        seed = ({self.default_class.label():
                 np.zeros(max(self.n_levels, 1), np.int64)}
                if progressive else {})
        self.exit_hist_by_class = {k: v.copy() for k, v in seed.items()}
        self.prefill_exit_hist_by_class = dict(seed)
        self._ttft: list[float] = []
        self._tpot: list[float] = []
        self._tokens = 0
        self._completed = 0
        self._elapsed = 0.0
        # EOS retirement signals from the emit thread: (slot, generation)
        self._eos_lock = threading.Lock()
        self._eos_signals: set[tuple[int, int]] = set()
        self._emit = (_EmitThread(self._process_emit, emit_queue_depth)
                      if async_emit else None)

    # ---------------------------------------------------------- warmup
    def warmup(self):
        """AOT-compile the decode step and one prefill executable per
        bucket (``jit(...).lower(...).compile()``).  Lowering against
        the live (committed) params/state pins the executables' in/out
        shardings; afterwards no request shape can trigger a trace.
        Progressive executables take the LevelPolicy rows as a trailing
        positional argument (class mixes are array values, not trace
        shapes)."""
        g = self.prefill_group

        def pol_sds(rows):
            return LevelPolicy(
                jax.ShapeDtypeStruct((rows,), jnp.int32),
                jax.ShapeDtypeStruct((rows,), jnp.int32),
                jax.ShapeDtypeStruct((rows,), jnp.float32))

        for lb in self.buckets:
            if lb in self._prefill_exe:
                continue
            args = [self.params,
                    jax.ShapeDtypeStruct((g, lb), jnp.int32),
                    jax.ShapeDtypeStruct((g,), jnp.int32)]
            if self.progressive:
                args.append(pol_sds(g))
            self._prefill_exe[lb] = (
                jax.jit(self._prefill_fn).lower(*args).compile())
        if self._decode_exe is None:
            args = [self.params, self.state,
                    jax.ShapeDtypeStruct((self.n_slots, 1), jnp.int32)]
            if self.progressive:
                args.extend([None, pol_sds(self.n_slots)])
            self._decode_exe = (
                jax.jit(self._decode_fn, donate_argnums=(1,))
                .lower(*args).compile())

    # ------------------------------------------------------------- api
    def submit(self, req: Request):
        if req.precision is not None and not self.progressive:
            raise ValueError("Request.precision steers the progressive "
                             "head walk: requires progressive=True")
        if req.t_arrival is None:
            req.t_arrival = time.perf_counter()
        self.queue.append(req)

    def _class_of(self, req: Request) -> PrecisionClass:
        return req.precision if req.precision is not None \
            else self.default_class

    def _class_hist(self, hists: dict, label: str) -> np.ndarray:
        if label not in hists:
            hists[label] = np.zeros(max(self.n_levels, 1), np.int64)
        return hists[label]

    def run(self, requests=None, max_steps: int = 100_000,
            realtime: bool = False):
        """Serve until the queue and all slots drain (or ``max_steps``
        decode dispatches).  ``requests`` is submitted first (offline
        driver convenience).  ``realtime=True`` honors future
        ``Request.t_arrival`` stamps — a pre-stamped trace (e.g. a
        Poisson arrival process) replays in real time; otherwise every
        queued request is admissible immediately."""
        if requests is not None:
            for r in requests:
                self.submit(r)
        t0 = time.perf_counter()
        steps0 = self.steps
        while self.queue or any(s.req is not None for s in self._slots):
            if self.steps - steps0 >= max_steps:
                break
            self._drain_eos_signals()
            self._admit(realtime)
            if all(s.req is None for s in self._slots):
                if not self.queue:
                    break
                if realtime:
                    nxt = min(r.t_arrival for r in self.queue)
                    dt = nxt - time.perf_counter()
                    if dt > 0:
                        time.sleep(min(dt, 0.05))
                    continue
                # EOS-retirement lag can leave every slot waiting on the
                # emit thread while the queue still holds work
                self._flush_emit()
                continue
            self._decode_step()
        self._flush_emit()
        self._drain_eos_signals()
        self._elapsed += time.perf_counter() - t0
        return self.steps

    def stats(self, latency: bool = True) -> dict:
        """Gateway counters (emit-thread flushed first): dispatch and
        token counts, throughput, progressive saved-levels histograms
        (same schema as `ContinuousBatcher.stats`), and — unless
        ``latency=False`` — p50/p99 TTFT and per-output-token seconds
        over completed requests."""
        self._flush_emit()
        out = {"steps": self.steps, "prefills": self.prefills,
               "progressive": self.progressive, "tokens": self._tokens,
               "completed": self._completed,
               "buckets": list(self.buckets),
               "tokens_per_s": (self._tokens / self._elapsed
                                if self._elapsed > 0 else 0.0)}
        if self.progressive:
            out.update(progressive_stats(self.n_levels, self.exit_hist,
                                         self.prefill_exit_hist,
                                         self.exit_hist_by_class,
                                         self.prefill_exit_hist_by_class))
        if latency:
            out.update(latency_percentiles(self._ttft, self._tpot))
        return out

    def close(self):
        if self._emit is not None:
            self._emit.close()
            self._emit = None

    # ------------------------------------------------------ device loop
    def _free_slots(self):
        return [i for i, s in enumerate(self._slots) if s.req is None]

    def _admissible(self, realtime: bool):
        if not realtime:
            return self.queue
        now = time.perf_counter()
        return [r for r in self.queue if r.t_arrival <= now]

    def _admit(self, realtime: bool = False):
        """Admit queued requests by PACKED bucket prefill: up to
        ``prefill_group`` admissible prompts sharing a length bucket go
        through one fixed-shape dispatch; short groups pad with dummy
        rows (true_len 1) whose outputs never leave the device."""
        while True:
            free = self._free_slots()
            cand = self._admissible(realtime)
            if not free or not cand:
                return
            lead = cand[0]
            lb = bucket_for(len(lead.prompt), self.buckets)
            group: list[Request] = []
            for r in cand:  # FIFO scan: later prompts may share the bucket
                if len(group) >= min(len(free), self.prefill_group):
                    break
                if bucket_for(len(r.prompt), self.buckets) <= lb:
                    group.append(r)
            for r in group:
                self.queue.remove(r)

            g = self.prefill_group
            tokens = np.zeros((g, lb), np.int32)
            true_len = np.ones((g,), np.int32)  # dummy rows: one pad token
            for i, r in enumerate(group):
                p = np.asarray(r.prompt, np.int32)
                tokens[i, :len(p)] = p
                true_len[i] = len(p)
            exe = self._prefill_exe.get(lb, self._prefill_jit)
            if self.progressive:
                # per-row group policy: admitted requests' classes,
                # dummy pad rows at the default class
                gcls = [self._class_of(r) for r in group]
                gcls += [self.default_class] * (g - len(group))
                out = exe(self.params, jnp.asarray(tokens),
                          jnp.asarray(true_len),
                          LevelPolicy.from_classes(gcls))
            else:
                out = exe(self.params, jnp.asarray(tokens),
                          jnp.asarray(true_len))
            if self.progressive:
                st1, _, tok, lv = out
            else:
                st1, logits = out
                tok = jnp.argmax(logits[:, -1], axis=-1,
                                 keepdims=True).astype(jnp.int32)
                lv = None
            self.prefills += 1

            entries = []
            for i, r in enumerate(group):
                slot = free[i]
                s = self._slots[slot]
                s.req = r
                s.rem = self._budget_steps(r)
                row = jax.tree.map(
                    lambda x, a: jax.lax.slice_in_dim(x, i, i + 1, axis=a)
                    if a >= 0 else x, st1, self._axes)
                self.state = _splice(self.state, row, slot, self._axes)
                self.cur_tok = self.cur_tok.at[slot, 0].set(tok[i, 0])
                if self.progressive:
                    self.slot_policy = self.slot_policy.set_row(
                        slot, self._class_of(r))
                entries.append((i, slot, s.gen, r))
            self._dispatch_emit(("prefill", entries, tok, lv))

    def _budget_steps(self, req: Request) -> int:
        """Decode steps owed to a request AFTER its prefill token,
        decided host-side at admission so the device loop retires slots
        without reading a device value.  Mirrors `ContinuousBatcher`
        exactly: retirement is evaluated after a decode, so every
        admitted request receives AT LEAST one decode step, then stops
        at the token budget (``len(output) >= max_new_tokens``) or the
        cache bound (``pos >= max_len - 1``), whichever bites first."""
        return max(1, min(req.max_new_tokens - 1,
                          self.max_len - 1 - len(req.prompt)))

    def _decode_step(self):
        if self.progressive:
            out = (self._decode_exe or self._decode_jit)(
                self.params, self.state, self.cur_tok, None,
                self.slot_policy)
        else:
            out = (self._decode_exe or self._decode_jit)(
                self.params, self.state, self.cur_tok)
        if self.progressive:
            self.state, tok, _, lv = out
        else:
            self.state, tok, _ = out
            lv = None
        self.cur_tok = tok
        self.steps += 1
        entries = []
        for slot, s in enumerate(self._slots):
            if s.req is None:
                continue
            entries.append((slot, s.gen, s.req))
            s.rem -= 1
            if s.rem <= 0:
                self._release(slot)
        self._dispatch_emit(("decode", entries, tok, lv))

    def _release(self, slot: int):
        s = self._slots[slot]
        s.req = None
        s.rem = 0
        s.gen += 1  # stale EOS signals for the old occupant die here
        if self.progressive:
            # idle rows revert to the default class (an `exact` leftover
            # would pin the early-exit loop at full depth)
            self.slot_policy = self.slot_policy.set_row(
                slot, self.default_class)

    def _drain_eos_signals(self):
        with self._eos_lock:
            signals, self._eos_signals = self._eos_signals, set()
        for slot, gen in signals:
            if self._slots[slot].req is not None and \
                    self._slots[slot].gen == gen:
                self._release(slot)

    # ------------------------------------------------------ emit thread
    def _dispatch_emit(self, item):
        if self._emit is not None:
            self._emit.put(item)
        else:
            self._process_emit(item)

    def _flush_emit(self):
        if self._emit is not None:
            self._emit.flush()

    def _process_emit(self, item):
        """Host-side token landing (emit thread): sync the device
        arrays, append tokens in dispatch order, stamp timestamps,
        detect EOS.  ``entries`` rows are (row-in-dispatch, slot, gen,
        req) for prefill and (slot, gen, req) for decode."""
        kind, entries, tok, lv = item
        tok = np.asarray(tok).reshape(-1)
        lv = np.asarray(lv).reshape(-1) if lv is not None else None
        now = time.perf_counter()
        if kind == "prefill":
            for row, slot, gen, req in entries:
                req.t_first_token = now
                if lv is not None:
                    level = int(lv[row])
                    req.prefill_exit_level = level
                    self.prefill_exit_hist[level] += 1
                    self._class_hist(self.prefill_exit_hist_by_class,
                                     self._class_of(req).label())[level] += 1
                self._land(req, int(tok[row]), slot, gen)
        else:
            for slot, gen, req in entries:
                if req.done:  # EOS already hit; drop the lagged tokens
                    continue
                if lv is not None:
                    level = int(lv[slot])
                    req.exit_levels.append(level)
                    self.exit_hist[level] += 1
                    self._class_hist(self.exit_hist_by_class,
                                     self._class_of(req).label())[level] += 1
                self._land(req, int(tok[slot]), slot, gen)

    def _land(self, req: Request, t: int, slot: int, gen: int):
        req.output.append(t)
        self._tokens += 1
        n_expect = 1 + self._budget_steps(req)
        eos = req.eos_id is not None and t == req.eos_id
        if eos or len(req.output) >= n_expect:
            req.done = True
            req.t_complete = time.perf_counter()
            if req.t_arrival is not None and req.t_first_token is not None:
                self._ttft.append(req.t_first_token - req.t_arrival)
                if len(req.output) > 1:
                    self._tpot.append((req.t_complete - req.t_first_token)
                                      / (len(req.output) - 1))
            self._completed += 1
            if eos:  # budget retirement the device loop already knows
                with self._eos_lock:
                    self._eos_signals.add((slot, gen))
