"""Serving engine: prefill / decode step factories, cache shardings,
batched greedy decoding, progressive-precision mode.

Cache sharding policy (per DESIGN.md §5): batch over DP axes when it
divides; on the "model" axis shard kv-heads when they divide 16,
otherwise head_dim (every assigned arch divides one of the two); SSM /
RG-LRU states shard their channel dim.  `long_500k` (batch=1) replicates
batch and relies on the model-axis sharding to fit.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.policy import LevelPolicy
from repro.core.progressive import streaming_argmax
from repro.core.quant import QuantConfig, QuantizedWeights, quantize
from repro.models.attention import KVCache
from repro.models.config import ModelConfig
from repro.models.encdec import (EncDecState, encdec_forward,
                                 init_encdec_state)
from repro.models.transformer import (LMState, init_lm_state, lm_forward,
                                      logits_from_hidden)
from repro.sharding.axes import dp_axes

__all__ = ["prepare_params", "make_prefill_step", "make_decode_step",
           "make_bucket_prefill_step", "prefill_buckets", "bucket_for",
           "supports_bucketed_prefill",
           "progressive_logits_from_hidden", "state_specs", "abstract_state",
           "greedy_generate"]


# ------------------------------------------------------- weight preparation
def prepare_params(cfg: ModelConfig, params, desc=None, prestack: bool = True,
                   mesh: Mesh | None = None):
    """Load-time serving weights: build the L2R weight cache ONCE.

    When ``cfg.l2r`` is set, every eligible matmul weight is converted to
    a :class:`~repro.core.quant.QuantizedWeights` record (int8 + per-
    out-channel scale) exactly once, here — the prefill/decode traces
    then stream activations through the dispatched level-stacked
    digit-plane kernel with NO per-step weight quantization.  Without an
    L2R config this is the identity (bf16/f32 serving).

    ``prestack=True`` (default) also caches every record's reversed RHS
    digit-plane stack (core/quant.py:PlaneOperands), so the decode/
    prefill traces carry no weight plane extraction either — planes are
    extracted exactly once per process.  The head cache is additionally
    built with the streaming window padding: the progressive head stream
    (``progressive_logits_from_hidden``, every decode step) consumes the
    cached stack with zero per-step operand preparation.  Costs D x (the
    head 2D-1 x) the int8 weight bytes; pass False for the
    extract-per-call layout.

    ``mesh`` (default: the installed ``sharding.ctx`` mesh) pins the
    head cache's sharding at build time: the (K, V) int8 head, its
    scales, and the window-padded plane stack are partitioned over the
    ``model`` axis on the vocab dim — the layout the ``shard_map``ped
    consensus head stream (core/progressive.py) consumes without any
    per-step resharding.  Backbone weights stay replicated (activations
    are batch-sharded instead; the head is the one vocab-axis matmul of
    every decode step).  Sharding never changes values.

    ``desc`` is the Param descriptor tree (for eligibility); defaults to
    rebuilding it from ``cfg`` for LM families.
    """
    if cfg.l2r is None:
        return params
    from repro.core.quant import quantize_weights
    from repro.models.common import quantize_tree
    from repro.sharding import ctx

    if mesh is None:
        mesh = ctx.get_mesh()
    if desc is None:
        assert cfg.family != "encdec", "pass the encdec desc tree explicitly"
        from repro.models.transformer import lm_build

        desc = lm_build(cfg)
    out = quantize_tree(desc, params, cfg.l2r, prestack=prestack)
    # the LM head (vocab-axis, excluded from quantize_tree so embedding
    # lookups keep the f32 table) is the LARGEST matmul of every decode
    # step — cache its int8 form too so logits_from_hidden and the
    # progressive head stream skip per-step weight quantization
    head = (out["embed"].T if cfg.tie_embeddings else out.get("head")) \
        if isinstance(out, dict) else None
    if head is not None and not isinstance(head, QuantizedWeights):
        out = {**out, "head_q": quantize_weights(
            head, cfg.l2r, prestack=prestack, window_pad=prestack,
            shard=(None, "model") if mesh is not None else None, mesh=mesh)}
    return out


# ------------------------------------------------------------- shardings
def _model_axis_for_cache(cfg: ModelConfig, mesh: Mesh) -> tuple:
    """(kv_heads_axis, head_dim_axis) for KV caches."""
    m = mesh.shape.get("model", 1)
    if cfg.n_kv % m == 0:
        return ("model", None)
    if cfg.head_dim % m == 0:
        return (None, "model")
    return (None, None)


def _bspec(mesh: Mesh, batch: int):
    axes = dp_axes(mesh)
    import math
    size = math.prod(mesh.shape[a] for a in axes)
    if batch % size == 0 and size > 1:
        return axes
    if batch % mesh.shape.get("data", 1) == 0:
        return "data"
    return None


def state_specs(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int,
                kv_shard: str = "heads"):
    """PartitionSpec tree matching init_lm_state/init_encdec_state.

    kv_shard="heads": model axis on kv-heads (or head_dim) — baseline.
    kv_shard="seq":   model axis on the cache sequence dim — decode
    attention then reduces over a sharded axis and GSPMD emits tiny
    softmax-stat all-reduces instead of gathering the whole cache
    (§Perf hillclimb C: 79 GB/step of KV all-gather eliminated).
    """
    b = _bspec(mesh, batch)
    kvh, hd = _model_axis_for_cache(cfg, mesh)
    m = mesh.shape.get("model", 1)

    def kv_spec():
        # the incrementally plane-stacked key cache (cfg.attn_l2r) adds
        # k_planes/k_scale leaves; their specs mirror the float cache
        # (None fields stay empty pytree nodes when the knob is off).
        # The plane axis is (2D-1)*dh — never sharded (head_dim shards
        # would split plane blocks); the scale has no head_dim axis.
        planes = cfg.attn_l2r is not None
        if kv_shard == "seq":
            seq_ax = "model"
            return KVCache(
                k=P(b, seq_ax, None, None),
                v=P(b, seq_ax, None, None),
                positions=P(b, seq_ax),
                k_planes=P(b, seq_ax, None, None) if planes else None,
                k_scale=P(b, seq_ax, None) if planes else None)
        return KVCache(
            k=P(b, None, kvh, hd), v=P(b, None, kvh, hd),
            positions=P(b, None),
            k_planes=P(b, None, kvh, None) if planes else None,
            k_scale=P(b, None, kvh) if planes else None)

    def mixer_spec(kind: str):
        if kind in ("global", "local"):
            return kv_spec()
        if kind == "ssd":
            d_inner = cfg.ssm_expand * cfg.d_model
            conv_dim = d_inner + 2 * cfg.ssm_state
            heads = d_inner // cfg.ssm_head_dim
            return {
                "ssd": P(b, "model" if heads % m == 0 else None, None, None),
                "conv": P(b, None, "model" if conv_dim % m == 0 else None),
            }
        if kind == "rec":
            w = cfg.lru_width or cfg.d_model
            wa = "model" if w % m == 0 else None
            return {"h": P(b, wa), "conv": P(b, None, wa)}
        raise ValueError(kind)

    if cfg.family == "encdec":
        c = kv_spec()
        return EncDecState(
            self_cache=KVCache(k=P(None, *c.k), v=P(None, *c.v),
                               positions=P(None, *c.positions)),
            cross_k=P(None, b, None, kvh, hd),
            cross_v=P(None, b, None, kvh, hd),
            pos=P(b),
        )

    prefix, repeats, unit, suffix = cfg.block_grouping()
    add_layer = lambda spec: jax.tree.map(
        lambda s: P(None, *s), spec, is_leaf=lambda x: isinstance(x, P))
    stack = None
    if repeats:
        stack = [add_layer(mixer_spec(kk[0])) for kk in unit]
    return LMState(
        prefix=[mixer_spec(kk[0]) for kk in prefix],
        stack=stack,
        suffix=[mixer_spec(kk[0]) for kk in suffix],
        pos=P(b),
    )


def abstract_state(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    """ShapeDtypeStruct state (dry-run input without allocation)."""
    init = (init_encdec_state if cfg.family == "encdec" else init_lm_state)
    return jax.eval_shape(lambda: init(cfg, batch, max_len, dtype))


# ------------------------------------------------------------ step factories
def _check_step_flags(progressive: bool, early_exit: bool,
                      policy: LevelPolicy | None = None) -> None:
    """Reject contradictory step-factory flag combinations.

    ``early_exit``/``levels`` knobs are kept as shims over the
    :class:`~repro.core.policy.LevelPolicy` path, but both shim and
    policy ride the progressive head stream — asking for either with
    ``progressive=False`` is a contradiction, not a silent no-op."""
    if early_exit and not progressive:
        raise ValueError(
            "contradictory arguments: early_exit=True requires "
            "progressive=True — early_exit stops the streamed head's "
            "level loop, which only exists on the progressive path "
            "(got progressive=False, early_exit=True)")
    if policy is not None and not progressive:
        raise ValueError(
            "contradictory arguments: policy requires progressive=True — "
            "LevelPolicy rows steer the streamed head's level walk, which "
            "only exists on the progressive path "
            "(got progressive=False with policy set)")


def make_prefill_step(cfg: ModelConfig, max_len: int,
                      cache_dtype=jnp.bfloat16,
                      progressive: bool = False,
                      early_exit: bool = False,
                      backbone_hints: bool = True,
                      mesh: Mesh | None = None,
                      policy: LevelPolicy | None = None) -> Callable:
    """(params, batch) -> (state, last_token_logits).

    ``progressive=True`` (LM families, requires ``cfg.l2r``) is
    batch-level progressive prefill: the backbone runs exactly over the
    whole prompt, and the LM head streams for the LAST prompt token ONLY
    — the other positions are never argmaxed by anyone, so they take the
    exact one-shot path (here: they are simply never fed to the head,
    the same ``hidden[:, -1:]`` slice the one-shot prefill uses).  The
    step then returns ``(state, logits, first_tok (B, 1) int32,
    exit_level (B, 1) int32)``; ``first_tok`` always equals
    ``argmax(logits_from_hidden(...))`` of the one-shot prefill.
    ``early_exit`` stops the head's level loop once every sequence in the
    prefill batch has decided (see make_decode_step).

    ``backbone_hints=False`` traces the step with the interior sharding
    hints scoped off (sharding/ctx.py:hints_disabled): the right setting
    whenever the backbone state is REPLICATED on the mesh — the hints
    would pin interior tensors of a replicated computation onto model
    axes, making GSPMD repartition (and float-reassociate) backbone
    contractions.  The streamed head still routes through the sharded
    consensus walk; with the hints off the whole step is bit-identical
    to the unmeshed trace.  ``mesh`` overrides the installed context
    mesh for the head stream (callers holding an explicit mesh — the
    batcher — must not depend on the module global being set).

    ``policy`` (factory default, overridable per call as a trailing
    step argument) routes the head stream through per-row
    :class:`~repro.core.policy.LevelPolicy` precision classes — one row
    per batch entry; ``early_exit`` stays as the batch-global shim.
    """
    _check_step_flags(progressive, early_exit, policy)
    default_policy = policy
    if progressive:
        assert cfg.family != "encdec", "progressive prefill: LM families only"
        assert cfg.l2r is not None, \
            "progressive prefill streams the quantized head: set cfg.l2r"

    def prefill(params, batch, policy=None):
        from contextlib import ExitStack

        from repro.sharding import ctx

        with ExitStack() as stack:
            if not backbone_hints:
                stack.enter_context(ctx.hints_disabled())
            return _prefill_body(params, batch, policy)

    def _prefill_body(params, batch, policy=None):
        if cfg.family == "encdec":
            state = init_encdec_state(cfg, batch["tokens"].shape[0], max_len,
                                      cache_dtype)
            hidden, state, _ = encdec_forward(
                cfg, params, tokens=batch["tokens"], frames=batch["frames"],
                mode="prefill", state=state)
        else:
            tokens = batch.get("tokens")
            embeds = batch.get("embeds")
            bsz = (tokens if tokens is not None else embeds).shape[0]
            state = init_lm_state(cfg, bsz, max_len, cache_dtype)
            hidden, state, _ = lm_forward(
                cfg, params, tokens=tokens, embeds=embeds,
                rope_positions=batch.get("rope_positions"),
                mode="prefill", state=state)
        if progressive:
            logits, tok, lv = progressive_logits_from_hidden(
                cfg, params, hidden[:, -1:], early_exit=early_exit,
                mesh=mesh,
                policy=policy if policy is not None else default_policy)
            return state, logits, tok.astype(jnp.int32), lv
        logits = logits_from_hidden(cfg, params, hidden[:, -1:])
        return state, logits

    return prefill


# ------------------------------------------------------- bucketed prefill
def prefill_buckets(max_len: int, min_bucket: int = 8) -> tuple[int, ...]:
    """Power-of-2 prompt-length buckets, capped at ``max_len``.

    Prompts pad to the smallest covering bucket, so prefill traces (and
    AOT executables) exist per BUCKET instead of per unique prompt
    length.  The last bucket is ``max_len`` itself (the cache bound),
    whether or not it is a power of two.
    """
    assert max_len >= 1
    out: list[int] = []
    b = min(min_bucket, max_len)
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


def bucket_for(length: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket covering ``length`` (buckets ascending)."""
    for b in buckets:
        if length <= b:
            return b
    raise ValueError(f"prompt length {length} exceeds largest bucket "
                     f"{buckets[-1]} (the cache bound)")


def supports_bucketed_prefill(cfg: ModelConfig) -> bool:
    """Bucketed (right-padded) prefill is exact only for attention
    mixers: causal masking makes pad positions invisible to every real
    position, and the pad cache entries can be marked empty afterwards.
    Recurrent mixers (ssd / rec) carry the state at the LAST position —
    pad tokens would contaminate it — so those families keep the
    exact-length prefill path."""
    return cfg.family != "encdec" and all(
        k in ("global", "local") for k, _ in cfg.layer_kinds())


def _mask_bucket_state(state: LMState, true_len: jax.Array) -> LMState:
    """Post-prefill fixup for a right-padded prompt: per-row ``pos``
    becomes the TRUE length and every KV-cache entry written by a pad
    position is marked empty (-1), so decode attention never sees pad
    keys and the first decoded token lands at position ``true_len`` —
    overwriting the stale pad k/v slot by slot as decoding proceeds.
    Bit-exact: masked entries contribute exact zeros to the softmax, and
    cache contents at slots < true_len are untouched."""
    tl = true_len.astype(jnp.int32).reshape(-1, 1)  # (B, 1): broadcasts
    #   against (B, L) and stacked (layers, B, L) position leaves alike

    def fix(c):
        if not isinstance(c, KVCache):
            return c
        return c._replace(
            positions=jnp.where(c.positions < tl, c.positions, -1))

    is_kv = lambda x: isinstance(x, KVCache)
    return LMState(
        prefix=jax.tree.map(fix, state.prefix, is_leaf=is_kv),
        stack=jax.tree.map(fix, state.stack, is_leaf=is_kv),
        suffix=jax.tree.map(fix, state.suffix, is_leaf=is_kv),
        pos=true_len.astype(jnp.int32),
    )


def make_bucket_prefill_step(cfg: ModelConfig, max_len: int,
                             cache_dtype=jnp.bfloat16,
                             progressive: bool = False,
                             early_exit: bool = False,
                             backbone_hints: bool = True,
                             mesh: Mesh | None = None,
                             policy: LevelPolicy | None = None) -> Callable:
    """(params, tokens (B, Lb), true_len (B,)) -> make_prefill_step returns.

    The bucketed form of :func:`make_prefill_step`: ``tokens`` is a
    whole BUCKET of right-padded prompts (one traced/compiled program
    per (B, bucket) shape, not per unique prompt length) and ``true_len``
    carries each row's real prompt length.  The head consumes the hidden
    state at ``true_len - 1`` per row (not the pad tail), the returned
    state's ``pos`` is the true length, and pad-written cache entries
    are marked empty — decode from this state is bit-identical to an
    unpadded prefill of the same prompt (tests/test_gateway.py).

    Rows are independent, so multiple queued prompts PACK into one
    dispatch: pad the batch with dummy rows (``true_len = 1``) and
    ignore their outputs.  Attention families only (see
    :func:`supports_bucketed_prefill`); local (ring) windows require
    the bucket to fit the window, asserted at trace time.

    ``policy`` works as in :func:`make_prefill_step`: factory default,
    per-call trailing override (the gateway lowers the policy
    positionally into each bucket's AOT executable).
    """
    _check_step_flags(progressive, early_exit, policy)
    assert supports_bucketed_prefill(cfg), \
        "bucketed prefill: attention-mixer LM families only"
    default_policy = policy
    if progressive:
        assert cfg.l2r is not None, \
            "progressive prefill streams the quantized head: set cfg.l2r"
    local = any(k == "local" for k, _ in cfg.layer_kinds())

    def prefill(params, tokens, true_len, policy=None):
        from contextlib import ExitStack

        from repro.sharding import ctx

        with ExitStack() as stack:
            if not backbone_hints:
                stack.enter_context(ctx.hints_disabled())
            return _body(params, tokens, true_len, policy)

    def _body(params, tokens, true_len, policy=None):
        bsz, lb = tokens.shape
        if local:
            assert lb <= cfg.window, (
                f"bucket {lb} exceeds the local attention window "
                f"{cfg.window}: the ring cache would wrap over real "
                f"prompt entries")
        state = init_lm_state(cfg, bsz, max_len, cache_dtype)
        hidden, state, _ = lm_forward(cfg, params, tokens=tokens,
                                      mode="prefill", state=state)
        idx = (true_len.astype(jnp.int32) - 1)[:, None, None]
        h_last = jnp.take_along_axis(hidden, idx, axis=1)  # (B, 1, d)
        state = _mask_bucket_state(state, true_len)
        if progressive:
            logits, tok, lv = progressive_logits_from_hidden(
                cfg, params, h_last, early_exit=early_exit, mesh=mesh,
                policy=policy if policy is not None else default_policy)
            return state, logits, tok.astype(jnp.int32), lv
        return state, logits_from_hidden(cfg, params, h_last)

    return prefill


def progressive_logits_from_hidden(cfg: ModelConfig, params, hidden,
                                   early_exit: bool = False,
                                   mesh: Mesh | None = None,
                                   policy: LevelPolicy | None = None):
    """Stream the LM head level-by-level, committing each row's token at
    its earliest sound MSDF level.

    The quantization recipe is exactly `logits_from_hidden`'s L2R path
    (dense -> l2r_matmul_f), so the returned logits are bit-identical to
    the full head evaluation and the committed tokens ALWAYS equal
    ``argmax(logits_from_hidden(...))`` — rows that never reach a sound
    early margin simply consume the whole stream.  ``early_exit=True``
    runs the head stream as the while-loop emitter that STOPS once every
    row has decided: tokens and exit levels stay bit-identical, but the
    returned logits are then the dequantized prefix at the exit level
    (core/progressive.py:streaming_argmax).  Returns
    ``(logits (..., V), tok (...,) int32, exit_level (...,) int32)``.

    When a mesh is installed (sharding/ctx.py), the stream runs as the
    ``shard_map``ped consensus walk — batch rows over the data axes,
    vocab shards over ``model``, early exit at the fleet-wide slowest
    row — with bit-identical logits, tokens, and exit levels
    (core/progressive.py:streaming_argmax, sharded walk).

    ``policy`` carries per-row :class:`~repro.core.policy.LevelPolicy`
    precision classes — one row per FLATTENED lead entry of ``hidden``
    (decode: one per batch slot) — threaded straight into the shared
    decision fold; ``exact`` rows roundtrip the full stream, ``budget``
    rows clamp at their level, ``bounded`` rows early-commit at their
    own tolerance.
    """
    qcfg = cfg.l2r or QuantConfig()
    if "head_q" in params:  # the prepare_params load-time head cache
        wq, ws = params["head_q"].q, params["head_q"].scale
        p = params["head_q"].planes
        if p is not None and p.matches(qcfg.n_bits, qcfg.log2_radix,
                                       ndim=2, side="rhs"):
            wq = p  # cached plane stack: zero per-step operand prep
    else:
        if cfg.tie_embeddings:
            w = params["embed"].T
        else:
            w = params["head"]
        wq, ws = quantize(w.astype(hidden.dtype), qcfg, axis=-1)
    lead = hidden.shape[:-1]
    x2 = hidden.reshape(-1, hidden.shape[-1])
    xq, xs = quantize(x2, qcfg, axis=0 if qcfg.per_channel else None)
    if policy is not None:
        policy = policy.reshape((x2.shape[0],))
    logits, tok, lv = streaming_argmax(xq, wq, xs, ws, qcfg.n_bits,
                                       qcfg.log2_radix,
                                       levels=cfg.l2r_levels,
                                       out_dtype=hidden.dtype,
                                       early_exit=early_exit, mesh=mesh,
                                       policy=policy)
    return (logits.reshape(*lead, -1), tok.reshape(lead), lv.reshape(lead))


def make_decode_step(cfg: ModelConfig, progressive: bool = False,
                     early_exit: bool = False,
                     backbone_hints: bool = True,
                     mesh: Mesh | None = None,
                     policy: LevelPolicy | None = None) -> Callable:
    """(params, state, tokens (B,1)) -> (state, next_tokens (B,1), logits).

    ``progressive=True`` (LM families, requires ``cfg.l2r``) streams the
    final head matmul most-significant-level first and commits each
    token at its earliest decision level; the step then also returns the
    per-row exit levels: ``(state, next_tokens, logits, exit_level
    (B,1))``.  Tokens are bit-identical to the non-progressive step —
    the exit levels are what a digit-serial deployment would NOT compute.
    ``early_exit=True`` additionally stops the head's level loop once
    every slot in the batch has decided (the while-loop emitter): the
    skipped levels become skipped wall-clock on this host, not just an
    accounting entry, at the price of exit-level logit values for the
    non-argmax entries (tokens and exit levels are unchanged).
    ``backbone_hints=False`` scopes the interior sharding hints off
    during tracing — the replicated-backbone mesh setting — and ``mesh``
    overrides the context mesh for the head stream; see
    :func:`make_prefill_step`.

    ``policy`` (factory default, overridable per call as the trailing
    step argument — ``decode(params, state, tokens, rope_positions,
    policy)``) streams the head under per-slot
    :class:`~repro.core.policy.LevelPolicy` precision classes; the
    batcher/gateway splice admitted requests' classes into the slot
    rows so one fused while loop serves heterogeneous SLAs.
    """
    _check_step_flags(progressive, early_exit, policy)
    default_policy = policy
    if progressive:
        assert cfg.family != "encdec", "progressive decode: LM families only"
        assert cfg.l2r is not None, \
            "progressive decode streams the quantized head: set cfg.l2r"

    def decode(params, state, tokens, rope_positions=None, policy=None):
        from contextlib import ExitStack

        from repro.sharding import ctx

        with ExitStack() as stack:
            if not backbone_hints:
                stack.enter_context(ctx.hints_disabled())
            return _decode_body(params, state, tokens, rope_positions,
                                policy)

    def _decode_body(params, state, tokens, rope_positions=None,
                     policy=None):
        if cfg.family == "encdec":
            hidden, state, _ = encdec_forward(
                cfg, params, tokens=tokens, mode="decode", state=state)
        else:
            hidden, state, _ = lm_forward(
                cfg, params, tokens=tokens, rope_positions=rope_positions,
                mode="decode", state=state)
        if progressive:
            logits, tok, lv = progressive_logits_from_hidden(
                cfg, params, hidden, early_exit=early_exit, mesh=mesh,
                policy=policy if policy is not None else default_policy)
            return state, tok.astype(jnp.int32), logits, lv
        logits = logits_from_hidden(cfg, params, hidden)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return state, next_tok, logits

    return decode


def greedy_generate(cfg: ModelConfig, params, prompt: jax.Array, steps: int,
                    max_len: int | None = None, cache_dtype=jnp.float32):
    """Batched greedy decoding loop (host-driven; example/serving path)."""
    b, s = prompt.shape
    max_len = max_len or (s + steps)
    prefill = jax.jit(make_prefill_step(cfg, max_len, cache_dtype))
    decode = jax.jit(make_decode_step(cfg))
    state, logits = prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for _ in range(steps - 1):
        state, tok, _ = decode(params, state, tok)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
