from .pipeline import *
