from .adamw import (AdamWConfig, OptState, adamw_init, adamw_update,
                    cosine_schedule, global_norm, clip_by_global_norm)
from .compression import EFState, ef_init, ef_compress_grads, compress_decompress

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "cosine_schedule", "global_norm", "clip_by_global_norm",
           "EFState", "ef_init", "ef_compress_grads", "compress_decompress"]
