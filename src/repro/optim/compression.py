"""Error-feedback int8 gradient compression for data-parallel all-reduce.

The distributed-optimization trick: before the DP gradient reduction,
gradients are quantized to int8 with per-tensor scales; the quantization
residual is carried in an error-feedback buffer and added back next step
(Seide et al. / EF-SGD construction, so convergence is preserved).  On a
real fleet the all-reduce then moves 4x fewer bytes (int8 vs f32); under
GSPMD the compressed tensors are what crosses the "data"/"pod" axes.

Digit-plane aside: the int8 wire format composes with the paper's L2R
arithmetic — a reduction over int8 digit planes is exactly the composite
counter-tree reduction, so the same MSDF machinery could stream the
gradient reduction MSB-first (documented as future work in DESIGN.md).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["EFState", "ef_init", "compress_decompress", "ef_compress_grads"]


class EFState(NamedTuple):
    residual: Any  # pytree of f32 error-feedback buffers


def ef_init(params) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _q8(x: jax.Array):
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(x: jax.Array):
    """Round-trip through the int8 wire format; returns (xhat, err)."""
    q, scale = _q8(x.astype(jnp.float32))
    xhat = q.astype(jnp.float32) * scale
    return xhat, x.astype(jnp.float32) - xhat


def ef_compress_grads(grads, ef: EFState):
    """Apply error feedback + int8 round trip to every gradient leaf.

    Returns (compressed_grads, new_ef).  In the jitted train step the
    int8 cast happens *before* the psum/all-reduce XLA inserts for the
    DP axes, which is where the 4x wire saving comes from.
    """
    def one(g, r):
        xhat, err = compress_decompress(g.astype(jnp.float32) + r)
        return xhat.astype(g.dtype), err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_r = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, EFState(residual=new_r)
