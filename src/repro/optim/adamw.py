"""AdamW + schedules + global-norm clipping, built from scratch on pytrees.

Optimizer state is a pytree mirroring the params (m, v) plus a step
counter; train/step.py shards m/v/master over the vacant data axes
(ZeRO-1) via sharding/axes.py:zero1_specs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "cosine_schedule", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


def adamw_update(cfg: AdamWConfig, grads, params, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, p, m, v):
        g = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = jax.tree.leaves(params)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(g, p, m, v) for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr,
    }
