"""Sharded checkpointing: atomic step directories, async writer, resume.

Format: one .npz per pytree "segment" (flattened leaves with their tree
paths as keys), plus a JSON manifest.  Writes go to ``step_XXXX.tmp`` and
are renamed atomically; a ``latest`` file points at the newest complete
step, so a crash mid-write can never corrupt the restore point — the
fault-tolerance supervisor (runtime/fault.py) relies on this invariant.

On a multi-host fleet each host writes only its addressable shards and
restore reassembles per-host (process-index namespaced files); on this
single-process container that degenerates to one file set, but the API
carries the host dimension.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointManager", "save_pytree", "load_pytree"]


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(tree, path: str):
    np.savez(path, **_flatten_with_paths(tree))


def load_pytree(template, path: str):
    """Restore into the structure of ``template`` (shapes must match)."""
    data = np.load(path, allow_pickle=False)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(x) for x in p)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves
    )


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True,
                 process_index: int | None = None):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self.proc = process_index if process_index is not None else jax.process_index()
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ---------- paths ----------
    def _step_dir(self, step: int, tmp: bool = False) -> str:
        return os.path.join(self.dir, f"step_{step:08d}" + (".tmp" if tmp else ""))

    def latest_step(self) -> int | None:
        f = os.path.join(self.dir, "latest")
        if not os.path.exists(f):
            return None
        with open(f) as fh:
            return int(fh.read().strip())

    # ---------- save ----------
    def _write(self, step: int, trees: dict[str, Any], extra: dict):
        try:
            tmp = self._step_dir(step, tmp=True)
            os.makedirs(tmp, exist_ok=True)
            for name, tree in trees.items():
                save_pytree(tree, os.path.join(tmp, f"{name}.proc{self.proc}.npz"))
            with open(os.path.join(tmp, "manifest.json"), "w") as fh:
                json.dump({"step": step, "time": time.time(), **extra}, fh)
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            with open(os.path.join(self.dir, "latest.tmp"), "w") as fh:
                fh.write(str(step))
            os.replace(os.path.join(self.dir, "latest.tmp"),
                       os.path.join(self.dir, "latest"))
            self._gc()
        except Exception as e:  # surfaced on next wait()/save()
            self._error = e

    def save(self, step: int, trees: dict[str, Any], extra: dict | None = None,
             block: bool = False):
        """trees: {"params": ..., "opt": ..., "data": pipeline.state_dict()}"""
        self.wait()
        if self._error:
            raise self._error
        # device -> host transfer happens here, synchronously (donated
        # buffers must not be mutated while the writer thread runs).
        host_trees = jax.tree.map(np.asarray, trees)
        extra = extra or {}
        if self.async_write and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_trees, extra), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host_trees, extra)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---------- restore ----------
    def restore(self, step: int, templates: dict[str, Any]) -> dict[str, Any]:
        d = self._step_dir(step)
        out = {}
        for name, tpl in templates.items():
            out[name] = load_pytree(tpl, os.path.join(d, f"{name}.proc{self.proc}.npz"))
        return out

    def restore_latest(self, templates: dict[str, Any]):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, templates)

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self._step_dir(step), "manifest.json")) as fh:
            return json.load(fh)
