"""L2R-quantized checkpoints: int8 weights + per-tensor scales on disk.

The serving-time storage format of the paper's pipeline (models/common.py
quantize_desc) doubles as a checkpoint codec: matmul weights are stored
as int8 digit-plane-ready payloads with f32 scales, halving checkpoint
bytes vs bf16 (4x vs f32) — useful both for serving snapshots and for
the high-frequency fault-tolerance checkpoints of large fleets (write
bandwidth is the limit on how often you can checkpoint).

Round-trip error is the W8A8 weight quantization error (bounded by
scale/2 per element — property-tested); training checkpoints that must
be bit-exact keep the full-precision path in manager.py.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import _is_param, _quantizable, quantize_params

from .manager import load_pytree, save_pytree

__all__ = ["save_quantized", "load_quantized", "quantized_nbytes",
           "save_prepared", "prepared_template", "load_prepared"]


def save_quantized(desc_tree, params, path: str):
    """Quantize eligible weights (int8 + scale) and save one .npz."""
    q = quantize_params(desc_tree, params)
    save_pytree(q, path)
    return q


def load_quantized(desc_tree, params_template, path: str,
                   dequantize: bool = False):
    """Restore a quantized checkpoint.

    dequantize=False returns the serving pytree ({"q","scale"} records,
    consumed directly by models/common.py:dense).  dequantize=True folds
    back to the template's float dtypes (for resuming non-serving work).
    """
    qtemplate = jax.eval_shape(
        lambda: quantize_params(desc_tree, params_template))
    q = load_pytree(qtemplate, path)
    if not dequantize:
        return q

    def f(p, w, orig):
        if isinstance(w, dict) and "q" in w:
            return (w["q"].astype(jnp.float32) * w["scale"]).astype(orig.dtype)
        return w

    return jax.tree.map(f, desc_tree, q, params_template, is_leaf=_is_param)


def quantized_nbytes(tree) -> int:
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree))


# ------------------------------------------------- prepared serving trees
# `serve.engine.prepare_params` output: QuantizedWeights records whose
# `planes` field carries the pre-stacked digit-plane operands
# (core/quant.py:PlaneOperands) plus the padded streaming head cache
# ("head_q").  The {"q","scale"} codec above predates that cache, so a
# gateway restoring from it re-extracted every weight's plane stack on
# each cold start; these entry points persist the PREPARED tree whole —
# plane stacks included — so serving resumes with zero re-extraction.
# Both record types are registered pytree dataclasses (data leaves +
# static meta), so the manager.py path-keyed .npz codec round-trips
# them bit-exactly with no extra format.

def save_prepared(prepared, path: str):
    """Save a `prepare_params` output tree (plane stacks and streaming
    head cache included) as one .npz."""
    save_pytree(prepared, path)
    return prepared


def prepared_template(cfg, params_template, desc=None, prestack: bool = True):
    """Abstract (ShapeDtypeStruct) prepared-tree template, evaluated at
    zero device cost — the restore target for :func:`load_prepared`.
    ``params_template`` only contributes shapes/dtypes; pass the same
    ``prestack`` the checkpoint was saved with."""
    from repro.serve.engine import prepare_params

    return jax.eval_shape(
        lambda p: prepare_params(cfg, p, desc=desc, prestack=prestack),
        params_template)


def load_prepared(cfg, params_template, path: str, desc=None,
                  prestack: bool = True):
    """Restore a prepared serving tree saved by :func:`save_prepared`:
    int8 payloads, scales, plane stacks, and the padded head cache all
    land bit-exact — a gateway cold start goes straight to AOT warmup
    with no weight preparation pass."""
    return load_pytree(
        prepared_template(cfg, params_template, desc, prestack), path)
