"""L2R-quantized checkpoints: int8 weights + per-tensor scales on disk.

The serving-time storage format of the paper's pipeline (models/common.py
quantize_desc) doubles as a checkpoint codec: matmul weights are stored
as int8 digit-plane-ready payloads with f32 scales, halving checkpoint
bytes vs bf16 (4x vs f32) — useful both for serving snapshots and for
the high-frequency fault-tolerance checkpoints of large fleets (write
bandwidth is the limit on how often you can checkpoint).

Round-trip error is the W8A8 weight quantization error (bounded by
scale/2 per element — property-tested); training checkpoints that must
be bit-exact keep the full-precision path in manager.py.
"""

from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import Param, _is_param, _quantizable, quantize_params

from .manager import load_pytree, save_pytree

__all__ = ["save_quantized", "load_quantized", "quantized_nbytes"]


def save_quantized(desc_tree, params, path: str):
    """Quantize eligible weights (int8 + scale) and save one .npz."""
    q = quantize_params(desc_tree, params)
    save_pytree(q, path)
    return q


def load_quantized(desc_tree, params_template, path: str,
                   dequantize: bool = False):
    """Restore a quantized checkpoint.

    dequantize=False returns the serving pytree ({"q","scale"} records,
    consumed directly by models/common.py:dense).  dequantize=True folds
    back to the template's float dtypes (for resuming non-serving work).
    """
    from repro.models.common import quantize_desc

    qdesc = quantize_desc(desc_tree)
    qtemplate = jax.eval_shape(
        lambda: quantize_params(desc_tree, params_template))
    q = load_pytree(qtemplate, path)
    if not dequantize:
        return q

    def f(p, w, orig):
        if isinstance(w, dict) and "q" in w:
            return (w["q"].astype(jnp.float32) * w["scale"]).astype(orig.dtype)
        return w

    return jax.tree.map(f, desc_tree, q, params_template, is_leaf=_is_param)


def quantized_nbytes(tree) -> int:
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree))
