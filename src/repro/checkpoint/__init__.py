from .manager import CheckpointManager, save_pytree, load_pytree
from .quantized import save_quantized, load_quantized, quantized_nbytes
