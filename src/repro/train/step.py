"""Training step factory: loss, grads, AdamW, remat, sequence sharding,
microbatch accumulation, optional int8 error-feedback grad compression.

Memory discipline for the big cells (gemma3-27b @ 1M tokens/step):
  * scanned blocks with jax.checkpoint (one block's activations live);
  * the residual stream is sequence-sharded over "model" between blocks
    (Megatron-SP: stored remat carries are 16x smaller; XLA inserts the
    all-gather / reduce-scatter pair around each block);
  * cross-entropy is computed in sequence chunks under jax.checkpoint —
    the (tokens, vocab) logits tensor is never materialized whole;
  * optimizer state is ZeRO-1 sharded over the vacant "data" axis.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.transformer import lm_forward
from repro.models.encdec import encdec_forward
from repro.optim.adamw import AdamWConfig, OptState, adamw_update
from repro.optim.compression import EFState, ef_compress_grads
from repro.sharding.axes import batch_spec, named, param_specs, zero1_specs

__all__ = ["TrainConfig", "make_loss_fn", "make_train_step", "train_step_shardings",
           "chunked_xent"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    remat: bool = True
    seq_shard: bool = True  # sequence-shard residual stream over "model"
    xent_chunk: int = 512
    microbatch: int = 1  # gradient-accumulation splits of the global batch
    ef_compression: bool = False  # int8 error-feedback gradient compression
    z_loss: float = 1e-4  # logit normalizer regularizer (stability)


def chunked_xent(hidden: jax.Array, w_out: jax.Array, labels: jax.Array,
                 chunk: int = 512, z_loss: float = 0.0):
    """Mean token cross-entropy without materializing full logits.

    hidden: (B, S, d); w_out: (d, V); labels: (B, S) int32.
    Scans over S in chunks; each chunk's logits are rematerialized in the
    backward pass (jax.checkpoint), so peak memory ~ (B, chunk, V-shard).
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    hs = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)  # (nc, B, C, d)
    ls = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xs):
        h, l = xs
        logits = jnp.einsum("bcd,dv->bcv", h.astype(jnp.float32),
                            w_out.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        loss = (lse - gold).sum()
        if z_loss:
            loss = loss + z_loss * jnp.square(lse).sum()
        correct = (logits.argmax(-1) == l).sum()
        return (carry[0] + loss, carry[1] + correct), None

    (total, correct), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (hs, ls)
    )
    n = b * s
    return total / n, correct.astype(jnp.float32) / n


def _resid_shard_fn(mesh: Mesh | None, tcfg: TrainConfig, batch_size: int):
    if mesh is None or not tcfg.seq_shard or "model" not in mesh.axis_names:
        return lambda x: x
    bspec = batch_spec(mesh, batch_size)[0]

    def f(x):
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, P(bspec, "model", None))
        )
    return f


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh | None = None):
    """loss_fn(params, batch) -> (loss, metrics). Handles all families."""

    def loss_fn(params, batch):
        bsz = (batch.get("tokens") if "tokens" in batch else batch["embeds"]).shape[0]
        resid = _resid_shard_fn(mesh, tcfg, bsz)
        if cfg.family == "encdec":
            hidden, _, aux = encdec_forward(
                cfg, params, tokens=batch["tokens"], frames=batch["frames"],
                mode="train", resid_shard=resid, remat=tcfg.remat,
            )
            w_out = params["embed"].T
        else:
            hidden, _, aux = lm_forward(
                cfg, params,
                tokens=batch.get("tokens"),
                embeds=batch.get("embeds"),
                rope_positions=batch.get("rope_positions"),
                mode="train", resid_shard=resid, remat=tcfg.remat,
            )
            w_out = params["embed"].T if cfg.tie_embeddings else params["head"]
        xent, acc = chunked_xent(hidden, w_out, batch["labels"],
                                 tcfg.xent_chunk, tcfg.z_loss)
        loss = xent + aux
        return loss, {"loss": xent, "aux": aux, "accuracy": acc}

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    ocfg: AdamWConfig,
    tcfg: TrainConfig = TrainConfig(),
    mesh: Mesh | None = None,
) -> Callable:
    """(params, opt_state, [ef_state,] batch) -> (params, opt_state, [ef,] metrics).

    Microbatching: the global batch is split on the leading axis and
    grads are accumulated in f32 before one optimizer step.
    """
    loss_fn = make_loss_fn(cfg, tcfg, mesh)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return grads, loss, metrics

    def train_step(params, opt_state, batch, ef_state=None):
        if tcfg.microbatch > 1:
            def split(x):
                return x.reshape(tcfg.microbatch, x.shape[0] // tcfg.microbatch,
                                 *x.shape[1:])
            mbatches = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, loss_acc = carry
                g, loss, _ = single(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mbatches)
            grads = jax.tree.map(lambda g: g / tcfg.microbatch, grads)
            loss = loss / tcfg.microbatch
            metrics = {"loss": loss, "aux": jnp.zeros(()), "accuracy": jnp.zeros(())}
        else:
            grads, loss, metrics = single(params, batch)

        if tcfg.ef_compression:
            assert ef_state is not None
            grads, ef_state = ef_compress_grads(grads, ef_state)

        params, opt_state, om = adamw_update(ocfg, grads, params, opt_state)
        metrics = {**metrics, **om}
        if tcfg.ef_compression:
            return params, opt_state, ef_state, metrics
        return params, opt_state, metrics

    return train_step


def train_step_shardings(cfg: ModelConfig, mesh: Mesh, desc_tree,
                         batch_shapes: dict, ef: bool = False):
    """(in_shardings, out_shardings) trees for jax.jit over train_step."""
    pspecs = param_specs(desc_tree, mesh)
    ospecs = OptState(step=P(), m=zero1_specs(desc_tree, mesh),
                      v=zero1_specs(desc_tree, mesh))
    bsz = next(iter(batch_shapes.values())).shape[0]
    bspec = {}
    for k, v in batch_shapes.items():
        if k == "rope_positions":  # (3, B, S)
            bspec[k] = P(None, batch_spec(mesh, v.shape[1])[0], None)
        else:
            bspec[k] = P(*batch_spec(mesh, bsz), *([None] * (len(v.shape) - 2)))
    metrics_spec = {k: P() for k in
                    ("loss", "aux", "accuracy", "grad_norm", "lr")}
    ins = (named(mesh, pspecs), named(mesh, ospecs), named(mesh, bspec))
    outs = (named(mesh, pspecs), named(mesh, ospecs), named(mesh, metrics_spec))
    if ef:
        efspec = EFState(residual=zero1_specs(desc_tree, mesh))
        ins = ins + (named(mesh, efspec),)
        outs = (named(mesh, pspecs), named(mesh, ospecs), named(mesh, efspec),
                named(mesh, metrics_spec))
    return ins, outs
