from .step import TrainConfig, make_train_step, make_loss_fn, chunked_xent, train_step_shardings
