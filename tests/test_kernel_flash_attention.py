"""Flash attention Pallas kernel: shape/dtype/mask sweeps vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention_pallas

CASES = [
    dict(sq=256, skv=256, h=4, kvh=2, dh=64, causal=True, window=None,
         bq=128, bkv=128),
    dict(sq=256, skv=256, h=4, kvh=1, dh=64, causal=True, window=64,
         bq=64, bkv=64),
    dict(sq=200, skv=200, h=2, kvh=2, dh=32, causal=True, window=None,
         bq=128, bkv=128),  # ragged -> padding path
    dict(sq=128, skv=128, h=8, kvh=4, dh=64, causal=False, window=None,
         bq=64, bkv=64),
    dict(sq=64, skv=64, h=2, kvh=2, dh=128, causal=True, window=16,
         bq=32, bkv=32),
]


@pytest.mark.parametrize("case", CASES)
def test_flash_vs_oracle(case):
    rng = np.random.default_rng(1)
    q = rng.standard_normal((2, case["sq"], case["h"], case["dh"])).astype(np.float32)
    k = rng.standard_normal((2, case["skv"], case["kvh"], case["dh"])).astype(np.float32)
    v = rng.standard_normal((2, case["skv"], case["kvh"], case["dh"])).astype(np.float32)
    out = flash_attention_pallas(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=case["causal"], window=case["window"],
        bq=case["bq"], bkv=case["bkv"])
    ref = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        causal=case["causal"], window=case["window"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_flash_bf16_inputs():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((1, 128, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.bfloat16)
    out = flash_attention_pallas(q, k, v, bq=64, bkv=64)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


def test_flash_matches_model_attention():
    """Kernel agrees with the model-stack chunked attention (the XLA
    path it replaces on TPU)."""
    from repro.models.attention import chunked_attention
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((2, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 256, 2, 64)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, bq=128, bkv=128)
    ref = chunked_attention(q, k, v, causal=True, q_chunk=128, kv_chunk=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
