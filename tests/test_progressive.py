"""Progressive precision (online early output) — the serving-level
analogue of the hardware's MSDF digit stream."""

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st  # optional hypothesis

from repro.core.progressive import earliest_decision_level, progressive_matmul


def test_progressive_snapshots_converge_exactly():
    rng = np.random.default_rng(0)
    a = rng.integers(-128, 128, size=(4, 32), dtype=np.int8)
    b = rng.integers(-128, 128, size=(32, 10), dtype=np.int8)
    res = progressive_matmul(jnp.asarray(a), jnp.asarray(b))
    exact = a.astype(np.int64) @ b.astype(np.int64)
    np.testing.assert_array_equal(np.asarray(res.partial[-1], np.int64), exact)
    errs = [np.abs(np.asarray(p, np.int64) - exact).max() for p in res.partial]
    assert all(x >= y for x, y in zip(errs, errs[1:]))
    bounds = np.asarray(res.tail_bound)
    for p, bnd in zip(res.partial, bounds):
        assert (np.abs(np.asarray(p, np.int64) - exact) <= bnd).all()


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_early_decision_is_sound(seed):
    """If the margin test fires at level L, the argmax at L equals the
    exact argmax — the online guarantee (decision invariant under any
    completion of the digit stream)."""
    rng = np.random.default_rng(seed)
    a = rng.integers(-128, 128, size=(6, 24), dtype=np.int8)
    b = rng.integers(-128, 128, size=(24, 12), dtype=np.int8)
    res = progressive_matmul(jnp.asarray(a), jnp.asarray(b))
    lv = np.asarray(earliest_decision_level(res))
    exact_arg = (a.astype(np.int64) @ b.astype(np.int64)).argmax(-1)
    for row in range(a.shape[0]):
        chosen = np.asarray(res.partial[lv[row], row]).argmax(-1)
        if lv[row] < res.partial.shape[0] - 1:  # fired early -> must be right
            assert chosen == exact_arg[row]


def test_average_early_exit_saves_levels():
    """On random data most rows decide before the last level — the
    throughput win of the online unit."""
    rng = np.random.default_rng(42)
    a = rng.integers(-128, 128, size=(64, 48), dtype=np.int8)
    b = rng.integers(-128, 128, size=(48, 16), dtype=np.int8)
    res = progressive_matmul(jnp.asarray(a), jnp.asarray(b))
    lv = np.asarray(earliest_decision_level(res))
    assert lv.mean() < res.partial.shape[0] - 1
