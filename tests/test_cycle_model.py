"""Reproduction checks for the paper's Tables I and II (cycle + hw model)."""

import pytest

from repro.core.cycle_model import (AcceleratorConfig, VGG16_CONV_LAYERS,
                                    inference_seconds, layer_cycles,
                                    network_cycles, peak_gops)
from repro.core import hw_model


def test_vgg16_layer_table():
    assert len(VGG16_CONV_LAYERS) == 13
    total_macs = sum(l.macs for l in VGG16_CONV_LAYERS)
    # VGG-16 conv MACs ~ 15.35G (published figure ~15.3G)
    assert abs(total_macs - 15.35e9) / 15.35e9 < 0.02


def test_cycle_formula_matches_paper_example():
    cfg = AcceleratorConfig()
    l1 = VGG16_CONV_LAYERS[0]  # conv1_1: 224x224x3 -> 64
    c = layer_cycles(l1, cfg, l2r=True)
    # (n^2+delta) * (9 + ceil(3/8)) * ceil(224*224/64) * 64
    assert c == (64 + 11) * 10 * 784 * 64


def test_peak_gops_reproduces_table2():
    # L2R: paper prints 48.97 GOPS; the formula with delta_Mult=11 gives
    # 49.15 (0.4% — documented in DESIGN.md §7). Baseline is exact.
    assert abs(peak_gops(l2r=True) - 48.97) / 48.97 < 0.005
    assert peak_gops(l2r=False) == pytest.approx(14.40)


def test_speedup_reproduces_paper_3p40x():
    s = network_cycles(l2r=False) / network_cycles(l2r=True)
    assert abs(s - 3.40) < 0.02  # paper: 3.40x for VGG-16


def test_table1_calibrated_area_power_exact():
    t1 = hw_model.table1()
    for design in ("baseline", "l2r_cipu"):
        assert t1[design]["area_um2"] == pytest.approx(
            hw_model.PAPER_TABLE1[design]["area_um2"], rel=1e-6)
        assert t1[design]["power_mw"] == pytest.approx(
            hw_model.PAPER_TABLE1[design]["power_mw"], rel=1e-6)


def test_table1_latency_predicted_within_10pct():
    t1 = hw_model.table1()
    for design in ("baseline", "l2r_cipu"):
        model = t1[design]["latency_ns"]
        paper = hw_model.PAPER_TABLE1[design]["latency_ns"]
        assert abs(model - paper) / paper < 0.10, (design, model, paper)


def test_table2_derived_columns():
    t2 = hw_model.table2()
    p = hw_model.PAPER_TABLE2
    # TOPS/W: model vs paper (paper rounds to 2 decimals)
    assert t2["l2r_cipu"]["tops_w"] == pytest.approx(p["l2r_cipu"]["tops_w"], abs=0.02)
    assert t2["baseline"]["tops_w"] == pytest.approx(p["baseline"]["tops_w"], abs=0.02)
    # GOPS/mm^2 (paper's "TOPS/mm2" column is numerically GOPS/mm^2)
    assert t2["l2r_cipu"]["gops_mm2"] == pytest.approx(p["l2r_cipu"]["gops_mm2"], rel=0.01)
    assert t2["baseline"]["gops_mm2"] == pytest.approx(p["baseline"]["gops_mm2"], rel=0.01)


def test_energy_and_area_gains_vs_external_designs():
    """The paper's headline multiples vs [4] (Cheng) and [5] (Eyeriss)."""
    t2 = hw_model.table2()
    p = hw_model.PAPER_TABLE2
    perf_vs_cheng = t2["l2r_cipu"]["gops"] / p["cheng2024"]["gops"]
    assert abs(perf_vs_cheng - 6.22) / 6.22 < 0.02  # paper: 6.22x
    energy_vs_cheng = t2["l2r_cipu"]["tops_w"] / p["cheng2024"]["tops_w"]
    assert 14 < energy_vs_cheng < 16.5  # paper: 15x
    perf_vs_eyeriss = t2["l2r_cipu"]["gops"] / p["eyeriss"]["gops"]
    assert abs(perf_vs_eyeriss - 1.06) / 1.06 < 0.02  # paper: 1.06x
    area_vs_eyeriss = t2["l2r_cipu"]["gops_mm2"] / p["eyeriss"]["gops_mm2"]
    assert abs(area_vs_eyeriss - 53.45) / 53.45 < 0.02  # paper: 53.45x
    area_vs_cheng = t2["l2r_cipu"]["gops_mm2"] / p["cheng2024"]["gops_mm2"]
    assert abs(area_vs_cheng - 10.4) / 10.4 < 0.05


def test_documented_inference_time_discrepancy():
    """The paper prints 0.86 ms for VGG-16 but its own Cycle_P formula
    gives ~1.02 s on one 8x8 tile — we reproduce the formula value and
    document the discrepancy (DESIGN.md §7)."""
    t = inference_seconds(l2r=True)
    assert 0.9 < t < 1.1  # formula-faithful value, seconds
    ratio = inference_seconds(l2r=False) / t
    assert abs(ratio - 3.41) < 0.02  # the *ratio* matches the paper
