"""Cycle-accurate composite IPU: exactness + online (MSDF) properties."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional hypothesis

from repro.core.ipu import simulate_cipu, simulate_cipu_python


@given(st.integers(0, 2**31 - 1), st.integers(1, 72))
@settings(max_examples=25, deadline=None)
def test_cipu_exact_vs_integer_dot(seed, k):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, size=(3, k), dtype=np.int64)
    b = rng.integers(0, 256, size=(3, k), dtype=np.int64)
    tr = simulate_cipu(jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32), 8)
    np.testing.assert_array_equal(np.asarray(tr.final, np.int64), (a * b).sum(-1))


def test_cipu_matches_python_golden():
    rng = np.random.default_rng(7)
    a = rng.integers(0, 256, size=(72,), dtype=np.int64)
    b = rng.integers(0, 256, size=(72,), dtype=np.int64)
    py = simulate_cipu_python(list(a), list(b), 8)
    tr = simulate_cipu(jnp.asarray(a[None], jnp.int32), jnp.asarray(b[None], jnp.int32), 8)
    assert py == int(tr.final[0]) == int((a * b).sum())


def test_online_output_digits_monotone():
    """Stable (emittable) MSBs never decrease — the defining online
    property: once a most-significant digit is produced it is final."""
    rng = np.random.default_rng(11)
    a = rng.integers(0, 256, size=(8, 72), dtype=np.int64)
    b = rng.integers(0, 256, size=(8, 72), dtype=np.int64)
    tr = simulate_cipu(jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32), 8)
    sb = np.asarray(tr.stable_bits)
    assert (np.diff(sb, axis=-1) >= 0).all()
    # by the end, all bits of the SOP are final
    width = 2 * 8 + int(np.ceil(np.log2(72)))
    assert (sb[:, -1] == width).all()


def test_online_delay_visible():
    """First stable bit appears well before the n^2-cycle stream ends."""
    rng = np.random.default_rng(13)
    a = rng.integers(128, 256, size=(4, 8), dtype=np.int64)  # big operands
    b = rng.integers(128, 256, size=(4, 8), dtype=np.int64)
    tr = simulate_cipu(jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32), 8)
    sb = np.asarray(tr.stable_bits)
    first = (sb > 0).argmax(axis=-1)
    assert (first < 32).all()  # MSDs stabilize in the first half


def test_cipu_width_guard():
    with pytest.raises(ValueError):
        simulate_cipu(jnp.zeros((1, 4), jnp.int32), jnp.zeros((1, 4), jnp.int32),
                      n_bits=16)


@pytest.mark.parametrize("n_bits", [4, 6, 8, 10])
def test_cipu_bitwidth_sweep(n_bits):
    """The unit is exact at any operand precision (paper evaluates n=8;
    the design-space sweep is what the hw model parameterizes)."""
    rng = np.random.default_rng(n_bits)
    hi = 1 << n_bits
    a = rng.integers(0, hi, size=(4, 16), dtype=np.int64)
    b = rng.integers(0, hi, size=(4, 16), dtype=np.int64)
    tr = simulate_cipu(jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32),
                       n_bits)
    np.testing.assert_array_equal(np.asarray(tr.final, np.int64),
                                  (a * b).sum(-1))
