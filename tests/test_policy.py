"""Per-request precision classes (core/policy.py): the ONE LevelPolicy
decision fold across the streaming head walk, the sharded consensus
walk, decode attention, and both serving engines.

Bit-parity contract of the refactor (the acceptance sweeps):

  * ``exact``        == the full-depth stream at every call site;
  * ``budget(L)``    == the truncated ``levels=L`` run at every L;
  * ``bounded(0.0)`` == the legacy batch-global early-exit walk;
  * a MIXED batch serves each row bit-identically to that row alone at
    its own class (heterogeneous SLAs in one fused while loop), through
    the raw walks, the ContinuousBatcher, and the ServingGateway —
    including under the virtual-8-device mesh.

Plus the satellites: the tracing guard on ``attn_exit_tap``, the
contradictory step-flag validation, the normalized (shared) stats
histogram schema, and the offline calibration controller.
"""

import dataclasses
import importlib.util
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import (LevelPolicy, MODE_BOUNDED, MODE_BUDGET,
                               MODE_EXACT, NO_CLAMP, PrecisionClass)
from repro.core.progressive import streaming_argmax
from repro.core.quant import QuantConfig

pytestmark = pytest.mark.policy

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------ class algebra
def test_precision_class_validation():
    with pytest.raises(ValueError):
        PrecisionClass("turbo")
    with pytest.raises(ValueError):
        PrecisionClass("budget")  # needs levels
    with pytest.raises(ValueError):
        PrecisionClass.budget(0)


def test_precision_class_labels_and_rows():
    assert PrecisionClass.exact().label() == "exact"
    assert PrecisionClass.budget(3).label() == "budget(3)"
    assert PrecisionClass.bounded(1e-4).label() == "bounded(0.0001)"
    assert PrecisionClass.exact().row() == (MODE_EXACT, NO_CLAMP, 0.0)
    assert PrecisionClass.budget(3).row() == (MODE_BUDGET, 3, 0.0)
    m, c, t = PrecisionClass.bounded(0.5).row()
    assert (m, c) == (MODE_BOUNDED, NO_CLAMP) and t == 0.5


def test_level_policy_rows_and_set_row():
    pol = LevelPolicy.exact(3)
    assert pol.rows == 3
    assert np.all(np.asarray(pol.mode) == MODE_EXACT)
    pol = pol.set_row(1, PrecisionClass.budget(2))
    assert int(pol.mode[1]) == MODE_BUDGET and int(pol.clamp[1]) == 2
    assert int(pol.mode[0]) == MODE_EXACT


# -------------------------------------------------------- head-walk parity
@pytest.fixture(scope="module")
def head():
    from repro.models.protohead import prototype_head

    cfg = QuantConfig()
    xq, xs, w_q, _ = prototype_head(np.random.default_rng(3), 96, 12, 9,
                                    cfg=cfg)
    bias = jnp.asarray(
        np.random.default_rng(4).normal(size=(12,)).astype(np.float32))
    return cfg, xq, xs, w_q, bias


def _argmax(cfg, xq, xs, w_q, bias=None, **kw):
    logits, tok, lv = streaming_argmax(xq, w_q.q, xs, w_q.scale, cfg.n_bits,
                                       cfg.log2_radix, bias=bias, **kw)
    return jax.tree.map(np.asarray, (logits, tok, lv))


@pytest.mark.parametrize("bias_on", [False, True])
def test_exact_policy_matches_full_scan(head, bias_on):
    cfg, xq, xs, w_q, bias = head
    b = bias if bias_on else None
    ref_lg, ref_tok, _ = _argmax(cfg, xq, xs, w_q, b)
    n_levels = 2 * cfg.planes - 1
    for early_exit in (False, True):
        lg, tok, lv = _argmax(cfg, xq, xs, w_q, b,
                              policy=LevelPolicy.exact(xq.shape[0]),
                              early_exit=early_exit)
        np.testing.assert_array_equal(ref_lg, lg)
        np.testing.assert_array_equal(ref_tok, tok)
        # exact rows never early-commit: full depth, by definition
        assert (lv == n_levels - 1).all()


@pytest.mark.parametrize("bias_on", [False, True])
def test_budget_policy_matches_truncated_levels(head, bias_on):
    cfg, xq, xs, w_q, bias = head
    b = bias if bias_on else None
    n_levels = 2 * cfg.planes - 1
    m = xq.shape[0]
    for lvl in range(1, n_levels + 1):
        _, ref_tok, _ = _argmax(cfg, xq, xs, w_q, b, levels=lvl)
        pol = LevelPolicy.budget(lvl, m)
        for early_exit in (False, True):
            _, tok, lv = _argmax(cfg, xq, xs, w_q, b, policy=pol,
                                 early_exit=early_exit)
            # the COMMITTED TOKEN is the budget contract: identical to
            # a levels=L truncated run, on both emitters
            np.testing.assert_array_equal(ref_tok, tok, err_msg=f"L={lvl}")
            # exit levels: rows may margin-commit EARLIER than the
            # clamp (the clamp is a ceiling, not a pin)
            assert (lv <= lvl - 1).all()


@pytest.mark.parametrize("bias_on", [False, True])
def test_bounded_policy_matches_legacy_early_exit(head, bias_on):
    cfg, xq, xs, w_q, bias = head
    b = bias if bias_on else None
    ref = _argmax(cfg, xq, xs, w_q, b, early_exit=True)
    got = _argmax(cfg, xq, xs, w_q, b,
                  policy=LevelPolicy.bounded(xq.shape[0]), early_exit=True)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(r, g)


def test_mixed_policy_rows_match_solo(head):
    cfg, xq, xs, w_q, _ = head
    m = xq.shape[0]
    classes = [PrecisionClass.exact(), PrecisionClass.budget(3),
               PrecisionClass.bounded()] * (m // 3)
    _, tok, lv = _argmax(cfg, xq, xs, w_q, None,
                         policy=LevelPolicy.from_classes(classes),
                         early_exit=True)
    for i, c in enumerate(classes):
        _, tok_i, lv_i = _argmax(cfg, xq[i:i + 1], xs[i:i + 1], w_q, None,
                                 policy=LevelPolicy.from_classes([c]),
                                 early_exit=True)
        assert tok[i] == tok_i[0], (i, c.label())
        assert lv[i] == lv_i[0], (i, c.label())


# ---------------------------------------------------- decode-attn parity
@pytest.fixture(scope="module")
def attn_inputs():
    rng = np.random.default_rng(0)
    B, L, H, Kv, dh = 3, 16, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(B, 1, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, L, Kv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, L, Kv, dh)), jnp.float32)
    kv_pos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    q_pos = jnp.full((B,), L - 1, jnp.int32)
    return q, k, v, kv_pos, q_pos


def _attn(attn_inputs, **kw):
    from repro.models.attention import decode_attention

    q, k, v, kv_pos, q_pos = attn_inputs
    return np.asarray(decode_attention(q, k, v, kv_pos, q_pos,
                                       l2r=QuantConfig(), **kw))


def test_attn_exact_policy_matches_full_depth(attn_inputs):
    b = attn_inputs[0].shape[0]
    np.testing.assert_array_equal(
        _attn(attn_inputs, policy=LevelPolicy.exact(b)),
        _attn(attn_inputs))


def test_attn_budget_policy_matches_truncated_levels(attn_inputs):
    b = attn_inputs[0].shape[0]
    n_levels = 2 * QuantConfig().planes - 1
    for lvl in range(1, n_levels + 1):
        np.testing.assert_array_equal(
            _attn(attn_inputs, policy=LevelPolicy.budget(lvl, b)),
            _attn(attn_inputs, levels=lvl), err_msg=f"L={lvl}")


def test_attn_bounded_policy_matches_legacy_early_exit(attn_inputs):
    b = attn_inputs[0].shape[0]
    np.testing.assert_array_equal(
        _attn(attn_inputs, policy=LevelPolicy.bounded(b, tol=1e-4)),
        _attn(attn_inputs, early_exit=True, exit_tol=1e-4))


def test_attn_mixed_budget_rows_snapshot_their_prefix(attn_inputs):
    """Budget rows in a MIXED batch serve softmax from the snapshotted
    levels=L score prefix — bit-identical to a solo truncated run even
    though exact batch-mates force the loop to full depth."""
    from repro.models.attention import decode_attention

    q, k, v, kv_pos, q_pos = attn_inputs
    classes = [PrecisionClass.exact(), PrecisionClass.budget(3),
               PrecisionClass.budget(5)]
    mix = _attn(attn_inputs, policy=LevelPolicy.from_classes(classes))
    for i, c in enumerate(classes):
        solo = np.asarray(decode_attention(
            q[i:i + 1], k[i:i + 1], v[i:i + 1], kv_pos[i:i + 1],
            q_pos[i:i + 1], l2r=QuantConfig(),
            policy=LevelPolicy.from_classes([c])))
        np.testing.assert_array_equal(mix[i], solo[0],
                                      err_msg=f"row {i} {c.label()}")


def test_attn_policy_requires_l2r(attn_inputs):
    from repro.models.attention import decode_attention

    q, k, v, kv_pos, q_pos = attn_inputs
    b = q.shape[0]
    # policy implies the digit-serial walk: the float path has no levels
    out_f = decode_attention(q, k, v, kv_pos, q_pos)
    assert out_f.shape == q.shape  # float path unaffected by the refactor
    with pytest.raises(ValueError, match="softcap"):
        decode_attention(q, k, v, kv_pos, q_pos, l2r=QuantConfig(),
                         softcap=30.0, policy=LevelPolicy.exact(b))


# -------------------------------------------------- satellite: tap tracing
def test_attn_exit_tap_raises_under_jit(attn_inputs):
    from repro.models.attention import attn_exit_tap, decode_attention

    q, k, v, kv_pos, q_pos = attn_inputs

    def step(q, k, v, kv_pos, q_pos):
        return decode_attention(q, k, v, kv_pos, q_pos, l2r=QuantConfig(),
                                early_exit=True)

    with attn_exit_tap() as rec:
        with pytest.raises(RuntimeError, match="disable_jit"):
            jax.jit(step)(q, k, v, kv_pos, q_pos)
    assert rec == []  # nothing silently recorded

    with attn_exit_tap() as rec:
        with jax.disable_jit():
            step(q, k, v, kv_pos, q_pos)
    assert len(rec) == 1 and "exit_levels" in rec[0]


def test_attn_no_tap_traces_fine(attn_inputs):
    q, k, v, kv_pos, q_pos = attn_inputs
    out = jax.jit(lambda *a: __import__(
        "repro.models.attention", fromlist=["decode_attention"]
    ).decode_attention(*a, l2r=QuantConfig(), early_exit=True))(
        q, k, v, kv_pos, q_pos)
    assert out.shape == q.shape


# ---------------------------------------------- satellite: step-flag guard
def test_step_factories_reject_contradictory_flags():
    from repro.configs import get_smoke
    from repro.serve.engine import (make_bucket_prefill_step,
                                    make_decode_step, make_prefill_step)

    cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
    factories = [lambda **k: make_decode_step(cfg, **k),
                 lambda **k: make_prefill_step(cfg, 16, **k),
                 lambda **k: make_bucket_prefill_step(cfg, 16, **k)]
    for fac in factories:
        with pytest.raises(ValueError) as e:
            fac(progressive=False, early_exit=True)
        assert "early_exit" in str(e.value) and "progressive" in str(e.value)
        with pytest.raises(ValueError) as e:
            fac(progressive=False, policy=LevelPolicy.exact(2))
        assert "policy" in str(e.value) and "progressive" in str(e.value)


# ------------------------------------------------------- serving parity
@pytest.fixture(scope="module")
def smoke_lm():
    from repro.configs import get_smoke
    from repro.models.common import materialize
    from repro.models.transformer import lm_build
    from repro.serve.engine import prepare_params

    cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
    desc = lm_build(cfg)
    params = prepare_params(cfg, materialize(desc, jax.random.PRNGKey(0)),
                            desc)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in (5, 7, 6)]
    return cfg, params, prompts


_CLASSES = [PrecisionClass.exact(), PrecisionClass.budget(3),
            PrecisionClass.bounded()]


def _requests(prompts, classes):
    from repro.serve.batching import Request

    return [Request(uid=i, prompt=p, max_new_tokens=4, precision=c)
            for i, (p, c) in enumerate(zip(prompts, classes))]


def test_mixed_class_batcher_matches_solo(smoke_lm):
    from repro.serve.batching import ContinuousBatcher

    cfg, params, prompts = smoke_lm

    def run(prompts_, classes_, n_slots):
        eng = ContinuousBatcher(cfg, params, n_slots=n_slots, max_len=32,
                                progressive=True, early_exit=True)
        reqs = _requests(prompts_, classes_)
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=200)
        return reqs, eng

    mixed, eng = run(prompts, _CLASSES, 3)
    for i, c in enumerate(_CLASSES):
        solo, _ = run(prompts[i:i + 1], [c], 1)
        assert mixed[i].output == solo[0].output, (i, c.label())
        assert mixed[i].exit_levels == solo[0].exit_levels, (i, c.label())
        assert mixed[i].prefill_exit_level == solo[0].prefill_exit_level

    st = eng.stats()
    assert set(st["exit_level_hist_by_class"]) == \
        {"exact", "budget(3)", "bounded(0)"}
    # per-class counts recompose the total
    total = np.zeros(st["n_levels"], np.int64)
    for h in st["exit_level_hist_by_class"].values():
        total += np.asarray(h)
    np.testing.assert_array_equal(total, np.asarray(st["exit_level_hist"]))


def test_mixed_class_gateway_matches_batcher(smoke_lm):
    from repro.serve.batching import ContinuousBatcher
    from repro.serve.gateway import ServingGateway

    cfg, params, prompts = smoke_lm
    breqs = _requests(prompts, _CLASSES)
    eng = ContinuousBatcher(cfg, params, n_slots=3, max_len=32,
                            progressive=True, early_exit=True)
    for r in breqs:
        eng.submit(r)
    eng.run(max_steps=200)

    greqs = _requests(prompts, _CLASSES)
    gw = ServingGateway(cfg, params, n_slots=3, max_len=32,
                        progressive=True, early_exit=True)
    gw.run(greqs)
    gw.close()
    for b, g in zip(breqs, greqs):
        assert b.output == g.output
        assert b.exit_levels == g.exit_levels
        assert b.prefill_exit_level == g.prefill_exit_level
    bst, gst = eng.stats(), gw.stats(latency=False)
    assert bst["exit_level_hist_by_class"] == gst["exit_level_hist_by_class"]
    assert bst["prefill_exit_level_hist_by_class"] == \
        gst["prefill_exit_level_hist_by_class"]


# ------------------------------------------- satellite: stats schema
def test_progressive_stats_schema_shared_and_normalized(smoke_lm):
    """The histogram block is ONE schema for both engines (string-label
    per-class keys, positional level lists), present from construction
    on — the raw-int vs stringified key drift cannot recur."""
    from repro.serve.batching import ContinuousBatcher, progressive_stats
    from repro.serve.gateway import ServingGateway

    cfg, params, _ = smoke_lm
    eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32,
                            progressive=True, early_exit=True)
    gw = ServingGateway(cfg, params, n_slots=2, max_len=32,
                        progressive=True, early_exit=True, aot_warmup=False)
    bst, gst = eng.stats(), gw.stats(latency=False)
    gw.close()
    shared = set(progressive_stats(1, np.zeros(1), np.zeros(1), {}, {}))
    assert shared <= set(bst) and shared <= set(gst)
    for st in (bst, gst):
        assert isinstance(st["exit_level_hist"], list)
        for key, hist in st["exit_level_hist_by_class"].items():
            assert isinstance(key, str) and isinstance(hist, list)
        # default class pre-seeded: schema stable before the first token
        assert list(st["exit_level_hist_by_class"]) == ["bounded(0)"]


def test_request_precision_requires_progressive(smoke_lm):
    from repro.serve.batching import ContinuousBatcher, Request

    cfg, params, prompts = smoke_lm
    eng = ContinuousBatcher(cfg, params, n_slots=1, max_len=32,
                            progressive=False)
    with pytest.raises(ValueError, match="progressive"):
        eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=2,
                           precision=PrecisionClass.exact()))
    with pytest.raises(ValueError, match="progressive"):
        ContinuousBatcher(cfg, params, n_slots=1, max_len=32,
                          progressive=False,
                          default_class=PrecisionClass.exact())


# -------------------------------------------------- calibration controller
def _calibrate():
    path = os.path.join(_REPO, "tools", "calibrate_levels.py")
    spec = importlib.util.spec_from_file_location("calibrate_levels", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_fit_budget():
    cal = _calibrate()
    assert cal.fit_budget([0, 0, 5, 3], coverage=0.99) == 4
    assert cal.fit_budget([0, 0, 5, 3], coverage=0.5) == 3
    assert cal.fit_budget([8, 0, 0, 0], coverage=1.0) == 1
    # no evidence -> hard error, never a degenerate "calibrated" budget
    with pytest.raises(ValueError, match="empty exit histogram"):
        cal.fit_budget([0, 0, 0, 0])
    with pytest.raises(ValueError):
        cal.fit_budget([1, 2], coverage=0.0)
    with pytest.raises(ValueError):
        cal.fit_budget([])
    # zero-evidence classes are skipped, not fitted
    assert cal.fit_class_budgets(
        {"a": [0, 0], "b": [0, 3]}, coverage=0.9) == {"b": 2}


def test_fit_class_budgets_and_cli(tmp_path):
    cal = _calibrate()
    stats = {"exit_level_hist_by_class": {
        "bounded(0)": [0, 4, 4, 0], "exact": [0, 0, 0, 9]}}
    fits = cal.fit_class_budgets(stats["exit_level_hist_by_class"],
                                 coverage=0.5)
    assert fits == {"bounded(0)": 2, "exact": 4}
    sp = tmp_path / "stats.json"
    sp.write_text(json.dumps(stats))
    op = tmp_path / "budgets.json"
    cal.main([str(sp), "--coverage", "0.5", "-o", str(op)])
    payload = json.loads(op.read_text())
    assert payload["budgets"] == {"bounded(0)": 2, "exact": 4}
    # per-layer form
    lp = tmp_path / "layers.json"
    lp.write_text(json.dumps({"layers": {"head": stats}}))
    cal.main([str(lp), "--coverage", "0.5", "-o", str(op)])
    assert json.loads(op.read_text())["budgets"]["head"]["exact"] == 4


# ------------------------------------------------- sharded consensus walk
SHARDED_POLICY = r"""
import sys
sys.path.insert(0, "src")
import numpy as np
import jax, jax.numpy as jnp
from repro.core.policy import LevelPolicy, PrecisionClass
from repro.core.progressive import streaming_argmax
from repro.core.quant import QuantConfig
from repro.models.protohead import prototype_head
from repro.launch.mesh import make_local_mesh

cfg = QuantConfig()
n_levels = 2 * cfg.planes - 1
xq, xs, w_q, _ = prototype_head(np.random.default_rng(3), 96, 16, 8,
                                cfg=cfg)
m = xq.shape[0]
classes = [PrecisionClass.exact(), PrecisionClass.budget(3),
           PrecisionClass.bounded(), PrecisionClass.budget(5)] * (m // 4)
pol = LevelPolicy.from_classes(classes)

def run(mesh, policy, **kw):
    out = streaming_argmax(xq, w_q.q, xs, w_q.scale, cfg.n_bits,
                           cfg.log2_radix, mesh=mesh, policy=policy, **kw)
    return jax.tree.map(np.asarray, out)

ref = run(None, pol, early_exit=True)
for shape in [(2, 4), (4, 2), (1, 8), (8, 1)]:
    mesh = make_local_mesh(*shape)
    got = run(mesh, pol, early_exit=True)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(r, g, err_msg=str(shape))
    # per-class sweeps under the mesh: exact == full, budget(L) ==
    # levels=L, bounded(0) == legacy early-exit
    full = run(mesh, None)
    ex = run(mesh, LevelPolicy.exact(m), early_exit=True)
    np.testing.assert_array_equal(full[0], ex[0], err_msg=str(shape))
    np.testing.assert_array_equal(full[1], ex[1], err_msg=str(shape))
    assert (ex[2] == n_levels - 1).all(), shape
    for L in (1, 3, n_levels):
        tr = run(mesh, None, levels=L)
        bu = run(mesh, LevelPolicy.budget(L, m), early_exit=True)
        np.testing.assert_array_equal(tr[1], bu[1], err_msg=str(shape))
        assert (bu[2] <= L - 1).all(), shape
    leg = run(mesh, None, early_exit=True)
    bo = run(mesh, LevelPolicy.bounded(m), early_exit=True)
    for r, g in zip(leg, bo):
        np.testing.assert_array_equal(r, g, err_msg=str(shape))
    print("mesh", shape, "ok")
print("ALL_OK")
"""


@pytest.mark.sharded
def test_sharded_policy_walk_bit_identical():
    """Mixed precision classes through the shard_mapped consensus walk
    on virtual-8-device meshes: tokens, exit levels, and logits all
    bit-identical to the unmeshed policy walk, and each class's parity
    sweep (exact/budget/bounded) holds under every mesh shape."""
    from repro.launch.mesh import virtual_device_env

    out = subprocess.run(
        [sys.executable, "-c", SHARDED_POLICY], capture_output=True,
        text=True, cwd=_REPO, env=virtual_device_env(8), timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    assert "ALL_OK" in out.stdout


SHARDED_MIXED_SERVING = r"""
import dataclasses
import sys
sys.path.insert(0, "src")
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_smoke
from repro.core.policy import PrecisionClass
from repro.core.quant import QuantConfig
from repro.launch.mesh import make_local_mesh
from repro.models.common import materialize
from repro.models.transformer import lm_build
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import prepare_params
from repro.sharding import ctx

cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
desc = lm_build(cfg)
raw = materialize(desc, jax.random.PRNGKey(0))
rng = np.random.default_rng(7)
prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
           for n in (5, 7, 6)]
classes = [PrecisionClass.exact(), PrecisionClass.budget(3),
           PrecisionClass.bounded()]

def serve(mesh):
    ctx.set_mesh(mesh)
    params = prepare_params(cfg, raw, desc, mesh=mesh)
    eng = ContinuousBatcher(cfg, params, n_slots=3, max_len=32,
                            progressive=True, early_exit=True, mesh=mesh)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=4, precision=c)
            for i, (p, c) in enumerate(zip(prompts, classes))]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=200)
    ctx.set_mesh(None)
    return [(r.output, r.exit_levels, r.prefill_exit_level)
            for r in reqs], eng.stats()

ref, stats_ref = serve(None)
got, stats_mesh = serve(make_local_mesh(2, 4))
assert ref == got, (ref, got)
assert stats_ref == stats_mesh, (stats_ref, stats_mesh)
print("ALL_OK")
"""


@pytest.mark.sharded
def test_sharded_mixed_class_serving_bit_identical():
    """A mixed exact/budget/bounded batch through the ContinuousBatcher
    on a (2, 4) virtual-8-device mesh: per-request outputs, exit
    levels, and the full stats() dict bit-identical to the unmeshed
    engine."""
    from repro.launch.mesh import virtual_device_env

    out = subprocess.run(
        [sys.executable, "-c", SHARDED_MIXED_SERVING], capture_output=True,
        text=True, cwd=_REPO, env=virtual_device_env(8), timeout=1500)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    assert "ALL_OK" in out.stdout
