"""Import shim for optional `hypothesis` (property-based testing).

The container may not ship hypothesis.  A bare module-level import would
fail the whole test module at *collection* time (taking the direct unit
tests down with it), and ``pytest.importorskip`` at module level would
skip the entire module.  Importing ``given``/``settings``/``st`` from
here instead keeps the non-property tests running: when hypothesis is
absent, ``@given(...)`` replaces the test with a cleanly *skipped* stub
and ``st.<anything>(...)`` returns inert placeholder strategies.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when dep is absent
    HAVE_HYPOTHESIS = False

    _SKIP = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_args, **_kwargs):
        def deco(fn):
            def _skipped_property_test():
                raise AssertionError("skipped stub should never run")

            _skipped_property_test.__name__ = fn.__name__
            _skipped_property_test.__doc__ = fn.__doc__
            return _SKIP(_skipped_property_test)

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategy:
        """Inert stand-in: composes like a strategy, never draws."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
