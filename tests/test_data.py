"""Data pipeline: determinism, sharding, elasticity, structure."""

import numpy as np

from repro.data.pipeline import DataConfig, ShardedPipeline, synthetic_batch


def test_determinism():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8)
    a = synthetic_batch(cfg, step=5, shard=0, n_shards=2)
    b = synthetic_batch(cfg, step=5, shard=0, n_shards=2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_shards_differ():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8)
    a = synthetic_batch(cfg, step=5, shard=0, n_shards=2)
    b = synthetic_batch(cfg, step=5, shard=1, n_shards=2)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_labels_are_next_tokens():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4)
    b = synthetic_batch(cfg, 0, 0, 1)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_structure_learnable():
    """With structure=1.0 the next token is a deterministic function."""
    cfg = DataConfig(vocab=97, seq_len=64, global_batch=4, structure=1.0)
    b = synthetic_batch(cfg, 0, 0, 1)
    pred = (b["tokens"] * 31 + 7) % 97
    np.testing.assert_array_equal(pred, b["labels"])


def test_resize_mid_stream():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=8)
    p = ShardedPipeline(cfg, shard=0, n_shards=1)
    next(p)
    p.resize(n_shards=2, shard=1)
    b = next(p)
    assert b["tokens"].shape == (4, 8)  # local batch shrank


def test_local_batch_divides():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=8)
    for n in (1, 2, 4, 8):
        b = synthetic_batch(cfg, 0, 0, n)
        assert b["tokens"].shape[0] == 8 // n
