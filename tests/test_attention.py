"""Chunked (flash-style) attention vs naive reference; caches; RoPE."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (apply_rope, chunked_attention,
                                    decode_attention, init_kv_cache,
                                    update_kv_cache)


def naive(q, k, v, causal=True, window=None, softcap=None, q_offset=0):
    b, sq, h, dh = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    kr = np.repeat(k, g, axis=2)
    vr = np.repeat(v, g, axis=2)
    s = np.einsum("bqhd,bshd->bhqs", q, kr).astype(np.float64) / math.sqrt(dh)
    if softcap:
        s = np.tanh(s / softcap) * softcap
    qpos = q_offset + np.arange(sq)
    kpos = np.arange(skv)
    mask = np.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None] <= qpos[:, None]
    if window:
        mask &= kpos[None] > qpos[:, None] - window
    s = np.where(mask[None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.einsum("bhqs,bshd->bqhd", p, vr)


CASES = [
    dict(sq=256, skv=256, h=4, kvh=2, dh=32, causal=True, window=None),
    dict(sq=256, skv=256, h=4, kvh=1, dh=32, causal=True, window=64),
    dict(sq=300, skv=300, h=6, kvh=3, dh=16, causal=True, window=100),
    dict(sq=128, skv=384, h=4, kvh=4, dh=32, causal=True, window=None, off=256),
    dict(sq=200, skv=200, h=2, kvh=2, dh=8, causal=False, window=None),
    dict(sq=256, skv=256, h=4, kvh=2, dh=32, causal=True, window=None, softcap=30.0),
]


@pytest.mark.parametrize("case", CASES)
def test_chunked_vs_naive(case):
    rng = np.random.default_rng(1)
    q = rng.standard_normal((2, case["sq"], case["h"], case["dh"])).astype(np.float32)
    k = rng.standard_normal((2, case["skv"], case["kvh"], case["dh"])).astype(np.float32)
    v = rng.standard_normal((2, case["skv"], case["kvh"], case["dh"])).astype(np.float32)
    out = chunked_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=case["causal"], window=case["window"],
        softcap=case.get("softcap"), q_chunk=96, kv_chunk=64,
        q_offset=case.get("off", 0),
    )
    ref = naive(q, k, v, case["causal"], case["window"],
                case.get("softcap"), case.get("off", 0))
    np.testing.assert_allclose(np.asarray(out), ref, atol=3e-5)


@pytest.mark.parametrize("case", CASES)
def test_chunked_quantized_tracks_naive(case):
    """Digit-serial QK^T inside chunked attention: W8A8 scores track the
    float oracle to quantization noise, for every mask/GQA/softcap case,
    and the result is independent of the chunking (per-vector scales
    commute with the KV-block split)."""
    from repro.core.quant import QuantConfig
    rng = np.random.default_rng(5)
    q = rng.standard_normal((2, case["sq"], case["h"], case["dh"])).astype(np.float32)
    k = rng.standard_normal((2, case["skv"], case["kvh"], case["dh"])).astype(np.float32)
    v = rng.standard_normal((2, case["skv"], case["kvh"], case["dh"])).astype(np.float32)
    kwargs = dict(causal=case["causal"], window=case["window"],
                  softcap=case.get("softcap"), q_offset=case.get("off", 0),
                  l2r=QuantConfig())
    out = chunked_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            q_chunk=96, kv_chunk=64, **kwargs)
    ref = naive(q, k, v, case["causal"], case["window"],
                case.get("softcap"), case.get("off", 0))
    np.testing.assert_allclose(np.asarray(out), ref, atol=0.12)
    out2 = chunked_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             q_chunk=64, kv_chunk=128, **kwargs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=3e-5)


def test_ring_cache_equals_window_attention():
    rng = np.random.default_rng(2)
    b, h, kvh, dh, window, total = 2, 4, 2, 32, 32, 100
    ks = rng.standard_normal((b, total, kvh, dh)).astype(np.float32)
    vs = rng.standard_normal((b, total, kvh, dh)).astype(np.float32)
    pos = np.tile(np.arange(total), (b, 1)).astype(np.int32)
    cache = init_kv_cache(b, window, kvh, dh, jnp.float32)
    cache = update_kv_cache(cache, jnp.asarray(ks), jnp.asarray(vs), jnp.asarray(pos))
    q = rng.standard_normal((b, 1, h, dh)).astype(np.float32)
    out = decode_attention(jnp.asarray(q), cache.k, cache.v, cache.positions,
                           jnp.full((b,), total - 1, jnp.int32), window=window)
    full_q = np.zeros((b, total, h, dh), np.float32)
    full_q[:, -1:] = q
    ref = naive(full_q, ks, vs, True, window)[:, -1:]
    np.testing.assert_allclose(np.asarray(out), ref, atol=3e-5)


def test_rope_relative_property():
    """<R(p)q, R(p+delta)k> must depend only on delta."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 32)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 32)).astype(np.float32))

    def score(p0, p1):
        qr = apply_rope(q, jnp.asarray([[p0]], jnp.int32))
        kr = apply_rope(k, jnp.asarray([[p1]], jnp.int32))
        return float(jnp.sum(qr * kr))

    assert score(0, 5) == pytest.approx(score(100, 105), rel=1e-4)
    assert score(3, 3) == pytest.approx(score(77, 77), rel=1e-4)


def test_mrope_sections_differ_from_standard():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((1, 4, 2, 16)).astype(np.float32))
    p1 = jnp.asarray(np.arange(4)[None], jnp.int32)
    std = apply_rope(x, p1)
    p3 = jnp.stack([p1, jnp.zeros_like(p1), jnp.zeros_like(p1)])
    mr = apply_rope(x, p3, mode="mrope", sections=(4, 2, 2))
    assert not np.allclose(np.asarray(std), np.asarray(mr))
    # with all three streams equal, mrope == standard rope
    p3_same = jnp.stack([p1, p1, p1])
    mr_same = apply_rope(x, p3_same, mode="mrope", sections=(4, 2, 2))
    np.testing.assert_allclose(np.asarray(std), np.asarray(mr_same), atol=1e-6)
