"""Streaming progressive-precision subsystem.

The load-bearing invariant: every per-level prefix the streaming emitter
produces is bit-identical to the level-stacked schedule truncated at that
depth — so early-exit consumers (VGG classify heads, progressive decode)
are reading the SAME arithmetic the production GEMM would finish, and
their committed decisions can never differ from the full result.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional hypothesis

from repro.core.l2r_gemm import l2r_matmul_int_stacked
from repro.core.progressive import (ProgressiveResult, earliest_decision_level,
                                    l2r_matmul_int_streaming, level_bounds,
                                    progressive_matmul, streaming_argmax,
                                    streaming_matmul_scan)
from repro.core.quant import QuantConfig, quantize, quantize_weights
from repro.kernels.l2r_gemm import (int_gemm_ref, l2r_gemm,
                                    l2r_gemm_progressive)

SWEEP = [(8, 1), (8, 2), (8, 4), (6, 2), (4, 2), (16, 4)]
RAGGED = [(13, 37, 11), (1, 64, 16), (45, 67, 31)]


def _rand_ints(rng, n_bits, shape):
    lo, hi = -(1 << (n_bits - 1)), 1 << (n_bits - 1)
    dt = np.int8 if n_bits <= 8 else np.int16
    return jnp.asarray(rng.integers(lo, hi, size=shape, dtype=dt))


# ------------------------------------------------ emitter bit-exactness
@pytest.mark.parametrize("n_bits,log2_radix", SWEEP)
@pytest.mark.parametrize("m,k,n", RAGGED)
def test_streaming_prefixes_bit_identical_to_stacked(n_bits, log2_radix,
                                                     m, k, n):
    """The tentpole invariant: level l of the stream == the stacked
    schedule truncated at levels=l+1, for every radix/bit-width/shape."""
    rng = np.random.default_rng(n_bits * 100 + log2_radix * 10 + m)
    a = _rand_ints(rng, n_bits, (m, k))
    b = _rand_ints(rng, n_bits, (k, n))
    d = n_bits // log2_radix
    res = progressive_matmul(a, b, n_bits, log2_radix)
    assert res.partial.shape == (2 * d - 1, m, n)
    for t in range(2 * d - 1):
        np.testing.assert_array_equal(
            np.asarray(res.partial[t]),
            np.asarray(l2r_matmul_int_stacked(a, b, n_bits, log2_radix,
                                              t + 1)),
            err_msg=f"level {t + 1}")


@pytest.mark.parametrize("n_bits,log2_radix", SWEEP)
def test_streaming_levels_truncation_matches_stacked(n_bits, log2_radix):
    rng = np.random.default_rng(n_bits + log2_radix)
    a = _rand_ints(rng, n_bits, (9, 21))
    b = _rand_ints(rng, n_bits, (21, 7))
    d = n_bits // log2_radix
    for lv in [0, 1, d, 2 * d - 1, None]:
        np.testing.assert_array_equal(
            np.asarray(l2r_matmul_int_streaming(a, b, n_bits, log2_radix,
                                                lv)),
            np.asarray(l2r_matmul_int_stacked(a, b, n_bits, log2_radix, lv)),
            err_msg=f"levels={lv}")


@pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
def test_streaming_schedule_dispatcher(backend):
    """schedule="streaming" through the backend dispatcher: exact result
    and truncated prefixes, both backends."""
    rng = np.random.default_rng(3)
    a = _rand_ints(rng, 8, (70, 90))
    b = _rand_ints(rng, 8, (90, 40))
    out = np.asarray(l2r_gemm(a, b, schedule="streaming", backend=backend))
    np.testing.assert_array_equal(out, np.asarray(int_gemm_ref(a, b)))
    out3 = np.asarray(l2r_gemm(a, b, levels=3, schedule="streaming",
                               backend=backend))
    np.testing.assert_array_equal(
        out3, np.asarray(l2r_matmul_int_stacked(a, b, 8, 2, 3)))


@pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
def test_progressive_dispatch_snapshot_stack(backend):
    """l2r_gemm_progressive: per-level stack == stacked prefixes on every
    backend (the Pallas path exercises the per-level output walk)."""
    rng = np.random.default_rng(5)
    a = _rand_ints(rng, 8, (70, 90))
    b = _rand_ints(rng, 8, (90, 40))
    res = l2r_gemm_progressive(a, b, backend=backend)
    assert res.partial.shape == (7, 70, 40)
    for t in range(7):
        np.testing.assert_array_equal(
            np.asarray(res.partial[t]),
            np.asarray(l2r_matmul_int_stacked(a, b, 8, 2, t + 1)),
            err_msg=f"{backend} level {t + 1}")


def test_streaming_fold_sees_every_prefix():
    """The fold consumer receives the exact per-level prefixes, in MSDF
    order, while the scan carries only the accumulator."""
    rng = np.random.default_rng(7)
    a = _rand_ints(rng, 8, (5, 12))
    b = _rand_ints(rng, 8, (12, 4))
    ref = progressive_matmul(a, b)

    def fold(carry, partial, idx):
        count, max_diff = carry
        diff = jnp.abs(partial - ref.partial[idx]).max()
        return count + 1, jnp.maximum(max_diff, diff)

    final, (count, max_diff), stack = streaming_matmul_scan(
        a, b, fold, (jnp.int32(0), jnp.int32(0)))
    assert stack is None  # emit=False: no (L, M, N) materialization
    assert int(count) == 7
    assert int(max_diff) == 0
    np.testing.assert_array_equal(np.asarray(final),
                                  np.asarray(ref.partial[-1]))


# ------------------------------------------------------ decision soundness
@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_early_exit_never_differs_from_full_argmax(seed):
    """Rows that exit early always pick the argmax of the full stream."""
    rng = np.random.default_rng(seed)
    a = _rand_ints(rng, 8, (6, 24))
    b = _rand_ints(rng, 8, (24, 12))
    res = progressive_matmul(a, b)
    lv = np.asarray(earliest_decision_level(res))
    full_arg = np.asarray(res.partial[-1]).argmax(-1)
    for row in range(a.shape[0]):
        chosen = np.asarray(res.partial[lv[row], row]).argmax(-1)
        assert chosen == full_arg[row], (row, lv[row])


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_streaming_argmax_commits_match_full(seed):
    """The fold-based committer (the serving primitive): every committed
    index equals the argmax of the fully dequantized logits."""
    rng = np.random.default_rng(seed)
    cfg = QuantConfig()
    x = jnp.asarray(rng.standard_normal((8, 48)).astype(np.float32))
    w = jnp.asarray((rng.standard_normal((48, 10)) * 0.3).astype(np.float32))
    xq, xs = quantize(x, cfg, axis=0)
    w_q = quantize_weights(w, cfg)
    logits, tok, lv = streaming_argmax(xq, w_q.q, xs, w_q.scale)
    np.testing.assert_array_equal(np.asarray(tok),
                                  np.asarray(logits).argmax(-1))
    assert (np.asarray(lv) <= 6).all()


def test_bound_i32_exactness_guard():
    """Levels whose tail bound exceeds the int32 decision range are
    UNDECIDABLE (never compared in a lossy dtype), not silently clipped
    into unsound early exits."""
    # K large enough that the early-level bounds blow past int32
    bounds = level_bounds(d=4, log2_radix=2, k=1 << 20)
    exact = bounds.exact
    clip = (2**31 - 1) // 2
    dec = np.asarray(bounds.decidable)
    for t, b in enumerate(exact):
        assert dec[t] == (b <= clip)
        if not dec[t]:
            assert int(np.asarray(bounds.i32)[t]) == clip
        else:
            assert int(np.asarray(bounds.i32)[t]) == b
        # the f32 report is always an upper bound of the exact value
        assert float(np.asarray(bounds.f32)[t]) >= b
    assert (~dec).any() and dec.any()
    # a synthetic result whose margin beats ANY in-range bound must still
    # not fire at undecidable levels
    L = len(exact)
    partial = jnp.zeros((L, 1, 2), jnp.int32).at[:, 0, 0].set(2**31 - 1)
    res = ProgressiveResult(partial=partial, tail_bound=bounds.f32,
                            bound_i32=bounds.i32, decidable=bounds.decidable)
    lv = int(np.asarray(earliest_decision_level(res))[0])
    first_decidable = int(np.argmax(dec))
    assert lv == first_decidable  # not 0, despite the level-0 margin


def test_levels_zero_empty_prefix():
    rng = np.random.default_rng(1)
    a = _rand_ints(rng, 8, (4, 8))
    b = _rand_ints(rng, 8, (8, 3))
    np.testing.assert_array_equal(
        np.asarray(l2r_matmul_int_streaming(a, b, levels=0)), 0)
    for backend in ("jnp", "pallas-interpret"):
        np.testing.assert_array_equal(
            np.asarray(l2r_gemm(a, b, levels=0, schedule="streaming",
                                backend=backend)), 0)


# ------------------------------------------------------------ end to end
def test_vgg16_classify_progressive_matches_apply():
    """The conv->head early-exit path: committed classes and returned
    logits are bit-identical to the one-shot vgg16_apply L2R forward."""
    from repro.models.cnn import (vgg16_apply, vgg16_build,
                                  vgg16_classify_progressive,
                                  vgg16_quantize_weights)
    from repro.models.common import materialize

    cfg = QuantConfig()
    params = materialize(vgg16_build(n_classes=10), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.standard_normal((2, 32, 32, 3)).astype(np.float32))
    cache = vgg16_quantize_weights(params, cfg)
    ref = np.asarray(vgg16_apply(params, img, l2r=cfg, weights_q=cache))
    pred, lv, logits = vgg16_classify_progressive(params, img, cfg,
                                                  weights_q=cache)
    np.testing.assert_array_equal(np.asarray(logits), ref)
    np.testing.assert_array_equal(np.asarray(pred), ref.argmax(-1))
    assert (np.asarray(lv) >= 0).all() and (np.asarray(lv) <= 6).all()


@pytest.fixture(scope="module")
def l2r_lm():
    from repro.configs import get_smoke
    from repro.models.common import materialize
    from repro.models.transformer import lm_build

    cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
    params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_progressive_decode_tokens_identical_to_greedy(l2r_lm):
    """Progressive decode commits the SAME tokens greedy_generate emits —
    the early exit only changes how many levels were needed, never the
    output."""
    from repro.serve.engine import (greedy_generate, make_decode_step,
                                    make_prefill_step)

    cfg, params = l2r_lm
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    ref = np.asarray(greedy_generate(cfg, params, prompt, steps=6,
                                     max_len=32))
    prefill = jax.jit(make_prefill_step(cfg, 32, jnp.float32))
    decode = jax.jit(make_decode_step(cfg, progressive=True))
    state, logits = prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out, levels = [np.asarray(tok)], []
    for _ in range(5):
        state, tok, _, lv = decode(params, state, tok)
        out.append(np.asarray(tok))
        levels.append(np.asarray(lv))
    np.testing.assert_array_equal(np.concatenate(out, axis=1), ref)
    levels = np.concatenate(levels, axis=1)
    assert levels.min() >= 0 and levels.max() <= 6


def test_progressive_decode_respects_l2r_levels(l2r_lm):
    """cfg.l2r_levels truncates the streamed head exactly like the
    one-shot head: logits AND tokens bit-identical between the
    progressive and non-progressive decode steps."""
    from repro.serve.engine import make_decode_step, make_prefill_step

    cfg5 = dataclasses.replace(l2r_lm[0], l2r_levels=5)
    params = l2r_lm[1]
    rng = np.random.default_rng(7)
    prompt = jnp.asarray(rng.integers(0, cfg5.vocab, (2, 8)), jnp.int32)
    prefill = jax.jit(make_prefill_step(cfg5, 16, jnp.float32))
    state, logits = prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    st_r, tok_r, logits_r = jax.jit(make_decode_step(cfg5))(
        params, state, tok)
    _, tok_p, logits_p, lv = jax.jit(make_decode_step(
        cfg5, progressive=True))(params, state, tok)
    np.testing.assert_array_equal(np.asarray(logits_p),
                                  np.asarray(logits_r))
    np.testing.assert_array_equal(np.asarray(tok_p), np.asarray(tok_r))
    assert np.asarray(lv).max() <= 4  # truncated stream: 5 levels max


def test_prepare_params_head_cache(l2r_lm):
    """prepare_params caches the int8 LM head; cached and fresh head
    quantization are bit-identical on both decode paths."""
    from repro.serve.engine import prepare_params, progressive_logits_from_hidden
    from repro.models.transformer import logits_from_hidden

    cfg, params = l2r_lm
    pp = prepare_params(cfg, params)
    assert "head_q" in pp
    rng = np.random.default_rng(9)
    hidden = jnp.asarray(rng.standard_normal((2, 1, cfg.d_model))
                         .astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(logits_from_hidden(cfg, pp, hidden)),
        np.asarray(logits_from_hidden(cfg, params, hidden)))
    lg_c, tok_c, lv_c = progressive_logits_from_hidden(cfg, pp, hidden)
    lg_f, tok_f, lv_f = progressive_logits_from_hidden(cfg, params, hidden)
    np.testing.assert_array_equal(np.asarray(lg_c), np.asarray(lg_f))
    np.testing.assert_array_equal(np.asarray(tok_c), np.asarray(tok_f))
    np.testing.assert_array_equal(np.asarray(lv_c), np.asarray(lv_f))


def test_batcher_progressive_stats(l2r_lm):
    """The continuous batcher in progressive mode: identical tokens to the
    non-progressive engine, per-request exit levels recorded, and the
    saved-levels histogram surfaced in stats()."""
    from repro.serve.batching import ContinuousBatcher, Request

    cfg, params = l2r_lm
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, (6,)).astype(np.int32)
               for _ in range(3)]

    def run(progressive):
        eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32,
                                progressive=progressive)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=100)
        return eng, reqs

    eng_p, reqs_p = run(True)
    eng_r, reqs_r = run(False)
    for rp, rr in zip(reqs_p, reqs_r):
        assert rp.output == rr.output, (rp.uid, rp.output, rr.output)
        # one exit level per decoded token (the prefill token has none)
        assert len(rp.exit_levels) == len(rp.output) - 1
    stats = eng_p.stats()
    assert stats["progressive"] and stats["n_levels"] == 7
    assert stats["tokens"] == sum(len(r.exit_levels) for r in reqs_p)
    assert sum(stats["exit_level_hist"]) == stats["tokens"]
    assert 0.0 <= stats["mean_exit_level"] <= 6.0
    assert not eng_r.stats().get("exit_level_hist")
