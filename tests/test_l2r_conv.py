"""Fused L2R conv (implicit im2col) + the load-time weight cache.

The fused conv must be bit-identical to materialized im2col + the MSDF
digit-plane GEMM on the same quantized operands (the tap decomposition
splits the (kh, kw, cin) contraction exactly), and W8A8-close to
lax.conv in float.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.l2r_gemm import l2r_matmul_int
from repro.core.quant import QuantConfig, QuantizedWeights, quantize_weights
from repro.kernels.l2r_gemm import l2r_conv2d, l2r_conv2d_progressive
from repro.kernels.l2r_gemm.ops import (_l2r_conv2d_int,
                                        _l2r_conv2d_progressive_int)


def _im2col_int(xq, wq, levels=None):
    """Oracle: materialized patches -> pair-loop MSDF GEMM, same ints."""
    bsz, h, w_, cin = xq.shape
    kh, kw, _, cout = wq.shape
    patches = jax.lax.conv_general_dilated_patches(
        xq.astype(jnp.float32), (kh, kw), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (B, H, W, cin*kh*kw), channel-major (cin, kh, kw) — exact in f32
    flat = jnp.round(patches).astype(jnp.int8).reshape(bsz * h * w_, -1)
    wmat = wq.transpose(2, 0, 1, 3).reshape(-1, cout)
    out = l2r_matmul_int(flat, wmat, 8, 2, levels)
    return np.asarray(out).reshape(bsz, h, w_, cout)


@pytest.mark.parametrize("levels", [None, 1, 3, 5, 7])
def test_fused_conv_bit_identical_to_im2col(levels):
    """Every truncation depth: tap-decomposed == patch-materialized."""
    rng = np.random.default_rng(0 if levels is None else levels)
    xq = jnp.asarray(rng.integers(-128, 128, (2, 9, 7, 5), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-128, 128, (3, 3, 5, 6), dtype=np.int8))
    out = np.asarray(_l2r_conv2d_int(xq, wq, 8, 2, levels, "jnp"))
    np.testing.assert_array_equal(out, _im2col_int(xq, wq, levels))


def test_fused_conv_1x1_and_5x5():
    rng = np.random.default_rng(9)
    xq = jnp.asarray(rng.integers(-128, 128, (1, 8, 8, 4), dtype=np.int8))
    for k in (1, 5):
        wq = jnp.asarray(rng.integers(-128, 128, (k, k, 4, 3), dtype=np.int8))
        out = np.asarray(_l2r_conv2d_int(xq, wq, 8, 2, None, "jnp"))
        np.testing.assert_array_equal(out, _im2col_int(xq, wq))


def test_fused_conv_backends_agree():
    rng = np.random.default_rng(4)
    xq = jnp.asarray(rng.integers(-128, 128, (1, 5, 5, 3), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-128, 128, (3, 3, 3, 4), dtype=np.int8))
    out_jnp = np.asarray(_l2r_conv2d_int(xq, wq, 8, 2, None, "jnp"))
    out_pl = np.asarray(_l2r_conv2d_int(xq, wq, 8, 2, None, "pallas-interpret"))
    np.testing.assert_array_equal(out_pl, out_jnp)


def test_fused_conv_w8a8_close_to_lax_conv():
    """Float-level acceptance: fused W8A8 conv vs the lax.conv reference."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 12, 12, 8)).astype(np.float32))
    w = jnp.asarray((rng.standard_normal((3, 3, 8, 16)) * 0.1).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((16,)).astype(np.float32))
    out = np.asarray(l2r_conv2d(x, w, b))
    ref = np.asarray(jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + b)
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert rel < 0.02, rel  # int8 W8A8 quantization error


def _lax_conv_int(xq, wq, stride=(1, 1), dilation=(1, 1)):
    """Strided/dilated integer conv oracle (f32 is exact for int8 taps)."""
    out = jax.lax.conv_general_dilated(
        xq.astype(jnp.float32), wq.astype(jnp.float32), stride, "SAME",
        rhs_dilation=dilation, dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return np.round(np.asarray(out)).astype(np.int64)


@pytest.mark.parametrize("stride,dilation", [
    ((2, 2), (1, 1)), ((1, 1), (2, 2)), ((2, 1), (1, 3)), ((3, 3), (2, 2)),
])
def test_fused_conv_stride_dilation_parity(stride, dilation):
    """Strided/dilated shifted-view slicing vs lax.conv_general_dilated,
    exact on the integer operands."""
    rng = np.random.default_rng(sum(stride) * 10 + sum(dilation))
    xq = jnp.asarray(rng.integers(-128, 128, (2, 11, 9, 5), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-128, 128, (3, 3, 5, 6), dtype=np.int8))
    out = np.asarray(_l2r_conv2d_int(xq, wq, 8, 2, None, "jnp",
                                     stride, dilation))
    np.testing.assert_array_equal(out.astype(np.int64),
                                  _lax_conv_int(xq, wq, stride, dilation))


def test_fused_conv_stride_backends_agree():
    rng = np.random.default_rng(21)
    xq = jnp.asarray(rng.integers(-128, 128, (1, 6, 6, 3), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-128, 128, (3, 3, 3, 4), dtype=np.int8))
    out_j = np.asarray(_l2r_conv2d_int(xq, wq, 8, 2, None, "jnp",
                                       (2, 2), (1, 1)))
    out_p = np.asarray(_l2r_conv2d_int(xq, wq, 8, 2, None,
                                       "pallas-interpret", (2, 2), (1, 1)))
    np.testing.assert_array_equal(out_p, out_j)


def test_fused_conv_strided_float_close_to_lax():
    rng = np.random.default_rng(22)
    x = jnp.asarray(rng.standard_normal((1, 9, 9, 4)).astype(np.float32))
    w = jnp.asarray((rng.standard_normal((3, 3, 4, 6)) * 0.2).astype(np.float32))
    out = np.asarray(l2r_conv2d(x, w, None, QuantConfig(), stride=2,
                                dilation=2))
    ref = np.asarray(jax.lax.conv_general_dilated(
        x, w, (2, 2), "SAME", rhs_dilation=(2, 2),
        dimension_numbers=("NHWC", "HWIO", "NHWC")))
    assert out.shape == ref.shape
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert rel < 0.02, rel


# ------------------------------------------------------- progressive conv
@pytest.mark.parametrize("stride,dilation", [((1, 1), (1, 1)), ((2, 2), (1, 1))])
def test_conv_progressive_prefixes_bit_identical(stride, dilation):
    """Level l of the conv stream == the fused conv truncated at l+1 —
    the conv-level analogue of the streaming GEMM invariant."""
    rng = np.random.default_rng(23)
    xq = jnp.asarray(rng.integers(-128, 128, (2, 7, 6, 5), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-128, 128, (3, 3, 5, 4), dtype=np.int8))
    stack = np.asarray(_l2r_conv2d_progressive_int(
        xq, wq, 8, 2, None, "jnp", stride, dilation))
    assert stack.shape[0] == 7
    for t in range(7):
        np.testing.assert_array_equal(
            stack[t],
            np.asarray(_l2r_conv2d_int(xq, wq, 8, 2, t + 1, "jnp",
                                       stride, dilation)),
            err_msg=f"level {t + 1}")


def test_conv_progressive_backends_agree():
    rng = np.random.default_rng(24)
    xq = jnp.asarray(rng.integers(-128, 128, (1, 5, 5, 3), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-128, 128, (3, 3, 3, 4), dtype=np.int8))
    s_j = np.asarray(_l2r_conv2d_progressive_int(xq, wq, 8, 2, None, "jnp",
                                                 (1, 1), (1, 1)))
    s_p = np.asarray(_l2r_conv2d_progressive_int(
        xq, wq, 8, 2, None, "pallas-interpret", (1, 1), (1, 1)))
    np.testing.assert_array_equal(s_p, s_j)


def test_conv_progressive_float_envelope():
    """The dequantized stream converges to the exact W8A8 conv and every
    prefix stays inside the scaled tail-bound envelope."""
    rng = np.random.default_rng(25)
    cfg = QuantConfig()
    x = jnp.asarray(rng.standard_normal((1, 8, 8, 4)).astype(np.float32))
    w = jnp.asarray((rng.standard_normal((3, 3, 4, 6)) * 0.2).astype(np.float32))
    res, scale = l2r_conv2d_progressive(x, w, cfg)
    exact = np.asarray(l2r_conv2d(x, w, None, cfg), np.float64)
    final = np.asarray(res.partial[-1], np.float64) * np.asarray(scale,
                                                                 np.float64)
    np.testing.assert_allclose(final, exact, rtol=1e-6, atol=1e-6)
    for t in range(res.partial.shape[0]):
        err = np.abs(np.asarray(res.partial[t], np.int64)
                     - np.asarray(res.partial[-1], np.int64))
        assert (err <= float(res.tail_bound[t])).all(), t


def test_fused_conv_weight_cache_bit_identical():
    """Passing the load-time cache must not change a single bit vs
    quantizing the same weights inside the call."""
    rng = np.random.default_rng(2)
    cfg = QuantConfig()
    x = jnp.asarray(rng.standard_normal((1, 8, 8, 4)).astype(np.float32))
    w = jnp.asarray((rng.standard_normal((3, 3, 4, 6)) * 0.2).astype(np.float32))
    w_q = quantize_weights(w, cfg)
    assert isinstance(w_q, QuantizedWeights)
    assert w_q.q.dtype == jnp.int8 and w_q.q.shape == w.shape
    out_cached = np.asarray(l2r_conv2d(x, None, None, cfg, w_q=w_q))
    out_fresh = np.asarray(l2r_conv2d(x, w, None, cfg))
    np.testing.assert_array_equal(out_cached, out_fresh)


def test_quantized_weights_is_pytree():
    """The cache must flow through jit/scan/tree transparently."""
    w_q = quantize_weights(jnp.ones((4, 3)))
    leaves, treedef = jax.tree.flatten(w_q)
    assert len(leaves) == 2
    back = jax.tree.unflatten(treedef, leaves)
    assert isinstance(back, QuantizedWeights)
    doubled = jax.jit(lambda t: jax.tree.map(lambda x: x, t))(w_q)
    assert isinstance(doubled, QuantizedWeights)


def test_vgg16_weight_cache_path():
    """vgg16_apply(l2r=...) through the prebuilt cache: bit-identical to
    the cache built internally, and the cache quantizes each weight once."""
    from repro.models.cnn import (vgg16_apply, vgg16_build,
                                  vgg16_quantize_weights)
    from repro.models.common import materialize

    cfg = QuantConfig()
    params = materialize(vgg16_build(n_classes=10), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.standard_normal((1, 32, 32, 3)).astype(np.float32))
    cache = vgg16_quantize_weights(params, cfg)
    assert all(isinstance(v, QuantizedWeights) for v in cache.values())
    out_cached = np.asarray(vgg16_apply(params, img, l2r=cfg, weights_q=cache))
    out_auto = np.asarray(vgg16_apply(params, img, l2r=cfg))
    np.testing.assert_array_equal(out_cached, out_auto)


def test_dense_quantized_weights_record():
    """models/common.dense consumes QuantizedWeights on both paths."""
    from repro.models.common import dense

    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((6, 32)).astype(np.float32))
    w = jnp.asarray((rng.standard_normal((32, 10)) * 0.2).astype(np.float32))
    cfg = QuantConfig()
    w_q = quantize_weights(w, cfg)
    # L2R path: cached weights == freshly quantized weights, bit for bit
    np.testing.assert_array_equal(
        np.asarray(dense(x, w_q, l2r=cfg)), np.asarray(dense(x, w, l2r=cfg)))
    # plain W8A8 path (no l2r config): close to the float matmul
    out = np.asarray(dense(x, w_q))
    ref = np.asarray(x @ w)
    assert np.abs(out - ref).max() / np.abs(ref).max() < 0.02
