"""Pre-stacked plane operands: bit-exact parity with inline extraction.

The digit-plane stacks are the real operands of every L2R schedule, so
building them once (PlaneOperands / the QuantizedWeights.planes load-time
cache) and reusing them across taps, steps and backends must change
NOTHING numerically: every prestacked entry point is swept against its
inline-extraction counterpart (n_bits x radix x levels x ragged shapes,
conv stride/dilation, jnp + pallas-interpret) for bit equality, and the
amortization itself is asserted by counting extraction calls (one
activation stack per feature map, zero weight extractions with a cache).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import (PlaneOperands, QuantConfig, quantize_weights,
                              stack_planes_lhs, stack_planes_rhs)
from repro.kernels.l2r_gemm import (int_gemm_ref, l2r_conv2d,
                                    l2r_conv2d_progressive,
                                    l2r_conv2d_progressive_while, l2r_gemm,
                                    l2r_gemm_progressive)
from repro.kernels.l2r_gemm import ops as l2r_ops


def _rand_ints(rng, n_bits, shape):
    lo, hi = -(1 << (n_bits - 1)), (1 << (n_bits - 1))
    dtype = np.int8 if n_bits <= 8 else np.int16
    return jnp.asarray(rng.integers(lo, hi, shape, dtype=dtype))


# ------------------------------------------------------------ layout core
@pytest.mark.parametrize("n_bits,log2_radix", [(8, 1), (8, 2), (8, 4),
                                               (16, 2)])
def test_plane_layout_conversion_exact(n_bits, log2_radix):
    """raw <-> shifted chunk conversion reproduces the direct extraction
    of either layout bit-for-bit, both sides, with and without the
    streaming window padding."""
    rng = np.random.default_rng(n_bits * 8 + log2_radix)
    a = _rand_ints(rng, n_bits, (9, 11))
    b = _rand_ints(rng, n_bits, (11, 6))
    for wp in (False, True):
        pa = PlaneOperands.prepare_lhs(a, n_bits, log2_radix, shifted=False,
                                       window_pad=wp)
        pb = PlaneOperands.prepare_rhs(b, n_bits, log2_radix, shifted=False,
                                       window_pad=wp)
        np.testing.assert_array_equal(
            np.asarray(pa.core_stack(True)),
            np.asarray(stack_planes_lhs(a, n_bits, log2_radix, shifted=True)))
        np.testing.assert_array_equal(
            np.asarray(pb.core_stack(True)),
            np.asarray(stack_planes_rhs(b, n_bits, log2_radix, shifted=True)))
        # round trip through the shifted layout is the identity
        rt = pa.with_layout(True).with_layout(False)
        np.testing.assert_array_equal(np.asarray(rt.stack),
                                      np.asarray(pa.stack))
        # the window stack is the core stack plus (D-1)*K zero columns
        d = pa.d
        w = np.asarray(pa.window_stack())
        assert w.shape[-1] == (2 * d - 1) * pa.k
        np.testing.assert_array_equal(w[..., :d * pa.k],
                                      np.asarray(pa.core_stack(False)))
        assert (w[..., d * pa.k:] == 0).all()


# ------------------------------------------------------------- GEMM parity
@pytest.mark.parametrize("n_bits,log2_radix", [(8, 1), (8, 2), (8, 4),
                                               (16, 2)])
@pytest.mark.parametrize("shape", [(7, 13, 5), (33, 65, 17)])
def test_gemm_prestacked_parity_jnp(n_bits, log2_radix, shape):
    """Every prestacked combination (lhs/rhs/both x raw/shifted x window
    padding) equals the inline path on the jnp backend, at full depth and
    truncated levels."""
    m, k, n = shape
    rng = np.random.default_rng(m + n_bits + log2_radix)
    a = _rand_ints(rng, n_bits, (m, k))
    b = _rand_ints(rng, n_bits, (k, n))
    d = n_bits // log2_radix
    for levels in (None, 1, min(3, 2 * d - 1)):
        ref = np.asarray(l2r_gemm(a, b, n_bits, log2_radix, levels,
                                  backend="jnp"))
        for shifted in (False, True):
            for wp in (False, True):
                pa = PlaneOperands.prepare_lhs(a, n_bits, log2_radix,
                                               shifted=shifted, window_pad=wp)
                pb = PlaneOperands.prepare_rhs(b, n_bits, log2_radix,
                                               shifted=shifted, window_pad=wp)
                for aa, bb in ((pa, b), (a, pb), (pa, pb)):
                    out = np.asarray(l2r_gemm(aa, bb, n_bits, log2_radix,
                                              levels, backend="jnp"))
                    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("levels", [None, 3])
def test_gemm_prestacked_parity_pallas_interpret(levels):
    """Prestacked operands through the pre-stacked Pallas kernel entry
    (interpret mode) equal the raw-operand kernel path bit-for-bit."""
    rng = np.random.default_rng(11)
    a = _rand_ints(rng, 8, (70, 90))
    b = _rand_ints(rng, 8, (90, 40))
    ref = np.asarray(l2r_gemm(a, b, levels=levels,
                              backend="pallas-interpret"))
    for shifted in (False, True):
        pa = PlaneOperands.prepare_lhs(a, shifted=shifted)
        pb = PlaneOperands.prepare_rhs(b, shifted=shifted, window_pad=True)
        out = np.asarray(l2r_gemm(pa, pb, levels=levels,
                                  backend="pallas-interpret"))
        np.testing.assert_array_equal(out, ref)
        out = np.asarray(l2r_gemm(a, pb, levels=levels,
                                  backend="pallas-interpret"))
        np.testing.assert_array_equal(out, ref)


def test_gemm_prestacked_streaming_schedule():
    """schedule="streaming" consumes prestacked operands (the streaming
    emitters read the same zero-padded window the inline path builds)."""
    rng = np.random.default_rng(12)
    a = _rand_ints(rng, 8, (19, 23))
    b = _rand_ints(rng, 8, (23, 9))
    ref = np.asarray(int_gemm_ref(a, b))
    pa = PlaneOperands.prepare_lhs(a, window_pad=True)
    pb = PlaneOperands.prepare_rhs(b)
    out = np.asarray(l2r_gemm(pa, pb, schedule="streaming", backend="jnp"))
    np.testing.assert_array_equal(out, ref)
    out = np.asarray(l2r_gemm(pa, pb, schedule="streaming", backend="jnp",
                              early_exit=True))
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
def test_gemm_progressive_prestacked_parity(backend):
    """The per-level snapshot stream is identical from prestacked and raw
    operands on both backends."""
    rng = np.random.default_rng(13)
    a = _rand_ints(rng, 8, (37, 53))
    b = _rand_ints(rng, 8, (53, 29))
    r_raw = l2r_gemm_progressive(a, b, backend=backend)
    r_pre = l2r_gemm_progressive(PlaneOperands.prepare_lhs(a),
                                 PlaneOperands.prepare_rhs(b),
                                 backend=backend)
    np.testing.assert_array_equal(np.asarray(r_raw.partial),
                                  np.asarray(r_pre.partial))


def test_gemm_prestacked_validation():
    """Mismatched layouts / sides / schedules are rejected loudly."""
    rng = np.random.default_rng(14)
    a = _rand_ints(rng, 8, (8, 8))
    b = _rand_ints(rng, 8, (8, 8))
    pa = PlaneOperands.prepare_lhs(a)
    pb = PlaneOperands.prepare_rhs(b)
    with pytest.raises(ValueError, match="lhs"):
        l2r_gemm(pb, b)  # rhs stack in the lhs slot
    with pytest.raises(ValueError, match="n_bits"):
        l2r_gemm(pa, b, n_bits=8, log2_radix=4)  # layout/config mismatch
    with pytest.raises(TypeError, match="pairs"):
        l2r_gemm(pa, pb, schedule="pairs")


def test_streaming_consumers_reject_mismatched_stack():
    """The streaming emitters (streaming_argmax & friends) validate the
    stack's digit config — a radix-mismatched stack would mis-slice the
    level walk silently otherwise."""
    from repro.core.progressive import streaming_argmax

    rng = np.random.default_rng(15)
    a = _rand_ints(rng, 8, (4, 8))
    b = _rand_ints(rng, 8, (8, 6))
    pb = PlaneOperands.prepare_rhs(b, 8, 2)
    xs = jnp.ones((4, 1), jnp.float32)
    ws = jnp.ones((1, 6), jnp.float32)
    with pytest.raises(ValueError, match="re-prepare"):
        streaming_argmax(a, pb, xs, ws, n_bits=8, log2_radix=4)
    pa = PlaneOperands.prepare_lhs(a, 8, 2)
    with pytest.raises(ValueError, match="rhs"):
        streaming_argmax(a, pa, xs, ws)  # lhs stack in the rhs slot


# ------------------------------------------------------------- conv parity
@pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
@pytest.mark.parametrize("stride,dilation", [(1, 1), (2, 1), (1, 2), (2, 2)])
def test_conv_weight_cache_parity(backend, stride, dilation):
    """l2r_conv2d with the prestacked weight cache == without, bit-for-
    bit, across stride/dilation geometries on both backends."""
    rng = np.random.default_rng(stride * 10 + dilation)
    cfg = QuantConfig()
    x = jnp.asarray(rng.standard_normal((2, 9, 7, 5)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((3, 3, 5, 6)).astype(np.float32))
    plain = quantize_weights(w, cfg)
    pre = quantize_weights(w, cfg, prestack=True, plane_axis=-2)
    o_plain = np.asarray(l2r_conv2d(x, None, cfg=cfg, w_q=plain,
                                    backend=backend, stride=stride,
                                    dilation=dilation))
    o_pre = np.asarray(l2r_conv2d(x, None, cfg=cfg, w_q=pre, backend=backend,
                                  stride=stride, dilation=dilation))
    np.testing.assert_array_equal(o_plain, o_pre)


@pytest.mark.parametrize("n_bits,log2_radix", [(8, 1), (8, 4)])
def test_conv_weight_cache_parity_radix_sweep(n_bits, log2_radix):
    """Cache parity holds at every digit width (jnp backend)."""
    rng = np.random.default_rng(n_bits + log2_radix)
    cfg = QuantConfig(n_bits=n_bits, log2_radix=log2_radix)
    x = jnp.asarray(rng.standard_normal((1, 6, 5, 3)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((3, 3, 3, 4)).astype(np.float32))
    plain = quantize_weights(w, cfg)
    pre = quantize_weights(w, cfg, prestack=True, plane_axis=-2)
    for levels in (None, 2):
        o_plain = np.asarray(l2r_conv2d(x, None, cfg=cfg, w_q=plain,
                                        levels=levels, backend="jnp"))
        o_pre = np.asarray(l2r_conv2d(x, None, cfg=cfg, w_q=pre,
                                      levels=levels, backend="jnp"))
        np.testing.assert_array_equal(o_plain, o_pre)


@pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
def test_conv_progressive_weight_cache_parity(backend):
    """The progressive conv's per-level stream is identical with the
    cached weight stack, and so is the early-exit while form (jnp)."""
    rng = np.random.default_rng(20)
    cfg = QuantConfig()
    x = jnp.asarray(rng.standard_normal((1, 7, 6, 4)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 5)).astype(np.float32))
    plain = quantize_weights(w, cfg)
    pre = quantize_weights(w, cfg, prestack=True, plane_axis=-2)
    r_plain, s_plain = l2r_conv2d_progressive(x, None, cfg=cfg, w_q=plain,
                                              backend=backend)
    r_pre, s_pre = l2r_conv2d_progressive(x, None, cfg=cfg, w_q=pre,
                                          backend=backend)
    np.testing.assert_array_equal(np.asarray(r_plain.partial),
                                  np.asarray(r_pre.partial))
    np.testing.assert_array_equal(np.asarray(s_plain), np.asarray(s_pre))
    if backend == "jnp":
        a_plain = l2r_conv2d_progressive_while(x, None, cfg=cfg, w_q=plain)
        a_pre = l2r_conv2d_progressive_while(x, None, cfg=cfg, w_q=pre)
        np.testing.assert_array_equal(np.asarray(a_plain[0]),
                                      np.asarray(a_pre[0]))


# -------------------------------------------------- extraction amortization
class _Counter:
    def __init__(self, fn):
        self.fn, self.calls = fn, 0

    def __call__(self, *a, **kw):
        self.calls += 1
        return self.fn(*a, **kw)


@pytest.mark.parametrize("backend,shape", [("jnp", (2, 10, 9, 3)),
                                           ("pallas-interpret", (2, 8, 11, 3))])
def test_conv_single_activation_extraction_per_feature_map(
        monkeypatch, backend, shape):
    """The fused conv performs exactly ONE activation plane extraction
    per feature map on every backend, and ZERO weight extractions when
    the load-time cache is present (the 3x3 layer's 9 taps share them).
    Shapes are unique per backend so the jitted conv core re-traces under
    the counting wrappers."""
    cfg = QuantConfig()
    rng = np.random.default_rng(30)
    x = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((3, 3, shape[-1], 6))
                    .astype(np.float32))
    pre = quantize_weights(w, cfg, prestack=True, plane_axis=-2)
    lhs = _Counter(l2r_ops.stack_planes_lhs)
    rhs = _Counter(l2r_ops.stack_planes_rhs)
    monkeypatch.setattr(l2r_ops, "stack_planes_lhs", lhs)
    monkeypatch.setattr(l2r_ops, "stack_planes_rhs", rhs)
    jax.block_until_ready(l2r_conv2d(x, None, cfg=cfg, w_q=pre,
                                     backend=backend))
    assert lhs.calls == 1, f"{lhs.calls} activation extractions (want 1)"
    assert rhs.calls == 0, f"{rhs.calls} weight extractions (want 0: cached)"


def test_conv_inline_weight_extraction_once_per_call(monkeypatch):
    """Without the cache the weight stack is still extracted exactly once
    per call (not once per tap)."""
    cfg = QuantConfig()
    rng = np.random.default_rng(31)
    x = jnp.asarray(rng.standard_normal((1, 12, 7, 3)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((3, 3, 3, 4)).astype(np.float32))
    plain = quantize_weights(w, cfg)
    lhs = _Counter(l2r_ops.stack_planes_lhs)
    rhs = _Counter(l2r_ops.stack_planes_rhs)
    monkeypatch.setattr(l2r_ops, "stack_planes_lhs", lhs)
    monkeypatch.setattr(l2r_ops, "stack_planes_rhs", rhs)
    jax.block_until_ready(l2r_conv2d(x, None, cfg=cfg, w_q=plain,
                                     backend="jnp"))
    assert lhs.calls == 1 and rhs.calls == 1


def test_streaming_head_zero_weight_extraction(monkeypatch):
    """streaming_argmax with the window-padded weight-stack cache does no
    weight plane extraction at all (the decode-step hot path)."""
    from repro.core import progressive as prog
    from repro.core.quant import quantize

    cfg = QuantConfig()
    rng = np.random.default_rng(32)
    x = jnp.asarray(rng.standard_normal((5, 24)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((24, 13)).astype(np.float32))
    w_q = quantize_weights(w, cfg, prestack=True, window_pad=True)
    xq, xs = quantize(x, cfg, axis=0)
    ref = prog.streaming_argmax(xq, w_q.q, xs, w_q.scale)
    lhs = _Counter(prog.stack_planes_lhs)
    rhs = _Counter(prog.stack_planes_rhs)
    monkeypatch.setattr(prog, "stack_planes_lhs", lhs)
    monkeypatch.setattr(prog, "stack_planes_rhs", rhs)
    out = prog.streaming_argmax(xq, w_q.planes, xs, w_q.scale)
    assert rhs.calls == 0, f"{rhs.calls} weight extractions (want 0: cached)"
    for r, o in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o))


# --------------------------------------------------------- model threading
def test_vgg16_prestack_cache_bit_identical():
    """vgg16_apply and the progressive classify path are bit-identical
    with and without the per-layer plane-stack cache."""
    from repro.models.cnn import (vgg16_apply, vgg16_build,
                                  vgg16_classify_progressive,
                                  vgg16_quantize_weights)
    from repro.models.common import materialize

    cfg = QuantConfig()
    params = materialize(vgg16_build(n_classes=12), jax.random.PRNGKey(0))
    rng = np.random.default_rng(40)
    imgs = jnp.asarray(rng.standard_normal((2, 32, 32, 3)).astype(np.float32))
    plain = vgg16_quantize_weights(params, cfg, prestack=False)
    pre = vgg16_quantize_weights(params, cfg, prestack=True)
    np.testing.assert_array_equal(
        np.asarray(vgg16_apply(params, imgs, l2r=cfg, weights_q=plain)),
        np.asarray(vgg16_apply(params, imgs, l2r=cfg, weights_q=pre)))
    for a, b in zip(vgg16_classify_progressive(params, imgs, cfg,
                                               weights_q=plain),
                    vgg16_classify_progressive(params, imgs, cfg,
                                               weights_q=pre)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_prestack_cache_bit_identical():
    """prepare_params(prestack=True): prefill + progressive decode emit
    identical tokens/exit levels/logits to the extract-per-call cache —
    including through the stacked-layer scan (whose slicing strips the
    plane stacks' layer axis)."""
    from repro.configs import get_smoke
    from repro.models.common import materialize
    from repro.models.transformer import lm_build
    from repro.serve.engine import (make_decode_step, make_prefill_step,
                                    prepare_params)

    cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
    params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
    plain = prepare_params(cfg, params, prestack=False)
    pre = prepare_params(cfg, params, prestack=True)
    rng = np.random.default_rng(41)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 6)), jnp.int32)
    prefill = jax.jit(make_prefill_step(cfg, 32, jnp.float32,
                                        progressive=True))
    decode = jax.jit(make_decode_step(cfg, progressive=True))
    s1, lg1, t1, lv1 = prefill(plain, {"tokens": prompt})
    s2, lg2, t2, lv2 = prefill(pre, {"tokens": prompt})
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(lv1), np.asarray(lv2))
    np.testing.assert_array_equal(np.asarray(lg1), np.asarray(lg2))
    _, t1b, lg1b, lv1b = decode(plain, s1, t1)
    _, t2b, lg2b, lv2b = decode(pre, s2, t2)
    np.testing.assert_array_equal(np.asarray(t1b), np.asarray(t2b))
    np.testing.assert_array_equal(np.asarray(lv1b), np.asarray(lv2b))
    np.testing.assert_array_equal(np.asarray(lg1b), np.asarray(lg2b))
