"""l2r-lint: exactness audit, overflow certifier, compiled-artifact audit.

Three families:
  * positive — every registered claimed-exact entry point passes every
    pass (the CI gate `tools/l2r_lint.py` in miniature);
  * negative — each pass catches a seeded violation (float op on an
    exact path, overflowing digit config, un-donated decode state);
  * adversarial tightness — operands that ACHIEVE the certifier's
    worst-case bound: int32-exact at the bound, wrapped one step beyond,
    so the bound is exact rather than merely safe.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import compiled as comp_audit
from repro.analysis import overflow
from repro.analysis.exactness import (ExactnessContract, audit_exactness,
                                      audit_hlo_text)
from repro.analysis.registry import iter_entries
from repro.core.l2r_gemm import l2r_matmul_int, l2r_matmul_int_stacked
from repro.core.quant import QuantConfig, quantize_weights

pytestmark = pytest.mark.analysis


# ------------------------------------------------------- exactness: positive
@pytest.mark.parametrize("entry", iter_entries(), ids=lambda e: e.name)
def test_registered_entries_pass_exactness(entry):
    if entry.contract is None:
        pytest.skip("sharding-only entry (no exactness contract)")
    if entry.skip:
        pytest.skip(entry.skip)
    fn, args = entry.build()
    rep = audit_exactness(fn, args, entry.contract, entry=entry.name)
    assert rep.ok, [v.to_json() for v in rep.violations]
    assert rep.tainted_eqns > 0  # the walk was actually on the taint path
    assert rep.int_dots + rep.f32_fastpath_dots > 0


@pytest.mark.parametrize("entry", iter_entries(), ids=lambda e: e.name)
def test_registered_entries_certify_overflow(entry):
    c = entry.contract
    if c is None:
        pytest.skip("sharding-only entry (no exactness contract)")
    cert = overflow.certify(c.n_bits, c.log2_radix, c.k, levels=c.levels)
    assert cert.sound, cert.describe()


# ------------------------------------------------------- exactness: negative
def _i8(shape, seed=0):
    return np.asarray(
        np.random.default_rng(seed).integers(-128, 128, shape), np.int8)


def test_exactness_flags_unguarded_f32_dot():
    """The seeded bug: an f32 contraction of digit-derived values
    without precision=HIGHEST (the bit-exactness break XLA's default
    precision introduces on TPU)."""
    def bad(aq, bq):
        out = jax.lax.dot_general(
            aq.astype(jnp.float32), bq.astype(jnp.float32),
            (((1,), (0,)), ((), ())))
        return out.astype(jnp.int32)

    rep = audit_exactness(bad, (_i8((4, 8)), _i8((8, 5), 1)),
                          ExactnessContract(k=8))
    assert not rep.ok
    assert any("HIGHEST" in v.reason for v in rep.violations)


def test_exactness_flags_float_op_on_exact_path():
    """A float op touching digit-derived values before the accumulator
    (the PR 5 float-reassociation bug class)."""
    def bad(aq, bq):
        a = aq.astype(jnp.float32) * 1.0001  # inexact scale mid-path
        out = jax.lax.dot_general(
            a, bq.astype(jnp.float32), (((1,), (0,)), ((), ())),
            precision=jax.lax.Precision.HIGHEST)
        return out.astype(jnp.int32)

    rep = audit_exactness(bad, (_i8((4, 8)), _i8((8, 5), 1)),
                          ExactnessContract(k=8))
    assert not rep.ok
    assert any("fast-path" in v.reason for v in rep.violations)


def test_exactness_flags_f32_without_contract():
    """allow_f32=False contracts reject ANY float excursion."""
    def walk(aq, bq):
        out = jax.lax.dot_general(
            aq.astype(jnp.float32), bq.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            precision=jax.lax.Precision.HIGHEST)
        return out.astype(jnp.int32)

    rep = audit_exactness(walk, (_i8((4, 8)), _i8((8, 5), 1)),
                          ExactnessContract(k=8, allow_f32=False))
    assert not rep.ok


def test_exactness_flags_narrow_int_accumulation():
    """Integer dot accumulating in the operand dtype (int8 wraps)."""
    def bad(aq, bq):
        return jax.lax.dot_general(aq, bq, (((1,), (0,)), ((), ())))

    rep = audit_exactness(bad, (_i8((4, 8)), _i8((8, 5), 1)),
                          ExactnessContract(k=8))
    assert not rep.ok
    assert any("int32" in v.reason for v in rep.violations)


def test_exactness_recurses_into_scan():
    """A violation hidden inside a lax.scan body is still found."""
    def bad(aq, bq):
        def body(acc, i):
            t = jax.lax.dot_general(
                aq.astype(jnp.float32), bq.astype(jnp.float32),
                (((1,), (0,)), ((), ())))  # default precision: seeded bug
            return acc + t.astype(jnp.int32), i
        acc0 = jnp.zeros((4, 5), jnp.int32)
        out, _ = jax.lax.scan(body, acc0, jnp.arange(3))
        return out

    rep = audit_exactness(bad, (_i8((4, 8)), _i8((8, 5), 1)),
                          ExactnessContract(k=8))
    assert not rep.ok


def test_audit_hlo_text_flags_bf16_and_unguarded_f32():
    hlo = """
HloModule m

ENTRY %main (a: bf16[4,8], b: bf16[8,5]) -> bf16[4,5] {
  %a = bf16[4,8]{1,0} parameter(0)
  %b = bf16[8,5]{1,0} parameter(1)
  ROOT %dot.0 = bf16[4,5]{1,0} dot(bf16[4,8]{1,0} %a, bf16[8,5]{1,0} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    v = audit_hlo_text(hlo, ExactnessContract(k=8))
    assert v and "bf16" in v[0].reason
    f32 = hlo.replace("bf16", "f32")
    assert audit_hlo_text(f32, ExactnessContract(k=8)) == []
    # f32 contraction present but the guard cannot hold for this k
    big_k = ExactnessContract(k=10**9)
    assert audit_hlo_text(f32, big_k)
    s32 = hlo.replace("bf16", "s32")
    assert audit_hlo_text(s32, ExactnessContract(k=10**9)) == []


# --------------------------------------------------------- overflow certifier
def test_certifier_known_extremes():
    # n=8, r=4: worst single product is qmin*qmin = 16384, reached at the
    # first MSDF prefix (top digits (-2)*(-2) << 12) and held to the end
    ext = overflow.per_element_extremes(8, 2)
    assert ext.exact
    cert = overflow.certify(8, 2, 1)
    assert cert.per_element == 16384 and cert.exact
    x, y, t = cert.witness
    assert x * y == 16384
    # truncation can only shrink the worst case
    prev = None
    for lv in range(1, 8):
        b = overflow.certify(8, 2, 7, levels=lv).bound
        if prev is not None:
            assert b >= prev
        prev = b


def test_certifier_interval_fallback_is_sound():
    cert = overflow.certify(16, 4, 64)
    assert not cert.exact and not cert.sound
    # the interval bound must dominate the true per-element extreme of a
    # narrower config it contains (8-bit operands are 16-bit operands)
    assert cert.per_element >= overflow.per_element_extremes(8, 4).magnitude()


def test_certificate_bound_is_achievable():
    """Adversarial tightness: operands achieving the worst case run
    int32-exact at the certified bound and WRAP one contraction element
    beyond it — the bound is exact, not merely safe."""
    cert1 = overflow.certify(8, 2, 1)
    x, y, _ = cert1.witness
    k_max = overflow.INT32_LIMIT // cert1.per_element  # 131071
    assert overflow.certify(8, 2, k_max).sound
    assert not overflow.certify(8, 2, k_max + 1).sound

    def run(k):
        aq = np.full((1, k), x, np.int8)
        bq = np.full((k, 1), y, np.int8)
        got = int(np.asarray(l2r_matmul_int_stacked(aq, bq, 8, 2))[0, 0])
        exact = int(x) * int(y) * k
        return got, exact

    got, exact = run(k_max)
    assert exact == cert1.per_element * k_max  # the bound is achieved...
    assert got == exact                        # ...and int32 holds there
    got, exact = run(k_max + 1)
    assert exact > overflow.INT32_LIMIT
    assert got != exact                        # one element beyond: wraps
    assert got == exact - 2**32                # deterministic int32 wrap


def test_dispatcher_guard_warns_by_default():
    from repro.kernels.l2r_gemm.ops import l2r_gemm
    aq = np.asarray(
        np.random.default_rng(0).integers(-100, 100, (2, 48)), np.int16)
    bq = np.asarray(
        np.random.default_rng(1).integers(-100, 100, (48, 3)), np.int16)
    overflow._WARNED.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = l2r_gemm(aq, bq, n_bits=16, log2_radix=4)
    assert out.shape == (2, 3)  # mod-2^32 parity workloads keep running
    msgs = [w for w in rec
            if issubclass(w.category, overflow.AccumulatorOverflowWarning)]
    assert msgs and "OVERFLOWS int32" in str(msgs[0].message)


def test_dispatcher_guard_strict_rejects(monkeypatch):
    from repro.kernels.l2r_gemm.ops import l2r_gemm
    monkeypatch.setenv("L2R_CERTIFY", "strict")
    aq = np.zeros((2, 48), np.int16)
    bq = np.zeros((48, 3), np.int16)
    with pytest.raises(OverflowError, match=r"worst-case \|accumulator\|"):
        l2r_gemm(aq, bq, n_bits=16, log2_radix=4)
    # sound configs pass untouched in strict mode
    out = l2r_gemm(_i8((2, 8)), _i8((8, 3), 1), n_bits=8, log2_radix=2)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(l2r_matmul_int(_i8((2, 8)), _i8((8, 3), 1), 8, 2)))


def test_quantize_weights_guard_strict(monkeypatch):
    monkeypatch.setenv("L2R_CERTIFY", "strict")
    cfg = QuantConfig(n_bits=8, log2_radix=2)
    k_max = overflow.INT32_LIMIT // overflow.certify(8, 2, 1).per_element
    w = np.ones((k_max + 1, 2), np.float32)
    with pytest.raises(OverflowError, match="quantize_weights"):
        quantize_weights(w, cfg, prestack=True)
    # without the prestacked contraction cache there is no declared K
    quantize_weights(np.ones((8, 2), np.float32), cfg, prestack=True)


def test_registry_sweep_all_sound():
    rows = overflow.audit_registry()
    assert len(rows) == 20  # 10 archs x (head, attention)
    assert all(r["sound"] for r in rows), \
        [r for r in rows if not r["sound"]]


# ----------------------------------------------------- compiled-artifact pass
def _toy_step():
    def step(params, state):
        return state * params + 1.0
    return step


def test_donation_report_and_probe():
    step = _toy_step()
    p = jnp.float32(2.0)
    s = jnp.arange(4, dtype=jnp.float32)
    donated = jax.jit(step, donate_argnums=(1,)).lower(p, s).compile()
    rep = comp_audit.donation_report(donated)
    assert rep["n_aliases"] >= 1
    plain = jax.jit(step).lower(p, s).compile()
    assert comp_audit.donation_report(plain)["n_aliases"] == 0
    # dynamic probe: the donated buffer is actually dead after the call
    live = comp_audit.probe_donation(
        jax.jit(step, donate_argnums=(1,)), (p, jnp.arange(4.0)), (1,))
    assert live[1] is True
    live = comp_audit.probe_donation(
        jax.jit(step), (p, jnp.arange(4.0)), (1,))
    assert live[1] is False


@pytest.fixture(scope="module")
def prog_model():
    from repro.configs import get_smoke
    from repro.models.common import materialize
    from repro.models.transformer import lm_build
    from repro.serve.engine import prepare_params
    cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
    params = prepare_params(cfg, materialize(lm_build(cfg),
                                             jax.random.PRNGKey(0)))
    return cfg, params


def _requests(cfg, n=2, max_new=3, seed=0):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, (int(L),)).astype(
                        np.int32),
                    max_new_tokens=max_new)
            for i, L in enumerate(rng.integers(3, 16, n))]


def test_gateway_audit_green(prog_model):
    from repro.serve import ServingGateway
    cfg, params = prog_model
    gw = ServingGateway(cfg, params, n_slots=2, max_len=32)
    gw.warmup()
    gw.run(_requests(cfg))
    rep = comp_audit.audit_gateway(gw)
    assert rep["ok"], rep["violations"]
    assert rep["aot_prefill_buckets"] == list(gw.buckets)
    assert rep["decode_donation"]["n_aliases"] >= 1


def test_batcher_audit_catches_undonated_state(prog_model):
    """The pre-PR 6 copy-per-step regression, deliberately seeded via
    donate_state=False: the auditor must flag it — and must pass the
    donated default."""
    from repro.serve import ContinuousBatcher
    cfg, params = prog_model

    good = ContinuousBatcher(cfg, params, n_slots=2, max_len=32)
    for r in _requests(cfg):
        good.submit(r)
    good.step()
    rep = comp_audit.audit_batcher(good)
    assert rep["ok"], rep["violations"]
    assert rep["donation"]["checked"] and rep["donation"]["n_dead"] > 0

    bad = ContinuousBatcher(cfg, params, n_slots=2, max_len=32,
                            donate_state=False)
    for r in _requests(cfg, seed=1):
        bad.submit(r)
    bad.step()
    rep = comp_audit.audit_batcher(bad)
    assert not rep["ok"]
    assert any("NOT donated" in v["reason"] for v in rep["violations"])
