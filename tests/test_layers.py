"""Mixer layers: SSD vs naive recurrence, RG-LRU, MoE routing invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import materialize
from repro.models.config import ModelConfig
from repro.models.moe import moe_apply, moe_build, moe_capacity
from repro.models.rglru import (init_rglru_state, rglru_apply, rglru_build,
                                rglru_decode)
from repro.models.ssm import ssd_chunked, ssm_apply, ssm_build, ssm_decode


def test_ssd_chunked_vs_naive_recurrence():
    rng = np.random.default_rng(3)
    B, S, H, P, N = 2, 64, 3, 8, 16
    x = rng.standard_normal((B, S, H, P)).astype(np.float32)
    dt = np.abs(rng.standard_normal((B, S, H))).astype(np.float32) * 0.5
    a = -np.abs(rng.standard_normal((B, S, H))).astype(np.float32) * 0.3
    bm = rng.standard_normal((B, S, N)).astype(np.float32) * 0.3
    cm = rng.standard_normal((B, S, N)).astype(np.float32) * 0.3
    y, fin = ssd_chunked(*(jnp.asarray(t) for t in (x, dt, a, bm, cm)), chunk=16)
    state = np.zeros((B, H, N, P))
    ys = []
    for t in range(S):
        state = state * np.exp(a[:, t])[..., None, None] + np.einsum(
            "bn,bhp->bhnp", bm[:, t], x[:, t] * dt[:, t][..., None])
        ys.append(np.einsum("bn,bhnp->bhp", cm[:, t], state))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), atol=1e-3)
    np.testing.assert_allclose(np.asarray(fin), state, atol=1e-3)


@pytest.fixture
def ssm_cfg():
    return ModelConfig(name="m", family="ssm", n_layers=1, d_model=32,
                       n_heads=4, n_kv=4, d_ff=0, vocab=64,
                       layer_pattern=("ssd",), ffn_pattern=("none",),
                       ssm_state=16, ssm_head_dim=8, ssm_chunk=16,
                       compute_dtype="float32")


def test_ssm_decode_matches_full(ssm_cfg):
    params = materialize(ssm_build(ssm_cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    u = jnp.asarray(rng.standard_normal((2, 24, 32)).astype(np.float32))
    yfull, _ = ssm_apply(ssm_cfg, params, u)
    _, st = ssm_apply(ssm_cfg, params, u[:, :23])
    ydec, _ = ssm_decode(ssm_cfg, params, u[:, 23:24], st)
    np.testing.assert_allclose(np.asarray(yfull[:, 23:24]), np.asarray(ydec),
                               atol=2e-3)


def test_rglru_decode_matches_full():
    cfg = ModelConfig(name="r", family="hybrid", n_layers=1, d_model=32,
                      n_heads=2, n_kv=1, d_ff=64, vocab=64,
                      layer_pattern=("rec",), lru_width=48,
                      compute_dtype="float32")
    params = materialize(rglru_build(cfg), jax.random.PRNGKey(1))
    rng = np.random.default_rng(6)
    u = jnp.asarray(rng.standard_normal((2, 24, 32)).astype(np.float32))
    yfull, _ = rglru_apply(cfg, params, u)
    ypre, st = rglru_apply(cfg, params, u[:, :23])
    np.testing.assert_allclose(np.asarray(yfull[:, :23]), np.asarray(ypre),
                               atol=1e-4)
    ydec, _ = rglru_decode(cfg, params, u[:, 23:24], st)
    np.testing.assert_allclose(np.asarray(yfull[:, 23:24]), np.asarray(ydec),
                               atol=2e-3)


def test_rglru_state_bounded():
    """|h| stays bounded (|a|<1 and sqrt(1-a^2) input normalization)."""
    cfg = ModelConfig(name="r", family="hybrid", n_layers=1, d_model=16,
                      n_heads=2, n_kv=1, d_ff=32, vocab=64,
                      layer_pattern=("rec",), lru_width=16,
                      compute_dtype="float32")
    params = materialize(rglru_build(cfg), jax.random.PRNGKey(2))
    rng = np.random.default_rng(7)
    st = init_rglru_state(cfg, 1)
    for _ in range(50):
        u = jnp.asarray(rng.standard_normal((1, 1, 16)).astype(np.float32)) * 3
        _, st = rglru_decode(cfg, params, u, st)
    assert np.abs(np.asarray(st["h"])).max() < 50


@pytest.fixture
def moe_cfg():
    return ModelConfig(name="moe", family="moe", n_layers=1, d_model=16,
                       n_heads=2, n_kv=1, d_ff=32, vocab=64,
                       ffn_pattern=("moe",), n_experts=8,
                       experts_per_token=2, moe_d_ff=24,
                       n_shared_experts=1, capacity_factor=2.0,
                       compute_dtype="float32")


def test_moe_output_finite_and_aux(moe_cfg):
    params = materialize(moe_build(moe_cfg), jax.random.PRNGKey(3))
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.standard_normal((2, 10, 16)).astype(np.float32))
    y, aux = moe_apply(moe_cfg, params, x)
    assert y.shape == (2, 10, 16)
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0


def test_moe_capacity_drops_scale(moe_cfg):
    assert moe_capacity(moe_cfg, 64) == 32  # 2*64/8 * 2.0
    import dataclasses
    tight = dataclasses.replace(moe_cfg, capacity_factor=0.5)
    assert moe_capacity(tight, 64) == 8


def test_moe_permutation_equivariance(moe_cfg):
    """Shuffling tokens shuffles outputs identically when capacity is
    dropless (routing is per-token)."""
    import dataclasses
    cfg = dataclasses.replace(moe_cfg, capacity_factor=8.0)
    params = materialize(moe_build(cfg), jax.random.PRNGKey(4))
    rng = np.random.default_rng(9)
    x = rng.standard_normal((1, 12, 16)).astype(np.float32)
    y, _ = moe_apply(cfg, params, jnp.asarray(x))
    perm = rng.permutation(12)
    y2, _ = moe_apply(cfg, params, jnp.asarray(x[:, perm]))
    np.testing.assert_allclose(np.asarray(y)[:, perm], np.asarray(y2),
                               atol=1e-4)
