"""Whisper (enc-dec) serving path: prefill + decode == train forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.common import materialize
from repro.models.encdec import (encdec_build, encdec_forward,
                                 init_encdec_state)


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke("whisper-base")
    params = materialize(encdec_build(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    frames = jnp.asarray(rng.standard_normal((2, cfg.encoder_seq, cfg.d_model)),
                         jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    return cfg, params, frames, toks


def test_decode_matches_train(model):
    cfg, params, frames, toks = model
    h, _, _ = encdec_forward(cfg, params, tokens=toks, frames=frames,
                             mode="train")
    st = init_encdec_state(cfg, 2, 16, jnp.float32)
    _, st, _ = encdec_forward(cfg, params, tokens=toks[:, :11], frames=frames,
                              mode="prefill", state=st)
    h_dec, st, _ = encdec_forward(cfg, params, tokens=toks[:, 11:12],
                                  mode="decode", state=st)
    np.testing.assert_allclose(np.asarray(h[:, 11:12]), np.asarray(h_dec),
                               atol=1e-4)


def test_multi_step_decode_consistent(model):
    """Two successive decode steps == the train forward at those positions."""
    cfg, params, frames, toks = model
    h, _, _ = encdec_forward(cfg, params, tokens=toks, frames=frames,
                             mode="train")
    st = init_encdec_state(cfg, 2, 16, jnp.float32)
    _, st, _ = encdec_forward(cfg, params, tokens=toks[:, :10], frames=frames,
                              mode="prefill", state=st)
    for pos in (10, 11):
        h_dec, st, _ = encdec_forward(cfg, params, tokens=toks[:, pos:pos + 1],
                                      mode="decode", state=st)
        np.testing.assert_allclose(np.asarray(h[:, pos:pos + 1]),
                                   np.asarray(h_dec), atol=1e-4)


def test_cross_attention_cache_reused(model):
    """Decode must not need encoder frames (cross-KV cached at prefill)."""
    cfg, params, frames, toks = model
    st = init_encdec_state(cfg, 2, 16, jnp.float32)
    _, st, _ = encdec_forward(cfg, params, tokens=toks[:, :11], frames=frames,
                              mode="prefill", state=st)
    # no frames / enc_out passed:
    h_dec, _, _ = encdec_forward(cfg, params, tokens=toks[:, 11:12],
                                 mode="decode", state=st)
    assert np.isfinite(np.asarray(h_dec)).all()
