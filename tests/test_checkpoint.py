"""Checkpoint manager: roundtrip, atomicity, latest pointer, GC, async."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, load_pytree, save_pytree


@pytest.fixture
def tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32)},
            "d": [jnp.zeros((1,)), jnp.full((2, 2), 7.0)]}


def test_pytree_roundtrip(tmp_path, tree):
    p = str(tmp_path / "t.npz")
    save_pytree(tree, p)
    out = load_pytree(tree, p)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manager_save_restore_latest(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    assert mgr.latest_step() is None
    mgr.save(10, {"params": tree}, extra={"note": "x"})
    mgr.save(20, {"params": tree})
    assert mgr.latest_step() == 20
    step, out = mgr.restore_latest({"params": tree})
    assert step == 20
    np.testing.assert_array_equal(np.asarray(out["params"]["a"]),
                                  np.asarray(tree["a"]))
    assert mgr.manifest(10)["note"] == "x"


def test_manager_gc_keeps_k(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"params": tree})
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]


def test_no_tmp_dirs_after_save(tmp_path, tree):
    """Atomicity invariant: a completed save leaves no .tmp residue (a
    crash mid-write leaves only .tmp, never a bad final dir)."""
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(5, {"params": tree})
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_async_save_then_wait(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(7, {"params": tree})
    mgr.wait()
    assert mgr.latest_step() == 7
    _, out = mgr.restore_latest({"params": tree})
    np.testing.assert_array_equal(np.asarray(out["params"]["a"]),
                                  np.asarray(tree["a"]))


def test_restore_shape_mismatch_raises(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, {"params": tree})
    bad = dict(tree, a=jnp.zeros((5, 5)))
    with pytest.raises(AssertionError):
        mgr.restore(1, {"params": bad})
