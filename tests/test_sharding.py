"""Sharding rules + a real multi-device pjit train step (subprocess with
8 fake host devices — the main process must keep seeing 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import lm_build
from repro.sharding.axes import safe_spec


def test_param_specs_divisible_everywhere():
    """Every sharded dim of every assigned arch divides the 16-way axis
    (this is what safe_spec guarantees structurally)."""
    import repro.sharding.axes as ax
    from repro.configs import ARCHS
    from repro.models.encdec import encdec_build

    class FakeMesh:  # avoid touching jax device state for the mesh
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    for arch in ARCHS:
        cfg = get_config(arch)
        desc = encdec_build(cfg) if cfg.family == "encdec" else lm_build(cfg)
        specs = ax.param_specs(desc, FakeMesh())
        from repro.models.common import Param
        flat_d = jax.tree.leaves(desc, is_leaf=lambda x: isinstance(x, Param))
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        for d, s in zip(flat_d, flat_s):
            for dim, axis in zip(d.shape, tuple(s)):
                if axis is not None:
                    size = (np.prod([16 for _ in axis])
                            if isinstance(axis, tuple) else 16)
                    assert dim % size == 0, (arch, d.shape, s)


def test_safe_spec_drops_and_dedupes():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    assert safe_spec((50280, 768), P("model", None), FakeMesh()) == P(None, None)
    assert safe_spec((64, 2048, 1408), P("model", None, "model"), FakeMesh()) \
        == P("model", None, None)
    assert safe_spec((512, 512), P("model", "data"), FakeMesh()) == P("model", "data")


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np, json, dataclasses
    from repro.configs import get_smoke
    from repro.models.common import materialize
    from repro.models.transformer import lm_build
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.step import TrainConfig, make_train_step, train_step_shardings
    from repro.launch.mesh import make_local_mesh

    assert len(jax.devices()) == 8
    mesh = make_local_mesh(data=4, model=2)
    cfg = get_smoke("smollm-135m")
    cfg = dataclasses.replace(cfg, d_model=64, d_ff=128, n_layers=2)
    desc = lm_build(cfg)
    params = materialize(desc, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3), TrainConfig(
        remat=True, seq_shard=True, xent_chunk=16), mesh)
    batch_shapes = {
        "tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
        "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32),
    }
    ins, outs = train_step_shardings(cfg, mesh, desc, batch_shapes)
    rng = np.random.default_rng(0)
    batch = {k: jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32)
             for k in ("tokens", "labels")}
    fn = jax.jit(step, in_shardings=ins, out_shardings=outs)
    p2, o2, m = fn(params, opt, batch)
    # reference: single-device result must match the sharded result
    m_ref = jax.jit(step)(params, opt, batch)[2]
    print(json.dumps({
        "loss": float(m["loss"]),
        "loss_ref": float(m_ref["loss"]),
        "grad_norm": float(m["grad_norm"]),
    }))
""")


@pytest.mark.slow
def test_multidevice_train_step_matches_single_device(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SUBPROC], capture_output=True,
                         text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
                         env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert np.isfinite(res["loss"])
    assert res["loss"] == pytest.approx(res["loss_ref"], rel=2e-2), res
