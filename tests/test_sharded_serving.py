"""Sharded L2R serving: the shard_mapped consensus streaming walk.

The load-bearing invariant: partitioning the plane-operand schedules over
a mesh — RHS weight stacks vocab-sharded on ``model``, LHS activation
stacks batch-sharded on ``data`` — changes WHERE each accumulator tile
lives but not a single bit of it (the contraction K is never sharded and
the integer/guarded-f32 arithmetic is order-exact), and the per-level
decision reductions (max/min/psum of identical floats across shards) are
exact, so streaming prefixes, committed decisions, and per-row exit
levels are bit-identical to the single-device oracle — including
``early_exit=True``, where the psum consensus stops every device at the
fleet-wide slowest row, exactly where the single-device while loop stops.

Multi-device tests run in a subprocess with 8 virtual host-platform
devices (the flag must be set before jax initializes; the main process
keeps its own device count).  They carry the ``sharded`` marker — the CI
virtual-8-device job runs ``pytest -m sharded``.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.progressive import sharded_walk_axes
from repro.core.quant import QuantConfig
from repro.launch.mesh import virtual_device_env
from repro.sharding import ctx

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subproc(script: str, timeout: int = 900):
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=_REPO, env=virtual_device_env(8), timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    return out.stdout


# ---------------------------------------------------------- routing logic
class _FakeMesh:
    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_sharded_walk_axes_routing():
    """Mesh routing: divisibility drops exactly the non-dividing side,
    trivial meshes (and no mesh) fall back to the single-device path."""
    mesh = _FakeMesh(data=2, model=4)
    assert sharded_walk_axes((8,), 16, mesh) == (mesh, ("data",), "model")
    # rows not divisible by data -> batch replicates, vocab still shards
    assert sharded_walk_axes((7,), 16, mesh) == (mesh, (), "model")
    # vocab not divisible by model -> vocab replicates, batch still shards
    assert sharded_walk_axes((8,), 10, mesh) == (mesh, ("data",), None)
    # neither divides -> single-device path
    assert sharded_walk_axes((7,), 10, mesh) is None
    # trivial mesh -> single-device path
    assert sharded_walk_axes((8,), 16, _FakeMesh(data=1, model=1)) is None
    # no mesh installed anywhere -> None
    assert sharded_walk_axes((8,), 16, None) is None
    # only 2-D tiles stream sharded
    assert sharded_walk_axes((2, 8), 16, mesh) is None


# ------------------------------------------------------------- satellites
def test_hint_overlong_spec_raises():
    """A hint spec naming more dims than the operand has used to be
    silently zip-truncated (trailing entries dropped, no error); now the
    rank mismatch raises with the shapes — in hint AND hint_uneven."""
    from repro.launch.mesh import make_local_mesh

    ctx.set_mesh(make_local_mesh(1, 1))
    x = jnp.zeros((4, 8))
    ctx.hint(x, "data")  # shorter spec: fine (trailing dims replicate)
    ctx.hint(x, "data", None)
    with pytest.raises(ValueError, match=r"rank 2"):
        ctx.hint(x, "data", None, "model")
    with pytest.raises(ValueError, match=r"\(4, 8\)"):
        ctx.hint_uneven(x, None, None, "model")
    ctx.set_mesh(None)
    # without a mesh both are identities (no constraint to mis-apply)
    assert ctx.hint(x, "data", None, "model") is x


def test_mesh_context_fixture_restores_none():
    """The autouse conftest fixture must have cleared the mesh installed
    by any earlier test before this one runs."""
    assert ctx.get_mesh() is None


def test_resolve_backend_env_typo_rejected_naming_source(monkeypatch):
    """A typo'd $REPRO_L2R_BACKEND fails at resolve time with a message
    naming the env var and listing the valid backends."""
    from repro.kernels.l2r_gemm import BACKEND_ENV_VAR, resolve_backend

    monkeypatch.setenv(BACKEND_ENV_VAR, "jnpp")
    with pytest.raises(ValueError, match=BACKEND_ENV_VAR) as ei:
        resolve_backend()
    msg = str(ei.value)
    for b in ("jnp", "pallas-interpret", "pallas-tpu", "auto"):
        assert b in msg, msg
    # the explicit argument names its own source
    monkeypatch.delenv(BACKEND_ENV_VAR)
    with pytest.raises(ValueError, match="backend argument"):
        resolve_backend("bogus")


def test_batcher_stats_schema_stable_before_first_token():
    """Progressive-mode stats() emits n_levels and the zero-filled exit
    histograms from construction on — the schema must not change shape
    once tokens start landing (monitoring consumers scrape it)."""
    from repro.configs import get_smoke
    from repro.models.common import materialize
    from repro.models.transformer import lm_build
    from repro.serve.batching import ContinuousBatcher, Request

    cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
    params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
    eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=16,
                            progressive=True)
    before = eng.stats()
    n_levels = 2 * cfg.l2r.planes - 1
    assert before["n_levels"] == n_levels
    assert before["tokens"] == 0 and before["prefills"] == 0
    assert before["exit_level_hist"] == [0] * n_levels
    assert before["prefill_exit_level_hist"] == [0] * n_levels
    assert before["mean_exit_level"] == 0.0
    assert before["mean_prefill_exit_level"] == 0.0
    eng.submit(Request(uid=0, prompt=np.asarray([3, 5, 7], np.int32),
                       max_new_tokens=2))
    eng.run(max_steps=8)
    after = eng.stats()
    assert set(after) == set(before), "stats() schema changed shape mid-run"
    assert after["tokens"] > 0 and after["prefills"] == 1


# ------------------------------------------- multi-device: streaming walk
SHARDED_STREAM = textwrap.dedent("""
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core.progressive import streaming_argmax
    from repro.core.quant import (PlaneOperands, QuantConfig, quantize,
                                  quantize_weights)
    from repro.launch.mesh import make_local_mesh
    from repro.sharding import ctx

    assert len(jax.devices()) == 8, jax.devices()
    eq = np.testing.assert_array_equal

    def oracle_and_sharded(call_kwargs, mesh):
        ctx.set_mesh(None)
        ref = jax.tree.map(np.asarray, streaming_argmax(**call_kwargs))
        # explicit mesh arg AND the installed-context route
        exp = jax.tree.map(np.asarray,
                           streaming_argmax(**call_kwargs, mesh=mesh))
        ctx.set_mesh(mesh)
        got = jax.tree.map(np.asarray, streaming_argmax(**call_kwargs))
        ctx.set_mesh(None)
        return ref, exp, got

    meshes = {"1x4": make_local_mesh(1, 4), "2x2": make_local_mesh(2, 2),
              "4x2": make_local_mesh(4, 2)}
    rng = np.random.default_rng(0)
    m, k, n = 8, 48, 16
    for n_bits, log2_radix in [(8, 2), (4, 2)]:
        cfg = QuantConfig(n_bits=n_bits, log2_radix=log2_radix)
        x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        w = jnp.asarray((rng.standard_normal((k, n)) * 0.3)
                        .astype(np.float32))
        xq, xs = quantize(x, cfg, axis=0)
        w_q = quantize_weights(w, cfg)
        bias = jnp.asarray(rng.standard_normal((n,)).astype(np.float32))
        for name, mesh in meshes.items():
            for early_exit in (False, True):
                kw = dict(xq=xq, wq=w_q.q, xs=xs, ws=w_q.scale,
                          n_bits=n_bits, log2_radix=log2_radix,
                          bias=bias, early_exit=early_exit)
                ref, exp, got = oracle_and_sharded(kw, mesh)
                for s in (exp, got):
                    for a, b, what in zip(ref, s,
                                          ("logits", "tok", "exit_level")):
                        eq(np.asarray(b), np.asarray(a),
                           err_msg=f"{name} bits={n_bits} ee={early_exit} "
                                   f"{what}")
        print(f"stream sweep ok bits={n_bits} r={1 << log2_radix}")

    # prefix bit-exactness at EVERY truncation depth, with exact
    # power-of-two scales so logits == float(int prefix) exactly: equal
    # logits at depth t <=> equal integer accumulator prefix at depth t
    cfg = QuantConfig()
    n_levels = 2 * cfg.planes - 1
    xq = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
    xs2 = jnp.full((m, 1), 2.0 ** -7, jnp.float32)
    ws2 = jnp.full((1, n), 2.0 ** -6, jnp.float32)
    for t in range(1, n_levels + 1):
        kw = dict(xq=xq, wq=wq, xs=xs2, ws=ws2, levels=t)
        ref, exp, got = oracle_and_sharded(kw, meshes["2x2"])
        for s in (exp, got):
            for a, b, what in zip(ref, s, ("logits", "tok", "exit_level")):
                eq(np.asarray(b), np.asarray(a),
                   err_msg=f"prefix depth {t} {what}")
    print("prefix sweep ok (all depths, pow2 scales)")

    # the window-padded weight plane-stack cache feeds the sharded walk
    # directly (vocab-sharded stack, zero per-step operand prep)
    w_pre = quantize_weights(w, cfg, prestack=True, window_pad=True,
                             shard=(None, "model"), mesh=meshes["1x4"])
    xq, xs = quantize(x, cfg, axis=0)
    for early_exit in (False, True):
        ctx.set_mesh(None)
        ref = jax.tree.map(np.asarray, streaming_argmax(
            xq, w_pre.q, xs, w_pre.scale, early_exit=early_exit))
        got = jax.tree.map(np.asarray, streaming_argmax(
            xq, w_pre.planes, xs, w_pre.scale, early_exit=early_exit,
            mesh=meshes["1x4"]))
        for a, b, what in zip(ref, got, ("logits", "tok", "exit_level")):
            eq(np.asarray(b), np.asarray(a),
               err_msg=f"plane-cache ee={early_exit} {what}")
    print("plane-stack cache ok")

    # non-divisible vocab (9 classes over a 2-way model axis): the model
    # axis drops, the batch still shards — result still the oracle's
    # bit for bit
    w10 = jnp.asarray((rng.standard_normal((k, 9)) * 0.3)
                      .astype(np.float32))
    wq10 = quantize_weights(w10, cfg)
    ref = jax.tree.map(np.asarray, streaming_argmax(
        xq, wq10.q, xs, wq10.scale, early_exit=True))
    got = jax.tree.map(np.asarray, streaming_argmax(
        xq, wq10.q, xs, wq10.scale, early_exit=True, mesh=meshes["4x2"]))
    for a, b in zip(ref, got):
        eq(np.asarray(b), np.asarray(a))
    print("uneven-vocab fallback ok")
    print("ALL_OK")
""")


@pytest.mark.sharded
def test_sharded_streaming_bit_exact_vs_oracle():
    """The shard_mapped consensus walk on a virtual 8-device host: logits
    (= the accumulator prefix, via exact pow2 scales), committed tokens,
    and per-row exit levels bit-identical to the single-device oracle —
    across meshes (1x4, 2x2, 4x2), digit configs, every truncation
    depth, both control flows, the cached vocab-sharded plane stack, and
    the non-divisible-vocab fallback."""
    out = _run_subproc(SHARDED_STREAM)
    assert "ALL_OK" in out


# ------------------------------------------- multi-device: serving paths
SHARDED_SERVING = textwrap.dedent("""
    import dataclasses
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core.quant import QuantConfig
    from repro.launch.mesh import install_local_mesh, make_local_mesh
    from repro.sharding import ctx

    assert len(jax.devices()) == 8, jax.devices()
    eq = np.testing.assert_array_equal

    # ---- VGG-16 progressive classification, fc8 vocab-sharded ----
    from repro.models.cnn import (vgg16_build, vgg16_classify_progressive,
                                  vgg16_quantize_weights)
    from repro.models.common import materialize

    qcfg = QuantConfig()
    params = materialize(vgg16_build(n_classes=16), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.standard_normal((4, 32, 32, 3))
                      .astype(np.float32))
    ctx.set_mesh(None)
    cache_r = vgg16_quantize_weights(params, qcfg)
    refs = {ee: jax.tree.map(np.asarray, vgg16_classify_progressive(
        params, img, qcfg, weights_q=cache_r, early_exit=ee))
        for ee in (False, True)}
    mesh = install_local_mesh(data=2, model=4)
    cache_s = vgg16_quantize_weights(params, qcfg)  # fc8 vocab-sharded
    for ee in (False, True):
        got = jax.tree.map(np.asarray, vgg16_classify_progressive(
            params, img, qcfg, weights_q=cache_s, early_exit=ee))
        for a, b, what in zip(refs[ee], got,
                              ("pred", "exit_level", "logits")):
            eq(np.asarray(b), np.asarray(a),
               err_msg=f"vgg16 ee={ee} {what}")
    ctx.set_mesh(None)
    print("vgg16 sharded classify ok")

    # ---- progressive prefill/decode, LM head vocab-sharded ----
    from repro.configs import get_smoke
    from repro.models.transformer import lm_build
    from repro.serve.engine import (make_decode_step, make_prefill_step,
                                    prepare_params)

    cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
    raw = materialize(lm_build(cfg), jax.random.PRNGKey(1))
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (4, 6)), jnp.int32)

    def run_engine(mesh):
        ctx.set_mesh(mesh)
        params = prepare_params(cfg, raw)  # head_q vocab-sharded if mesh
        # replicated backbone on a mesh -> interior hints scoped off
        # (the bit-parity serving contract; the head walk still shards)
        hints = mesh is None
        prefill = jax.jit(make_prefill_step(cfg, 24, jnp.float32,
                                            progressive=True,
                                            early_exit=True,
                                            backbone_hints=hints))
        state, logits, tok, lv = prefill(params, {"tokens": prompt})
        toks, lvs = [np.asarray(tok)], [np.asarray(lv)]
        dec = jax.jit(make_decode_step(cfg, progressive=True,
                                       early_exit=True,
                                       backbone_hints=hints))
        cur = tok.astype(jnp.int32)
        for _ in range(3):
            state, cur, _, lv = dec(params, state, cur)
            toks.append(np.asarray(cur))
            lvs.append(np.asarray(lv))
        ctx.set_mesh(None)
        return np.stack(toks), np.stack(lvs)

    tok_r, lv_r = run_engine(None)
    tok_s, lv_s = run_engine(make_local_mesh(2, 4))
    eq(tok_s, tok_r, err_msg="sharded decode tokens")
    eq(lv_s, lv_r, err_msg="sharded decode exit levels")
    print("engine sharded prefill+decode ok")

    # ---- ContinuousBatcher on the mesh ----
    from repro.serve.batching import ContinuousBatcher, Request

    prompts = [rng.integers(0, cfg.vocab, (5,)).astype(np.int32)
               for _ in range(3)]

    def run_batcher(mesh, state_sharding="replicated"):
        ctx.set_mesh(mesh)
        params = prepare_params(cfg, raw)
        eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=24,
                                progressive=True, early_exit=True,
                                state_sharding=state_sharding)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=3)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=50)
        ctx.set_mesh(None)
        return reqs, eng.stats()

    # default ("replicated" state) mesh batcher: bit-identical to the
    # unmeshed run — only the consensus head walk is sharded, and it is
    # exact for ANY hidden states
    reqs_r, stats_r = run_batcher(None)
    reqs_s, stats_s = run_batcher(make_local_mesh(2, 4))
    for rr, rs in zip(reqs_r, reqs_s):
        assert rs.output == rr.output, (rs.output, rr.output)
        assert rs.exit_levels == rr.exit_levels
        assert rs.prefill_exit_level == rr.prefill_exit_level
    assert stats_s == stats_r, (stats_s, stats_r)
    print("batcher sharded ok")

    # explicit mesh= WITHOUT the installed context: the sharded walk
    # must engage through the argument chain alone (batcher -> step
    # factories -> progressive_logits_from_hidden -> streaming_argmax)
    ctx.set_mesh(None)
    m_exp = make_local_mesh(2, 4)
    eng = ContinuousBatcher(cfg, prepare_params(cfg, raw, mesh=m_exp),
                            n_slots=2, max_len=24, progressive=True,
                            early_exit=True, mesh=m_exp)
    reqs_e = [Request(uid=i, prompt=p, max_new_tokens=3)
              for i, p in enumerate(prompts)]
    for r in reqs_e:
        eng.submit(r)
    eng.run(max_steps=50)
    for rr, re_ in zip(reqs_r, reqs_e):
        assert re_.output == rr.output
        assert re_.exit_levels == rr.exit_levels
    assert eng.stats() == stats_r
    print("batcher explicit-mesh ok")

    # the scaling state layouts ("batch": slot axis over data; "specs":
    # the full state_specs policy).  GSPMD may repartition interior
    # float contractions under them, so only structural equality is
    # contractual — tokens flow, counts and schema match
    for mode in ("batch", "specs"):
        reqs_f, stats_f = run_batcher(make_local_mesh(2, 4),
                                      state_sharding=mode)
        assert [len(r.output) for r in reqs_f] == \
            [len(r.output) for r in reqs_r], mode
        assert stats_f["tokens"] == stats_r["tokens"], mode
        assert set(stats_f) == set(stats_r), mode
        print(f"batcher {mode}-sharded ok")
    print("ALL_OK")
""")


@pytest.mark.sharded
def test_sharded_serving_end_to_end_identical():
    """vgg16_classify_progressive, progressive prefill/decode, and the
    ContinuousBatcher on a (2, 4) virtual-device mesh: predictions,
    tokens, exit levels, logits, and stats all bit-identical to the
    unmeshed single-device runs (early_exit included — the consensus
    loop stops at the fleet-wide slowest row)."""
    out = _run_subproc(SHARDED_SERVING, timeout=1500)
    assert "ALL_OK" in out
