"""Early-exit streaming scan (`lax.while_loop` over MSDF levels).

The load-bearing invariant: the while-loop emitter executes the IDENTICAL
per-level arithmetic of the fixed-length scan (the oracle), so its prefix
after t levels, its committed decisions, and its exit levels are all
bit-identical — the only thing early exit changes is that the level loop
STOPS once every row has decided, turning saved levels into saved
wall-clock inside the fused computation.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional hypothesis

from repro.core.l2r_gemm import l2r_matmul_int_stacked
from repro.core.progressive import (l2r_matmul_int_streaming, plane_count,
                                    streaming_argmax, streaming_matmul_scan,
                                    streaming_matmul_while)
from repro.core.quant import QuantConfig, quantize, quantize_weights
from repro.kernels.l2r_gemm import (l2r_conv2d_progressive,
                                    l2r_conv2d_progressive_while, l2r_gemm,
                                    l2r_gemm_pallas_streaming)

SWEEP = [(8, 1), (8, 2), (8, 4), (6, 2), (4, 2), (16, 4)]
RAGGED = [(13, 37, 11), (1, 64, 16), (45, 67, 31)]


def _rand_ints(rng, n_bits, shape):
    lo, hi = -(1 << (n_bits - 1)), 1 << (n_bits - 1)
    dt = np.int8 if n_bits <= 8 else np.int16
    return jnp.asarray(rng.integers(lo, hi, size=shape, dtype=dt))


# ------------------------------------------------- while == scan, bitwise
@pytest.mark.parametrize("n_bits,log2_radix", SWEEP)
@pytest.mark.parametrize("m,k,n", RAGGED)
def test_while_full_run_bit_identical_to_scan(n_bits, log2_radix, m, k, n):
    """No decision state -> the while loop runs every level and its result
    (and every intermediate prefix) is bit-identical to the scan/stacked
    oracle, across radix/bit-width/ragged shapes."""
    rng = np.random.default_rng(n_bits * 1000 + log2_radix * 100 + m)
    a = _rand_ints(rng, n_bits, (m, k))
    b = _rand_ints(rng, n_bits, (k, n))
    d = plane_count(n_bits, log2_radix)
    acc, _, t = streaming_matmul_while(a, b, n_bits=n_bits,
                                       log2_radix=log2_radix)
    assert int(t) == 2 * d - 1
    np.testing.assert_array_equal(
        np.asarray(acc),
        np.asarray(l2r_matmul_int_stacked(a, b, n_bits, log2_radix)))
    np.testing.assert_array_equal(
        np.asarray(l2r_matmul_int_streaming(a, b, n_bits, log2_radix,
                                            early_exit=True)),
        np.asarray(l2r_matmul_int_streaming(a, b, n_bits, log2_radix)))


@pytest.mark.parametrize("n_bits,log2_radix", SWEEP)
def test_while_stops_at_fold_decision(n_bits, log2_radix):
    """A fold that declares itself done after `stop` levels halts the loop
    there, and the accumulator equals the stacked schedule truncated at
    exactly that depth (the while prefix IS the scan prefix)."""
    rng = np.random.default_rng(n_bits + 7 * log2_radix)
    a = _rand_ints(rng, n_bits, (9, 21))
    b = _rand_ints(rng, n_bits, (21, 7))
    d = plane_count(n_bits, log2_radix)
    for stop in [1, d, 2 * d - 1]:
        acc, count, t = streaming_matmul_while(
            a, b, lambda c, p, i: c + 1, jnp.int32(0),
            lambda c: c >= stop, n_bits, log2_radix)
        assert int(t) == stop == int(count)
        np.testing.assert_array_equal(
            np.asarray(acc),
            np.asarray(l2r_matmul_int_stacked(a, b, n_bits, log2_radix,
                                              stop)))


@pytest.mark.parametrize("levels", [0, 3, None])
def test_while_levels_truncation(levels):
    """`levels` truncates the while emitter exactly like the scan."""
    rng = np.random.default_rng(0)
    a = _rand_ints(rng, 8, (6, 18))
    b = _rand_ints(rng, 8, (18, 5))
    acc_w, _, t = streaming_matmul_while(a, b, levels=levels)
    acc_s, _, _ = streaming_matmul_scan(a, b, levels=levels)
    assert int(t) == (7 if levels is None else levels)
    np.testing.assert_array_equal(np.asarray(acc_w), np.asarray(acc_s))


# ------------------------------------------------ argmax consumer parity
@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_streaming_argmax_early_exit_matches_scan(seed):
    """Committed tokens AND per-row exit levels are bit-identical between
    the early-exit while loop and the fixed scan (the oracle)."""
    rng = np.random.default_rng(seed)
    cfg = QuantConfig()
    x = jnp.asarray(rng.standard_normal((8, 48)).astype(np.float32))
    w = jnp.asarray((rng.standard_normal((48, 10)) * 0.3).astype(np.float32))
    xq, xs = quantize(x, cfg, axis=0)
    w_q = quantize_weights(w, cfg)
    _, tok_s, lv_s = streaming_argmax(xq, w_q.q, xs, w_q.scale)
    logits_e, tok_e, lv_e = streaming_argmax(xq, w_q.q, xs, w_q.scale,
                                             early_exit=True)
    np.testing.assert_array_equal(np.asarray(tok_e), np.asarray(tok_s))
    np.testing.assert_array_equal(np.asarray(lv_e), np.asarray(lv_s))
    # the early-exit logits are the dequantized prefix at the exit level:
    # their argmax still equals the committed token on every row
    np.testing.assert_array_equal(np.asarray(logits_e).argmax(-1),
                                  np.asarray(tok_e))


def test_all_rows_undecidable_runs_every_level():
    """Identical weight columns make the top-1 margin zero forever: no
    row can ever decide, so the while loop MUST run every level, fall
    back to the full argmax, and agree with the scan path bit for bit."""
    rng = np.random.default_rng(3)
    cfg = QuantConfig()
    x = jnp.asarray(rng.standard_normal((6, 32)).astype(np.float32))
    w_np = rng.standard_normal((32, 8)).astype(np.float32) * 0.3
    w_np[:] = w_np[:, :1]  # every column tied: margin 0 at every level
    w_q = quantize_weights(jnp.asarray(w_np), cfg)
    xq, xs = quantize(x, cfg, axis=0)
    n_levels = 2 * cfg.planes - 1

    # the raw emitter: an argmax-decision fold that never fires
    def fold(c, partial, idx):
        return c

    acc, _, t = streaming_matmul_while(
        xq, w_q.q, fold, None, lambda c: jnp.bool_(False))
    assert int(t) == n_levels  # undecidable -> full stream executed
    np.testing.assert_array_equal(
        np.asarray(acc), np.asarray(l2r_matmul_int_stacked(xq, w_q.q)))

    logits_s, tok_s, lv_s = streaming_argmax(xq, w_q.q, xs, w_q.scale)
    logits_e, tok_e, lv_e = streaming_argmax(xq, w_q.q, xs, w_q.scale,
                                             early_exit=True)
    assert (np.asarray(lv_e) == n_levels - 1).all()
    np.testing.assert_array_equal(np.asarray(lv_e), np.asarray(lv_s))
    np.testing.assert_array_equal(np.asarray(tok_e), np.asarray(tok_s))
    # stream exhausted -> even the logit values match the oracle exactly
    np.testing.assert_array_equal(np.asarray(logits_e), np.asarray(logits_s))


# --------------------------------------------------- dispatcher + kernel
@pytest.mark.parametrize("levels", [None, 3, 0])
def test_dispatcher_early_exit_mode(levels):
    """schedule="streaming" + early_exit on the jnp backend: bit-identical
    to the stacked schedule at every truncation depth."""
    rng = np.random.default_rng(5)
    a = _rand_ints(rng, 8, (70, 90))
    b = _rand_ints(rng, 8, (90, 40))
    np.testing.assert_array_equal(
        np.asarray(l2r_gemm(a, b, levels=levels, schedule="streaming",
                            backend="jnp", early_exit=True)),
        np.asarray(l2r_matmul_int_stacked(a, b, 8, 2, levels)))


def test_pallas_streaming_level_count_scalar():
    """The streaming kernel's dynamic level-count scalar: planes below the
    count are bit-identical to the full run (steps at higher levels skip
    compute + write); the count is a runtime value, not a static arg."""
    rng = np.random.default_rng(6)
    a = jnp.asarray(rng.integers(-128, 128, (128, 256), dtype=np.int8))
    b = jnp.asarray(rng.integers(-128, 128, (256, 128), dtype=np.int8))
    full = np.asarray(l2r_gemm_pallas_streaming(a, b, interpret=True))
    for cnt in [1, 3, 7]:
        cut = np.asarray(l2r_gemm_pallas_streaming(
            a, b, interpret=True, level_count=jnp.int32(cnt)))
        np.testing.assert_array_equal(cut[:cnt], full[:cnt],
                                      err_msg=f"level_count={cnt}")


# ----------------------------------------------------- conv early exit
def test_conv_progressive_while_matches_scan_stack():
    """The early-exit conv runs the scan's per-level term: full run equals
    the last stack level, a fold-stopped run equals the stack at that
    depth, for default and strided geometry."""
    rng = np.random.default_rng(7)
    cfg = QuantConfig()
    x = jnp.asarray(rng.standard_normal((2, 10, 10, 8)).astype(np.float32))
    w = jnp.asarray((rng.standard_normal((3, 3, 8, 16)) * 0.2)
                    .astype(np.float32))
    for stride in [1, 2]:
        res, scale = l2r_conv2d_progressive(x, w, cfg, stride=stride)
        acc, _, t, scale_w = l2r_conv2d_progressive_while(x, w, cfg,
                                                          stride=stride)
        assert int(t) == res.partial.shape[0]
        np.testing.assert_array_equal(np.asarray(acc),
                                      np.asarray(res.partial[-1]))
        np.testing.assert_array_equal(np.asarray(scale_w), np.asarray(scale))
        acc3, _, t3, _ = l2r_conv2d_progressive_while(
            x, w, cfg, fold=lambda c, p, i: c + 1, init=jnp.int32(0),
            done_fn=lambda c: c >= 3, stride=stride)
        assert int(t3) == 3
        np.testing.assert_array_equal(np.asarray(acc3),
                                      np.asarray(res.partial[2]))


# ------------------------------------------------------------ end to end
def test_vgg16_classify_progressive_early_exit_identical():
    """Early-exit classification: classes and exit levels bit-identical to
    the scan path, classes equal to the one-shot vgg16_apply argmax."""
    from repro.models.cnn import (vgg16_apply, vgg16_build,
                                  vgg16_classify_progressive,
                                  vgg16_quantize_weights)
    from repro.models.common import materialize

    cfg = QuantConfig()
    params = materialize(vgg16_build(n_classes=10), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.standard_normal((2, 32, 32, 3)).astype(np.float32))
    cache = vgg16_quantize_weights(params, cfg)
    ref = np.asarray(vgg16_apply(params, img, l2r=cfg, weights_q=cache))
    pred_s, lv_s, _ = vgg16_classify_progressive(params, img, cfg,
                                                 weights_q=cache)
    pred_e, lv_e, _ = vgg16_classify_progressive(params, img, cfg,
                                                 weights_q=cache,
                                                 early_exit=True)
    np.testing.assert_array_equal(np.asarray(pred_e), np.asarray(pred_s))
    np.testing.assert_array_equal(np.asarray(lv_e), np.asarray(lv_s))
    np.testing.assert_array_equal(np.asarray(pred_e), ref.argmax(-1))


@pytest.fixture(scope="module")
def l2r_lm():
    from repro.configs import get_smoke
    from repro.models.common import materialize
    from repro.models.transformer import lm_build

    cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
    params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_progressive_prefill_streams_last_token_only(l2r_lm):
    """Batch-progressive prefill: the committed first token equals the
    one-shot prefill argmax, the spliced state is identical, and the exit
    level is a valid stream position."""
    from repro.serve.engine import make_prefill_step

    cfg, params = l2r_lm
    rng = np.random.default_rng(5)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    ref_prefill = jax.jit(make_prefill_step(cfg, 32, jnp.float32))
    st_r, logits_r = ref_prefill(params, {"tokens": prompt})
    prog_prefill = jax.jit(make_prefill_step(cfg, 32, jnp.float32,
                                             progressive=True))
    st_p, logits_p, tok, lv = prog_prefill(params, {"tokens": prompt})
    np.testing.assert_array_equal(
        np.asarray(tok), np.asarray(logits_r).argmax(-1))
    np.testing.assert_array_equal(np.asarray(logits_p),
                                  np.asarray(logits_r))
    assert np.asarray(lv).min() >= 0 and np.asarray(lv).max() <= 6
    for a, b in zip(jax.tree.leaves(st_p), jax.tree.leaves(st_r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_step_early_exit_tokens_identical(l2r_lm):
    """progressive + early_exit decode: same tokens and exit levels as the
    scan-based progressive step (and hence as greedy decoding)."""
    from repro.serve.engine import make_decode_step, make_prefill_step

    cfg, params = l2r_lm
    rng = np.random.default_rng(13)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    prefill = jax.jit(make_prefill_step(cfg, 32, jnp.float32))
    state, logits = prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    dec_s = jax.jit(make_decode_step(cfg, progressive=True))
    dec_e = jax.jit(make_decode_step(cfg, progressive=True, early_exit=True))
    st_s, st_e = state, state
    for _ in range(4):
        st_s, tok_s, _, lv_s = dec_s(params, st_s, tok)
        st_e, tok_e, _, lv_e = dec_e(params, st_e, tok)
        np.testing.assert_array_equal(np.asarray(tok_e), np.asarray(tok_s))
        np.testing.assert_array_equal(np.asarray(lv_e), np.asarray(lv_s))
        tok = tok_s


def test_batcher_records_prefill_exit_levels(l2r_lm):
    """ContinuousBatcher(progressive=True): prefill exit levels land on
    the requests and in stats() alongside the decode histogram, and the
    emitted tokens still match the non-progressive engine."""
    from repro.serve.batching import ContinuousBatcher, Request

    cfg, params = l2r_lm
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, cfg.vocab, (5,)).astype(np.int32)
               for _ in range(3)]

    def run(progressive, early_exit=False):
        eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32,
                                progressive=progressive,
                                early_exit=early_exit)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=3)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=100)
        return eng, reqs

    eng_p, reqs_p = run(True)
    eng_e, reqs_e = run(True, early_exit=True)
    _, reqs_r = run(False)
    for rp, re_, rr in zip(reqs_p, reqs_e, reqs_r):
        assert rp.output == rr.output == re_.output
        assert rp.prefill_exit_level is not None
        assert rp.prefill_exit_level == re_.prefill_exit_level
        assert rp.exit_levels == re_.exit_levels
    for rr in reqs_r:
        assert rr.prefill_exit_level is None
    stats = eng_p.stats()
    assert stats["prefills"] == len(prompts)
    assert sum(stats["prefill_exit_level_hist"]) == stats["prefills"]
    assert 0.0 <= stats["mean_prefill_exit_level"] <= stats["n_levels"] - 1
    assert stats["tokens"] == sum(len(r.exit_levels) for r in reqs_p)


def test_dispatcher_early_exit_rejected_where_unhonorable():
    """early_exit=True is rejected loudly by schedules/backends that have
    no level loop to stop (it used to be silently dropped): pairs and
    stacked schedules raise, and the Pallas backends point to the
    streaming kernel's dynamic level_count scalar."""
    rng = np.random.default_rng(9)
    a = _rand_ints(rng, 8, (16, 16))
    b = _rand_ints(rng, 8, (16, 16))
    for schedule in ("pairs", "stacked"):
        with pytest.raises(ValueError, match="streaming"):
            l2r_gemm(a, b, schedule=schedule, early_exit=True)
    with pytest.raises(ValueError, match="level_count"):
        l2r_gemm(a, b, schedule="streaming", backend="pallas-interpret",
                 early_exit=True)
