"""Continuous batching engine: correctness vs straight-line decoding,
and the slot-splice tree surgery (explicit batch axes, no shape
heuristics)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.common import materialize
from repro.models.transformer import lm_build
from repro.serve.batching import (ContinuousBatcher, Request, _pad_value,
                                  _splice, infer_batch_axes)
from repro.serve.engine import greedy_generate


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke("smollm-135m")
    params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_batcher_matches_straightline_greedy(model):
    """Requests served through slot splicing produce exactly the tokens
    of an isolated greedy decode of the same prompt."""
    cfg, params = model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=(L,)).astype(np.int32)
               for L in (8, 5, 11)]
    # reference: each prompt decoded alone
    refs = []
    for p in prompts:
        out = greedy_generate(cfg, params, jnp.asarray(p[None]), steps=6,
                              max_len=32)
        refs.append(np.asarray(out)[0].tolist())

    eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=200)
    for r, ref in zip(reqs, refs):
        assert r.done
        assert r.output[:6] == ref, (r.uid, r.output, ref)


def test_batcher_more_requests_than_slots(model):
    cfg, params = model
    rng = np.random.default_rng(1)
    eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, (6,)).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=200)
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 4 for r in reqs)


def test_batcher_eos_retires_early(model):
    cfg, params = model
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    # discover the 2nd generated token and use it as the EOS id
    ref = np.asarray(greedy_generate(cfg, params, jnp.asarray(prompt[None]),
                                     steps=3, max_len=32))[0]
    eng = ContinuousBatcher(cfg, params, n_slots=1, max_len=32)
    req = Request(uid=0, prompt=prompt, max_new_tokens=10, eos_id=int(ref[1]))
    eng.submit(req)
    eng.run(max_steps=100)
    assert req.done
    assert len(req.output) == 2  # stopped at EOS, not max_new_tokens


# --------------------------------------------------- slot splice surgery
def _axes_for(batch_tree, single_tree):
    """Batch-axis tree for synthetic splice tests, via the same
    structure-derived inference the batcher uses (two abstract batch
    sizes; here the donor IS the batch=1 evaluation)."""
    two = jax.tree.map(
        lambda b, s: jax.ShapeDtypeStruct(
            tuple(2 if bd != sd else sd
                  for bd, sd in zip(b.shape, s.shape)), b.dtype),
        batch_tree, single_tree)
    return infer_batch_axes(
        jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                     single_tree), two)


def test_splice_stacked_leaf_with_single_layer():
    """Regression: a stacked (layers, batch, ...) cache leaf with
    n_layers == 1.  The old shape heuristic (`s.shape[0] == b.shape[0]
    and ... != 1`) fell through to the batch-axis-0 branch and smeared
    the donor over the whole batch at layer 0; the explicit batch-axis
    tag splices on axis 1."""
    n_slots, n_layers, L, dh = 4, 1, 6, 3
    b = {"cache": jnp.zeros((n_layers, n_slots, L, dh), jnp.float32),
         "pos": jnp.zeros((n_slots,), jnp.int32)}
    s = {"cache": jnp.ones((n_layers, 1, L, dh), jnp.float32),
         "pos": jnp.full((1,), 5, jnp.int32)}
    axes = _axes_for(b, s)
    assert axes["cache"] == 1 and axes["pos"] == 0
    out = _splice(b, s, 2, axes)
    np.testing.assert_array_equal(np.asarray(out["cache"][0, 2]), 1.0)
    for slot in (0, 1, 3):
        np.testing.assert_array_equal(np.asarray(out["cache"][0, slot]), 0.0)
    assert int(out["pos"][2]) == 5 and int(out["pos"][0]) == 0


def test_splice_ignores_batch_independent_nslots_sized_leaf():
    """Regression: a batch-INDEPENDENT leaf whose leading dim happens to
    equal n_slots (and a head_dim == n_slots cache) must not be spliced
    on the coincidental axis."""
    n_slots = 4
    head_dim = n_slots  # the coincidence the heuristic tripped over
    b = {"per_layer": jnp.arange(n_slots, dtype=jnp.float32),  # (layers,)
         "kv": jnp.zeros((1, n_slots, 6, head_dim), jnp.float32),
         "pos": jnp.zeros((n_slots,), jnp.int32)}
    s = {"per_layer": jnp.arange(n_slots, dtype=jnp.float32),
         "kv": jnp.ones((1, 1, 6, head_dim), jnp.float32),
         "pos": jnp.full((1,), 3, jnp.int32)}
    axes = _axes_for(b, s)
    assert axes["per_layer"] == -1 and axes["kv"] == 1
    out = _splice(b, s, 1, axes)
    # batch-independent leaf untouched; kv landed at [:, 1] only
    np.testing.assert_array_equal(np.asarray(out["per_layer"]),
                                  np.arange(n_slots, dtype=np.float32))
    np.testing.assert_array_equal(np.asarray(out["kv"][0, 1]), 1.0)
    np.testing.assert_array_equal(np.asarray(out["kv"][0, 0]), 0.0)


def test_splice_pad_value_all_integer_dtypes():
    """Regression: the empty sentinel must cover EVERY integer dtype, not
    just int32 — an int8/int16 donor cache shorter than the live leaf
    pads with -1 (and unsigned with all-ones), never with a "valid" 0."""
    assert _pad_value(jnp.zeros((1,), jnp.int32)) == -1
    assert _pad_value(jnp.zeros((1,), jnp.int8)) == -1
    assert _pad_value(jnp.zeros((1,), jnp.int16)) == -1
    assert _pad_value(jnp.zeros((1,), jnp.uint32)) == 2**32 - 1
    assert _pad_value(jnp.zeros((1,), jnp.float32)) == 0
    b = {"positions": jnp.zeros((4, 8), jnp.int8)}
    s = {"positions": jnp.arange(1, 6, dtype=jnp.int8).reshape(1, 5)}
    out = _splice(b, s, 2, {"positions": 0})
    np.testing.assert_array_equal(np.asarray(out["positions"][2, :5]),
                                  np.arange(1, 6, dtype=np.int8))
    np.testing.assert_array_equal(np.asarray(out["positions"][2, 5:]), -1)


# ------------------------------------------- donation / buckets / latency
def test_batcher_decode_donates_state_buffers(model):
    """donate_argnums on the jitted decode step: the per-step KV-cache
    copy disappears — XLA writes the updated cache into the donated
    input buffer (pointer-identical output) and the donated reference
    is invalidated."""
    cfg, params = model
    rng = np.random.default_rng(5)
    eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32)
    eng.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab, (6,))
                       .astype(np.int32), max_new_tokens=8))
    eng.step()  # admit + first decode (splice allocates fresh buffers)
    before = eng.state
    k0 = before.stack[0].k  # a large cache leaf
    ptr = k0.unsafe_buffer_pointer()
    eng.step()
    assert eng.state.stack[0].k.unsafe_buffer_pointer() == ptr, \
        "decode step copied the KV cache instead of updating in place"
    with pytest.raises(RuntimeError):
        np.asarray(k0)  # the donated buffer is dead


def test_batcher_decode_no_donation_opt_out(model):
    cfg, params = model
    rng = np.random.default_rng(5)
    eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32,
                            donate_state=False)
    eng.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab, (6,))
                       .astype(np.int32), max_new_tokens=8))
    eng.step()
    k0 = eng.state.stack[0].k
    eng.step()
    np.asarray(k0)  # still alive: no donation


def test_batcher_prefill_compiles_once_per_bucket(model):
    """Regression for the per-unique-prompt-length retrace: admits
    route through the bucket pad, so three different prompt lengths in
    one bucket share ONE prefill trace, and a fourth length in the next
    bucket adds exactly one more."""
    cfg, params = model
    rng = np.random.default_rng(6)
    eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32)
    assert eng.bucketed

    def serve(length):
        eng.submit(Request(uid=length,
                           prompt=rng.integers(0, cfg.vocab, (length,))
                           .astype(np.int32), max_new_tokens=2))
        eng.run(max_steps=1000)

    for L in (5, 6, 7):  # all bucket 8
        serve(L)
    assert eng._bucket_prefill._cache_size() == 1, \
        "prefill retraced within one length bucket"
    serve(9)  # bucket 16
    assert eng._bucket_prefill._cache_size() == 2
    serve(12)  # bucket 16 again: no new trace
    assert eng._bucket_prefill._cache_size() == 2
    # the unbucketed single-prompt prefill path was never touched
    assert eng._prefill1._cache_size() == 0


def test_batcher_bucketed_matches_unbucketed(model):
    """The bucket pad is bit-invisible: same tokens with bucketing
    forced off (the recurrent-family fallback path)."""
    cfg, params = model
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
               for L in (5, 11, 3)]

    def run(bucketed):
        eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32,
                                bucketed=bucketed)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=1000)
        return [r.output for r in reqs]

    assert run(True) == run(False)


def test_batcher_latency_stats_opt_in(model):
    """Per-request latency percentiles surface under stats(latency=True)
    and ONLY there — the default schema stays deterministic for a fixed
    request set (replica-consistency tests compare it exactly)."""
    cfg, params = model
    rng = np.random.default_rng(8)
    eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, (5,))
                    .astype(np.int32), max_new_tokens=3) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=1000)
    plain = eng.stats()
    for k in ("completed", "ttft_p50_s", "ttft_p99_s",
              "tpot_p50_s", "tpot_p99_s"):
        assert k not in plain
    lat = eng.stats(latency=True)
    assert lat["completed"] == 3
    assert lat["ttft_p99_s"] >= lat["ttft_p50_s"] > 0
    assert lat["tpot_p99_s"] >= lat["tpot_p50_s"] > 0
    for r in reqs:
        assert r.t_arrival <= r.t_first_token <= r.t_complete


def test_batcher_single_layer_model_matches_greedy(model):
    """End-to-end regression for the n_layers == 1 splice: the stacked
    cache has a leading axis of size 1, which the old heuristic spliced
    on the wrong axis (corrupting every other slot's cache)."""
    cfg, _ = model
    cfg1 = dataclasses.replace(cfg, n_layers=1)
    params = materialize(lm_build(cfg1), jax.random.PRNGKey(1))
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg1.vocab, size=(L,)).astype(np.int32)
               for L in (7, 4, 9)]
    refs = [np.asarray(greedy_generate(cfg1, params, jnp.asarray(p[None]),
                                       steps=5, max_len=32))[0].tolist()
            for p in prompts]
    eng = ContinuousBatcher(cfg1, params, n_slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=200)
    for r, ref in zip(reqs, refs):
        assert r.done
        assert r.output[:5] == ref, (r.uid, r.output, ref)
