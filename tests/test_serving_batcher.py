"""Continuous batching engine: correctness vs straight-line decoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.common import materialize
from repro.models.transformer import lm_build
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import greedy_generate


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke("smollm-135m")
    params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_batcher_matches_straightline_greedy(model):
    """Requests served through slot splicing produce exactly the tokens
    of an isolated greedy decode of the same prompt."""
    cfg, params = model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=(L,)).astype(np.int32)
               for L in (8, 5, 11)]
    # reference: each prompt decoded alone
    refs = []
    for p in prompts:
        out = greedy_generate(cfg, params, jnp.asarray(p[None]), steps=6,
                              max_len=32)
        refs.append(np.asarray(out)[0].tolist())

    eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=200)
    for r, ref in zip(reqs, refs):
        assert r.done
        assert r.output[:6] == ref, (r.uid, r.output, ref)


def test_batcher_more_requests_than_slots(model):
    cfg, params = model
    rng = np.random.default_rng(1)
    eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, (6,)).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=200)
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 4 for r in reqs)


def test_batcher_eos_retires_early(model):
    cfg, params = model
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    # discover the 2nd generated token and use it as the EOS id
    ref = np.asarray(greedy_generate(cfg, params, jnp.asarray(prompt[None]),
                                     steps=3, max_len=32))[0]
    eng = ContinuousBatcher(cfg, params, n_slots=1, max_len=32)
    req = Request(uid=0, prompt=prompt, max_new_tokens=10, eos_id=int(ref[1]))
    eng.submit(req)
    eng.run(max_steps=100)
    assert req.done
    assert len(req.output) == 2  # stopped at EOS, not max_new_tokens
