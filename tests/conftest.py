import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# tests/ itself, so test modules can import the shared _hypothesis_compat
# shim regardless of pytest's rootdir/importmode.
sys.path.insert(0, os.path.dirname(__file__))
