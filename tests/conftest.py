import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# tests/ itself, so test modules can import the shared _hypothesis_compat
# shim regardless of pytest's rootdir/importmode.
sys.path.insert(0, os.path.dirname(__file__))


def pytest_collection_modifyitems(items):
    """`tier1` is an alias for "everything that is not slow", so the
    verify gate is the single entry point `pytest -m tier1` instead of a
    marker-expression every runner has to get right."""
    for item in items:
        if item.get_closest_marker("slow") is None:
            item.add_marker(pytest.mark.tier1)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    """Drop jax's in-process executable caches between test MODULES.

    A full tier-1 run compiles many hundreds of XLA CPU executables into
    one process; on single-CPU hosts the accumulated JIT state has been
    observed to segfault the XLA compiler late in the suite (inside
    backend_compile, at a different test each run — including on trees
    with no local changes).  Clearing per module bounds the live
    executable set at the cost of recompiling the handful of helpers
    shared across modules; correctness is untouched (jitted functions
    simply retrace on next call)."""
    yield
    import jax

    jax.clear_caches()


@pytest.fixture(autouse=True)
def _mesh_context_hygiene():
    """Restore sharding.ctx.set_mesh(None) after EVERY test: an installed
    mesh silently changes hint() from identity to a sharding constraint
    AND routes the whole progressive serving stack (streaming_argmax,
    prepare_params, ContinuousBatcher) onto the sharded paths — a mesh
    leaked from one test would change the behavior of every test after
    it."""
    yield
    from repro.sharding import ctx

    ctx.set_mesh(None)
