"""Property tests (hypothesis) for the L2R arithmetic core invariants."""

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st  # optional hypothesis

from repro.core.online import msdf_pairs, online_delay, tail_bound
from repro.core.quant import (QuantConfig, dequantize, digit_planes,
                              from_digit_planes, quantize)


@given(st.integers(1, 4))
def test_msdf_pairs_complete_and_ordered(log2r):
    n_bits = 8 if log2r != 3 else 6
    d = n_bits // log2r
    pairs = msdf_pairs(d)
    assert len(pairs) == d * d  # every (i, j) exactly once
    assert len(set(pairs)) == d * d
    sigs = [i + j for i, j in pairs]
    assert sigs == sorted(sigs, reverse=True)  # MSDF order


@given(st.lists(st.integers(-128, 127), min_size=1, max_size=64),
       st.sampled_from([1, 2, 4]))
@settings(max_examples=50, deadline=None)
def test_digit_plane_roundtrip(vals, log2r):
    x = jnp.asarray(np.array(vals, np.int8))
    pl = digit_planes(x, 8, log2r)
    assert pl.shape[0] == 8 // log2r
    rec = from_digit_planes(pl, log2r)
    np.testing.assert_array_equal(np.asarray(rec), np.array(vals, np.int32))


@given(st.integers(0, 6))
@settings(max_examples=20, deadline=None)
def test_tail_bound_monotone_decreasing(lv):
    d = 4
    b0 = tail_bound(d, lv, 2, k=16)
    b1 = tail_bound(d, lv + 1, 2, k=16)
    assert b1 <= b0
    assert tail_bound(d, 2 * d - 1, 2, k=16) == 0  # full stream -> exact


@given(st.lists(st.floats(-10, 10, allow_nan=False), min_size=4, max_size=64))
@settings(max_examples=50, deadline=None)
def test_quantize_error_bound(vals):
    x = jnp.asarray(np.array(vals, np.float32)).reshape(1, -1)
    cfg = QuantConfig(per_channel=False)
    q, scale = quantize(x, cfg)
    err = np.abs(np.asarray(dequantize(q, scale)) - np.asarray(x))
    assert (err <= np.asarray(scale) * 0.5 + 1e-7).all()


def test_online_delay_small():
    # the online delay of the plane stream is a small constant, like the
    # paper's delta_Mult (radix-4, n=8: a few levels)
    d = online_delay(8, 2)
    assert 1 <= d <= 7
    assert online_delay(8, 4) <= d + 1


@given(st.integers(1, 200), st.integers(1, 1000))
@settings(max_examples=30, deadline=None)
def test_tail_bound_is_valid_bound(seed, k):
    """Randomized check: |exact - truncated| <= tail_bound at every level."""
    from repro.core.l2r_gemm import l2r_matmul_int

    rng = np.random.default_rng(seed)
    kk = min(k, 64)
    a = rng.integers(-128, 128, size=(2, kk), dtype=np.int8)
    b = rng.integers(-128, 128, size=(kk, 3), dtype=np.int8)
    exact = a.astype(np.int64) @ b.astype(np.int64)
    for lv in range(1, 8):
        out = np.asarray(l2r_matmul_int(jnp.asarray(a), jnp.asarray(b),
                                        8, 2, levels=lv), np.int64)
        bound = tail_bound(4, lv, 2, kk)
        assert (np.abs(exact - out) <= bound).all(), lv
