"""Trip-count-aware HLO analyzer vs known-FLOP programs (1 CPU device)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_single_matmul_flops_exact():
    m, k, n = 64, 128, 32
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    res = analyze(_hlo(lambda x, y: x @ y, a, b))
    assert res["flops"] == pytest.approx(2 * m * k * n, rel=1e-6)


def test_scan_multiplies_by_trip_count():
    m = 32
    a = jax.ShapeDtypeStruct((m, m), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    res = analyze(_hlo(f, a))
    assert res["flops"] == pytest.approx(7 * 2 * m ** 3, rel=0.01)


def test_nested_scan():
    m = 16
    a = jax.ShapeDtypeStruct((m, m), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ x, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    res = analyze(_hlo(f, a))
    assert res["flops"] == pytest.approx(15 * 2 * m ** 3, rel=0.01)


def test_bytes_scale_with_result_sizes():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    small = analyze(_hlo(lambda x, y: x @ y, a, a))
    b = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    big = analyze(_hlo(lambda x, y: x @ y, b, b))
    assert big["bytes"] > 3 * small["bytes"]


def test_no_collectives_on_single_device():
    a = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    res = analyze(_hlo(lambda x: x * 2 + 1, a))
    assert res["total_wire_bytes"] == 0
