"""Trip-count-aware HLO analyzer vs known-FLOP programs (1 CPU device)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_single_matmul_flops_exact():
    m, k, n = 64, 128, 32
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    res = analyze(_hlo(lambda x, y: x @ y, a, b))
    assert res["flops"] == pytest.approx(2 * m * k * n, rel=1e-6)


def test_scan_multiplies_by_trip_count():
    m = 32
    a = jax.ShapeDtypeStruct((m, m), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    res = analyze(_hlo(f, a))
    assert res["flops"] == pytest.approx(7 * 2 * m ** 3, rel=0.01)


def test_nested_scan():
    m = 16
    a = jax.ShapeDtypeStruct((m, m), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ x, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    res = analyze(_hlo(f, a))
    assert res["flops"] == pytest.approx(15 * 2 * m ** 3, rel=0.01)


def test_bytes_scale_with_result_sizes():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    small = analyze(_hlo(lambda x, y: x @ y, a, a))
    b = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    big = analyze(_hlo(lambda x, y: x @ y, b, b))
    assert big["bytes"] > 3 * small["bytes"]


def test_no_collectives_on_single_device():
    a = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    res = analyze(_hlo(lambda x: x * 2 + 1, a))
    assert res["total_wire_bytes"] == 0


# ------------------------------------- sharded/tiled text-level parsing
# TPU and sharded lowerings annotate types with tiled layouts
# (``{1,0:T(8,128)S(1)}``) and pass tuples through ``opt-barrier``; the
# parser must read operand types through both (single-device CPU dumps
# never exercise these spellings, hence the synthetic module).
_TILED_HLO = """
HloModule tiled

%body (p: (f32[8,16]{1,0:T(8,128)S(1)}, s32[])) -> (f32[8,16], s32[]) {
  %p = (f32[8,16]{1,0:T(8,128)S(1)}, s32[]) parameter(0)
  %x = f32[8,16]{1,0:T(8,128)} get-tuple-element((f32[8,16]{1,0:T(8,128)S(1)}, s32[]) %p), index=0
  %i = s32[] get-tuple-element((f32[8,16]{1,0:T(8,128)S(1)}, s32[]) %p), index=1
  ROOT %t = (f32[8,16]{1,0}, s32[]) tuple(f32[8,16]{1,0:T(8,128)} %x, s32[] %i)
}

ENTRY %main (a: f32[8,16], b: f32[16,4]) -> f32[8,4] {
  %a = f32[8,16]{1,0:T(8,128)S(1)} parameter(0)
  %b = f32[16,4]{1,0:T(8,128)} parameter(1)
  ROOT %dot.1 = f32[8,4]{1,0:T(8,128)} dot(f32[8,16]{1,0:T(8,128)S(1)} %a, f32[16,4]{1,0:T(8,128)} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_tiled_layout_operands_parse():
    from repro.launch.hlo_analysis import _op_kind, parse_module
    comps = parse_module(_TILED_HLO)
    assert set(comps) == {"body", "main"}
    kinds = [_op_kind(r) for _, r in comps["main"]["instrs"]]
    assert "dot" in kinds
    res = analyze(_TILED_HLO)
    assert res["flops"] == pytest.approx(2 * 8 * 16 * 4, rel=1e-6)


def test_tuple_typed_operands_parse():
    from repro.launch.hlo_analysis import _op_kind, parse_module
    comps = parse_module(_TILED_HLO)
    kinds = [_op_kind(r) for _, r in comps["body"]["instrs"]]
    assert kinds.count("get-tuple-element") == 2
    assert "tuple" in kinds
    # the tuple-typed ROOT result must not confuse the rhs type split
    (rhs,) = [r for _, r in comps["body"]["instrs"]
              if _op_kind(r) == "tuple"]
    assert rhs.startswith("(f32[8,16]")


# -------------------------------------------- shared collective parser
_COLL_HLO = """\
HloModule jit_sharded, num_partitions=8

%region_add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %ar-start = (f32[8,16]{1,0}, f32[8,16]{1,0}) all-reduce-start(f32[8,16]{1,0} %p0), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%region_add, metadata={op_name="jit(f)/psum"}
  %ar-done = f32[8,16]{1,0} all-reduce-done((f32[8,16]{1,0}, f32[8,16]{1,0}) %ar-start)
  ROOT %ag.1 = f32[8,128]{1,0} all-gather(f32[8,16]{1,0} %ar-done), replica_groups={}, dimensions={1}
}
"""


def test_collective_records_async_pair_counted_once():
    """An all-reduce-start/-done pair is ONE transfer: the -start's
    tuple result must not be summed (double-count) and the -done must
    not be recorded at all."""
    from repro.launch.hlo_analysis import collective_records
    recs = collective_records(_COLL_HLO)
    assert [r["kind"] for r in recs] == ["all-reduce", "all-gather"]
    ar = recs[0]
    assert ar["is_async"] and ar["result_bytes"] == 8 * 16 * 4
    assert ar["group_size"] == 4 and ar["n_groups"] == 2
    assert ar["reduce_op"] == "add" and ar["op_name"] == "jit(f)/psum"
    # ring all-reduce over a group of 4: 2*(4-1)/4 * 512 bytes
    assert ar["wire_bytes"] == pytest.approx(2 * 3 / 4 * 512)
    # empty replica_groups={} = one group of every partition (8)
    ag = recs[1]
    assert ag["group_size"] == 8 and ag["n_groups"] == 1
    assert ag["wire_bytes"] == pytest.approx(7 / 8 * 8 * 128 * 4)


def test_parse_replica_groups_forms():
    from repro.launch.hlo_analysis import parse_replica_groups
    # full multi-group list: size must come from the groups, not the
    # first group only
    assert parse_replica_groups(
        "all-reduce(...), replica_groups={{0,1},{2,3},{4,5},{6,7}}") \
        == (2, 4)
    assert parse_replica_groups(
        "all-gather(...), replica_groups={{0,1,2,3,4,5,6,7}}") == (8, 1)
    # iota v2 form [n_groups,size]<=[total]
    assert parse_replica_groups(
        "all-reduce(...), replica_groups=[2,4]<=[8]") == (4, 2)
    # empty = all partitions together (module header count)
    assert parse_replica_groups(
        "all-reduce(...), replica_groups={}", num_partitions=8) == (8, 1)
    assert parse_replica_groups(
        "all-reduce(...), replica_groups={}") == (2, 1)


def test_roofline_parse_collectives_uses_shared_parser():
    """roofline.parse_collectives is a fold over the same records —
    async dedupe and multi-group sizes included."""
    from repro.launch.roofline import parse_collectives
    out = parse_collectives(_COLL_HLO)
    assert out["counts"]["all-reduce"] == 1
    assert out["counts"]["all-gather"] == 1
    assert out["wire_bytes"]["all-reduce"] == pytest.approx(2 * 3 / 4 * 512)
    assert out["total_wire_bytes"] == pytest.approx(
        2 * 3 / 4 * 512 + 7 / 8 * 4096)


def test_analyze_counts_async_collective_once():
    """analyze()'s wire-byte accounting goes through the same -start
    handling: the tuple-typed -start result is one payload."""
    res = analyze(_COLL_HLO)
    assert res["collective_counts"]["all-reduce"] == 1
    assert res["collective_wire_bytes"]["all-reduce"] == \
        pytest.approx(2 * 3 / 4 * 512)
