"""End-to-end training behaviour: loss decreases, microbatch equivalence,
gradient compression convergence, chunked xent == full xent."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data.pipeline import DataConfig, ShardedPipeline
from repro.models.common import materialize
from repro.models.transformer import lm_build
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.optim.compression import ef_init
from repro.train.step import TrainConfig, chunked_xent, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("smollm-135m")
    cfg = dataclasses.replace(cfg, vocab=128)
    params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
    dcfg = DataConfig(vocab=128, seq_len=32, global_batch=8, structure=0.95)
    return cfg, params, dcfg


def _run(cfg, params, dcfg, tcfg, n_steps=30, ef=False):
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=n_steps,
                       weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, ocfg, tcfg))
    opt = adamw_init(params)
    efs = ef_init(params) if ef else None
    pipe = ShardedPipeline(dcfg)
    losses = []
    p = params
    for _ in range(n_steps):
        b = next(pipe)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if ef:
            p, opt, efs, m = step(p, opt, batch, efs)
        else:
            p, opt, m = step(p, opt, batch)
        losses.append(float(m["loss"]))
    return losses


def test_loss_decreases(setup):
    cfg, params, dcfg = setup
    losses = _run(cfg, params, dcfg, TrainConfig(remat=False, seq_shard=False,
                                                 xent_chunk=32))
    # 30 steps on the structured stream: clear descent from ln(128)=4.85
    assert losses[-1] < losses[0] - 0.4, losses[::5]


def test_remat_equals_noremat(setup):
    cfg, params, dcfg = setup
    l1 = _run(cfg, params, dcfg, TrainConfig(remat=False, seq_shard=False,
                                             xent_chunk=32), n_steps=3)
    l2 = _run(cfg, params, dcfg, TrainConfig(remat=True, seq_shard=False,
                                             xent_chunk=32), n_steps=3)
    np.testing.assert_allclose(l1, l2, rtol=1e-4)


def test_microbatch_accumulation_close(setup):
    cfg, params, dcfg = setup
    l1 = _run(cfg, params, dcfg, TrainConfig(remat=False, seq_shard=False,
                                             xent_chunk=32), n_steps=3)
    l4 = _run(cfg, params, dcfg, TrainConfig(remat=False, seq_shard=False,
                                             xent_chunk=32, microbatch=4),
              n_steps=3)
    # same data, grads averaged over microbatches -> same trajectory
    np.testing.assert_allclose(l1[0], l4[0], rtol=1e-3)


def test_ef_compression_still_converges(setup):
    cfg, params, dcfg = setup
    losses = _run(cfg, params, dcfg,
                  TrainConfig(remat=False, seq_shard=False, xent_chunk=32,
                              ef_compression=True), ef=True)
    # int8 EF compression must not break the descent
    assert losses[-1] < losses[0] - 0.35, losses[::5]


def test_chunked_xent_equals_full():
    rng = np.random.default_rng(0)
    b, s, d, v = 2, 32, 16, 50
    hidden = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    full_logits = np.asarray(hidden @ w, np.float64)
    lse = np.log(np.exp(full_logits - full_logits.max(-1, keepdims=True)).sum(-1)) \
        + full_logits.max(-1)
    gold = np.take_along_axis(full_logits, np.asarray(labels)[..., None], -1)[..., 0]
    ref = (lse - gold).mean()
    for chunk in (4, 8, 32):
        loss, acc = chunked_xent(hidden, w, labels, chunk=chunk, z_loss=0.0)
        assert float(loss) == pytest.approx(float(ref), rel=1e-5), chunk


def test_grad_of_chunked_xent_finite():
    rng = np.random.default_rng(1)
    hidden = jnp.asarray(rng.standard_normal((2, 16, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, 30)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 30, (2, 16)), jnp.int32)
    g = jax.grad(lambda h: chunked_xent(h, w, labels, chunk=8)[0])(hidden)
    assert np.isfinite(np.asarray(g)).all()
