"""Sharding auditor: collective-schedule linting + sync-cost certs.

Four families:
  * positive — the registered sharded entries verify end-to-end under a
    virtual 8-device mesh: exactly the declared per-level reductions,
    zero GSPMD resharding, conformant layouts, priced certificates;
  * negative (synthetic HLO) — injected all-gather on a plane stack,
    float add all-reduce, resharded-K reduce-scatter, untagged
    collective: each fails `audit_partitioned_hlo` on its own;
  * negative (jaxpr) — float psum over a dequantized (plane-derived)
    value, jaxpr-level data movers, schedule-count mismatches: caught
    at trace time, before any compile;
  * PR 5 regression — the replicated-backbone decode trace with the
    interior sharding hints left ON reproduces the original GSPMD
    float-reassociation bug shape, and the auditor flags it; the same
    trace with hints off verifies clean.

Multi-device cases run in a subprocess with 8 virtual host-platform
devices (the flag must be set before jax initializes); everything else
runs in-process on whatever this host has (a 1x1 mesh traces fine).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.analysis.collective_cost import (CollectiveRecord,
                                            sync_cost_certificate)
from repro.analysis.registry import ExactEntry, iter_entries
from repro.analysis.sharding import (ReductionSpec, ShardingContract,
                                     audit_partitioned_hlo,
                                     audit_sharded_registry, audit_sharding)
from repro.launch.mesh import virtual_device_env

pytestmark = pytest.mark.analysis

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subproc(script: str, timeout: int = 900):
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=_REPO, env=virtual_device_env(8), timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    return out.stdout


# ------------------------------------------------- positive: the registry
@pytest.mark.sharded
def test_registered_consensus_entries_verify():
    """Both sharded consensus entries pass the full audit on a virtual
    8-device host: declared schedule exactly, zero all-gathers, priced
    sync-cost certificate with the sync-every-k table."""
    _run_subproc(textwrap.dedent("""
        from repro.analysis.registry import iter_entries
        from repro.analysis.sharding import audit_sharded_registry

        entries = [e for e in iter_entries(("head",))
                   if e.sharding is not None]
        assert sorted(e.name for e in entries) == [
            "head/sharded-consensus", "head/sharded-consensus-while"]
        rows = {r["entry"]: r for r in audit_sharded_registry(entries)}

        for name, census, n_coll in (
                ("head/sharded-consensus", {"all-reduce": 7}, 37),
                ("head/sharded-consensus-while", {"all-reduce": 8}, 44)):
            r = rows[name]
            assert r["status"] == "ok", r["violations"]
            # the partitioned module: reductions only, nothing moved
            assert r["collectives"]["census"] == census, r["collectives"]
            # the traced per-level schedule: the 4-pmax/1-pmin decision
            # triple (+ the consensus psum on the early-exit walk)
            prims = sorted(rec["prim"] for rec in r["schedule"]["per_level"])
            want = ["pmax"] * 4 + ["pmin"]
            if name.endswith("-while"):
                want = sorted(want + ["psum"])
            assert prims == sorted(want), prims
            assert all(rec["tag"].startswith("l2r_coll")
                       for rec in r["schedule"]["per_level"])
            # layout conformance rows all hold
            assert r["layout"] and all(row["ok"] for row in r["layout"])
            # the certificate prices the declared schedule
            cert = r["cost"]
            assert cert["collectives_per_walk"] == n_coll, cert
            assert cert["wire_bytes_per_walk"] > 0
            ks = cert["sync_every_k"]
            assert [e["k"] for e in ks] == [1, 2, 4, 8]
            assert ks[0]["savings_frac"] == 0.0
            savings = [e["savings_frac"] for e in ks]
            assert savings == sorted(savings) and savings[-1] > 0.5, savings
            assert 0.0 < cert["collective_share"] < 1.0, cert
        print("CONSENSUS-AUDIT-OK")
    """))


def test_every_sharded_entry_declares_a_contract():
    """Registry consistency: the `sharded` tag and a ShardingContract
    come together — a sharded entry with no contract is exactly the
    silent coverage gap the auditor exists to close."""
    entries = [e for e in iter_entries() if "sharded" in e.tags]
    assert entries, "registry lost its sharded entries"
    for e in entries:
        assert e.sharding is not None, e.name
        assert e.sharding.budget >= 0
        assert dict(e.sharding.mesh_axes).keys() == {"data", "model"}
        # sharding-only entries (contract=None) must still be swept by
        # SOME pass — the sharding one
        if e.contract is None:
            assert e.sharding is not None


# -------------------------------------------- negative: synthetic SPMD HLO
_REGIONS = textwrap.dedent("""\
    %region_add (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %r = f32[] add(f32[] %a, f32[] %b)
    }

    %region_max (c: f32[], d: f32[]) -> f32[] {
      %c = f32[] parameter(0)
      %d = f32[] parameter(1)
      ROOT %m = f32[] maximum(f32[] %c, f32[] %d)
    }

    %region_min (e: s32[], f: s32[]) -> s32[] {
      %e = s32[] parameter(0)
      %f = s32[] parameter(1)
      ROOT %n = s32[] minimum(s32[] %e, s32[] %f)
    }
""")


def _module(*body_lines: str) -> str:
    return ("HloModule jit_walk, num_partitions=8\n\n" + _REGIONS
            + "\nENTRY %main.42 (p0: f32[8,16]) -> f32[8,16] {\n"
            + "  %p0 = f32[8,16]{1,0} parameter(0)\n"
            + "".join(f"  {ln}\n" for ln in body_lines)
            + "}\n")


def _contract(**kw) -> ShardingContract:
    from repro.core.policy import COLL_TAG_MAX, COLL_TAG_MIN
    kw.setdefault("mesh_axes", (("data", 2), ("model", 4)))
    kw.setdefault("per_level", (ReductionSpec("pmax", 4, COLL_TAG_MAX),
                                ReductionSpec("pmin", 1, COLL_TAG_MIN)))
    return ShardingContract(**kw)


def test_hlo_injected_all_gather_fails():
    """An all-gather in the partitioned module means GSPMD moved a
    sharded operand — on a plane-stack input that is the K-never-sharded
    invariant breaking."""
    text = _module(
        'ROOT %all-gather.1 = s8[8,7,16,128]{3,2,1,0} all-gather('
        's8[8,7,16,16]{3,2,1,0} %p0), channel_id=1, '
        'replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={3}, '
        'metadata={op_name="jit(walk)/plane_stack_gather"}')
    violations, recs = audit_partitioned_hlo(text, _contract(), "neg")
    assert len(recs) == 1 and recs[0]["kind"] == "all-gather"
    assert any("K-never-sharded" in v.reason for v in violations)


def test_hlo_float_add_all_reduce_fails():
    """A float `add` all-reduce is the PR 5 reassociation class: a
    partitioned float contraction's partial sums joined across shards."""
    text = _module(
        'ROOT %all-reduce.9 = f32[8,16]{1,0} all-reduce('
        'f32[8,16]{1,0} %p0), channel_id=2, '
        'replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%region_add, '
        'metadata={op_name="jit(walk)/dot_general"}')
    violations, recs = audit_partitioned_hlo(text, _contract(), "neg")
    assert recs[0]["reduce_op"] == "add" and recs[0]["dtype"] == "f32"
    assert any("reassociated" in v.reason for v in violations)


def test_hlo_resharded_k_reduce_scatter_fails():
    """A reduce-scatter means the contraction axis was sharded and its
    partial results redistributed — forbidden outright."""
    text = _module(
        'ROOT %reduce-scatter.3 = f32[8,2]{1,0} reduce-scatter('
        'f32[8,16]{1,0} %p0), channel_id=3, '
        'replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={1}, '
        'to_apply=%region_add')
    violations, _ = audit_partitioned_hlo(text, _contract(), "neg")
    assert any(v.primitive == "reduce-scatter" for v in violations)


def test_hlo_untagged_all_reduce_fails():
    """An all-reduce whose op_name carries none of the declared
    l2r_coll tags was inserted by the partitioner, not the walk."""
    text = _module(
        'ROOT %all-reduce.4 = f32[8,16]{1,0} all-reduce('
        'f32[8,16]{1,0} %p0), replica_groups={{0,1,2,3},{4,5,6,7}}, '
        'to_apply=%region_max, metadata={op_name="jit(walk)/some_max"}')
    violations, _ = audit_partitioned_hlo(text, _contract(), "neg")
    assert any("never declared" in v.reason for v in violations)


def test_hlo_declared_tagged_schedule_passes():
    """The clean shape: tagged max/min all-reduces within budget."""
    text = _module(
        '%ar.1 = f32[8,16]{1,0} all-reduce(f32[8,16]{1,0} %p0), '
        'replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%region_max, '
        'metadata={op_name="jit(walk)/l2r_coll_max/pmax"}',
        'ROOT %all-reduce.2 = f32[8,16]{1,0} all-reduce('
        'f32[8,16]{1,0} %ar.1), replica_groups={{0,1,2,3},{4,5,6,7}}, '
        'to_apply=%region_min, '
        'metadata={op_name="jit(walk)/l2r_coll_min/pmin"}')
    violations, recs = audit_partitioned_hlo(text, _contract(), "pos")
    assert len(recs) == 2
    assert violations == [], [v.reason for v in violations]


def test_hlo_budget_overrun_fails():
    """More static collectives than the contract budget — even if each
    one individually looks legitimate — is a build failure."""
    line = ('%ar.@I@ = f32[8,16]{1,0} all-reduce(f32[8,16]{1,0} %p0), '
            'replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%region_max, '
            'metadata={op_name="jit(walk)/l2r_coll_max/pmax"}')
    contract = _contract(max_collectives=2)
    text = _module(*[line.replace("@I@", str(i)) for i in range(3)])
    violations, _ = audit_partitioned_hlo(text, contract, "neg")
    assert any("budget exceeded" in v.reason for v in violations)


# ------------------------------------------------- negative: jaxpr checks
def _mesh_1x1() -> Mesh:
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(devs, ("data", "model"))


def test_float_psum_on_dequantized_value_flagged():
    """The PR 5 class at trace time: an int8 contraction dequantized to
    f32 then summed across shards — the cross-shard add reassociates the
    float sum, so the `deq` provenance taint must flag the psum."""
    mesh = _mesh_1x1()

    def body(aq, bq):
        acc = jax.lax.dot_general(aq.astype(jnp.int32),
                                  bq.astype(jnp.int32),
                                  (((1,), (0,)), ((), ())))
        deq = acc.astype(jnp.float32) * np.float32(0.5)
        return jax.lax.psum(deq, "model")

    fn = shard_map(body, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                   check_rep=False)
    aq = np.ones((2, 4), np.int8)
    bq = np.ones((4, 3), np.int8)
    contract = ShardingContract(mesh_axes=(("data", 1), ("model", 1)))
    rep = audit_sharding(fn, (aq, bq), contract, entry="neg/float-psum",
                         with_cost=False)
    assert not rep.ok
    assert any("reassociates" in v.reason and v.primitive == "psum"
               for v in rep.violations), [v.reason for v in rep.violations]


def test_int_psum_on_quantized_value_passes_taint():
    """The allowed shape: the cross-shard sum happens on the int32
    accumulator (order-exact), dequantization only after."""
    mesh = _mesh_1x1()

    def body(aq, bq):
        acc = jax.lax.dot_general(aq.astype(jnp.int32),
                                  bq.astype(jnp.int32),
                                  (((1,), (0,)), ((), ())))
        return jax.lax.psum(acc, "model").astype(jnp.float32)

    fn = shard_map(body, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                   check_rep=False)
    contract = ShardingContract(
        mesh_axes=(("data", 1), ("model", 1)),
        per_walk=(ReductionSpec("psum", 1),))
    rep = audit_sharding(fn, (np.ones((2, 4), np.int8),
                              np.ones((4, 3), np.int8)),
                         contract, entry="pos/int-psum", with_cost=False)
    assert rep.ok, [v.reason for v in rep.violations]


def test_jaxpr_all_gather_is_forbidden():
    mesh = _mesh_1x1()

    def body(x):
        return jax.lax.all_gather(x, "model")

    fn = shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P("model"),
                   check_rep=False)
    contract = ShardingContract(mesh_axes=(("data", 1), ("model", 1)))
    rep = audit_sharding(fn, (np.ones((2, 4), np.int8),), contract,
                         entry="neg/all-gather", with_cost=False)
    assert any(v.primitive == "all_gather"
               and "reductions-only" in v.reason for v in rep.violations)


def test_schedule_count_mismatch_flagged():
    """Declaring 2 pmax but tracing 1 (or vice versa) is a mismatch —
    the contract pins the schedule exactly, both directions."""
    mesh = _mesh_1x1()

    def body(x):
        return jax.lax.pmax(x, "model")

    fn = shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                   check_rep=False)
    contract = ShardingContract(mesh_axes=(("data", 1), ("model", 1)),
                                per_walk=(ReductionSpec("pmax", 2),))
    rep = audit_sharding(fn, (np.ones((2,), np.float32),), contract,
                         entry="neg/mismatch", with_cost=False)
    assert any("schedule mismatch" in v.reason and "traced 1 x pmax" in
               v.reason for v in rep.violations), \
        [v.reason for v in rep.violations]


# -------------------------------------------------- PR 5 regression shape
@pytest.mark.slow
@pytest.mark.sharded
def test_pr5_hints_enabled_backbone_is_flagged():
    """The original bug, reproduced on purpose: interior sharding hints
    left ON over a replicated backbone make GSPMD repartition float
    contractions — partial sums joined by float add all-reduces, plus a
    storm of gathers.  The auditor must flag that trace; the fixed
    trace (backbone_hints=False, the registered entry) must verify."""
    _run_subproc(textwrap.dedent("""
        import dataclasses

        import jax
        import numpy as np

        from repro.analysis.registry import (_consensus_contract,
                                             _local_mesh, _mesh_shape)
        from repro.analysis.sharding import audit_sharding
        from repro.configs import get_smoke
        from repro.core.quant import QuantConfig
        from repro.models.common import materialize
        from repro.models.transformer import init_lm_state, lm_build
        from repro.serve.engine import make_decode_step, prepare_params
        from repro.sharding import ctx

        data, model = _mesh_shape()
        mesh = _local_mesh(data, model)
        cfg = dataclasses.replace(get_smoke("smollm-135m"),
                                  l2r=QuantConfig())
        params = prepare_params(cfg, materialize(lm_build(cfg),
                                                 jax.random.PRNGKey(0)))
        contract = dataclasses.replace(
            _consensus_contract(data, model, False), in_specs=())
        batch = data * 2
        state = init_lm_state(cfg, batch, 32)
        toks = np.zeros((batch, 1), np.int32)

        # the bug shape: hints ON, backbone replicated
        step = make_decode_step(cfg, progressive=True,
                                backbone_hints=True, mesh=mesh)
        ctx.set_mesh(mesh)
        try:
            rep = audit_sharding(step, (params, state, toks), contract,
                                 entry="pr5-regression", with_cost=False)
        finally:
            ctx.set_mesh(None)
        assert not rep.ok
        reasons = " | ".join(v.reason for v in rep.violations)
        assert "reassociated" in reasons, reasons
        assert any(v.primitive == "all-gather" for v in rep.violations), \\
            reasons
        assert "budget exceeded" in reasons, reasons

        # the fix: hints off — same trace, clean schedule
        step_ok = make_decode_step(cfg, progressive=True,
                                   backbone_hints=False, mesh=mesh)
        rep_ok = audit_sharding(step_ok, (params, state, toks), contract,
                                entry="pr5-fixed", with_cost=False)
        assert rep_ok.ok, [v.reason for v in rep_ok.violations]
        print("PR5-REGRESSION-OK")
    """))


# ------------------------------------------------ skips must fail loudly
def test_skipped_registry_entry_fails_loudly():
    """A registered sharded entry that cannot run is a VIOLATION row by
    default — `skipped` must never read as `passed` in CI; only an
    explicit allow_skips downgrades it."""
    fake = ExactEntry(
        name="fake/sharded", build=lambda: (None, ()),
        tags=("sharded",), skip="needs >= 2 devices (have 1)",
        sharding=ShardingContract(mesh_axes=(("data", 2), ("model", 4))))
    rows = audit_sharded_registry([fake])
    assert rows[0]["status"] == "violation"
    assert "SKIPPED" in rows[0]["violations"][0]["reason"]
    assert "xla_force_host_platform_device_count" in \
        rows[0]["violations"][0]["reason"]

    rows = audit_sharded_registry([fake], allow_skips=True)
    assert rows[0]["status"] == "skip"
    assert rows[0]["reason"] == "needs >= 2 devices (have 1)"


def test_lint_cli_sharding_flag(tmp_path):
    """CLI wiring: --sharding adds the sharding section to the JSON
    report; --allow-skips keeps small hosts green."""
    import importlib.util
    import json

    spec = importlib.util.spec_from_file_location(
        "l2r_lint", os.path.join(_REPO, "tools", "l2r_lint.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    out = tmp_path / "report.json"
    rc = mod.main(["--sharding", "--allow-skips", "--skip-compiled",
                   "--tags", "cache", "--json", str(out)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert "sharding" in report
    assert [r["entry"] for r in report["sharding"]] == \
        ["cache/sharded-weights"]
    assert report["sharding"][0]["status"] in ("ok", "skip")


# ------------------------------------------------------ sync-cost pricing
def test_sync_cost_certificate_pricing():
    """Hand-built schedule: counts, ring wire bytes and the
    sync-every-k table are exactly the closed-form values."""
    rec = lambda prim, in_loop, shape=(4,): CollectiveRecord(
        prim=prim, axes=("model",), dtype="float32", shape=shape,
        in_loop=in_loop, tag="l2r_coll_max")
    records = [rec("pmax", True), rec("pmax", True), rec("pmin", True),
               rec("pmax", False)]
    cert = sync_cost_certificate(records, (("data", 2), ("model", 4)),
                                 n_levels=7)
    assert cert["chips"] == 8 and cert["n_levels"] == 7
    assert cert["per_level"]["count"] == 3
    assert cert["per_walk"]["count"] == 1
    assert cert["collectives_per_walk"] == 7 * 3 + 1
    # ring all-reduce over the 4-wide model axis: 2*(4-1)/4 * 16 bytes
    per_red = 2 * 3 / 4 * 16
    assert cert["per_level"]["wire_bytes"] == pytest.approx(3 * per_red)
    assert cert["wire_bytes_per_walk"] == pytest.approx(7 * 3 * per_red
                                                        + per_red)
    ks = {e["k"]: e for e in cert["sync_every_k"]}
    assert ks[1]["sync_levels"] == 7 and ks[1]["savings_frac"] == 0.0
    assert ks[2]["sync_levels"] == 4   # ceil(7/2)
    assert ks[4]["sync_levels"] == 2
    assert ks[8]["sync_levels"] == 1
    assert ks[8]["collectives"] == 3 + 1
    savings = [e["savings_frac"] for e in cert["sync_every_k"]]
    assert savings == sorted(savings)


def test_sync_cost_certificate_axis_of_one_is_free():
    """A reduction over a 1-wide axis moves nothing — the certificate
    prices it at zero wire bytes (matters for 2-device data=1 meshes)."""
    records = [CollectiveRecord(prim="psum", axes=("data",),
                                dtype="int32", shape=(), in_loop=True)]
    cert = sync_cost_certificate(records, (("data", 1), ("model", 2)),
                                 n_levels=3)
    assert cert["collectives_per_walk"] == 3
    assert cert["wire_bytes_per_walk"] == 0.0
    assert cert["collective_s"] == 0.0
    assert all(e["savings_frac"] == 0.0 for e in cert["sync_every_k"])
