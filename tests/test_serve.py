"""Serving engine: prefill+decode consistency, greedy generation,
progressive-precision serving."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.quant import QuantConfig
from repro.models.common import materialize
from repro.models.transformer import init_lm_state, lm_build, lm_forward
from repro.serve.engine import greedy_generate, make_decode_step, make_prefill_step


@pytest.mark.parametrize("arch", ["smollm-135m", "gemma3-27b", "mamba2-130m",
                                  "recurrentgemma-2b"])
def test_prefill_decode_matches_train_forward(arch):
    cfg = get_smoke(arch)
    params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    h, _, _ = lm_forward(cfg, params, tokens=toks, mode="train")
    st = init_lm_state(cfg, 2, max_len=16, dtype=jnp.float32)
    _, st, _ = lm_forward(cfg, params, tokens=toks[:, :11], mode="prefill", state=st)
    h_dec, _, _ = lm_forward(cfg, params, tokens=toks[:, 11:12], mode="decode", state=st)
    np.testing.assert_allclose(np.asarray(h[:, 11:12], np.float32),
                               np.asarray(h_dec, np.float32), atol=5e-2)


def test_greedy_generate_deterministic():
    cfg = get_smoke("smollm-135m")
    params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    out1 = greedy_generate(cfg, params, prompt, steps=5)
    out2 = greedy_generate(cfg, params, prompt, steps=5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 5)


def test_decode_step_factory_argmax_consistency():
    cfg = get_smoke("smollm-135m")
    params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    prefill = make_prefill_step(cfg, max_len=16, cache_dtype=jnp.float32)
    decode = make_decode_step(cfg)
    state, logits = prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    state, tok2, logits2 = decode(params, state, tok)
    assert tok2.shape == (2, 1)
    np.testing.assert_array_equal(
        np.asarray(tok2), np.asarray(jnp.argmax(logits2, -1)))


def test_progressive_precision_serving_is_exact_at_full_levels():
    """The paper's L2R mode with all MSDF levels == plain int8 serving."""
    cfg = get_smoke("smollm-135m")
    cfg_l2r = dataclasses.replace(cfg, l2r=QuantConfig(), l2r_levels=None)
    params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    h_f, _, _ = lm_forward(cfg, params, tokens=toks, mode="train")
    h_q, _, _ = lm_forward(cfg_l2r, params, tokens=toks, mode="train")
    # quantized path close to float path (int8 noise through 6 layers)
    rel = (np.abs(np.asarray(h_f, np.float32) - np.asarray(h_q, np.float32)).max()
           / (np.abs(np.asarray(h_f, np.float32)).max() + 1e-9))
    assert rel < 0.35, rel
    # truncated MSDF stream degrades gracefully (still finite)
    cfg_l3 = dataclasses.replace(cfg, l2r=QuantConfig(), l2r_levels=4)
    h_p, _, _ = lm_forward(cfg_l3, params, tokens=toks, mode="train")
    assert np.isfinite(np.asarray(h_p, np.float32)).all()
