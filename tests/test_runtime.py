"""Fault tolerance: crash/restore replay, straggler skip, elastic replan."""

import numpy as np
import pytest

from repro.data.pipeline import DataConfig, ShardedPipeline
from repro.runtime.fault import (FaultTolerantLoop, StragglerPolicy,
                                 elastic_replan)


def make_loop(fault_source, ckpt_every=5, data=None):
    saved = {}
    state0 = {"sum": 0.0, "step": 0}

    def step_fn(state, batch):
        s = dict(state)
        s["sum"] += float(batch["tokens"].mean())
        s["step"] += 1
        return s, {"v": s["sum"]}

    def save_fn(step, state):
        saved["ckpt"] = (step, dict(state))

    def restore_fn():
        if "ckpt" in saved:
            return saved["ckpt"][0], dict(saved["ckpt"][1])
        return None, None

    data = data or ShardedPipeline(DataConfig(vocab=64, seq_len=8, global_batch=4))
    loop = FaultTolerantLoop(step_fn, save_fn, restore_fn, data,
                             ckpt_every=ckpt_every, fault_source=fault_source)
    return loop, state0


def test_run_without_faults():
    loop, s0 = make_loop(lambda s: None)
    state, hist = loop.run(s0, 10)
    assert state["step"] == 10
    assert len(hist) == 10


def test_crash_restores_from_checkpoint():
    crashed = []

    def fault(step):
        if step == 7 and not crashed:
            crashed.append(step)
            return "crash"
        return None

    loop, s0 = make_loop(fault, ckpt_every=5)
    state, hist = loop.run(s0, 10)
    assert ("restored" in [e for _, e in loop.events]
            or (5, "restored") in loop.events)
    assert state["step"] == 10  # completed despite the crash
    assert (7, "crash") in loop.events


def test_crash_exhausts_retries():
    loop, s0 = make_loop(lambda s: "crash" if s == 3 else None)
    with pytest.raises(RuntimeError):
        loop.run(s0, 10)


def test_straggler_skip_event():
    # deadline needs min_samples observations; then one slow step skips
    loop, s0 = make_loop(lambda s: "slow" if s == 8 else None)
    loop.straggler = StragglerPolicy(factor=3.0, min_samples=3)
    state, _ = loop.run(s0, 12)
    assert (8, "straggler-skip") in loop.events
    assert state["step"] == 12


def test_elastic_replan_divisibility():
    p = elastic_replan(global_batch=256, healthy_hosts=15, host_id=3)
    assert p.n_shards == 8  # largest divisor of 256 <= 15... 8? 256%8==0
    assert 256 % p.n_shards == 0
    p2 = elastic_replan(global_batch=256, healthy_hosts=16, host_id=3)
    assert p2.n_shards == 16


def test_elastic_resize_event():
    resizes = []
    loop, s0 = make_loop(lambda s: "resize:4" if s == 6 else None)
    loop.on_resize = lambda n: resizes.append(n)
    loop.run(s0, 10)
    assert resizes == [4]


def test_data_replay_after_restore_is_exact():
    """Counter-based pipeline replays identical batches after restart."""
    dcfg = DataConfig(vocab=64, seq_len=8, global_batch=4)
    p1 = ShardedPipeline(dcfg)
    batches = [next(p1) for _ in range(6)]
    p2 = ShardedPipeline(dcfg)
    p2.load_state_dict({"step": 3, "shard": 0, "n_shards": 1})
    replay = next(p2)
    np.testing.assert_array_equal(batches[3]["tokens"], replay["tokens"])
