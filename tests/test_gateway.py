"""Serving gateway: bucketed packed prefill, AOT warmup, donated decode,
async emit — all bit-identical to the plain continuous batcher."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.quant import QuantConfig
from repro.models.common import materialize
from repro.models.transformer import lm_build
from repro.serve import (ContinuousBatcher, Request, ServingGateway,
                         bucket_for, greedy_generate, prefill_buckets,
                         supports_bucketed_prefill)
from repro.serve.engine import prepare_params


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke("smollm-135m")
    params = materialize(lm_build(cfg), jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def prog_model():
    cfg = dataclasses.replace(get_smoke("smollm-135m"), l2r=QuantConfig())
    params = prepare_params(cfg, materialize(lm_build(cfg),
                                             jax.random.PRNGKey(0)))
    return cfg, params


def _mixed_requests(cfg, lengths, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, (L,)).astype(np.int32),
                    max_new_tokens=max_new)
            for i, L in enumerate(lengths)]


# ------------------------------------------------------------- buckets
def test_prefill_buckets_shape():
    assert prefill_buckets(128) == (8, 16, 32, 64, 128)
    assert prefill_buckets(100) == (8, 16, 32, 64, 100)
    assert prefill_buckets(8) == (8,)
    assert prefill_buckets(5) == (5,)
    bk = prefill_buckets(64)
    assert bucket_for(1, bk) == 8 and bucket_for(8, bk) == 8
    assert bucket_for(9, bk) == 16 and bucket_for(64, bk) == 64
    with pytest.raises(ValueError):
        bucket_for(65, bk)


def test_supports_bucketed_prefill_gates_recurrent():
    cfg = get_smoke("smollm-135m")
    assert supports_bucketed_prefill(cfg)
    # a recurrent mixer would carry pad contamination in its state
    for arch in ("mamba2-2.7b", "rwkv7-3b"):
        try:
            rec = get_smoke(arch)
        except (KeyError, ValueError, AssertionError):
            continue
        assert not supports_bucketed_prefill(rec)


# --------------------------------------------------- gateway bit-parity
def test_gateway_matches_plain_batcher_mixed_buckets(model):
    """Mixed prompt lengths spanning every bucket, served through the
    gateway (packed prefill + AOT + donation + async emit), produce
    exactly the plain batcher's token streams."""
    cfg, params = model
    lengths = (3, 8, 5, 11, 17, 23, 9, 31)  # buckets 8, 16, 32
    ref = _mixed_requests(cfg, lengths)
    eng = ContinuousBatcher(cfg, params, n_slots=3, max_len=32)
    for r in ref:
        eng.submit(r)
    eng.run(max_steps=1000)

    served = _mixed_requests(cfg, lengths)
    gw = ServingGateway(cfg, params, n_slots=4, max_len=32,
                        prefill_group=3)
    gw.run(served)
    gw.close()
    for a, b in zip(ref, served):
        assert b.done
        assert a.output == b.output, (a.uid, a.output, b.output)


def test_gateway_matches_straightline_greedy(model):
    """Each gateway stream equals an isolated greedy decode — batching
    composition (packed prefill rows, slot neighbors) moves no token."""
    cfg, params = model
    reqs = _mixed_requests(cfg, (8, 5, 11), max_new=6)
    refs = [np.asarray(greedy_generate(cfg, params,
                                       jnp.asarray(r.prompt[None]),
                                       steps=6, max_len=32))[0].tolist()
            for r in reqs]
    gw = ServingGateway(cfg, params, n_slots=2, max_len=32,
                        prefill_group=2)
    gw.run(reqs)
    gw.close()
    for r, ref in zip(reqs, refs):
        assert r.done and r.output[:6] == ref, (r.uid, r.output, ref)


def test_gateway_progressive_exit_level_parity(prog_model):
    """Progressive early-exit mode: tokens AND per-token MSDF exit
    levels match the plain batcher exactly (exit decisions ride the
    same streamed head regardless of batch composition)."""
    cfg, params = prog_model
    lengths = (4, 9, 6, 13)
    ref = _mixed_requests(cfg, lengths)
    eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32,
                            progressive=True, early_exit=True)
    for r in ref:
        eng.submit(r)
    eng.run(max_steps=1000)

    served = _mixed_requests(cfg, lengths)
    gw = ServingGateway(cfg, params, n_slots=3, max_len=32,
                        prefill_group=2, progressive=True, early_exit=True)
    gw.run(served)
    gw.close()
    for a, b in zip(ref, served):
        assert a.output == b.output, (a.uid, a.output, b.output)
        assert a.exit_levels == b.exit_levels
        assert a.prefill_exit_level == b.prefill_exit_level
    st = gw.stats()
    assert st["tokens"] == sum(len(r.output) for r in served)
    assert sum(st["exit_level_hist"]) == sum(
        len(r.exit_levels) for r in served)


# ------------------------------------------------------------ slot churn
def test_gateway_slot_churn_under_full_queue(model):
    """Many more requests than slots: every admission wave reuses freed
    slots (generation counters guard the EOS signals) and every request
    completes with its full budget."""
    cfg, params = model
    reqs = _mixed_requests(cfg, (6, 4, 7, 5, 9, 3, 8, 6, 5, 4, 7, 6),
                           max_new=4, seed=1)
    gw = ServingGateway(cfg, params, n_slots=2, max_len=32,
                        prefill_group=2)
    gw.run(reqs)
    gw.close()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 4 for r in reqs)
    st = gw.stats()
    assert st["completed"] == len(reqs)
    assert st["tokens"] == 4 * len(reqs)


def test_gateway_eos_retires_early(model):
    """EOS detection happens on the emit thread and frees the slot via
    the (slot, generation) signal: the stream stops AT the EOS token,
    exactly like the plain batcher, and lagged decodes are dropped."""
    cfg, params = model
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    ref = np.asarray(greedy_generate(cfg, params, jnp.asarray(prompt[None]),
                                     steps=3, max_len=32))[0]
    req = Request(uid=0, prompt=prompt, max_new_tokens=10,
                  eos_id=int(ref[1]))
    filler = _mixed_requests(cfg, (5, 6, 7), max_new=8, seed=3)
    gw = ServingGateway(cfg, params, n_slots=2, max_len=32,
                        prefill_group=2)
    gw.run([req] + filler)
    gw.close()
    assert req.done
    assert len(req.output) == 2 and req.output[-1] == int(ref[1])
    assert all(r.done and len(r.output) == 8 for r in filler)


# ------------------------------------------------------------ async emit
def test_gateway_async_emit_ordering_matches_sync(model):
    """The async emit thread appends tokens in sequence order per
    request: token streams equal the synchronous-emit gateway's (same
    machinery, inline) token for token."""
    cfg, params = model
    lengths = (5, 9, 4, 12, 7)
    sync = _mixed_requests(cfg, lengths)
    gw_s = ServingGateway(cfg, params, n_slots=2, max_len=32,
                          prefill_group=2, async_emit=False)
    gw_s.run(sync)
    gw_s.close()

    async_ = _mixed_requests(cfg, lengths)
    gw_a = ServingGateway(cfg, params, n_slots=2, max_len=32,
                          prefill_group=2, async_emit=True,
                          emit_queue_depth=2)
    gw_a.run(async_)
    gw_a.close()
    for a, b in zip(sync, async_):
        assert a.output == b.output, (a.uid, a.output, b.output)
        assert b.t_arrival is not None and b.t_first_token is not None
        assert b.t_complete is not None
        assert b.t_arrival <= b.t_first_token <= b.t_complete


def test_gateway_emit_thread_error_propagates(model):
    """A failure on the emit thread surfaces on the caller at flush
    time, not silently."""
    cfg, params = model
    gw = ServingGateway(cfg, params, n_slots=2, max_len=32,
                        prefill_group=2)
    gw._emit.put(("bogus-kind-causes-unpack-error",))
    with pytest.raises(BaseException):
        gw._emit.flush()
    gw.close()


# -------------------------------------------------------- AOT executables
def test_gateway_aot_warmup_covers_every_bucket(model):
    """Warmup compiles one executable per bucket plus the decode step;
    serving mixed lengths afterwards never touches the jit fallback."""
    cfg, params = model
    gw = ServingGateway(cfg, params, n_slots=2, max_len=32,
                        prefill_group=2, aot_warmup=True)
    assert set(gw._prefill_exe) == set(gw.buckets) == {8, 16, 32}
    assert gw._decode_exe is not None
    reqs = _mixed_requests(cfg, (3, 9, 20), max_new=3)
    gw.run(reqs)
    gw.close()
    assert all(r.done for r in reqs)
    # the fallback jit entry points were never traced
    assert gw._prefill_jit._cache_size() == 0
    assert gw._decode_jit._cache_size() == 0


def test_gateway_realtime_honors_arrival_stamps(model):
    """realtime=True delays admission to each request's t_arrival; the
    tokens still match the offline drain."""
    import time

    cfg, params = model
    lengths = (5, 7, 4)
    offline = _mixed_requests(cfg, lengths, max_new=3)
    gw1 = ServingGateway(cfg, params, n_slots=2, max_len=32,
                         prefill_group=2)
    gw1.run(offline)
    gw1.close()

    online = _mixed_requests(cfg, lengths, max_new=3)
    gw2 = ServingGateway(cfg, params, n_slots=2, max_len=32,
                         prefill_group=2)
    t0 = time.perf_counter()
    for i, r in enumerate(online):
        r.t_arrival = t0 + 0.02 * i
        gw2.submit(r)
    gw2.run(realtime=True)
    gw2.close()
    for a, b in zip(offline, online):
        assert a.output == b.output
        assert b.t_first_token >= b.t_arrival
