"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle.

The kernel is int32-exact, so assertions are bit-equality (the strongest
possible allclose).  interpret=True executes the kernel body on CPU.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.l2r_gemm import (int_gemm_ref, l2r_gemm, l2r_gemm_ref,
                                    l2r_matmul_f)

SHAPES = [
    (128, 256, 128),   # exactly one block
    (256, 512, 256),   # multi-block every axis
    (64, 64, 64),      # smaller than a block (padding path)
    (130, 300, 77),    # ragged
    (1, 256, 128),     # single row
    (128, 32, 512),    # shallow K
]


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_kernel_exact_int8(m, k, n):
    rng = np.random.default_rng(m * 1000 + k + n)
    a = rng.integers(-128, 128, size=(m, k), dtype=np.int8)
    b = rng.integers(-128, 128, size=(k, n), dtype=np.int8)
    out = l2r_gemm(jnp.asarray(a), jnp.asarray(b))
    ref = int_gemm_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("log2_radix", [1, 2, 4])
def test_kernel_radix_sweep(log2_radix):
    rng = np.random.default_rng(42)
    a = rng.integers(-128, 128, size=(128, 256), dtype=np.int8)
    b = rng.integers(-128, 128, size=(256, 128), dtype=np.int8)
    out = l2r_gemm(jnp.asarray(a), jnp.asarray(b), log2_radix=log2_radix)
    ref = int_gemm_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("levels", list(range(1, 8)))
def test_kernel_progressive_levels_match_oracle(levels):
    rng = np.random.default_rng(levels)
    a = rng.integers(-128, 128, size=(128, 256), dtype=np.int8)
    b = rng.integers(-128, 128, size=(256, 128), dtype=np.int8)
    out = l2r_gemm(jnp.asarray(a), jnp.asarray(b), levels=levels)
    ref = l2r_gemm_ref(jnp.asarray(a), jnp.asarray(b), levels=levels)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_kernel_progressive_error_decreases():
    rng = np.random.default_rng(5)
    a = rng.integers(-128, 128, size=(128, 256), dtype=np.int8)
    b = rng.integers(-128, 128, size=(256, 128), dtype=np.int8)
    exact = np.asarray(int_gemm_ref(jnp.asarray(a), jnp.asarray(b)), np.int64)
    errs = []
    for lv in range(1, 8):
        out = np.asarray(l2r_gemm(jnp.asarray(a), jnp.asarray(b), levels=lv), np.int64)
        errs.append(np.abs(out - exact).max())
    assert errs[-1] == 0
    assert all(e1 >= e2 for e1, e2 in zip(errs, errs[1:]))


@pytest.mark.parametrize("n_bits,dtype", [(8, np.int8), (6, np.int8), (4, np.int8)])
def test_kernel_bitwidth_sweep(n_bits, dtype):
    rng = np.random.default_rng(n_bits)
    lo, hi = -(1 << (n_bits - 1)), 1 << (n_bits - 1)
    a = rng.integers(lo, hi, size=(128, 256), dtype=dtype)
    b = rng.integers(lo, hi, size=(256, 128), dtype=dtype)
    out = l2r_gemm(jnp.asarray(a), jnp.asarray(b), n_bits=n_bits, log2_radix=2)
    ref = int_gemm_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_float_wrapper_close_to_matmul():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((64, 256)).astype(np.float32)
    w = rng.standard_normal((256, 96)).astype(np.float32)
    out = np.asarray(l2r_matmul_f(jnp.asarray(x), jnp.asarray(w)))
    ref = x @ w
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert rel < 0.02, rel  # int8 W8A8 quantization error
